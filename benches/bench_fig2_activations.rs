//! Figure 2 / Figures 8-9 — activation distributions at the k_proj
//! input site: FP16 vs BiLLM vs ARB-LLM vs BTC (with its learnable
//! transformation). The paper's point: BTC's transform collapses the
//! dynamic range (max-abs 8 -> 0.4 on LLaMA-2-7B).

use btc_llm::benchsuite::{load_workload, quick_mode};
use btc_llm::data::ByteTokenizer;
use btc_llm::eval::error_stats::activation_stats;
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::benchkit::{benchline, Table};

fn main() -> anyhow::Result<()> {
    let model = if quick_mode() { "tinylm_s" } else { "tinylm_m" };
    let w = load_workload(model)?;
    let tok = ByteTokenizer::default();
    let text = String::from_utf8_lossy(&w.corpus).into_owned();
    let tokens: Vec<u16> = tok.encode(&text)[..512.min(w.eval_tokens.len())].to_vec();

    let lanes = [
        ("FP16", QuantConfig::fp16()),
        ("BiLLM", QuantConfig::billm()),
        ("ARB-LLM", QuantConfig::arb_llm()),
        ("BTC-LLM", QuantConfig::btc(0.8)),
    ];
    let mut t = Table::new(&["Method", "site", "max|x| raw", "max|x| seen by GEMM", "p99", "kurtosis"]);
    for (label, cfg) in lanes {
        let qm = quantize_model(&w.raw, &w.corpus, &cfg)?;
        let stats = activation_stats(&qm.model, &tokens, 256);
        // k_proj input of the *middle* layer (the paper's example site).
        let mid = qm.model.cfg.n_layer / 2;
        let s = stats.iter().find(|s| s.layer == mid && s.site.starts_with("ln1")).unwrap();
        let seen = s.transformed.as_ref().unwrap_or(&s.raw);
        t.row(&[
            label.to_string(),
            format!("l{}.k_proj", mid),
            format!("{:.3}", s.raw.max_abs),
            format!("{:.3}", seen.max_abs),
            format!("{:.3}", seen.p99),
            format!("{:.2}", seen.kurtosis),
        ]);
        benchline("fig2", &[("method", label.to_string()),
                            ("maxabs", format!("{:.4}", seen.max_abs)),
                            ("kurtosis", format!("{:.3}", seen.kurtosis))]);
    }
    println!("\nFigure 2 (activation distribution at k_proj input)");
    t.print();
    println!("\nExpected shape: BTC's transformed activations have the smallest max-abs;");
    println!("BiLLM/ARB leave the raw outliers untouched.");
    Ok(())
}
