//! Table 4 — binary codebook on a natively-binary model (FBI-LLM
//! analog: QAT-lite TinyLM whose linear weights are already ±alpha).
//! The codebook squeezes the remaining redundancy below 1 bit.

use btc_llm::benchsuite::{eval_lane, fmt_ppl, load_workload, quick_mode};
use btc_llm::quant::pipeline::QuantConfig;
use btc_llm::util::benchkit::{benchline, Table};

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let w = load_workload("fbi_s")?;
    let eval_tokens = if quick { 1200 } else { 3000 };
    let zs = if quick { None } else { Some(48) };

    let mut t = Table::new(&["Method", "Bits", "payload", "PPL", "acc"]);
    // Original: the QAT model served at its native 1-bit precision
    // (binarization of ±alpha weights is exact — naive lane).
    {
        let mut cfg = QuantConfig::naive();
        cfg.target_bits = 1.0;
        let r = eval_lane(&w, &cfg, eval_tokens, zs)?;
        t.row(&[
            "Original (1-bit QAT)".into(),
            "1.00".into(),
            format!("{:.2}", r.payload_bits),
            fmt_ppl(r.ppl),
            r.mean_acc.map(|a| format!("{a:.1}")).unwrap_or("-".into()),
        ]);
        benchline("table4", &[("bits", "1.0".into()), ("ppl", format!("{:.4}", r.ppl))]);
    }
    for bits in [0.8, 0.7, 0.5] {
        // FBI_BC: codebook on the binary weights, no transform (the
        // model is already binary; transform would break exactness).
        let mut cfg = QuantConfig::btc(bits);
        cfg.transform_p = false;
        cfg.transform_sigma = false;
        cfg.n_splits = 0;
        let r = eval_lane(&w, &cfg, eval_tokens, zs)?;
        t.row(&[
            format!("FBI-LLM_BC@{bits}"),
            format!("{bits:.2}"),
            format!("{:.2}", r.payload_bits),
            fmt_ppl(r.ppl),
            r.mean_acc.map(|a| format!("{a:.1}")).unwrap_or("-".into()),
        ]);
        benchline("table4", &[("bits", bits.to_string()), ("ppl", format!("{:.4}", r.ppl))]);
    }
    println!("\nTable 4 (codebook on natively-binary FBI analog): graceful PPL increase down to 0.5b");
    t.print();
    Ok(())
}
