//! Microbench autotuner harness: sweep the kernel tuning knobs
//! (LUT-GEMM gather tile, spawn-amortization floor, prefill chunk) on
//! this machine and persist the winners as a TOML consumed at serve
//! startup (`[serve] tuning_file` / `--tuning-file`).
//!
//! ```text
//! cargo bench --bench bench_autotune -- [--quick] [--out tuning.toml]
//! ```
//!
//! With `BENCH_JSON=1` the per-candidate sweep points are also written
//! to `BENCH_autotune.json` (artifact-only — the perf_compare gate
//! does not consume it, since tuned winners are machine-dependent).

use btc_llm::benchsuite::quick_mode;
use btc_llm::util::autotune;
use btc_llm::util::benchkit::{benchline, JsonReport, Table};
use btc_llm::util::parallel;
use btc_llm::util::simd;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let out_path = {
        let args: Vec<String> = std::env::args().collect();
        args.iter()
            .position(|a| a == "--out")
            .and_then(|i| args.get(i + 1).cloned())
            .unwrap_or_else(|| "tuning.toml".to_string())
    };
    println!(
        "autotune sweep: simd={} threads={} ({} mode)",
        simd::active().name(),
        parallel::threads(),
        if quick { "quick" } else { "full" }
    );

    let rep = autotune::run(quick);

    let mut t = Table::new(&["knob", "candidate", "mean"]);
    let mut json = JsonReport::new("autotune");
    for p in &rep.points {
        let chosen = match p.knob {
            "gather_tile" => p.value == rep.tuning.gather_tile,
            "par_min_work" => p.value == rep.tuning.par_min_work,
            "prefill_chunk" => p.value == rep.tuning.prefill_chunk,
            _ => false,
        };
        let mark = if chosen { " *" } else { "" };
        t.row(&[
            p.knob.to_string(),
            format!("{}{mark}", p.value),
            format!("{:.1}us", p.mean_ns / 1e3),
        ]);
        let kv = [
            ("knob", p.knob.to_string()),
            ("value", p.value.to_string()),
            ("mean_ns", format!("{:.1}", p.mean_ns)),
            ("chosen", chosen.to_string()),
        ];
        benchline("autotune", &kv);
        json.row(&kv);
    }
    t.print();
    println!("\nwinners: {}", rep.tuning.summary());

    let toml = rep.tuning.to_toml();
    std::fs::write(&out_path, &toml)?;
    println!("wrote {out_path}");
    // Round-trip through the serve-startup loader as a self-check.
    let back = autotune::Tuning::from_file(&out_path)
        .map_err(|e| anyhow::anyhow!("round-trip failed: {e}"))?;
    anyhow::ensure!(back == rep.tuning, "tuning TOML round-trip mismatch");
    let _ = json.write_if_enabled();
    Ok(())
}
