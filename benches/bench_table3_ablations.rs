//! Table 3 — the five ablations on TinyLM-M (paper: LLaMA-2-7B):
//!   (a) codebook vector length sweep at 0.8 bits
//!   (b) learned-transform components (none / P / P + D±)
//!   (c) memory + codebook overhead vs bits
//!   (d) activation quantization W0.8A{16,8,4}
//!   (e) number of split points 1/2/3
//! Run one with `--only 3a` … `--only 3e` (default: all).

use btc_llm::benchsuite::{eval_lane, fmt_ppl, load_workload, quick_mode};
use btc_llm::eval::memory;
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::argparse::Args;
use btc_llm::util::benchkit::{benchline, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));
    let quick = quick_mode();
    let only = args.get("only").map(|s| s.to_string());
    let run = |tag: &str| only.as_deref().map(|o| o == tag).unwrap_or(true);
    let model = if quick { "tinylm_s" } else { "tinylm_m" };
    let w = load_workload(model)?;
    let eval_tokens = if quick { 1200 } else { 3000 };
    let zs = if quick { None } else { Some(48) };

    // ---- 3a: vector length sweep -------------------------------------
    if run("3a") {
        let mut t = Table::new(&["v", "c", "payload", "PPL", "acc", "quant(s)"]);
        let vs: &[usize] = if quick { &[8, 16] } else { &[4, 8, 10, 12, 16, 20] };
        for &v in vs {
            let mut cfg = QuantConfig::btc(0.8);
            cfg.v = v;
            let r = eval_lane(&w, &cfg, eval_tokens, zs)?;
            t.row(&[
                v.to_string(),
                cfg.derived_c().to_string(),
                format!("{:.2}", r.payload_bits),
                fmt_ppl(r.ppl),
                r.mean_acc.map(|a| format!("{a:.1}")).unwrap_or("-".into()),
                format!("{:.1}", r.quant_secs),
            ]);
            benchline("table3a", &[("v", v.to_string()), ("ppl", format!("{:.4}", r.ppl)),
                                   ("quant_s", format!("{:.2}", r.quant_secs))]);
        }
        println!("\nTable 3a (codebook vector length @0.8b): longer v -> better PPL, more quant time");
        t.print();
    }

    // ---- 3b: transform components ------------------------------------
    if run("3b") {
        let mut t = Table::new(&["Transform", "PPL", "acc"]);
        for (label, p, s) in [("none", false, false), ("P", true, false), ("P + D±", true, true)] {
            let mut cfg = QuantConfig::btc(0.8);
            cfg.transform_p = p;
            cfg.transform_sigma = s;
            let r = eval_lane(&w, &cfg, eval_tokens, zs)?;
            t.row(&[
                label.to_string(),
                fmt_ppl(r.ppl),
                r.mean_acc.map(|a| format!("{a:.1}")).unwrap_or("-".into()),
            ]);
            benchline("table3b", &[("transform", label.to_string()), ("ppl", format!("{:.4}", r.ppl))]);
        }
        println!("\nTable 3b (learned transform @0.8b): none > P > P+D± in PPL");
        t.print();
    }

    // ---- 3c: memory + codebook overhead -------------------------------
    if run("3c") {
        let mut t = Table::new(&["Config", "Model Mem", "Codebook Mem", "overhead", "compression"]);
        {
            let fp = quantize_model(&w.raw, &w.corpus, &QuantConfig::fp16())?;
            let r = memory::report(&fp.model);
            t.row(&["FP16".into(), memory::human_bytes(r.fp16_total_bytes), "-".into(), "-".into(), "1.0x".into()]);
        }
        for bits in [0.9, 0.8, 0.7] {
            let qm = quantize_model(&w.raw, &w.corpus, &QuantConfig::btc(bits))?;
            let r = memory::report(&qm.model);
            t.row(&[
                format!("{bits}bit"),
                memory::human_bytes(r.total_bytes),
                memory::human_bytes(r.codebook_bytes),
                format!("{:.1}%", 100.0 * r.codebook_overhead),
                format!("{:.1}x", r.compression),
            ]);
            benchline("table3c", &[("bits", bits.to_string()),
                                   ("total_bytes", r.total_bytes.to_string()),
                                   ("codebook_bytes", r.codebook_bytes.to_string()),
                                   ("compression", format!("{:.2}", r.compression))]);
        }
        println!("\nTable 3c (memory): codebook overhead shrinks with bits (c shrinks)");
        t.print();
        println!("note: overhead % is larger than the paper's 1-9% because TinyLM is ~1000x");
        println!("smaller than LLaMA-2-7B while the codebook is shared-size — amortization");
        println!("improves with model scale exactly as §4.3 argues (compare tinylm_s vs _l).");
    }

    // ---- 3d: activation quantization ----------------------------------
    if run("3d") {
        let mut t = Table::new(&["Config", "PPL", "acc"]);
        for act_bits in [16u32, 8, 4] {
            let mut cfg = QuantConfig::btc(0.8);
            cfg.act_bits = act_bits;
            let r = eval_lane(&w, &cfg, eval_tokens, zs)?;
            t.row(&[
                format!("W0.8A{act_bits}"),
                fmt_ppl(r.ppl),
                r.mean_acc.map(|a| format!("{a:.1}")).unwrap_or("-".into()),
            ]);
            benchline("table3d", &[("act_bits", act_bits.to_string()), ("ppl", format!("{:.4}", r.ppl))]);
        }
        println!("\nTable 3d (activation quantization): A8 ~ A16 >> A4");
        t.print();
    }

    // ---- 3e: split points ---------------------------------------------
    if run("3e") {
        let mut t = Table::new(&["Split points", "PPL", "acc"]);
        for splits in [1usize, 2, 3] {
            let mut cfg = QuantConfig::btc(0.8);
            cfg.n_splits = splits;
            let r = eval_lane(&w, &cfg, eval_tokens, zs)?;
            t.row(&[
                splits.to_string(),
                fmt_ppl(r.ppl),
                r.mean_acc.map(|a| format!("{a:.1}")).unwrap_or("-".into()),
            ]);
            benchline("table3e", &[("splits", splits.to_string()), ("ppl", format!("{:.4}", r.ppl))]);
        }
        println!("\nTable 3e (split points): more splits -> better PPL");
        t.print();
    }
    Ok(())
}
