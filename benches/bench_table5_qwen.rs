//! Table 5 / Table 7 — second model family (TinyQwen: GQA attention,
//! different widths — the Qwen2.5/Qwen3 analog) across bit-widths,
//! demonstrating the method generalizes beyond the primary family.

use btc_llm::benchsuite::{eval_lane, fmt_ppl, load_workload, quick_mode};
use btc_llm::quant::pipeline::QuantConfig;
use btc_llm::util::benchkit::{benchline, Table};

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let models: &[&str] = if quick { &["tinyqwen_s"] } else { &["tinyqwen_s", "tinyqwen_m"] };
    let eval_tokens = if quick { 1200 } else { 3000 };
    let zs = if quick { None } else { Some(40) };

    let mut t = Table::new(&["Model", "Config", "PPL", "acc"]);
    for model in models {
        let w = load_workload(model)?;
        let fp = eval_lane(&w, &QuantConfig::fp16(), eval_tokens, zs)?;
        t.row(&[
            w.name.clone(),
            "FP16".into(),
            fmt_ppl(fp.ppl),
            fp.mean_acc.map(|a| format!("{a:.1}")).unwrap_or("-".into()),
        ]);
        for bits in [1.11, 0.9, 0.8, 0.7] {
            let r = eval_lane(&w, &QuantConfig::btc(bits), eval_tokens, zs)?;
            t.row(&[
                w.name.clone(),
                format!("{bits}bit"),
                fmt_ppl(r.ppl),
                r.mean_acc.map(|a| format!("{a:.1}")).unwrap_or("-".into()),
            ]);
            benchline("table5", &[("model", w.name.clone()), ("bits", bits.to_string()),
                                  ("ppl", format!("{:.4}", r.ppl))]);
        }
    }
    println!("\nTable 5 (Qwen-analog family, GQA): same graceful degradation as the primary family");
    t.print();
    Ok(())
}
