//! Figure 1 — binary sub-vector clustering: how much probability mass
//! the most frequent patterns / learned centroids capture, standard
//! mapping (all 2^v indices) vs the binary codebook.

use btc_llm::benchsuite::{load_workload, quick_mode};
use btc_llm::quant::binarize::BinaryLayer;
use btc_llm::quant::codebook::{collect_vectors, BinaryCodebook};
use btc_llm::util::benchkit::{benchline, Table};
use std::collections::HashMap;

fn main() -> anyhow::Result<()> {
    let model = if quick_mode() { "tinylm_s" } else { "tinylm_m" };
    let w = load_workload(model)?;
    let v = 10usize; // the paper's Fig. 1 uses length-10 vectors
    // Binarize every linear layer, collect sub-vectors.
    let mut vectors = Vec::new();
    for li in 0..w.raw.config.n_layer {
        for name in btc_llm::io::RawModel::linear_names(li) {
            let wm = w.raw.matrix(&name)?;
            let bl = BinaryLayer::quantize(&wm);
            vectors.extend(collect_vectors(&bl, v));
        }
    }
    let n = vectors.len() as f64;
    let mut counts: HashMap<u64, u64> = HashMap::new();
    for &x in &vectors {
        *counts.entry(x).or_insert(0) += 1;
    }
    let mut freq: Vec<u64> = counts.values().copied().collect();
    freq.sort_unstable_by(|a, b| b.cmp(a));

    let (cb, assign, stats) = BinaryCodebook::build(&vectors, v, 512, 5);
    let mut cmass = vec![0u64; cb.c()];
    for &k in &assign {
        cmass[k as usize] += 1;
    }
    cmass.sort_unstable_by(|a, b| b.cmp(a));

    let mut t = Table::new(&["top-K", "unique-pattern mass", "512-centroid mass", "uniform (1024 idx)"]);
    for k in [16usize, 64, 256, 512] {
        let um: u64 = freq.iter().take(k).sum();
        let cm: u64 = cmass.iter().take(k).sum();
        t.row(&[
            k.to_string(),
            format!("{:.1}%", 100.0 * um as f64 / n),
            format!("{:.1}%", 100.0 * cm as f64 / n),
            format!("{:.1}%", 100.0 * k as f64 / 1024.0),
        ]);
        benchline("fig1", &[("k", k.to_string()),
                            ("unique_mass", format!("{:.4}", um as f64 / n)),
                            ("centroid_mass", format!("{:.4}", cm as f64 / n))]);
    }
    println!("\nFigure 1 (v={v}): {} vectors, {} unique, codebook c={} (exact={})",
             vectors.len(), stats.n_unique, stats.c, stats.exact);
    t.print();
    println!("\nExpected shape: pattern mass concentrates far above uniform -> redundancy the");
    println!("codebook exploits (the paper's motivation figure).");
    Ok(())
}
