//! Appendix C.4 — codebook construction speed: the binary-specialized
//! K-means (XOR+POPCNT + unique-census) vs floating-point K-means on
//! the same data (the paper reports ~2.3x faster than GPTVQ).

use btc_llm::benchsuite::{load_workload, quick_mode};
use btc_llm::quant::binarize::BinaryLayer;
use btc_llm::quant::codebook::{collect_vectors, BinaryCodebook};
use btc_llm::quant::fpvq::FpVqLayer;
use btc_llm::tensor::Matrix;
use btc_llm::util::benchkit::{bench, benchline, black_box, Table};

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let model = if quick { "tinylm_s" } else { "tinylm_m" };
    let w = load_workload(model)?;
    // One representative layer, same (v, c, iters) for both builders.
    let wm = w.raw.matrix("l0.wgate")?;
    let bl = BinaryLayer::quantize(&wm);
    let v = 8usize;
    let c = 256usize;
    let iters = 5usize;
    let vectors = collect_vectors(&bl, v);
    // Sign matrix as floats for the fp k-means.
    let signs = Matrix::from_vec(bl.rows, bl.cols, bl.b.unpack());

    let reps = if quick { 2 } else { 5 };
    let b = bench("binary codebook", 1, reps, || {
        black_box(BinaryCodebook::build(&vectors, v, c, iters));
    });
    let f = bench("fp kmeans", 1, reps, || {
        black_box(FpVqLayer::quantize(&signs, v, c, iters, 1));
    });
    let speedup = f.mean_ns() / b.mean_ns();
    let mut t = Table::new(&["builder", "mean", "p50"]);
    t.row(&["binary K-means (XOR+POPCNT)".into(), format!("{:.2}ms", b.mean_ms()),
            format!("{:.2}ms", b.percentile_ns(0.5) as f64 / 1e6)]);
    t.row(&["fp K-means (same data)".into(), format!("{:.2}ms", f.mean_ms()),
            format!("{:.2}ms", f.percentile_ns(0.5) as f64 / 1e6)]);
    println!("\nApp. C.4 (codebook build speed, {} vectors, v={v}, c={c}, {iters} iters)", vectors.len());
    t.print();
    println!("speedup: {speedup:.2}x (paper: ~2.3x vs GPTVQ)");
    benchline("codebook_speed", &[("binary_ms", format!("{:.3}", b.mean_ms())),
                                  ("fp_ms", format!("{:.3}", f.mean_ms())),
                                  ("speedup", format!("{speedup:.3}"))]);
    Ok(())
}
