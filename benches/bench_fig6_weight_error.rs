//! Figures 6-7 — relative weight quantization error per layer for
//! BTC-LLM vs ARB-LLM vs BiLLM (the visual claim: BTC's error maps are
//! uniformly smaller).

use btc_llm::benchsuite::{load_workload, quick_mode};
use btc_llm::eval::error_stats::weight_errors;
use btc_llm::model::Transformer;
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::benchkit::{benchline, Table};

fn main() -> anyhow::Result<()> {
    let model = if quick_mode() { "tinylm_s" } else { "tinylm_m" };
    let w = load_workload(model)?;
    let fp = Transformer::from_raw(&w.raw)?;

    let lanes = [
        ("BiLLM", QuantConfig::billm()),
        ("ARB-LLM", QuantConfig::arb_llm()),
        ("BTC-LLM@1.11", QuantConfig::btc(1.11)),
        ("BTC-LLM@0.8", QuantConfig::btc(0.8)),
    ];
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut means = Vec::new();
    for (label, cfg) in &lanes {
        let qm = quantize_model(&w.raw, &w.corpus, cfg)?;
        let errs = weight_errors(&fp, &qm.model);
        let mean: f64 = errs.iter().map(|(_, _, e)| e).sum::<f64>() / errs.len() as f64;
        means.push((label.to_string(), mean));
        for (li, name, e) in errs {
            rows.push(vec![label.to_string(), format!("l{li}.{name}"), format!("{e:.4}")]);
        }
        benchline("fig6", &[("method", label.to_string()), ("mean_rel_err", format!("{mean:.5}"))]);
    }
    let mut t = Table::new(&["Method", "layer", "rel err"]);
    for r in rows.iter().take(if quick_mode() { 12 } else { 28 }) {
        t.row(r);
    }
    println!("\nFigures 6-7 (relative weight quantization error; first rows shown)");
    t.print();
    let mut mt = Table::new(&["Method", "mean rel err (all layers)"]);
    for (l, m) in &means {
        mt.row(&[l.clone(), format!("{m:.4}")]);
    }
    println!();
    mt.print();
    println!("\nExpected shape: BTC@1.11 < ARB < BiLLM; BTC@0.8 pays a modest codebook penalty.");
    Ok(())
}
