//! §5.3 latency / end-to-end serving — the coordinator with dynamic
//! batching replaying a request trace over three weight backends:
//! FP16 dense, W1A16 binary (sign-GEMM engine) and BTC sub-1-bit
//! (LUT-GEMM engine). Reports tokens/s and latency percentiles.

use std::time::Duration;

use btc_llm::benchsuite::{load_workload, quick_mode};
use btc_llm::coordinator::Server;
use btc_llm::data::{corpus, ByteTokenizer};
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::benchkit::{benchline, Table};

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let w = load_workload("tinylm_s")?;
    let n_requests = if quick { 8 } else { 32 };
    let max_new = if quick { 16 } else { 32 };
    let tok = ByteTokenizer::default();
    let prompts = corpus::prompts(n_requests, 7);

    let lanes = [
        ("FP16", QuantConfig::fp16()),
        ("W1A16 binary", QuantConfig::naive()),
        ("BTC 0.8 (LUT)", QuantConfig::btc(0.8)),
    ];
    let mut t = Table::new(&["backend", "tokens/s", "p50 lat", "p99 lat", "mean batch"]);
    for (label, cfg) in lanes {
        let mut qm = quantize_model(&w.raw, &w.corpus, &cfg)?;
        qm.model.prepare_engines();
        let server = Server::start(qm.model, 8, Duration::from_millis(2), 7);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| server.submit(tok.encode(p), max_new, 0.0))
            .collect();
        let mut total_tokens = 0usize;
        for rx in rxs {
            let r = rx.recv().expect("response");
            total_tokens += r.tokens.len() - r.prompt_len;
        }
        let wall = t0.elapsed().as_secs_f64();
        let tps = total_tokens as f64 / wall;
        t.row(&[
            label.to_string(),
            format!("{tps:.1}"),
            format!("{:.1}ms", server.metrics.latency_percentile_us(0.5) as f64 / 1e3),
            format!("{:.1}ms", server.metrics.latency_percentile_us(0.99) as f64 / 1e3),
            format!("{:.2}", server.metrics.mean_batch_size()),
        ]);
        benchline("serve_e2e", &[("backend", label.replace(' ', "_")),
                                 ("tokens_per_s", format!("{tps:.2}"))]);
        server.shutdown();
    }
    println!("\nEnd-to-end serving ({} requests, <= {max_new} new tokens each)", n_requests);
    t.print();
    println!("\nNote: at TinyLM widths the decode hot path is attention + norm overhead;");
    println!("the weight-GEMM speedup shows at MLP shapes — see bench_fig5_latency.");
    Ok(())
}
