//! §5.3 latency / end-to-end serving — the coordinator with dynamic
//! batching replaying a request trace over three weight backends:
//! FP16 dense, W1A16 binary (sign-GEMM engine) and BTC sub-1-bit
//! (LUT-GEMM engine). Sweeps the batch size (B=1/4/16) and reports
//! tokens/s, latency percentiles and the prefill/decode µs-per-token
//! split.
//!
//! Hermetic: when the trained artifacts are absent (`make artifacts`
//! not run — e.g. the CI perf-smoke job) the bench falls back to a
//! synthetic serving-shaped model so the numbers stay comparable
//! run-over-run.

use std::time::Duration;

use btc_llm::benchsuite::{load_workload, quick_mode};
use btc_llm::coordinator::Server;
use btc_llm::data::{corpus, ByteTokenizer};
use btc_llm::io::weights::{ModelConfig, RawModel};
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::benchkit::{benchline, JsonReport, Table};
use btc_llm::util::fixture::synth_raw_model;
use btc_llm::util::parallel;

fn workload() -> (RawModel, Vec<u8>, &'static str) {
    match load_workload("tinylm_s") {
        Ok(w) => (w.raw, w.corpus, "tinylm_s"),
        Err(_) => {
            let cfg = ModelConfig {
                vocab: 192,
                d_model: 96,
                n_layer: 2,
                n_head: 6,
                n_kv_head: 3,
                d_ff: 192,
                max_seq: 160,
                rope_theta: 10000.0,
            };
            let (raw, corpus) = synth_raw_model(11, cfg);
            (raw, corpus, "synthetic")
        }
    }
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let (raw, corpus_bytes, wl_name) = workload();
    let max_new = if quick { 16 } else { 32 };
    let batches: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    let tok = ByteTokenizer::default();
    let threads = parallel::threads();

    let lanes = [
        ("FP16", QuantConfig::fp16()),
        ("W1A16 binary", QuantConfig::naive()),
        ("BTC 0.8 (LUT)", QuantConfig::btc(0.8)),
    ];
    let mut t = Table::new(&[
        "backend", "B", "tokens/s", "p50 lat", "p99 lat", "mean batch", "prefill us/tok", "decode us/tok",
    ]);
    let mut report = JsonReport::new("serve");
    for (label, cfg) in lanes {
        let mut qm = quantize_model(&raw, &corpus_bytes, &cfg)?;
        // Prepare engines once per lane; the per-batch-size clones
        // carry them, so Server::start's ensure_engines is a no-op.
        qm.model.prepare_engines();
        for &bsz in batches {
            let n_requests = bsz * if quick { 2 } else { 4 };
            let prompts = corpus::prompts(n_requests, 7);
            let server = Server::start(qm.model.clone(), bsz, Duration::from_millis(2), 7);
            let t0 = std::time::Instant::now();
            let rxs: Vec<_> = prompts
                .iter()
                .map(|p| server.submit(tok.encode(p), max_new, 0.0))
                .collect();
            let mut total_tokens = 0usize;
            for rx in rxs {
                let r = rx.recv().expect("response");
                total_tokens += r.tokens.len() - r.prompt_len;
            }
            let wall = t0.elapsed().as_secs_f64();
            let tps = total_tokens as f64 / wall;
            let m = &server.metrics;
            let (pf_us, dc_us) = (m.prefill_us_per_token(), m.decode_us_per_token());
            t.row(&[
                label.to_string(),
                bsz.to_string(),
                format!("{tps:.1}"),
                format!("{:.1}ms", m.latency_percentile_us(0.5) as f64 / 1e3),
                format!("{:.1}ms", m.latency_percentile_us(0.99) as f64 / 1e3),
                format!("{:.2}", m.mean_batch_size()),
                format!("{pf_us:.0}"),
                format!("{dc_us:.0}"),
            ]);
            let kv = [
                ("backend", label.replace(' ', "_")),
                ("batch", bsz.to_string()),
                ("tokens_per_s", format!("{tps:.2}")),
                ("p50_ms", format!("{:.2}", m.latency_percentile_us(0.5) as f64 / 1e3)),
                ("p99_ms", format!("{:.2}", m.latency_percentile_us(0.99) as f64 / 1e3)),
                ("prefill_us_per_tok", format!("{pf_us:.1}")),
                ("decode_us_per_tok", format!("{dc_us:.1}")),
                ("threads", threads.to_string()),
                ("workload", wl_name.to_string()),
            ];
            benchline("serve_e2e", &kv);
            report.row(&kv);
            server.shutdown();
        }
    }
    println!(
        "\nEnd-to-end serving ({wl_name}, <= {max_new} new tokens/request, {threads} threads)"
    );
    t.print();
    let _ = report.write_if_enabled();
    println!("\nNote: at TinyLM widths the decode hot path is attention + norm overhead;");
    println!("the weight-GEMM speedup shows at MLP shapes — see bench_fig5_latency.");
    Ok(())
}
