//! §5.3 latency / end-to-end serving — the continuous-batching
//! coordinator replaying request traces over three weight backends:
//! FP16 dense, W1A16 binary (sign-GEMM engine) and BTC sub-1-bit
//! (LUT-GEMM engine). Two scenarios per backend:
//!
//! - `batch`: the classic closed-loop sweep (B=1/4/16) reporting
//!   tokens/s, latency percentiles and the prefill/decode
//!   µs-per-token split;
//! - `staggered`: one long-running background generation plus short
//!   requests arriving while it decodes — the in-flight admission
//!   path — reporting time-to-first-token and inter-token latency
//!   percentiles plus how many short requests completed before the
//!   long one (head-of-line-blocking truth; with the old
//!   batch-to-completion loop this is 0);
//! - `adversarial` (BTC lane only): the multi-tenant QoS scenario —
//!   one flooding tenant against two well-behaved ones, run under
//!   FIFO and weighted-round-robin admission, reporting per-tenant
//!   p95 TTFT/ITL and the ratio against each tenant's solo run (the
//!   fairness bar: WRR keeps well-behaved tenants within 2x of solo;
//!   FIFO does not);
//! - `prefix`: the KV-memory scenario — N long-context requests
//!   sharing a common prompt prefix, run once with an f32 KV pool and
//!   once with `kv_bits=4` cold-block quantization, reporting pool
//!   utilization, peak blocks per request, prefix-shared positions,
//!   peak KV resident bytes (f32 vs int4) and the in-flight peak vs
//!   what worst-case flat reservation would have admitted under the
//!   same block budget;
//! - `spec`: speculative decoding at M=1 — a btc-0.8 draft of the
//!   same checkpoint proposes tokens that an fp16 / btc-1.11 target
//!   verifies in one batched forward (DESIGN.md §13), reporting
//!   decode µs/token with speculation on vs off, accepted tokens per
//!   round, and the on/off speedup; greedy output is asserted
//!   bit-identical, and `PALLAS_PERF_ASSERT=1` arms the ≥1.2× M=1
//!   decode-speedup + ≥1.5 accepted/round gates on the hermetic
//!   synthetic run.
//!
//! Hermetic: when the trained artifacts are absent (`make artifacts`
//! not run — e.g. the CI perf-smoke job) the bench falls back to a
//! synthetic serving-shaped model so the numbers stay comparable
//! run-over-run. `BENCH_JSON=1` writes `BENCH_serve.json`, which the
//! CI perf gate compares against `benches/baseline/` (see
//! examples/perf_compare.rs).

use std::time::Duration;

use btc_llm::benchsuite::{load_workload, quick_mode};
use btc_llm::coordinator::{
    AdmitPolicy, EvictionKind, QosConfig, Server, ServerOptions, SpecConfig, StopSet, TenantSpec,
};
use btc_llm::data::{corpus, ByteTokenizer};
use btc_llm::io::weights::{ModelConfig, RawModel};
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::benchkit::{benchline, percentile_sorted, JsonReport, Table};
use btc_llm::util::fixture::synth_raw_model;
use btc_llm::util::parallel;

fn workload() -> (RawModel, Vec<u8>, &'static str) {
    match load_workload("tinylm_s") {
        Ok(w) => (w.raw, w.corpus, "tinylm_s"),
        Err(_) => {
            let cfg = ModelConfig {
                vocab: 192,
                d_model: 96,
                n_layer: 2,
                n_head: 6,
                n_kv_head: 3,
                d_ff: 192,
                max_seq: 160,
                rope_theta: 10000.0,
            };
            let (raw, corpus) = synth_raw_model(11, cfg);
            (raw, corpus, "synthetic")
        }
    }
}

fn percentile_ms(sorted_us: &[u64], p: f64) -> f64 {
    percentile_sorted(sorted_us, p) as f64 / 1e3
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let (raw, corpus_bytes, wl_name) = workload();
    let max_new = if quick { 16 } else { 32 };
    let batches: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    let tok = ByteTokenizer::default();
    let threads = parallel::threads();

    let lanes = [
        ("FP16", QuantConfig::fp16()),
        ("W1A16 binary", QuantConfig::naive()),
        ("BTC 0.8 (LUT)", QuantConfig::btc(0.8)),
    ];
    let mut t = Table::new(&[
        "backend", "B", "tokens/s", "p50 lat", "p99 lat", "mean batch", "prefill us/tok", "decode us/tok",
    ]);
    let mut stag = Table::new(&[
        "backend", "shorts", "ttft p50", "ttft p95", "itl p50", "done before long",
    ]);
    let mut prefix_t = Table::new(&[
        "backend", "kv", "tokens/s", "kv peak", "blk/req", "shared pos", "inflight peak", "util",
    ]);
    let mut qos_t = Table::new(&[
        "policy", "tenant", "ttft p95", "itl p95", "solo ttft p95", "vs solo",
    ]);
    let mut report = JsonReport::new("serve");
    for (label, cfg) in lanes {
        let mut qm = quantize_model(&raw, &corpus_bytes, &cfg)?;
        // Prepare engines once per lane; the per-scenario clones carry
        // them, so Server::start's ensure_engines is a no-op.
        qm.model.prepare_engines();

        // --- Scenario 1: closed-loop batch sweep ---------------------
        for &bsz in batches {
            let n_requests = bsz * if quick { 2 } else { 4 };
            let prompts = corpus::prompts(n_requests, 7);
            let server = Server::start(qm.model.clone(), bsz, Duration::from_millis(2), 7);
            let t0 = std::time::Instant::now();
            let rxs: Vec<_> = prompts
                .iter()
                .map(|p| server.submit(tok.encode(p), max_new, 0.0).expect("submit"))
                .collect();
            let mut total_tokens = 0usize;
            for rx in rxs {
                let r = rx.recv().expect("response");
                total_tokens += r.tokens.len() - r.prompt_len;
            }
            let wall = t0.elapsed().as_secs_f64();
            let tps = total_tokens as f64 / wall;
            let m = &server.metrics;
            let (pf_us, dc_us) = (m.prefill_us_per_token(), m.decode_us_per_token());
            t.row(&[
                label.to_string(),
                bsz.to_string(),
                format!("{tps:.1}"),
                format!("{:.1}ms", m.latency_percentile_us(0.5) as f64 / 1e3),
                format!("{:.1}ms", m.latency_percentile_us(0.99) as f64 / 1e3),
                format!("{:.2}", m.mean_batch_size()),
                format!("{pf_us:.0}"),
                format!("{dc_us:.0}"),
            ]);
            let kv = [
                ("scenario", "batch".to_string()),
                ("backend", label.replace(' ', "_")),
                ("batch", bsz.to_string()),
                ("tokens_per_s", format!("{tps:.2}")),
                ("p50_ms", format!("{:.2}", m.latency_percentile_us(0.5) as f64 / 1e3)),
                ("p99_ms", format!("{:.2}", m.latency_percentile_us(0.99) as f64 / 1e3)),
                ("prefill_us_per_tok", format!("{pf_us:.1}")),
                ("decode_us_per_tok", format!("{dc_us:.1}")),
                ("threads", threads.to_string()),
                ("workload", wl_name.to_string()),
            ];
            benchline("serve_e2e", &kv);
            report.row(&kv);
            server.shutdown();
        }

        // --- Scenario 2: staggered arrivals under a long generation --
        // One long request decodes in the background; short requests
        // trickle in and must be admitted in flight. TTFT/ITL come
        // from the per-request response stamps; `done_before_long`
        // counts short completions with a smaller completion sequence
        // number than the long request (0 under batch-to-completion).
        // Prompt positions + generated tokens must stay within the
        // model's RoPE table (max_seq 160 on both workloads).
        let long_new = if quick { 48 } else { 96 };
        let n_short = if quick { 4 } else { 8 };
        let short_new = 8;
        let opts = ServerOptions {
            max_batch: 4,
            batch_wait: Duration::from_millis(1),
            seed: 7,
            prefill_chunk: 16,
            // Run to the token budget: comparable work per run.
            stop: StopSet::none(),
            ..ServerOptions::default()
        };
        let server = Server::start_with_opts(qm.model.clone(), opts);
        let prompts = corpus::prompts(n_short + 1, 13);
        let t0 = std::time::Instant::now();
        let long_rx = server
            .submit(tok.encode(&prompts[0]), long_new, 0.0)
            .expect("submit long");
        let short_rxs: Vec<_> = prompts[1..]
            .iter()
            .map(|p| {
                // Arrivals staggered across the long decode.
                std::thread::sleep(Duration::from_millis(2));
                server.submit(tok.encode(p), short_new, 0.0).expect("submit short")
            })
            .collect();
        let shorts: Vec<_> = short_rxs.into_iter().map(|rx| rx.recv().expect("short")).collect();
        let long = long_rx.recv().expect("long");
        let wall = t0.elapsed().as_secs_f64();
        let total_tokens: usize = shorts
            .iter()
            .map(|r| r.tokens.len() - r.prompt_len)
            .sum::<usize>()
            + (long.tokens.len() - long.prompt_len);
        let mut ttfts_us: Vec<u64> = shorts.iter().map(|r| r.ttft.as_micros() as u64).collect();
        ttfts_us.sort_unstable();
        let mut itls_us: Vec<u64> = shorts
            .iter()
            .filter(|r| r.tokens.len() - r.prompt_len > 1)
            .map(|r| {
                ((r.latency - r.ttft).as_micros() as u64)
                    / (r.tokens.len() - r.prompt_len - 1) as u64
            })
            .collect();
        itls_us.sort_unstable();
        let done_before_long = shorts.iter().filter(|r| r.seq < long.seq).count();
        let (ttft_p50, ttft_p95) = (percentile_ms(&ttfts_us, 0.5), percentile_ms(&ttfts_us, 0.95));
        let itl_p50 = percentile_ms(&itls_us, 0.5);
        stag.row(&[
            label.to_string(),
            n_short.to_string(),
            format!("{ttft_p50:.1}ms"),
            format!("{ttft_p95:.1}ms"),
            format!("{itl_p50:.2}ms"),
            format!("{done_before_long}/{n_short}"),
        ]);
        let kv = [
            ("scenario", "staggered".to_string()),
            ("backend", label.replace(' ', "_")),
            ("batch", "4".to_string()),
            ("long_new_tokens", long_new.to_string()),
            ("n_short", n_short.to_string()),
            ("tokens_per_s", format!("{:.2}", total_tokens as f64 / wall)),
            ("ttft_p50_ms", format!("{ttft_p50:.2}")),
            ("ttft_p95_ms", format!("{ttft_p95:.2}")),
            ("itl_p50_ms", format!("{itl_p50:.3}")),
            ("done_before_long", done_before_long.to_string()),
            ("threads", threads.to_string()),
            ("workload", wl_name.to_string()),
        ];
        benchline("serve_e2e", &kv);
        report.row(&kv);
        server.shutdown();

        // --- Scenario 3: long context + shared prefix (KV memory) ----
        // N requests share a block-aligned prompt prefix and each
        // generate a long continuation. A base request warms the
        // prefix (streaming: its first token proves the prompt is
        // prefilled + registered), then the rest attach its blocks.
        // Run twice — f32 pool vs kv_bits=4 — and report the measured
        // pool numbers the ROADMAP's memory story turns on.
        let ms = raw.config.max_seq;
        let kv_block = 8usize;
        let prefix_len = 4 * kv_block; // four shareable full blocks
        let suffix_len = 8usize;
        let gen_len =
            ms.saturating_sub(prefix_len + suffix_len + 4).min(100).max(8);
        let n_requests = if quick { 4 } else { 6 };
        let worst_blocks = (prefix_len + suffix_len + gen_len + 1).div_ceil(kv_block);
        // Budget sized to the *actual* shared-prefix demand — well
        // under n_requests worst cases, so worst-case flat reservation
        // could only admit `budget / worst_blocks` requests at once
        // while the paged pool runs all of them.
        let shared_blocks = prefix_len / kv_block;
        let budget_blocks = shared_blocks + n_requests * (worst_blocks - shared_blocks) + 1;
        let vocab = raw.config.vocab as u16;
        let prefix: Vec<u16> = (0..prefix_len).map(|i| ((i * 7 + 3) % vocab as usize) as u16).collect();
        let mut peak_bytes_by_cfg = Vec::new();
        for kv_bits in [16u32, 4] {
            let opts = ServerOptions {
                max_batch: n_requests.max(2),
                batch_wait: Duration::from_millis(1),
                seed: 7,
                prefill_chunk: 32,
                stop: StopSet::none(),
                kv_block,
                kv_pool_blocks: budget_blocks,
                kv_bits,
                kv_local_window: 8,
                ..ServerOptions::default()
            };
            let server = Server::start_with_opts(qm.model.clone(), opts);
            let t0 = std::time::Instant::now();
            let suffix = |r: usize| -> Vec<u16> {
                (0..suffix_len).map(|i| (((i * 5 + r * 11 + 1) % vocab as usize) as u16)).collect()
            };
            let mut base_prompt = prefix.clone();
            base_prompt.extend(suffix(0));
            let (stream, base_rx) = server
                .submit_streaming_with(base_prompt, gen_len, 0.0, StopSet::none())
                .expect("submit base");
            // First token => base prompt fully prefilled, prefix
            // blocks registered and attachable.
            stream.recv().expect("base first token");
            let rxs: Vec<_> = (1..n_requests)
                .map(|r| {
                    let mut p = prefix.clone();
                    p.extend(suffix(r));
                    server.submit_with(p, gen_len, 0.0, StopSet::none(), None).expect("submit")
                })
                .collect();
            let mut total_tokens = 0usize;
            for rx in rxs {
                let r = rx.recv().expect("prefix response");
                total_tokens += r.tokens.len() - r.prompt_len;
            }
            let base = base_rx.recv().expect("base response");
            total_tokens += base.tokens.len() - base.prompt_len;
            drop(stream);
            let wall = t0.elapsed().as_secs_f64();
            use std::sync::atomic::Ordering::Relaxed;
            let m = &server.metrics;
            let peak_blocks = m.kv_blocks_peak.load(Relaxed);
            let peak_bytes = m.kv_resident_peak_bytes.load(Relaxed);
            let shared_pos = m.kv_shared_positions.load(Relaxed);
            let inflight_peak = m.peak_in_flight.load(Relaxed);
            let quant_peak = m.kv_quant_blocks_peak.load(Relaxed);
            let tps = total_tokens as f64 / wall;
            let util = peak_blocks as f64 / budget_blocks as f64;
            peak_bytes_by_cfg.push(peak_bytes);
            prefix_t.row(&[
                label.to_string(),
                if kv_bits >= 16 { "f32".into() } else { format!("int{kv_bits}") },
                format!("{tps:.1}"),
                format!("{:.0}KB", peak_bytes as f64 / 1024.0),
                format!("{:.1}", peak_blocks as f64 / n_requests as f64),
                shared_pos.to_string(),
                format!("{inflight_peak} (flat {})", budget_blocks / worst_blocks),
                format!("{util:.2}"),
            ]);
            let kv = [
                ("scenario", "prefix".to_string()),
                ("backend", label.replace(' ', "_")),
                ("kv_bits", kv_bits.to_string()),
                ("n_requests", n_requests.to_string()),
                ("prefix_len", prefix_len.to_string()),
                ("gen_len", gen_len.to_string()),
                ("kv_block", kv_block.to_string()),
                ("kv_pool_blocks", budget_blocks.to_string()),
                ("tokens_per_s", format!("{tps:.2}")),
                ("kv_peak_blocks", peak_blocks.to_string()),
                ("kv_peak_bytes", peak_bytes.to_string()),
                ("kv_quant_blocks_peak", quant_peak.to_string()),
                ("kv_blocks_per_request", format!("{:.2}", peak_blocks as f64 / n_requests as f64)),
                ("kv_shared_positions", shared_pos.to_string()),
                ("inflight_peak", inflight_peak.to_string()),
                ("worst_case_flat_slots", (budget_blocks / worst_blocks).to_string()),
                ("pool_utilization", format!("{util:.3}")),
                ("threads", threads.to_string()),
                ("workload", wl_name.to_string()),
            ];
            benchline("serve_e2e", &kv);
            report.row(&kv);
            server.shutdown();
        }
        // The sub-1-bit memory story, continuously enforced on the
        // hermetic synthetic workload (trained artifacts may have
        // shapes where the margin differs; there we only report).
        let ratio = peak_bytes_by_cfg[0] as f64 / peak_bytes_by_cfg[1].max(1) as f64;
        println!("  {label}: KV peak bytes f32/int4 = {ratio:.2}x");
        if wl_name == "synthetic" {
            assert!(
                ratio >= 3.0,
                "{label}: int4 KV pool must shrink >= 3x vs f32 (got {ratio:.2}x)"
            );
        }

        // --- Scenario 4: adversarial multi-tenant mix (QoS) ----------
        // One flooding tenant (weight 1, class 1) dumps a burst of
        // short requests; two well-behaved tenants (weight 2, class 0)
        // then submit a couple of normal requests into the backlog.
        // Under FIFO the polite tenants queue behind the whole flood;
        // under weighted round-robin their class drains first, so
        // their p95 TTFT stays within 2x of a solo run. QoS ordering
        // is backend-independent, so the scenario runs on the BTC
        // lane only.
        if label.starts_with("BTC") {
            let vocab = raw.config.vocab as usize;
            let flood_n = if quick { 24 } else { 40 };
            let flood_prompts: Vec<Vec<u16>> = (0..flood_n)
                .map(|i| (0..4).map(|j| ((j * 3 + i * 5 + 1) % vocab) as u16).collect())
                .collect();
            let polite_prompt = |t: usize, k: usize| -> Vec<u16> {
                (0..96).map(|j| ((j * 7 + t * 17 + k * 29 + 2) % vocab) as u16).collect()
            };
            let qos_opts = |admission: AdmitPolicy| ServerOptions {
                max_batch: 4,
                batch_wait: Duration::from_millis(1),
                seed: 7,
                prefill_chunk: 32,
                stop: StopSet::none(),
                qos: QosConfig {
                    admission,
                    eviction: EvictionKind::Newest,
                    tenants: vec![
                        TenantSpec { id: "flood".into(), weight: 1, priority: 1, max_pending: 0 },
                        TenantSpec { id: "alice".into(), weight: 2, priority: 0, max_pending: 0 },
                        TenantSpec { id: "bob".into(), weight: 2, priority: 0, max_pending: 0 },
                    ],
                },
                ..ServerOptions::default()
            };
            // Solo references: each polite tenant alone on the server,
            // same options, same prompts — the baseline the fairness
            // claim is measured against.
            let mut solo_ttft_ms = std::collections::BTreeMap::new();
            for (ti, tenant) in ["alice", "bob"].into_iter().enumerate() {
                let server = Server::start_with_opts(
                    qm.model.clone(),
                    qos_opts(AdmitPolicy::WeightedRoundRobin),
                );
                let rxs: Vec<_> = (0..2)
                    .map(|k| {
                        server
                            .submit_qos(tenant, polite_prompt(ti, k), 8, 0.0, Some(StopSet::none()), None)
                            .expect("solo submit")
                    })
                    .collect();
                for rx in rxs {
                    rx.recv().expect("solo response");
                }
                solo_ttft_ms.insert(
                    tenant,
                    server.metrics.tenant_ttft_percentile_us(tenant, 0.95) as f64 / 1e3,
                );
                server.shutdown();
            }
            for policy in [AdmitPolicy::Fifo, AdmitPolicy::WeightedRoundRobin] {
                let server = Server::start_with_opts(qm.model.clone(), qos_opts(policy));
                let flood_rxs: Vec<_> = flood_prompts
                    .iter()
                    .map(|p| {
                        server
                            .submit_qos("flood", p.clone(), 4, 0.0, Some(StopSet::none()), None)
                            .expect("flood submit")
                    })
                    .collect();
                // Let the flood occupy the batch and build a backlog
                // before the polite tenants arrive.
                std::thread::sleep(Duration::from_millis(10));
                let polite_rxs: Vec<_> = (0..2usize)
                    .flat_map(|k| [("alice", 0usize, k), ("bob", 1usize, k)])
                    .map(|(t, ti, k)| {
                        server
                            .submit_qos(t, polite_prompt(ti, k), 8, 0.0, Some(StopSet::none()), None)
                            .expect("polite submit")
                    })
                    .collect();
                for rx in polite_rxs.into_iter().chain(flood_rxs) {
                    rx.recv().expect("adversarial response");
                }
                for tenant in ["alice", "bob", "flood"] {
                    let ttft_p95 =
                        server.metrics.tenant_ttft_percentile_us(tenant, 0.95) as f64 / 1e3;
                    let itl_p95 =
                        server.metrics.tenant_itl_percentile_us(tenant, 0.95) as f64 / 1e3;
                    let solo = solo_ttft_ms.get(tenant).copied();
                    let vs_solo = solo.map(|s| ttft_p95 / s.max(1e-6));
                    qos_t.row(&[
                        policy.as_str().to_string(),
                        tenant.to_string(),
                        format!("{ttft_p95:.1}ms"),
                        format!("{itl_p95:.2}ms"),
                        solo.map_or("-".into(), |s| format!("{s:.1}ms")),
                        vs_solo.map_or("-".into(), |r| format!("{r:.2}x")),
                    ]);
                    let mut kv = vec![
                        ("scenario", "adversarial".to_string()),
                        ("backend", label.replace(' ', "_")),
                        ("batch", "4".to_string()),
                        ("policy", policy.as_str().to_string()),
                        ("tenant", tenant.to_string()),
                        ("flood_n", flood_n.to_string()),
                        ("ttft_p95_ms", format!("{ttft_p95:.2}")),
                        ("itl_p95_ms", format!("{itl_p95:.3}")),
                    ];
                    if let (Some(s), Some(r)) = (solo, vs_solo) {
                        kv.push(("solo_ttft_p95_ms", format!("{s:.2}")));
                        kv.push(("ttft_vs_solo", format!("{r:.2}")));
                    }
                    kv.push(("threads", threads.to_string()));
                    kv.push(("workload", wl_name.to_string()));
                    benchline("serve_e2e", &kv);
                    report.row(&kv);
                    // The fairness claim, continuously enforced on the
                    // hermetic workload: WRR keeps well-behaved p95
                    // TTFT within 2x of solo; FIFO demonstrably does
                    // not (the flood backlog is far larger than that).
                    if wl_name == "synthetic" {
                        if let Some(r) = vs_solo {
                            match policy {
                                AdmitPolicy::WeightedRoundRobin => assert!(
                                    r <= 2.0,
                                    "{tenant} under wrr: ttft p95 {r:.2}x solo (must be <= 2x)"
                                ),
                                AdmitPolicy::Fifo => assert!(
                                    r > 2.0,
                                    "{tenant} under fifo: ttft p95 {r:.2}x solo (flood backlog \
                                     should dominate; is the scenario still adversarial?)"
                                ),
                            }
                        }
                    }
                }
                server.shutdown();
            }
        }
    }

    // --- Scenario 5: speculative decoding at M=1 (spec) --------------
    // One raw checkpoint, two bit-widths: a btc-0.8 draft proposes up
    // to k tokens per round and the target verifies all of them in a
    // single batched forward, accepting the longest agreeing prefix —
    // greedy output is bit-identical by construction (asserted below),
    // so the decode-latency delta is the whole story. Speculation's
    // profit is the draft/target per-forward cost gap, and at the
    // serving-shape TinyLM widths attention + norm dominate (see the
    // closing note), so the hermetic run uses a GEMM-heavy shape where
    // the fp32 target streams megabytes of weights per token while the
    // sub-1-bit draft stays cache-resident — the regime the paper's
    // latency story (and this gate) is about. Two targets bracket the
    // tradeoff: fp16 maximizes the draft's cost advantage, btc-1.11
    // maximizes draft/target agreement (adjacent bit budgets of the
    // same codebook quantizer).
    let mut spec_t = Table::new(&[
        "target", "spec", "tokens/s", "decode us/tok", "acc/round", "acc p50/p95", "rounds",
    ]);
    let spec_src = (wl_name == "synthetic").then(|| {
        let cfg = ModelConfig {
            vocab: 192,
            d_model: 256,
            n_layer: 2,
            n_head: 8,
            n_kv_head: 4,
            d_ff: 1024,
            max_seq: 160,
            rope_theta: 10000.0,
        };
        synth_raw_model(11, cfg)
    });
    let (spec_raw, spec_corpus) = spec_src
        .as_ref()
        .map_or((&raw, corpus_bytes.as_slice()), |(r, c)| (r, c.as_slice()));
    let mut draft_qm = quantize_model(spec_raw, spec_corpus, &QuantConfig::btc(0.8))?;
    draft_qm.model.prepare_engines();
    let spec_new = if quick { 48 } else { 96 };
    let spec_prompts = corpus::prompts(if quick { 2 } else { 3 }, 23);
    let mut spec_best = (0f64, 0f64); // (speedup, accepted/round) across targets
    for (tlabel, tcfg) in [("FP16", QuantConfig::fp16()), ("BTC 1.11 (LUT)", QuantConfig::btc(1.11))]
    {
        let mut tqm = quantize_model(spec_raw, spec_corpus, &tcfg)?;
        tqm.model.prepare_engines();
        let mut decode_us = [0f64; 2];
        let mut outputs: [Vec<Vec<u16>>; 2] = [Vec::new(), Vec::new()];
        let mut accepted = 0f64;
        for (si, spec_on) in [(0usize, false), (1, true)] {
            let server = Server::start_with_opts(
                tqm.model.clone(),
                ServerOptions {
                    // M=1: the latency-bound regime speculation targets.
                    max_batch: 1,
                    batch_wait: Duration::from_millis(1),
                    seed: 7,
                    stop: StopSet::none(),
                    spec: spec_on
                        .then(|| SpecConfig::new(draft_qm.model.clone(), "btc-0.8", 2, 6)),
                    ..ServerOptions::default()
                },
            );
            let t0 = std::time::Instant::now();
            let rxs: Vec<_> = spec_prompts
                .iter()
                .map(|p| {
                    // Clamp so prompt + generation always fits the
                    // RoPE table (max_seq 160 on every workload here).
                    let mut ids = tok.encode(p);
                    ids.truncate(160 - spec_new - 1);
                    server.submit(ids, spec_new, 0.0).expect("submit spec")
                })
                .collect();
            let mut total_tokens = 0usize;
            for rx in rxs {
                let r = rx.recv().expect("spec response");
                total_tokens += r.tokens.len() - r.prompt_len;
                outputs[si].push(r.tokens);
            }
            let wall = t0.elapsed().as_secs_f64();
            let tps = total_tokens as f64 / wall;
            let m = &server.metrics;
            decode_us[si] = m.decode_us_per_token();
            use std::sync::atomic::Ordering::Relaxed;
            let (acc, p50, p95, rounds) = (
                m.mean_spec_accepted(),
                m.spec_accepted_percentile(0.5),
                m.spec_accepted_percentile(0.95),
                m.spec_rounds.load(Relaxed),
            );
            if spec_on {
                accepted = acc;
            }
            spec_t.row(&[
                tlabel.to_string(),
                if spec_on { "btc-0.8 k<=6" } else { "off" }.to_string(),
                format!("{tps:.1}"),
                format!("{:.0}", decode_us[si]),
                if spec_on { format!("{acc:.2}") } else { "-".into() },
                if spec_on { format!("{p50}/{p95}") } else { "-".into() },
                if spec_on { rounds.to_string() } else { "-".into() },
            ]);
            let mut kv = vec![
                ("scenario", "spec".to_string()),
                ("backend", tlabel.replace(' ', "_")),
                ("batch", "1".to_string()),
                ("spec", if spec_on { "on" } else { "off" }.to_string()),
                ("gen_new", spec_new.to_string()),
                ("tokens_per_s", format!("{tps:.2}")),
                ("decode_us_per_tok", format!("{:.1}", decode_us[si])),
            ];
            if spec_on {
                kv.push(("accepted_per_round", format!("{acc:.3}")));
                kv.push(("accepted_p50", p50.to_string()));
                kv.push(("accepted_p95", p95.to_string()));
                kv.push(("spec_rounds", rounds.to_string()));
                kv.push((
                    "spec_speedup_m1",
                    format!("{:.3}", decode_us[0] / decode_us[1].max(1e-9)),
                ));
            }
            kv.push(("threads", threads.to_string()));
            kv.push(("workload", wl_name.to_string()));
            benchline("serve_e2e", &kv);
            report.row(&kv);
            server.shutdown();
        }
        // The exactness contract, enforced wherever the bench runs:
        // speculation must never change greedy output.
        assert_eq!(
            outputs[0], outputs[1],
            "{tlabel}: speculative greedy output diverged from plain decoding"
        );
        let speedup = decode_us[0] / decode_us[1].max(1e-9);
        println!("  spec {tlabel}: M=1 decode speedup {speedup:.2}x, {accepted:.2} accepted/round");
        spec_best.0 = spec_best.0.max(speedup);
        spec_best.1 = spec_best.1.max(accepted);
    }
    // CI perf-smoke gates (PALLAS_PERF_ASSERT=1, never tier-1), on the
    // agreeing-synthetic config only — the trained artifact's shape
    // and acceptance profile are whatever training produced, so there
    // we only report. The best row across the two targets must clear
    // both floors: speculation that neither speeds up decode nor
    // accepts drafts is dead weight and should fail the PR.
    if wl_name == "synthetic" && std::env::var("PALLAS_PERF_ASSERT").is_ok_and(|v| v == "1") {
        assert!(
            spec_best.0 >= 1.2,
            "spec: best M=1 decode speedup {:.2}x < 1.2x floor",
            spec_best.0
        );
        assert!(
            spec_best.1 >= 1.5,
            "spec: best mean acceptance {:.2} tokens/round < 1.5 floor",
            spec_best.1
        );
    }

    println!(
        "\nEnd-to-end serving ({wl_name}, <= {max_new} new tokens/request, {threads} threads)"
    );
    t.print();
    let n_short = if quick { 4 } else { 8 };
    println!(
        "\nStaggered arrivals ({wl_name}: {n_short} short requests of 8 tokens behind one long \
         generation; TTFT measured submit → first token)"
    );
    stag.print();
    println!(
        "\nLong context + shared prefix ({wl_name}: block-paged KV pool, refcounted prefix \
         blocks, int4 cold blocks vs f32; 'inflight peak (flat N)' compares sustained \
         concurrency against worst-case flat reservation under the same block budget)"
    );
    prefix_t.print();
    println!(
        "\nAdversarial multi-tenant mix (BTC lane: one flooding tenant w=1/class 1 vs two \
         well-behaved tenants w=2/class 0; 'vs solo' compares each tenant's p95 TTFT in the mix \
         against the same tenant running alone)"
    );
    qos_t.print();
    println!(
        "\nSpeculative decoding (M=1, btc-0.8 draft, greedy bit-identity asserted; hermetic \
         runs use a GEMM-heavy {} checkpoint where the weight-traffic gap between target and \
         sub-1-bit draft is the speedup lever)",
        if wl_name == "synthetic" { "256x1024" } else { wl_name }
    );
    spec_t.print();
    let _ = report.write_if_enabled();
    println!("\nNote: at TinyLM widths the decode hot path is attention + norm overhead;");
    println!("the weight-GEMM speedup shows at MLP shapes — see bench_fig5_latency.");
    Ok(())
}
