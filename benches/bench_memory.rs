//! Memory truth bench — the continuously-enforced version of the
//! paper's sub-1-bit claim. Quantizes a synthetic btc-0.8 model at a
//! realistic width (d_model 1024, so packed-plane rows align to whole
//! words), then compares three numbers per category:
//!
//! - **accounted** bits (`eval::memory`, the convention the tables use),
//! - **resident** bytes (what the backends actually hold in RAM), and
//! - **wire** bytes (the serialized QLM1 v3 payloads / the real file).
//!
//! Asserts the invariants the packed-plane refactor bought:
//! measured resident linear bits/weight <= 1.0 for the btc-0.8 lane,
//! resident within 5% of accounted, and the saved file within 5% of
//! the accounted total. A regression of the truth gap (e.g. someone
//! widening a buffer "temporarily") fails the perf-smoke job.
//!
//! Emits `BENCH_memory.json` under `BENCH_JSON=1`.

use btc_llm::benchsuite::quick_mode;
use btc_llm::eval::memory;
use btc_llm::io::qweights;
use btc_llm::io::weights::ModelConfig;
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::benchkit::{benchline, JsonReport, Table};
use btc_llm::util::fixture::synth_raw_model;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    // d_model 1024 / d_ff 2048: with v=16 and 13-bit indices every
    // plane row is a whole number of u64 words, so resident == wire ==
    // accounted up to the container header — the honest best case the
    // refactor is designed to hit. n_layer 2 amortizes the shared
    // codebook the way a real model does.
    let cfg = ModelConfig {
        vocab: 256,
        d_model: 1024,
        n_layer: if quick { 1 } else { 2 },
        n_head: 4,
        n_kv_head: 4,
        d_ff: 2048,
        max_seq: 32,
        rope_theta: 10000.0,
    };
    let (raw, corpus) = synth_raw_model(17, cfg);
    let qc = QuantConfig {
        calib_seqs: 4,
        calib_seq_len: 24,
        calib_rows: 48,
        transform_outer: 1,
        arb_iters: 2,
        em_iters: if quick { 2 } else { 3 },
        ..QuantConfig::btc(0.8)
    };
    let t0 = std::time::Instant::now();
    let qm = quantize_model(&raw, &corpus, &qc)?;
    let quant_secs = t0.elapsed().as_secs_f64();
    let r = memory::report(&qm.model);

    let path = std::env::temp_dir().join("btc_bench_memory.qlm");
    qweights::save(&path, &qm.model)?;
    let file_bytes = std::fs::metadata(&path)?.len() as usize;
    let _ = std::fs::remove_file(&path);

    let accounted_total = r.linear_bytes + r.codebook_bytes + r.transform_bytes;
    let mut t = Table::new(&["category", "accounted", "resident", "wire"]);
    t.row(&[
        "linears".into(),
        memory::human_bytes(r.linear_bytes),
        memory::human_bytes(r.linear_resident_bytes),
        memory::human_bytes(r.linear_wire_bytes),
    ]);
    t.row(&[
        "codebook".into(),
        memory::human_bytes(r.codebook_bytes),
        memory::human_bytes(r.codebook_resident_bytes),
        memory::human_bytes(r.codebook_bytes), // v3 ships packed = accounted
    ]);
    t.row(&[
        "file total".into(),
        memory::human_bytes(accounted_total),
        "-".into(),
        memory::human_bytes(file_bytes),
    ]);
    println!(
        "\nMemory truth (synthetic btc-0.8, d={}, {} layers)",
        raw.config.d_model, raw.config.n_layer
    );
    t.print();
    println!(
        "bits/weight: accounted {:.4}, resident {:.4} (quantized in {quant_secs:.1}s)",
        r.linear_bits_per_weight, r.resident_bits_per_weight
    );

    let kv = [
        ("accounted_linear_bytes", r.linear_bytes.to_string()),
        ("resident_linear_bytes", r.linear_resident_bytes.to_string()),
        ("wire_linear_bytes", r.linear_wire_bytes.to_string()),
        ("codebook_bytes", r.codebook_bytes.to_string()),
        ("codebook_resident_bytes", r.codebook_resident_bytes.to_string()),
        ("file_bytes", file_bytes.to_string()),
        ("accounted_total_bytes", accounted_total.to_string()),
        ("accounted_bits_per_weight", format!("{:.5}", r.linear_bits_per_weight)),
        ("resident_bits_per_weight", format!("{:.5}", r.resident_bits_per_weight)),
        ("quant_secs", format!("{quant_secs:.2}")),
    ];
    benchline("memory", &kv);
    let mut report = JsonReport::new("memory");
    report.row(&kv);
    let _ = report.write_if_enabled();

    // --- Enforced invariants (the sub-1-bit truth, not a vibe) -------
    assert!(
        r.resident_bits_per_weight <= 1.0,
        "btc-0.8 lane must be sub-1-bit in RAM: measured {:.4} bits/weight",
        r.resident_bits_per_weight
    );
    let resident_gap =
        (r.linear_resident_bytes as f64 - r.linear_bytes as f64).abs() / r.linear_bytes as f64;
    assert!(
        resident_gap <= 0.05,
        "resident {} vs accounted {} ({:.1}% gap > 5%)",
        r.linear_resident_bytes,
        r.linear_bytes,
        resident_gap * 100.0
    );
    let file_gap = (file_bytes as f64 - accounted_total as f64).abs() / accounted_total as f64;
    assert!(
        file_gap <= 0.05,
        "QLM1 v3 file {} vs accounted {} ({:.1}% gap > 5%)",
        file_bytes,
        accounted_total,
        file_gap * 100.0
    );
    println!("memory truth invariants hold: sub-1-bit resident, resident/file within 5% of accounted");
    Ok(())
}
