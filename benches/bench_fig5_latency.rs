//! Figure 5 — kernel latency vs batch M on an MLP-shaped layer:
//! FP32 dense GEMM vs dequant-then-GEMM vs W1A16 sign-GEMM vs
//! Binary-Codebook LUT-GEMM, plus the weight-memory panel.
//!
//! The paper measures an H800 (8192x28672); we measure the same
//! *relative* curve on CPU at a scaled shape (1024x3584 default).
//! Headline claim to reproduce: LUT-GEMM >= 1.6x over the dequant
//! path at sub-1-bit, sign-GEMM competitive with FP at small M.

use btc_llm::benchsuite::quick_mode;
use btc_llm::engine::{dense, BinaryGemmEngine, EngineCtx, LutGemmEngine, QuantizedActs};
use btc_llm::quant::binarize::BinaryLayer;
use btc_llm::quant::codebook::{collect_vectors, BinaryCodebook, CodebookLayer};
use btc_llm::tensor::Matrix;
use btc_llm::util::benchkit::{bench_for_ms, benchline, black_box, JsonReport, Table};
use btc_llm::util::parallel;
use btc_llm::util::rng::Rng;
use btc_llm::util::simd::{self, Level};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    // MLP shape (out=3584, in=1024) ~ 1/8-scale of the paper's layer.
    let (o, n) = if quick { (896, 256) } else { (3584, 1024) };
    let v = 16usize;
    let c = 1 << 13; // 0.8125 index bits/weight
    let mut rng = Rng::new(42);
    let w = Matrix::randn(o, n, &mut rng);
    let bl = BinaryLayer::quantize(&w);
    let vectors = collect_vectors(&bl, v);
    let (cb, assign, _) = BinaryCodebook::build(&vectors, v, c, 3);
    let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
    let ctx = EngineCtx::current();
    let xnor = BinaryGemmEngine::with_ctx(&bl, &ctx);
    let lut = LutGemmEngine::try_with_ctx(&cl, &ctx).expect("block aligned");
    // Scalar-lane twins of the same engines: the in-process baseline
    // for the SIMD speedup columns and the CI decode-throughput gate.
    let level = simd::active();
    let sctx = ctx.clone().with_level(Level::Scalar);
    let xnor_s = BinaryGemmEngine::with_ctx(&bl, &sctx);
    let lut_s = LutGemmEngine::try_with_ctx(&cl, &sctx).expect("block aligned");
    let wdense = bl.reconstruct();

    let budget = if quick { 150 } else { 500 };
    let ms: &[usize] = if quick { &[1, 8] } else { &[1, 2, 4, 8, 16, 32] };
    let threads = parallel::threads();
    let mut report = JsonReport::new("fig5");
    let mut t = Table::new(&[
        "M",
        "fp32 GEMM",
        "dequant+GEMM",
        "W1A16 sign",
        "W1A8 sign",
        "LUT-GEMM",
        "W1A8 LUT",
        "LUT vs dequant",
        "best vs scalar",
    ]);
    for &m in ms {
        let x = Matrix::randn(m, n, &mut rng);
        let fp = bench_for_ms("fp", budget, 5, || {
            black_box(dense::linear(&x, &wdense));
        });
        // Scalar fp lane: `dense::linear` dispatches on the global
        // level, so force it for this measurement only. `main` is the
        // only thread spawning work here, and the worker pool reads
        // the level per call, so the swap is race-free; restore the
        // exact prior level afterwards (it is always supported).
        let fp_s = {
            simd::set_level(Level::Scalar);
            let s = bench_for_ms("fp_scalar", budget, 5, || {
                black_box(dense::linear(&x, &wdense));
            });
            simd::set_level(level);
            s
        };
        let dq = bench_for_ms("dequant", budget, 5, || {
            black_box(dense::dequant_linear(&x, || cl.reconstruct()));
        });
        let sg = bench_for_ms("sign", budget, 5, || {
            black_box(xnor.forward(&x));
        });
        let sg_s = bench_for_ms("sign_scalar", budget, 5, || {
            black_box(xnor_s.forward(&x));
        });
        let lg = bench_for_ms("lut", budget, 5, || {
            black_box(lut.forward(&x));
        });
        let lg_s = bench_for_ms("lut_scalar", budget, 5, || {
            black_box(lut_s.forward(&x));
        });
        // W1A8 integer lanes, end to end: the per-row activation
        // quantization is inside the timed region because that is what
        // `Linear::forward` pays per call on the int path.
        let sg_i8 = bench_for_ms("sign_i8", budget, 5, || {
            let qa = QuantizedActs::quantize(&x, 8);
            black_box(xnor.forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols));
        });
        let lg_i8 = bench_for_ms("lut_i8", budget, 5, || {
            let qa = QuantizedActs::quantize(&x, 8);
            black_box(lut.forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols));
        });
        let speedup = dq.mean_ns() / lg.mean_ns();
        let best_simd = (fp_s.mean_ns() / fp.mean_ns())
            .max(sg_s.mean_ns() / sg.mean_ns())
            .max(lg_s.mean_ns() / lg.mean_ns());
        t.row(&[
            m.to_string(),
            format!("{:.2}ms", fp.mean_ms()),
            format!("{:.2}ms", dq.mean_ms()),
            format!("{:.2}ms", sg.mean_ms()),
            format!("{:.2}ms", sg_i8.mean_ms()),
            format!("{:.2}ms", lg.mean_ms()),
            format!("{:.2}ms", lg_i8.mean_ms()),
            format!("{speedup:.2}x"),
            format!("{best_simd:.2}x"),
        ]);
        // Scalar-lane and W1A8 numbers ride as extra FIELDS on the
        // same (m, threads)-keyed row — perf_compare keys rows on
        // those two, so adding fields (not rows) keeps old baselines
        // valid.
        let kv = [("m", m.to_string()),
                  ("fp_ms", format!("{:.4}", fp.mean_ms())),
                  ("dequant_ms", format!("{:.4}", dq.mean_ms())),
                  ("sign_ms", format!("{:.4}", sg.mean_ms())),
                  ("lut_ms", format!("{:.4}", lg.mean_ms())),
                  ("sign_i8_ms", format!("{:.4}", sg_i8.mean_ms())),
                  ("lut_i8_ms", format!("{:.4}", lg_i8.mean_ms())),
                  ("fp_scalar_ms", format!("{:.4}", fp_s.mean_ms())),
                  ("sign_scalar_ms", format!("{:.4}", sg_s.mean_ms())),
                  ("lut_scalar_ms", format!("{:.4}", lg_s.mean_ms())),
                  ("simd", level.name().to_string()),
                  ("threads", threads.to_string())];
        benchline("fig5", &kv);
        report.row(&kv);
        if m == 1 {
            let int8_speedup = sg.mean_ns() / sg_i8.mean_ns();
            println!(
                "decode (M=1): best vector-lane speedup vs scalar {best_simd:.2}x, \
                 W1A8 sign vs f32 sign {int8_speedup:.2}x (simd={})",
                level.name()
            );
            // CI perf-smoke gates (PALLAS_PERF_ASSERT=1, never tier-1):
            // on a vector-capable runner the decode path must beat the
            // scalar lanes by the ISSUE's 1.3x floor, and the W1A8
            // sign lane (quantize + i8 dot) must not lose to the f32
            // sign lane — conservative 1.05x floor, since the win
            // grows with width and this is the scaled-down shape.
            let gate = std::env::var("PALLAS_PERF_ASSERT").is_ok_and(|v| v == "1");
            if gate && level != Level::Scalar {
                anyhow::ensure!(
                    best_simd >= 1.3,
                    "decode speedup {best_simd:.2}x < 1.3x floor (simd={})",
                    level.name()
                );
                anyhow::ensure!(
                    int8_speedup >= 1.05,
                    "W1A8 decode speedup {int8_speedup:.2}x < 1.05x floor (simd={})",
                    level.name()
                );
            }
        }
    }
    println!("\nFigure 5 (kernel latency, {o}x{n}, v={v}, c={c}, {threads} threads)");
    t.print();
    let _ = report.write_if_enabled();

    // Memory panel — *measured* resident bytes of each engine's owned
    // buffers (not a shipping estimate; see eval::memory for the
    // accounted-vs-resident split).
    let mut mt = Table::new(&["format", "resident bytes", "vs fp32"]);
    let fp_bytes = o * n * 4;
    for (name, bytes) in [
        ("fp32 dense", fp_bytes),
        ("W1A16 packed", xnor.resident_bytes()),
        ("LUT codebook (idx+keys)", lut.resident_bytes()),
    ] {
        mt.row(&[name.to_string(), bytes.to_string(), format!("{:.1}x", fp_bytes as f64 / bytes as f64)]);
    }
    println!();
    mt.print();
    println!("\nExpected shape: LUT-GEMM avoids dequantization entirely (paper's 1.6x claim);");
    println!("sign-GEMM beats fp at small M; memory panel shows the >20x weight compression.");
    Ok(())
}
