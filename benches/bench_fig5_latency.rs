//! Figure 5 — kernel latency vs batch M on an MLP-shaped layer:
//! FP32 dense GEMM vs dequant-then-GEMM vs W1A16 sign-GEMM vs
//! Binary-Codebook LUT-GEMM, plus the weight-memory panel.
//!
//! The paper measures an H800 (8192x28672); we measure the same
//! *relative* curve on CPU at a scaled shape (1024x3584 default).
//! Headline claim to reproduce: LUT-GEMM >= 1.6x over the dequant
//! path at sub-1-bit, sign-GEMM competitive with FP at small M.

use btc_llm::benchsuite::quick_mode;
use btc_llm::engine::{dense, BinaryGemmEngine, LutGemmEngine};
use btc_llm::quant::binarize::BinaryLayer;
use btc_llm::quant::codebook::{collect_vectors, BinaryCodebook, CodebookLayer};
use btc_llm::tensor::Matrix;
use btc_llm::util::benchkit::{bench_for_ms, benchline, black_box, JsonReport, Table};
use btc_llm::util::parallel;
use btc_llm::util::rng::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    // MLP shape (out=3584, in=1024) ~ 1/8-scale of the paper's layer.
    let (o, n) = if quick { (896, 256) } else { (3584, 1024) };
    let v = 16usize;
    let c = 1 << 13; // 0.8125 index bits/weight
    let mut rng = Rng::new(42);
    let w = Matrix::randn(o, n, &mut rng);
    let bl = BinaryLayer::quantize(&w);
    let vectors = collect_vectors(&bl, v);
    let (cb, assign, _) = BinaryCodebook::build(&vectors, v, c, 3);
    let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
    let xnor = BinaryGemmEngine::new(&bl);
    let lut = LutGemmEngine::try_new(&cl).expect("block aligned");
    let wdense = bl.reconstruct();

    let budget = if quick { 150 } else { 500 };
    let ms: &[usize] = if quick { &[1, 8] } else { &[1, 2, 4, 8, 16, 32] };
    let threads = parallel::threads();
    let mut report = JsonReport::new("fig5");
    let mut t = Table::new(&["M", "fp32 GEMM", "dequant+GEMM", "W1A16 sign", "LUT-GEMM", "LUT vs dequant"]);
    for &m in ms {
        let x = Matrix::randn(m, n, &mut rng);
        let fp = bench_for_ms("fp", budget, 5, || {
            black_box(dense::linear(&x, &wdense));
        });
        let dq = bench_for_ms("dequant", budget, 5, || {
            black_box(dense::dequant_linear(&x, || cl.reconstruct()));
        });
        let sg = bench_for_ms("sign", budget, 5, || {
            black_box(xnor.forward(&x));
        });
        let lg = bench_for_ms("lut", budget, 5, || {
            black_box(lut.forward(&x));
        });
        let speedup = dq.mean_ns() / lg.mean_ns();
        t.row(&[
            m.to_string(),
            format!("{:.2}ms", fp.mean_ms()),
            format!("{:.2}ms", dq.mean_ms()),
            format!("{:.2}ms", sg.mean_ms()),
            format!("{:.2}ms", lg.mean_ms()),
            format!("{speedup:.2}x"),
        ]);
        let kv = [("m", m.to_string()),
                  ("fp_ms", format!("{:.4}", fp.mean_ms())),
                  ("dequant_ms", format!("{:.4}", dq.mean_ms())),
                  ("sign_ms", format!("{:.4}", sg.mean_ms())),
                  ("lut_ms", format!("{:.4}", lg.mean_ms())),
                  ("threads", threads.to_string())];
        benchline("fig5", &kv);
        report.row(&kv);
    }
    println!("\nFigure 5 (kernel latency, {o}x{n}, v={v}, c={c}, {threads} threads)");
    t.print();
    let _ = report.write_if_enabled();

    // Memory panel — *measured* resident bytes of each engine's owned
    // buffers (not a shipping estimate; see eval::memory for the
    // accounted-vs-resident split).
    let mut mt = Table::new(&["format", "resident bytes", "vs fp32"]);
    let fp_bytes = o * n * 4;
    for (name, bytes) in [
        ("fp32 dense", fp_bytes),
        ("W1A16 packed", xnor.resident_bytes()),
        ("LUT codebook (idx+keys)", lut.resident_bytes()),
    ] {
        mt.row(&[name.to_string(), bytes.to_string(), format!("{:.1}x", fp_bytes as f64 / bytes as f64)]);
    }
    println!();
    mt.print();
    println!("\nExpected shape: LUT-GEMM avoids dequantization entirely (paper's 1.6x claim);");
    println!("sign-GEMM beats fp at small M; memory panel shows the >20x weight compression.");
    Ok(())
}
