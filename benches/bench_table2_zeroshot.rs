//! Table 2 — zero-shot accuracy at 0.8 bits: FP16 vs STBLLM vs BTC on
//! the 7 probe tasks (synthetic analogs of Winogrande/OBQA/HellaSwag/
//! BoolQ/ARC-e/ARC-c/RTE — DESIGN.md §2).

use btc_llm::benchsuite::{eval_lane, load_workload, quick_mode};
use btc_llm::quant::pipeline::QuantConfig;
use btc_llm::util::benchkit::{benchline, Table};

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let models: &[&str] = if quick { &["tinylm_s"] } else { &["tinylm_m", "tinylm_l"] };
    let n = if quick { 20 } else { 64 };
    let lanes = [
        ("FP16", QuantConfig::fp16()),
        ("STBLLM", QuantConfig::stbllm(0.8)),
        ("BTC-LLM", QuantConfig::btc(0.8)),
    ];
    let mut table = Table::new(&[
        "Model", "Method", "W-Bits", "agree", "embed", "categ", "induc", "count", "brack",
        "adjor", "Average",
    ]);
    for model in models {
        let w = load_workload(model)?;
        for (label, cfg) in &lanes {
            let r = eval_lane(&w, cfg, 1200, Some(n))?;
            let mut cells = vec![
                r.model.clone(),
                label.to_string(),
                format!("{:.2}", r.bits_label),
            ];
            for (_, acc) in &r.per_task {
                cells.push(format!("{acc:.1}"));
            }
            cells.push(format!("{:.2}", r.mean_acc.unwrap_or(0.0)));
            table.row(&cells);
            benchline(
                "table2",
                &[
                    ("model", r.model.clone()),
                    ("method", r.method.clone()),
                    ("bits", format!("{:.2}", r.bits_label)),
                    ("mean_acc", format!("{:.2}", r.mean_acc.unwrap_or(0.0))),
                ],
            );
        }
    }
    println!("\nTable 2 (zero-shot accuracy %, higher is better)");
    table.print();
    println!("\nExpected shape: BTC > STBLLM at 0.8 bits on the mean; both below FP16.");
    Ok(())
}
