//! Table 1 / Figure 3 — WikiText2-analog perplexity across methods ×
//! bit-widths × model sizes (TinyLM family standing in for LLaMA;
//! DESIGN.md §2). Prints the paper-shaped table plus BENCHLINE rows.
//!
//! Columns: nominal W-bits (the paper's label), payload bits (honest
//! signs/indices/masks — exposing STBLLM's mask overhead, the paper's
//! intro critique) and perplexity.

use btc_llm::benchsuite::{eval_lane, fmt_ppl, load_workload, quick_mode};
use btc_llm::quant::pipeline::QuantConfig;
use btc_llm::util::benchkit::{benchline, Table};

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let models: &[&str] =
        if quick { &["tinylm_s"] } else { &["tinylm_s", "tinylm_m", "tinylm_l"] };
    let eval_tokens = if quick { 1500 } else { 4000 };

    let lanes: Vec<(String, QuantConfig)> = {
        let mut v: Vec<(String, QuantConfig)> = vec![
            ("FP16".into(), QuantConfig::fp16()),
            ("FP-VQ@2b (QuIP#/VPTQ/GPTVQ lane)".into(), QuantConfig::fpvq(2.0)),
            ("BiLLM".into(), QuantConfig::billm()),
            ("ARB-LLM".into(), QuantConfig::arb_llm()),
            ("BTC-LLM@1.11".into(), QuantConfig::btc(1.11)),
        ];
        for bits in [0.9, 0.8, 0.7] {
            v.push((format!("FP-VQ@{bits}"), QuantConfig::fpvq(bits)));
            v.push((format!("STBLLM@{bits}"), QuantConfig::stbllm(bits)));
            v.push((format!("BTC-LLM@{bits}"), QuantConfig::btc(bits)));
        }
        if quick {
            v.retain(|(n, _)| !n.starts_with("FP-VQ@0"));
        }
        v
    };

    let mut table = Table::new(&["Method", "W-Bits", "payload", "model", "PPL", "quant(s)"]);
    for model in models {
        let w = load_workload(model)?;
        for (label, cfg) in &lanes {
            let r = eval_lane(&w, cfg, eval_tokens, None)?;
            table.row(&[
                label.clone(),
                format!("{:.2}", r.bits_label),
                format!("{:.2}", r.payload_bits),
                r.model.clone(),
                fmt_ppl(r.ppl),
                format!("{:.1}", r.quant_secs),
            ]);
            benchline(
                "table1",
                &[
                    ("model", r.model.clone()),
                    ("method", r.method.clone()),
                    ("bits", format!("{:.2}", r.bits_label)),
                    ("payload_bits", format!("{:.3}", r.payload_bits)),
                    ("ppl", format!("{:.4}", r.ppl)),
                ],
            );
        }
    }
    println!("\nTable 1 (PPL, lower is better) — Fig. 3 is the BTC/STBLLM/FP-VQ PPL-vs-bits series");
    table.print();
    println!("\nExpected shape vs paper: BTC@1.11 < BiLLM/ARB; BTC degrades gracefully to 0.7;");
    println!("FP-VQ collapses sub-1-bit; STBLLM's nominal bits hide >1.0 payload (mask overhead).");
    Ok(())
}
