"""L2 model: shapes, invariances, QAT binarization, quantized-linear path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.model import (
    CONFIGS, LINEAR_NAMES, ModelConfig, apply_rope, binarize_params,
    binarize_ste, forward, init_params, loss_fn, quantized_linear, rmsnorm,
    rope_angles,
)


@pytest.fixture(scope="module")
def small():
    cfg = CONFIGS["tinylm_s"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_param_count_matches_init(small):
    cfg, params = small
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == cfg.param_count()


def test_forward_shape_and_finite(small):
    cfg, params = small
    toks = jnp.zeros((2, 16), jnp.int32)
    logits = forward(cfg, params, toks)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_forward_causality(small):
    """Changing a future token must not change past logits."""
    cfg, params = small
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, 96, size=(1, 12)).astype(np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 1) % 96
    l1 = forward(cfg, params, jnp.asarray(t1))
    l2 = forward(cfg, params, jnp.asarray(t2))
    assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), rtol=2e-4, atol=2e-4)


def test_gqa_forward_shape():
    cfg = CONFIGS["tinyqwen_s"]
    assert cfg.n_kv_head != cfg.n_head
    params = init_params(cfg, jax.random.PRNGKey(1))
    logits = forward(cfg, params, jnp.zeros((1, 8), jnp.int32))
    assert logits.shape == (1, 8, cfg.vocab)


def test_rope_preserves_norm():
    cfg = CONFIGS["tinylm_s"]
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 8, 3, cfg.head_dim)), jnp.float32)
    y = apply_rope(x, rope_angles(cfg, 8))
    assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_position_zero_identity():
    cfg = CONFIGS["tinylm_s"]
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 4, 2, cfg.head_dim)), jnp.float32)
    y = apply_rope(x, rope_angles(cfg, 4))
    assert_allclose(np.asarray(y[:, 0]), np.asarray(x[:, 0]), rtol=1e-6)


def test_rmsnorm_unit_scale():
    x = jnp.asarray([[3.0, 4.0]])
    y = rmsnorm(x, jnp.ones(2))
    # rms of y must be ~1
    assert abs(float(jnp.sqrt(jnp.mean(y * y))) - 1.0) < 1e-3


def test_loss_decreases_on_repeated_data(small):
    """One-batch overfit sanity: a few Adam steps reduce the loss."""
    from compile.train import adam_init, train_step
    cfg, params = small
    toks = jnp.asarray(np.tile(np.arange(33, dtype=np.int32) % 90, (4, 1)))
    opt = adam_init(params)
    l0 = float(loss_fn(cfg, params, toks))
    p = params
    for _ in range(10):
        p, opt, loss, _ = train_step(cfg, p, opt, toks, total_steps=10)
    assert float(loss) < l0


def test_binarize_ste_is_row_binary():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(6, 10)), jnp.float32)
    wb = binarize_ste(w)
    vals = np.asarray(wb)
    for r in range(6):
        uniq = np.unique(np.abs(vals[r]))
        assert len(uniq) == 1  # alpha_r * (+-1)


def test_binarize_params_only_linears(small):
    cfg, params = small
    bp = binarize_params(params)
    assert np.array_equal(np.asarray(bp["emb"]), np.asarray(params["emb"]))
    w = np.asarray(bp["l0.wq"])
    assert len(np.unique(np.abs(w[0]))) == 1


def test_quantized_linear_binary_matches_dense(small):
    cfg, params = small
    w = params["l0.wq"]
    alpha = jnp.mean(jnp.abs(w), axis=1)
    b = jnp.sign(jnp.where(w == 0, 1.0, w))
    mu = jnp.zeros(w.shape[0])
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 5, cfg.d_model)), jnp.float32)
    qw = {"kind": "binary", "b": b, "alpha": alpha, "mu": mu}
    y = quantized_linear(x, qw)
    want = ref.binary_gemm_ref(x.reshape(10, -1), b, alpha, mu).reshape(2, 5, -1)
    assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-4)
