"""AOT artifact sanity: manifest contents and HLO-text invariants.

Runs against artifacts/ when present (i.e. after `make artifacts`);
skips otherwise so the suite works on a clean checkout.
"""

import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.txt")),
    reason="artifacts not built (run `make artifacts`)",
)


def test_manifest_lists_expected_artifacts():
    lines = open(os.path.join(ART, "manifest.txt")).read().splitlines()
    names = {l.split()[0] for l in lines}
    for expected in [
        "corpus_train.txt", "corpus_eval.txt", "tinylm_s.bin", "tinylm_m.bin",
        "tinylm_l.bin", "tinyqwen_s.bin", "tinyqwen_m.bin", "fbi_s.bin",
        "binary_gemm.hlo.txt", "lut_gemm.hlo.txt", "tinylm_s_fwd.hlo.txt",
    ]:
        assert expected in names, f"missing {expected}"
    for name in names:
        assert os.path.exists(os.path.join(ART, name)), name


def test_hlo_text_constants_not_elided():
    """Regression: the default printer elides big constants as `{...}`,
    which the Rust-side parser reads as garbage (zeros). All artifacts
    must be printed with print_large_constants=True."""
    for name in ["binary_gemm.hlo.txt", "lut_gemm.hlo.txt", "tinylm_s_fwd.hlo.txt"]:
        text = open(os.path.join(ART, name)).read()
        assert "constant({...})" not in text, f"{name} has elided constants"


def test_hlo_entry_signature():
    """tinylm_s_fwd takes tokens + 29 sorted tensors (the documented
    calling convention for the Rust runtime)."""
    text = open(os.path.join(ART, "tinylm_s_fwd.hlo.txt")).read()
    # entry layout: tokens (s32) + 29 f32 tensors.
    entry = text.splitlines()[0]
    assert entry.startswith("HloModule")
    assert "s32[1,32]" in entry  # tokens arg first
    n_args = entry.split("->")[0].count("f32[") + entry.split("->")[0].count("s32[")
    assert n_args == 30, f"expected 30 entry args, got {n_args}"


def test_fbi_weights_are_binary():
    """The FBI analog ships natively-binary linear weights."""
    import numpy as np
    from compile import blob

    cfg, params = blob.load(os.path.join(ART, "fbi_s.bin"))
    w = np.asarray(params["l0.wq"])
    # every row: exactly two magnitudes (+a, -a)
    for r in range(0, w.shape[0], 16):
        mags = np.abs(w[r])
        spread = mags.max() - mags.min()
        assert spread <= 1e-6 * mags.max(), f"row {r} not binary: spread {spread}"


def test_trained_models_better_than_chance():
    """Each trained blob's final loss must be far below ln(128)=4.85."""
    import glob

    for path in glob.glob(os.path.join(ART, "train_metrics_*.txt")):
        lines = [l for l in open(path).read().splitlines() if not l.startswith("#")]
        final = float(lines[-1].split()[1])
        assert final < 2.5, f"{os.path.basename(path)}: final loss {final}"
