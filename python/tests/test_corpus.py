"""Corpus generator: determinism, ASCII-only, structural guarantees."""

from compile import corpus


def test_deterministic():
    assert corpus.generate(5000, seed=7) == corpus.generate(5000, seed=7)


def test_seed_changes_output():
    assert corpus.generate(5000, seed=7) != corpus.generate(5000, seed=8)


def test_ascii_vocab_bound():
    text = corpus.generate(20000, seed=42)
    assert all(ord(ch) < 128 for ch in text)
    assert len(text) >= 20000


def test_sentences_terminated():
    text = corpus.generate(10000, seed=42)
    for line in text.strip().split("\n"):
        assert line.endswith("."), line


def test_agreement_morphology_present():
    """Both singular and plural agreement forms must occur (needed by the
    zero-shot agreement probe)."""
    text = corpus.generate(50000, seed=42)
    assert "the cat " in text or "the dog " in text
    assert " run ." in text and " runs ." in text


def test_category_facts_consistent():
    """'X is an animal' only for animal nouns."""
    text = corpus.generate(80000, seed=42)
    for line in text.split("\n"):
        if " is an animal" in line:
            noun = line.split()[1]
            assert noun in corpus.ANIMALS


def test_brackets_balanced():
    text = corpus.generate(50000, seed=42)
    for line in text.split("\n"):
        if line.startswith("("):
            depth = 0
            for tok in line.split():
                if tok == "(":
                    depth += 1
                elif tok == ")":
                    depth -= 1
                assert depth >= 0
            assert depth == 0


def test_splitmix_matches_reference_vector():
    """Pin the PRNG so rust/src/util/rng.rs and corpus.py can never drift."""
    rng = corpus.SplitMix64(42)
    got = [rng.next_u64() for _ in range(3)]
    assert got == [
        13679457532755275413,
        2949826092126892291,
        5139283748462763858,
    ]
