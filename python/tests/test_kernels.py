"""L1 kernel correctness: Pallas kernels vs pure-jnp oracle.

hypothesis sweeps shapes/dtypes; assert_allclose against ref.py — this
is the CORE correctness signal for the compute hot-spot.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import binary_gemm, codebook_keys, lut_gemm, pattern_matrix, ref

SETTINGS = dict(max_examples=25, deadline=None)


def make_inputs(rng, m, n, o, dtype):
    x = rng.normal(size=(m, n)).astype(dtype)
    b = rng.choice([-1.0, 1.0], size=(o, n)).astype(dtype)
    alpha = rng.uniform(0.2, 2.0, size=o).astype(dtype)
    mu = (rng.normal(size=o) * 0.1).astype(dtype)
    return jnp.asarray(x), jnp.asarray(b), jnp.asarray(alpha), jnp.asarray(mu)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 16),
    n=st.sampled_from([8, 32, 96, 128]),
    o=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
    row_tile=st.sampled_from([4, 16, 128]),
)
def test_binary_gemm_matches_ref(m, n, o, seed, row_tile):
    rng = np.random.default_rng(seed)
    x, b, alpha, mu = make_inputs(rng, m, n, o, np.float32)
    got = binary_gemm(x, b, alpha, mu, row_tile=row_tile)
    want = ref.binary_gemm_ref(x, b, alpha, mu)
    assert got.shape == (m, o)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(np.float32, 1e-4), (jnp.bfloat16, 0.5)])
def test_binary_gemm_dtypes(dtype, tol):
    rng = np.random.default_rng(0)
    x, b, alpha, mu = make_inputs(rng, 4, 64, 32, np.float32)
    x = x.astype(dtype)
    got = binary_gemm(x, b.astype(dtype), alpha.astype(dtype), mu.astype(dtype))
    want = ref.binary_gemm_ref(x, b, alpha, mu)
    assert got.dtype == x.dtype
    assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@settings(**SETTINGS)
@given(
    m=st.integers(1, 12),
    nb=st.integers(1, 8),
    v=st.sampled_from([4, 8, 16, 20]),
    o=st.integers(1, 64),
    c=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_gemm_matches_ref(m, nb, v, o, c, seed):
    rng = np.random.default_rng(seed)
    n = nb * v
    x = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    cb = jnp.asarray(rng.choice([-1.0, 1.0], size=(c, v)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, c, size=(o, nb)), jnp.int32)
    alpha = jnp.asarray(rng.uniform(0.2, 2.0, size=o), jnp.float32)
    mu = jnp.asarray(rng.normal(size=o) * 0.1, jnp.float32)
    mu_bits = 4 if v % 4 == 0 else v  # v=20 -> mu=4 works (20 % 4 == 0)
    got = lut_gemm(x, cb, idx, alpha, mu, mu_bits=mu_bits, row_tile=16)
    want = ref.lut_gemm_ref(x, cb, idx, alpha, mu)
    assert got.shape == (m, o)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


@settings(**SETTINGS)
@given(
    v=st.sampled_from([4, 8, 12, 16, 20]),
    mu_bits=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_twostage_ref_equals_dense_ref(v, mu_bits, seed):
    """The staged LUT formulation is algebraically identical to the dense
    reconstruction — the invariant the Rust engine relies on."""
    if v % mu_bits:
        return
    rng = np.random.default_rng(seed)
    m, nb, o, c = 3, 4, 16, 9
    x = jnp.asarray(rng.normal(size=(m, nb * v)), jnp.float32)
    cb = jnp.asarray(rng.choice([-1.0, 1.0], size=(c, v)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, c, size=(o, nb)), jnp.int32)
    alpha = jnp.asarray(rng.uniform(0.2, 2.0, size=o), jnp.float32)
    mu = jnp.asarray(rng.normal(size=o) * 0.1, jnp.float32)
    staged = ref.lut_gemm_twostage_ref(x, cb, idx, alpha, mu, mu_bits=mu_bits)
    dense = ref.lut_gemm_ref(x, cb, idx, alpha, mu)
    assert_allclose(np.asarray(staged), np.asarray(dense), rtol=1e-4, atol=1e-3)


def test_pattern_matrix_and_keys_roundtrip():
    """key[k,p] must decode back to the codebook's sign pattern."""
    pat = pattern_matrix(4)
    assert pat.shape == (16, 4)
    rng = np.random.default_rng(1)
    cb = jnp.asarray(rng.choice([-1.0, 1.0], size=(13, 16)), jnp.float32)
    keys = codebook_keys(cb, 4)
    assert keys.shape == (13, 4)
    # Decode: pattern_matrix[key] per segment == codebook segment.
    dec = np.asarray(pat)[np.asarray(keys)].reshape(13, 16)
    assert np.array_equal(dec, np.asarray(cb))


def test_lut_gemm_rejects_bad_shapes():
    x = jnp.zeros((2, 10))
    cb = jnp.ones((4, 4))
    idx = jnp.zeros((3, 2), jnp.int32)
    with pytest.raises(AssertionError):
        lut_gemm(x, cb, idx, jnp.ones(3), jnp.zeros(3))  # 2*4 != 10
