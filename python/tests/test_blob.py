"""TLM1 weight-blob format roundtrip (python writer side)."""

import jax
import numpy as np

from compile import blob
from compile.model import CONFIGS, init_params


def test_roundtrip(tmp_path):
    cfg = CONFIGS["tinylm_s"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "m.bin")
    blob.save(path, cfg, params)
    cfg2, params2 = blob.load(path)
    assert (cfg2.vocab, cfg2.d_model, cfg2.n_layer) == (cfg.vocab, cfg.d_model, cfg.n_layer)
    assert (cfg2.n_head, cfg2.n_kv_head, cfg2.d_ff) == (cfg.n_head, cfg.n_kv_head, cfg.d_ff)
    assert abs(cfg2.rope_theta - cfg.rope_theta) < 1e-3
    assert set(params2) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k], np.float32), params2[k])


def test_header_layout(tmp_path):
    """Byte-level header pin so rust/src/io/weights.rs cannot drift."""
    cfg = CONFIGS["tinylm_s"]
    params = {"emb": np.zeros((2, 3), np.float32)}
    path = str(tmp_path / "h.bin")
    blob.save(path, cfg, params)
    raw = open(path, "rb").read()
    assert raw[:4] == b"TLM1"
    import struct
    ver, vocab, d, nl, nh, nkv, dff, mseq = struct.unpack_from("<8I", raw, 4)
    assert (ver, vocab, d) == (1, cfg.vocab, cfg.d_model)
    (nt,) = struct.unpack_from("<I", raw, 40)
    assert nt == 1
