"""L2: TinyLM — the JAX model whose linear layers are the quantization
targets, used both to TRAIN the build-time workload models and to lower
the AOT inference graphs (fp + kernel-backed quantized variants).

Architecture: pre-norm decoder-only transformer (LLaMA-style):
RMSNorm, RoPE, multi-head attention with optional GQA (the "TinyQwen"
family), SwiGLU FFN, tied input/output embedding.

Params are a flat dict[str, jnp.ndarray] with the SAME tensor names the
Rust side reads from the TLM1 weight blob (io/weights.rs):
  emb (vocab, d), lnf (d,), and per layer i:
  l{i}.ln1, l{i}.wq (d, d), l{i}.wk (kv_dim, d), l{i}.wv (kv_dim, d),
  l{i}.wo (d, d), l{i}.ln2, l{i}.wgate (ff, d), l{i}.wup (ff, d),
  l{i}.wdown (d, ff).
All linears are stored (out, in), applied as y = x @ W^T.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import binary_gemm, lut_gemm


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 128
    d_model: int = 128
    n_layer: int = 4
    n_head: int = 4
    n_kv_head: int = 4
    d_ff: int = 344
    max_seq: int = 128
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_head * self.head_dim

    def param_count(self, params=None) -> int:
        per_layer = (
            self.d_model * self.d_model * 2
            + self.kv_dim * self.d_model * 2
            + 3 * self.d_model * self.d_ff
            + 2 * self.d_model
        )
        return self.vocab * self.d_model + self.n_layer * per_layer + self.d_model


# The model zoo. Sizes are scaled so that training + the full bench grid
# run on a single CPU core (DESIGN.md §2); "tinyllama" mirrors the LLaMA
# rows of Tables 1-2, "tinyqwen" (GQA) mirrors the Qwen rows of Table 5,
# "fbi" is the QAT-binary FBI-LLM analog of Table 4.
CONFIGS = {
    "tinylm_s": ModelConfig("tinylm_s", d_model=96, n_layer=3, n_head=3, n_kv_head=3, d_ff=256),
    "tinylm_m": ModelConfig("tinylm_m", d_model=128, n_layer=4, n_head=4, n_kv_head=4, d_ff=344),
    "tinylm_l": ModelConfig("tinylm_l", d_model=192, n_layer=6, n_head=6, n_kv_head=6, d_ff=512),
    "tinyqwen_s": ModelConfig("tinyqwen_s", d_model=128, n_layer=4, n_head=4, n_kv_head=2, d_ff=320),
    "tinyqwen_m": ModelConfig("tinyqwen_m", d_model=160, n_layer=5, n_head=5, n_kv_head=1, d_ff=416),
    "fbi_s": ModelConfig("fbi_s", d_model=96, n_layer=3, n_head=3, n_kv_head=3, d_ff=256),
}

LINEAR_NAMES = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"]


def init_params(cfg: ModelConfig, key) -> dict:
    """LLaMA-style init: normal(0, 0.02sqrt-scaled) for linears."""
    params = {}
    keys = jax.random.split(key, 2 + cfg.n_layer)
    params["emb"] = jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02
    params["lnf"] = jnp.ones((cfg.d_model,))
    for i in range(cfg.n_layer):
        lk = jax.random.split(keys[2 + i], 7)
        s = 0.02
        so = 0.02 / jnp.sqrt(2.0 * cfg.n_layer)  # scaled residual-out init
        params[f"l{i}.ln1"] = jnp.ones((cfg.d_model,))
        params[f"l{i}.ln2"] = jnp.ones((cfg.d_model,))
        params[f"l{i}.wq"] = jax.random.normal(lk[0], (cfg.d_model, cfg.d_model)) * s
        params[f"l{i}.wk"] = jax.random.normal(lk[1], (cfg.kv_dim, cfg.d_model)) * s
        params[f"l{i}.wv"] = jax.random.normal(lk[2], (cfg.kv_dim, cfg.d_model)) * s
        params[f"l{i}.wo"] = jax.random.normal(lk[3], (cfg.d_model, cfg.d_model)) * so
        params[f"l{i}.wgate"] = jax.random.normal(lk[4], (cfg.d_ff, cfg.d_model)) * s
        params[f"l{i}.wup"] = jax.random.normal(lk[5], (cfg.d_ff, cfg.d_model)) * s
        params[f"l{i}.wdown"] = jax.random.normal(lk[6], (cfg.d_model, cfg.d_ff)) * so
    return params


def rmsnorm(x, w, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rope_angles(cfg: ModelConfig, seq: int):
    """(seq, head_dim/2) rotation angles, computed with NUMPY so they
    embed as literal constants in the lowered HLO. (XLA's own cos/sin
    lose accuracy for large arguments in the pinned xla_extension 0.5.1
    the Rust runtime uses — table precomputation sidesteps that and is
    standard practice anyway.)"""
    hd = cfg.head_dim
    inv = cfg.rope_theta ** (-np.arange(0, hd, 2, dtype=np.float64) / hd)
    pos = np.arange(seq, dtype=np.float64)
    return pos[:, None] * inv[None, :]


def apply_rope(x, ang):
    """x: (..., seq, n_head, head_dim); rotate pairs (even, odd) halves.

    Uses the "split-half" convention (first half = real, second half =
    imag), matching rust/src/model/rope.rs.
    """
    hd = x.shape[-1]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    cos = jnp.asarray(np.cos(ang), x.dtype)[None, :, None, :]
    sin = jnp.asarray(np.sin(ang), x.dtype)[None, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _linear(x, w):
    return x @ w.T


def attention(cfg: ModelConfig, params, i, x):
    """Causal self-attention with optional GQA. x: (b, s, d)."""
    b, s, d = x.shape
    hd = cfg.head_dim
    q = _linear(x, params[f"l{i}.wq"]).reshape(b, s, cfg.n_head, hd)
    k = _linear(x, params[f"l{i}.wk"]).reshape(b, s, cfg.n_kv_head, hd)
    v = _linear(x, params[f"l{i}.wv"]).reshape(b, s, cfg.n_kv_head, hd)
    ang = rope_angles(cfg, s)
    q = apply_rope(q, ang)
    k = apply_rope(k, ang)
    if cfg.n_kv_head != cfg.n_head:
        rep = cfg.n_head // cfg.n_kv_head
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
    return _linear(out, params[f"l{i}.wo"])


def ffn(cfg: ModelConfig, params, i, x):
    g = _linear(x, params[f"l{i}.wgate"])
    u = _linear(x, params[f"l{i}.wup"])
    return _linear(jax.nn.silu(g) * u, params[f"l{i}.wdown"])


def forward(cfg: ModelConfig, params, tokens):
    """tokens: (b, s) int32 -> logits (b, s, vocab). FP path."""
    x = params["emb"][tokens]
    for i in range(cfg.n_layer):
        x = x + attention(cfg, params, i, rmsnorm(x, params[f"l{i}.ln1"]))
        x = x + ffn(cfg, params, i, rmsnorm(x, params[f"l{i}.ln2"]))
    x = rmsnorm(x, params["lnf"])
    return x @ params["emb"].T  # tied embedding


def loss_fn(cfg: ModelConfig, params, tokens):
    """Next-token cross-entropy. tokens: (b, s+1)."""
    logits = forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


# ---------------------------------------------------------------------------
# QAT-lite (FBI-LLM analog, Table 4): straight-through binary weights.
# ---------------------------------------------------------------------------

def binarize_ste(w):
    """Row-wise alpha*sign(w) with straight-through gradient."""
    alpha = jnp.mean(jnp.abs(w), axis=1, keepdims=True)
    wb = alpha * jnp.sign(jnp.where(w == 0, 1.0, w))
    return w + jax.lax.stop_gradient(wb - w)


def binarize_params(params):
    """Apply STE binarization to every linear weight (not norms/emb)."""
    out = dict(params)
    for name, w in params.items():
        if any(name.endswith("." + ln) for ln in LINEAR_NAMES):
            out[name] = binarize_ste(w)
    return out


def loss_fn_qat(cfg: ModelConfig, params, tokens):
    return loss_fn(cfg, binarize_params(params), tokens)


# ---------------------------------------------------------------------------
# Quantized forward using the L1 kernels (python-side validation + the
# AOT parity graphs; the deployed path is the Rust engine).
# ---------------------------------------------------------------------------

def quantized_linear(x, qw):
    """Apply one quantized linear. qw is a dict with kind 'binary'
    {b, alpha, mu} or 'codebook' {codebook, idx, alpha, mu}."""
    b, s, n = x.shape
    x2 = x.reshape(b * s, n)
    if qw["kind"] == "binary":
        y = binary_gemm(x2, qw["b"], qw["alpha"], qw["mu"])
    elif qw["kind"] == "codebook":
        y = lut_gemm(x2, qw["codebook"], qw["idx"], qw["alpha"], qw["mu"])
    else:
        raise ValueError(qw["kind"])
    return y.reshape(b, s, -1)


def quantized_ffn(cfg: ModelConfig, qparams, i, x):
    g = quantized_linear(x, qparams[f"l{i}.wgate"])
    u = quantized_linear(x, qparams[f"l{i}.wup"])
    return quantized_linear(jax.nn.silu(g) * u, qparams[f"l{i}.wdown"])
