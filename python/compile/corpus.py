"""Synthetic "tinywiki" PCFG corpus generator.

Stand-in for WikiText2 in the offline reproduction (see DESIGN.md §2).
A deterministic probabilistic grammar over English-like sentences with
enough latent structure (number agreement, embedded clauses, category
facts, induction patterns, balanced brackets) that (a) a tiny LM learns
non-trivial statistics and (b) the 7 zero-shot probe tasks have
well-defined correct/distractor continuations.

Pure-python, stdlib-free randomness via SplitMix64 so the corpus is
bit-reproducible across machines (and re-implementable in Rust).
"""

from __future__ import annotations


class SplitMix64:
    """Tiny deterministic PRNG (same algorithm as rust/src/util/rng.rs)."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = seed & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def choice(self, xs):
        return xs[self.below(len(xs))]

    def uniform(self) -> float:
        return self.next_u64() / float(1 << 64)


# (singular, plural) noun pairs — regular morphology only, so the
# agreement probe is learnable by a byte-level model.
NOUNS = [
    ("cat", "cats"), ("dog", "dogs"), ("bird", "birds"), ("fox", "foxes"),
    ("cow", "cows"), ("frog", "frogs"), ("crab", "crabs"), ("hen", "hens"),
    ("rock", "rocks"), ("lamp", "lamps"), ("door", "doors"), ("cup", "cups"),
    ("box", "boxes"), ("car", "cars"), ("ship", "ships"), ("coin", "coins"),
]
ANIMALS = {"cat", "dog", "bird", "fox", "cow", "frog", "crab", "hen"}
# (3rd-sg, plural) verb pairs.
VERBS = [
    ("runs", "run"), ("sleeps", "sleep"), ("jumps", "jump"),
    ("sings", "sing"), ("hides", "hide"), ("waits", "wait"),
    ("turns", "turn"), ("falls", "fall"),
]
ADJS = ["big", "small", "red", "blue", "old", "new", "slow", "fast"]
PLACES = ["barn", "lake", "hill", "road", "town", "yard", "cave", "dock"]
NUMBER_WORDS = ["one", "two", "three", "four", "five", "six", "seven", "eight"]


def noun_phrase(rng: SplitMix64, plural: bool) -> str:
    noun = rng.choice(NOUNS)[1 if plural else 0]
    if rng.uniform() < 0.4:
        return f"the {rng.choice(ADJS)} {noun}"
    return f"the {noun}"


def sent_agreement(rng: SplitMix64) -> str:
    """the (adj) cat runs . / the (adj) cats run ."""
    plural = rng.uniform() < 0.5
    verb = rng.choice(VERBS)[1 if plural else 0]
    return f"{noun_phrase(rng, plural)} {verb} ."


def sent_embedded(rng: SplitMix64) -> str:
    """long-range agreement across an embedded clause."""
    plural = rng.uniform() < 0.5
    inner = rng.choice(NOUNS)[0]
    verb = rng.choice(VERBS)[1 if plural else 0]
    head = rng.choice(NOUNS)[1 if plural else 0]
    return f"the {head} that sees the {inner} {verb} ."


def sent_category(rng: SplitMix64) -> str:
    """category facts: animals are animals, the rest are objects."""
    noun_sg = rng.choice(NOUNS)[0]
    kind = "animal" if noun_sg in ANIMALS else "object"
    return f"the {noun_sg} is an {kind} ." if kind == "animal" else f"the {noun_sg} is an object ."


def sent_place(rng: SplitMix64) -> str:
    plural = rng.uniform() < 0.3
    verb = rng.choice(VERBS)[1 if plural else 0]
    return f"{noun_phrase(rng, plural)} {verb} near the {rng.choice(PLACES)} ."


def sent_counting(rng: SplitMix64) -> str:
    """one two three ... — order structure for the order probe."""
    start = rng.below(4)
    ln = 3 + rng.below(4)
    return " ".join(NUMBER_WORDS[start:start + ln]) + " ."


def sent_induction(rng: SplitMix64) -> str:
    """A B ... A B — repeated bigram, for the induction probe."""
    a = rng.choice(NOUNS)[0]
    b = rng.choice(PLACES)
    mid = rng.choice(ADJS)
    return f"{a} {b} {mid} {a} {b} ."


def sent_brackets(rng: SplitMix64) -> str:
    """balanced brackets over letters."""
    depth = 1 + rng.below(2)
    letters = "abcdefgh"
    out = []
    for _ in range(depth):
        out.append("(")
        out.append(letters[rng.below(8)])
    out.append(letters[rng.below(8)])
    out.extend(")" * depth)
    return " ".join(out) + " ."


SENTENCE_KINDS = [
    (sent_agreement, 0.30),
    (sent_embedded, 0.12),
    (sent_category, 0.15),
    (sent_place, 0.18),
    (sent_counting, 0.10),
    (sent_induction, 0.08),
    (sent_brackets, 0.07),
]


def sentence(rng: SplitMix64) -> str:
    u = rng.uniform()
    acc = 0.0
    for fn, w in SENTENCE_KINDS:
        acc += w
        if u < acc:
            return fn(rng)
    return sent_agreement(rng)


def generate(n_chars: int, seed: int = 42) -> str:
    """Generate roughly n_chars of corpus text (newline-joined sentences)."""
    rng = SplitMix64(seed)
    parts = []
    total = 0
    while total < n_chars:
        s = sentence(rng)
        parts.append(s)
        total += len(s) + 1
    return "\n".join(parts) + "\n"


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--train-chars", type=int, default=400_000)
    ap.add_argument("--eval-chars", type=int, default=40_000)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    import os

    os.makedirs(args.out_dir, exist_ok=True)
    train = generate(args.train_chars, seed=42)
    evaltxt = generate(args.eval_chars, seed=1042)  # disjoint stream
    with open(os.path.join(args.out_dir, "corpus_train.txt"), "w") as f:
        f.write(train)
    with open(os.path.join(args.out_dir, "corpus_eval.txt"), "w") as f:
        f.write(evaltxt)
    print(f"corpus: train={len(train)} eval={len(evaltxt)} chars")


if __name__ == "__main__":
    main()
