"""TLM1 weight-blob format — the interchange between the L2 trainer and
the Rust coordinator (rust/src/io/weights.rs reads this).

Layout (little-endian):
  magic  b"TLM1"
  u32    version (=1)
  u32    vocab, d_model, n_layer, n_head, n_kv_head, d_ff, max_seq
  f32    rope_theta
  u32    n_tensors
  per tensor:
    u32  name_len; name utf-8 bytes
    u32  ndim; u32 dims[ndim]
    f32  data (row-major)
"""

import struct

import numpy as np

from .model import CONFIGS, ModelConfig

MAGIC = b"TLM1"


def save(path: str, cfg: ModelConfig, params: dict) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<8I", 1, cfg.vocab, cfg.d_model, cfg.n_layer,
                            cfg.n_head, cfg.n_kv_head, cfg.d_ff, cfg.max_seq))
        f.write(struct.pack("<f", cfg.rope_theta))
        names = sorted(params.keys())
        f.write(struct.pack("<I", len(names)))
        for name in names:
            arr = np.asarray(params[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes(order="C"))


def load(path: str):
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == MAGIC, "bad magic"
    off = 4
    ver, vocab, d, nl, nh, nkv, dff, mseq = struct.unpack_from("<8I", data, off)
    off += 32
    (theta,) = struct.unpack_from("<f", data, off)
    off += 4
    assert ver == 1
    cfg = ModelConfig("loaded", vocab, d, nl, nh, nkv, dff, mseq, theta)
    (nt,) = struct.unpack_from("<I", data, off)
    off += 4
    params = {}
    for _ in range(nt):
        (ln,) = struct.unpack_from("<I", data, off)
        off += 4
        name = data[off : off + ln].decode()
        off += ln
        (ndim,) = struct.unpack_from("<I", data, off)
        off += 4
        dims = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(data, np.float32, n, off).reshape(dims)
        off += 4 * n
        params[name] = arr
    return cfg, params
