# L1: Pallas kernels for the paper's compute hot-spot.
from .binary_gemm import binary_gemm
from .lut_gemm import codebook_keys, lut_gemm, pattern_matrix
from . import ref

__all__ = ["binary_gemm", "lut_gemm", "codebook_keys", "pattern_matrix", "ref"]
