"""W1A16 sign-GEMM Pallas kernel.

Computes y = x @ (alpha * B + mu)^T for a binarized weight matrix
B in {-1,+1}^{o x n} with per-output-row scale alpha and bias mu,
WITHOUT materializing the dequantized weight: the kernel contracts x
against the ±1 matrix (addition/subtraction on real hardware; the MXU
bf16 path on TPU) and folds alpha/mu in afterwards:

    y[i, r] = alpha[r] * <x[i], B[r]> + mu[r] * sum(x[i]).

HARDWARE NOTE (DESIGN.md §Hardware-Adaptation): on GPU the paper packs
bits into shared memory and uses add/sub; on TPU the profitable mapping
is a bf16 MXU matmul against the ±1 matrix with the scale fused on the
VPU. Grid tiles over output rows so the B tile lives in VMEM.

Pallas is ALWAYS invoked with interpret=True here: real-TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, b_ref, alpha_ref, mu_ref, o_ref):
    x = x_ref[...]
    b = b_ref[...]
    # Contract against ±1 weights; on TPU this hits the MXU in bf16.
    dots = jax.lax.dot_general(
        x, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (m, o_tile)
    xsum = jnp.sum(x, axis=1, keepdims=True)  # (m, 1)
    o_ref[...] = (dots * alpha_ref[...][None, :] + xsum * mu_ref[...][None, :]).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("row_tile",))
def binary_gemm(x, b, alpha, mu, row_tile=128):
    """Pallas W1A16 sign-GEMM. x: (m, n); b: (o, n) ±1 (float dtype);
    alpha, mu: (o,). Returns (m, o) in x.dtype."""
    m, n = x.shape
    o, n2 = b.shape
    assert n == n2, f"shape mismatch {x.shape} vs {b.shape}"
    row_tile = min(row_tile, o)
    # Pad o to a multiple of the tile so the grid is exact.
    pad = (-o) % row_tile
    if pad:
        b = jnp.pad(b, ((0, pad), (0, 0)))
        alpha = jnp.pad(alpha, (0, pad))
        mu = jnp.pad(mu, (0, pad))
    o_pad = o + pad
    grid = (o_pad // row_tile,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, n), lambda i: (0, 0)),           # x broadcast
            pl.BlockSpec((row_tile, n), lambda i: (i, 0)),    # B row tile
            pl.BlockSpec((row_tile,), lambda i: (i,)),        # alpha tile
            pl.BlockSpec((row_tile,), lambda i: (i,)),        # mu tile
        ],
        out_specs=pl.BlockSpec((m, row_tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, o_pad), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, b.astype(x.dtype), alpha.astype(x.dtype), mu.astype(x.dtype))
    return out[:, :o]
