"""Binary-Codebook LUT-GEMM Pallas kernel (paper App. H).

The weight matrix never exists at runtime: it is a codebook
C in {-1,+1}^{c x v} plus an index matrix I in [0,c)^{o x nb}
(nb = n / v), with per-row scale alpha and bias mu.

Two-stage lookup structure, faithful to the paper:

  Stage-I  (activation LUT): split each length-v activation block into
           P = v/mu segments of mu elements; LUT[j,p,s] holds the signed
           sum of segment (j,p) under ±1 pattern s (2^mu patterns).
           Built as one small matmul against the constant pattern matrix.
  Stage-II (codebook LUT):   CBLUT[j,k] = sum_p LUT[j, p, key[k,p]]
           where key[k,p] packs the mu sign bits of codebook entry k,
           segment p — precomputed offline from C (`codebook_keys`).
  Gather:  y[i,r] = alpha[r] * sum_j CBLUT[i, j, I[r,j]]
                  + mu[r] * sum(x[i]).

HARDWARE MAPPING (DESIGN.md §Hardware-Adaptation): the CUDA version
places LUT/CBLUT in shared memory and replicates across warps; here the
grid tiles output rows, CBLUT is built once per grid step in VMEM and
reused by the whole row tile (the paper's "large tile of output rows"),
and the index gather lowers to dynamic-slice streams. The LUT build is
VPU work; there is deliberately no MXU matmul on the per-row path.

interpret=True always — Mosaic custom-calls cannot run on CPU PJRT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pattern_matrix(mu_bits, dtype=jnp.float32):
    """S[s, t] = 2*bit_t(s) - 1, shape (2^mu, mu)."""
    s = jnp.arange(1 << mu_bits, dtype=jnp.int32)
    t = jnp.arange(mu_bits, dtype=jnp.int32)
    return (2 * ((s[:, None] >> t[None, :]) & 1) - 1).astype(dtype)


def codebook_keys(codebook, mu_bits):
    """key[k, p] = packed mu-bit sign pattern of codebook entry k, segment p.

    Precomputed OFFLINE at quantization time (the codebook is static).
    codebook: (c, v) ±1 -> (c, v/mu) int32.
    """
    c, v = codebook.shape
    assert v % mu_bits == 0
    p = v // mu_bits
    bits = ((codebook.reshape(c, p, mu_bits) + 1) // 2).astype(jnp.int32)
    t = jnp.arange(mu_bits, dtype=jnp.int32)
    return jnp.sum(bits << t[None, None, :], axis=-1)


def _kernel(mu_bits, x_ref, key_ref, idx_ref, alpha_ref, mu_ref, o_ref):
    x = x_ref[...]                       # (m, n)
    key = key_ref[...]                   # (c, p)
    idx = idx_ref[...]                   # (o_tile, nb)
    m, n = x.shape
    c, p = key.shape
    o_tile, nb = idx.shape
    v = n // nb
    npat = 1 << mu_bits

    # Stage-I: activation LUTs. One small matmul against the constant
    # pattern matrix: LUT[i, j, pp, s].
    patterns = pattern_matrix(mu_bits, x.dtype)  # (npat, mu)
    xseg = x.reshape(m, nb, p, mu_bits)
    lut = jax.lax.dot_general(
        xseg, patterns, (((3,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (m, nb, p, npat)

    # Stage-II: codebook LUT. CBLUT[i, j, k] = sum_pp LUT[i, j, pp, key[k, pp]].
    keyt = jnp.broadcast_to(key.T[None, None, :, :], (m, nb, p, c))
    cblut = jnp.take_along_axis(lut, keyt, axis=3).sum(axis=2)  # (m, nb, c)

    # Gather-accumulate over the index tile: one lookup + add per block.
    idxt = jnp.broadcast_to(idx.T[None, :, :], (m, nb, o_tile))
    dots = jnp.take_along_axis(cblut, idxt, axis=2).sum(axis=1)  # (m, o_tile)

    xsum = jnp.sum(x, axis=1, keepdims=True)
    o_ref[...] = (
        dots * alpha_ref[...][None, :] + xsum * mu_ref[...][None, :]
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mu_bits", "row_tile"))
def lut_gemm(x, codebook, idx, alpha, mu, mu_bits=4, row_tile=128):
    """Binary-codebook LUT-GEMM.

    x: (m, n); codebook: (c, v) ±1 float; idx: (o, nb) int32 with
    nb*v == n; alpha, mu: (o,). Returns (m, o) in x.dtype.
    """
    m, n = x.shape
    c, v = codebook.shape
    o, nb = idx.shape
    assert nb * v == n, f"{nb}*{v} != {n}"
    assert v % mu_bits == 0
    key = codebook_keys(codebook, mu_bits)  # offline in deployment
    row_tile = min(row_tile, o)
    pad = (-o) % row_tile
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        alpha = jnp.pad(alpha, (0, pad))
        mu = jnp.pad(mu, (0, pad))
    o_pad = o + pad
    grid = (o_pad // row_tile,)
    out = pl.pallas_call(
        functools.partial(_kernel, mu_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, n), lambda i: (0, 0)),             # x broadcast
            pl.BlockSpec((c, v // mu_bits), lambda i: (0, 0)),  # keys broadcast
            pl.BlockSpec((row_tile, nb), lambda i: (i, 0)),     # index tile
            pl.BlockSpec((row_tile,), lambda i: (i,)),
            pl.BlockSpec((row_tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((m, row_tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, o_pad), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, key, idx.astype(jnp.int32), alpha.astype(x.dtype), mu.astype(x.dtype))
    return out[:, :o]
