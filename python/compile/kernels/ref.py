"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Everything here is the *semantic* definition; the Pallas kernels in
`binary_gemm.py` / `lut_gemm.py` must match these to float tolerance.
"""

import jax.numpy as jnp


def reconstruct_binary(b, alpha, mu):
    """W_hat[r, :] = alpha[r] * B[r, :] + mu[r].

    b: (o, n) in {-1, +1}; alpha, mu: (o,). Returns (o, n) float.
    """
    return alpha[:, None] * b + mu[:, None]


def binary_gemm_ref(x, b, alpha, mu):
    """y = x @ W_hat^T with W_hat = alpha*B + mu (per output row).

    x: (m, n); b: (o, n) ±1; alpha, mu: (o,). Returns (m, o).
    """
    w = reconstruct_binary(b.astype(x.dtype), alpha.astype(x.dtype), mu.astype(x.dtype))
    return x @ w.T


def expand_codebook(codebook, idx):
    """Materialize W's ±1 matrix from codebook entries.

    codebook: (c, v) ±1; idx: (o, nb) int; returns (o, nb*v).
    """
    o, nb = idx.shape
    _, v = codebook.shape
    return codebook[idx].reshape(o, nb * v)


def lut_gemm_ref(x, codebook, idx, alpha, mu):
    """Reference for the Binary-Codebook LUT-GEMM (paper App. H).

    x: (m, n) with n = nb*v; codebook: (c, v) ±1; idx: (o, nb) int;
    alpha, mu: (o,). y[i, r] = alpha[r] * sum_j <x_block[i,j], C[idx[r,j]]>
                              + mu[r] * sum(x[i]).
    """
    b = expand_codebook(codebook, idx).astype(x.dtype)
    return binary_gemm_ref(x, b, alpha, mu)


def lut_gemm_twostage_ref(x, codebook, idx, alpha, mu, mu_bits=4):
    """Two-stage LUT formulation (Stage-I activation LUT over mu_bits-wide
    ±1 patterns, Stage-II codebook LUT, index-gather accumulation).

    Algebraically identical to lut_gemm_ref; spelled out LUT-wise so the
    Rust CPU engine and the Pallas kernel share an oracle for the *staged*
    computation.
    """
    m, n = x.shape
    c, v = codebook.shape
    o, nb = idx.shape
    assert n == nb * v and v % mu_bits == 0
    p = v // mu_bits
    npat = 1 << mu_bits
    # Pattern matrix S[s, t] = ±1 from the bits of s (bit t -> position t).
    s_codes = jnp.arange(npat, dtype=jnp.int32)
    t_codes = jnp.arange(mu_bits, dtype=jnp.int32)
    patterns = (2 * ((s_codes[:, None] >> t_codes[None, :]) & 1) - 1).astype(x.dtype)
    # Stage-I: LUT[i, j, pp, s] = <x[i, j, pp, :], patterns[s]>
    xseg = x.reshape(m, nb, p, mu_bits)
    lut = jnp.einsum("ijpt,st->ijps", xseg, patterns)
    # Codebook keys: key[k, pp] = packed bits of C[k, pp*mu : (pp+1)*mu].
    bits = ((codebook.reshape(c, p, mu_bits) + 1) // 2).astype(jnp.int32)
    key = jnp.sum(bits << t_codes[None, None, :], axis=-1)  # (c, p)
    # Stage-II: CBLUT[i, j, k] = sum_pp LUT[i, j, pp, key[k, pp]]
    cblut = jnp.take_along_axis(
        lut, jnp.broadcast_to(key.T[None, None, :, :], (m, nb, p, c)), axis=3
    ).sum(axis=2)  # (m, nb, c)
    # Gather-accumulate: y[i, r] = sum_j CBLUT[i, j, idx[r, j]]
    gathered = jnp.take_along_axis(
        cblut, jnp.broadcast_to(idx.T[None, :, :], (m, nb, o)), axis=2
    )  # (m, nb, o)
    dots = gathered.sum(axis=1)  # (m, o)
    return alpha[None, :] * dots + mu[None, :] * x.sum(axis=1, keepdims=True)
