"""Build-time trainer for the TinyLM workload models.

Runs ONCE under `make artifacts` (python is never on the request path).
optax/flax are not in the offline image, so this is a self-contained
Adam + cosine schedule + grad clipping implementation over the pure
functional model in model.py.

Also trains the QAT-lite binary model (FBI-LLM analog, Table 4) via the
straight-through estimator in model.binarize_params.
"""

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import blob
from .model import CONFIGS, ModelConfig, init_params, loss_fn, loss_fn_qat


def make_batches(corpus: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    """Random crops of length seq+1 from the byte corpus."""
    rng = np.random.default_rng(seed)
    hi = len(corpus) - seq - 2
    for _ in range(steps):
        starts = rng.integers(0, hi, size=batch)
        yield np.stack([corpus[s : s + seq + 1] for s in starts]).astype(np.int32)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


@functools.partial(jax.jit, static_argnames=("cfg", "qat", "lr_max", "total_steps"))
def train_step(cfg: ModelConfig, params, opt, tokens, qat=False,
               lr_max=3e-3, total_steps=400):
    lfn = loss_fn_qat if qat else loss_fn
    loss, grads = jax.value_and_grad(lambda p: lfn(cfg, p, tokens))(params)
    # Global-norm clip.
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
    t = opt["t"] + 1
    # 20-step warmup then cosine decay.
    tf = t.astype(jnp.float32)
    warm = jnp.minimum(tf / 20.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(tf / total_steps, 1.0)))
    lr = lr_max * warm * (0.1 + 0.9 * cos)
    b1, b2, eps = 0.9, 0.999, 1e-8
    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g * scale, opt["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * (g * scale) ** 2, opt["v"], grads)
    bc1 = 1 - b1 ** tf
    bc2 = 1 - b2 ** tf
    new_params = jax.tree.map(
        lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps),
        params, new_m, new_v,
    )
    return new_params, {"m": new_m, "v": new_v, "t": t}, loss, lr


def train_model(name: str, corpus: np.ndarray, out_dir: str, steps: int,
                batch: int = 8, seq: int = 128, seed: int = 42,
                qat: bool = False, log_every: int = 10) -> dict:
    cfg = CONFIGS[name]
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)
    curve = []
    t0 = time.time()
    for step, tokens in enumerate(make_batches(corpus, batch, seq, steps, seed)):
        params, opt, loss, lr = train_step(
            cfg, params, opt, jnp.asarray(tokens), qat=qat, total_steps=steps
        )
        if step % log_every == 0 or step == steps - 1:
            curve.append((step, float(loss)))
            print(f"[{name}] step {step:4d} loss {float(loss):.4f} lr {float(lr):.2e} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    # For the QAT model, bake the binarized weights in (the FBI analog
    # ships natively-binary linear weights).
    if qat:
        from .model import binarize_params
        params = jax.tree.map(lambda x: x, binarize_params(params))
    blob.save(os.path.join(out_dir, f"{name}.bin"), cfg, params)
    with open(os.path.join(out_dir, f"train_metrics_{name}.txt"), "w") as f:
        f.write(f"# model={name} params={cfg.param_count()} steps={steps} "
                f"batch={batch} seq={seq} qat={int(qat)}\n")
        for s, l in curve:
            f.write(f"{s} {l:.6f}\n")
    print(f"[{name}] done: final loss {curve[-1][1]:.4f}, "
          f"{cfg.param_count()} params, {time.time()-t0:.0f}s", flush=True)
    return params
