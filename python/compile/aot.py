"""AOT build orchestrator (`make artifacts`).

Runs the ENTIRE python side once and writes everything the Rust
coordinator needs into artifacts/:

  corpus_train.txt / corpus_eval.txt   synthetic tinywiki corpus
  <model>.bin                          TLM1 weight blobs (6 models)
  train_metrics_<model>.txt            loss curves (e2e example replays)
  binary_gemm.hlo.txt                  L1 W1A16 kernel, AOT-lowered
  lut_gemm.hlo.txt                     L1 codebook LUT-GEMM, AOT-lowered
  tinylm_s_fwd.hlo.txt                 full fp forward (weights baked)
  manifest.txt                         shapes/paths for the Rust runtime

Interchange is HLO **text**: the image's xla_extension 0.5.1 rejects
jax>=0.5 serialized HloModuleProto (64-bit instruction ids); the text
parser reassigns ids (see /opt/xla-example/README.md). Lowered with
return_tuple=True; the Rust side unwraps with to_tuple1().
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import blob, corpus
from .kernels import binary_gemm, lut_gemm
from .model import CONFIGS, forward
from .train import train_model

# (name, steps, qat) — sizes/steps chosen for a 1-core CPU build.
MODEL_PLAN = [
    ("tinylm_s", 400, False),
    ("tinylm_m", 400, False),
    ("tinylm_l", 300, False),
    ("tinyqwen_s", 300, False),
    ("tinyqwen_m", 300, False),
    ("fbi_s", 400, True),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default printer elides
    # non-scalar constants as `{...}`, which the text parser then reads
    # as garbage — e.g. the RoPE cos/sin tables silently became zeros.
    return comp.as_hlo_text(print_large_constants=True)


def write_hlo(path: str, fn, *example_args) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)", flush=True)


def lower_kernels(out_dir: str, manifest: list) -> None:
    """Lower the two L1 kernels with parity-test shapes.

    The Rust runtime executes these HLOs via PJRT and cross-checks its
    own engine (engine/lutgemm.rs, engine/xnor.rs) on identical inputs.
    """
    m, n, o = 8, 96, 64
    c, v = 32, 16
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    write_hlo(
        os.path.join(out_dir, "binary_gemm.hlo.txt"),
        lambda x, b, a, mu: (binary_gemm(x, b, a, mu),),
        spec((m, n), f32), spec((o, n), f32), spec((o,), f32), spec((o,), f32),
    )
    manifest.append(f"binary_gemm.hlo.txt kind=kernel m={m} n={n} o={o}")
    write_hlo(
        os.path.join(out_dir, "lut_gemm.hlo.txt"),
        lambda x, cb, idx, a, mu: (lut_gemm(x, cb, idx, a, mu, mu_bits=4),),
        spec((m, n), f32), spec((c, v), f32), spec((o, n // v), jnp.int32),
        spec((o,), f32), spec((o,), f32),
    )
    manifest.append(f"lut_gemm.hlo.txt kind=kernel m={m} n={n} o={o} c={c} v={v} mu=4")


def lower_model_forward(out_dir: str, manifest: list, name: str, seq: int = 32) -> None:
    """Lower a full fp forward pass to HLO text.

    Weights are EXPLICIT parameters in sorted-name order, AFTER the
    tokens argument (jax would hoist large closed-over constants into
    hidden trailing parameters anyway — making them explicit pins the
    calling convention for the Rust runtime, which feeds tensors from
    the TLM1 blob in the same sorted order; see examples/hlo_parity.rs).
    Proves the whole L2 graph (RoPE/GQA/SwiGLU) composes under PJRT.
    """
    cfg, params = blob.load(os.path.join(out_dir, f"{name}.bin"))
    names = sorted(params.keys())

    def fwd(toks, *tensors):
        p = dict(zip(names, tensors))
        return (forward(cfg, p, toks),)

    specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    write_hlo(
        os.path.join(out_dir, f"{name}_fwd.hlo.txt"),
        fwd,
        jax.ShapeDtypeStruct((1, seq), jnp.int32),
        *specs,
    )
    manifest.append(
        f"{name}_fwd.hlo.txt kind=forward model={name} batch=1 seq={seq} "
        f"args=tokens+sorted_tensors n_tensors={len(names)}"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description="BTC-LLM artifact builder")
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--quick", action="store_true",
                    help="tiny corpus + few steps (CI smoke, not for benches)")
    ap.add_argument("--force", action="store_true", help="rebuild everything")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    manifest = []

    # 1. Corpus.
    train_path = os.path.join(out, "corpus_train.txt")
    if args.force or not os.path.exists(train_path):
        n_train = 40_000 if args.quick else 400_000
        text = corpus.generate(n_train, seed=42)
        with open(train_path, "w") as f:
            f.write(text)
        with open(os.path.join(out, "corpus_eval.txt"), "w") as f:
            f.write(corpus.generate(n_train // 10, seed=1042))
        print(f"corpus: {n_train} train chars", flush=True)
    with open(train_path, "rb") as f:
        corpus_bytes = np.frombuffer(f.read(), dtype=np.uint8)
    manifest.append("corpus_train.txt kind=corpus")
    manifest.append("corpus_eval.txt kind=corpus")

    # 2. Train the model zoo (cached: skipped when the blob exists).
    plan = MODEL_PLAN if not args.quick else [("tinylm_s", 30, False), ("fbi_s", 30, True)]
    for name, steps, qat in plan:
        path = os.path.join(out, f"{name}.bin")
        if args.force or not os.path.exists(path):
            train_model(name, corpus_bytes, out, steps=steps, qat=qat)
        else:
            print(f"[{name}] cached", flush=True)
        manifest.append(f"{name}.bin kind=weights qat={int(qat)}")
        manifest.append(f"train_metrics_{name}.txt kind=metrics")

    # 3. AOT-lower the L1 kernels + a full model forward to HLO text.
    lower_kernels(out, manifest)
    lower_model_forward(out, manifest, "tinylm_s")

    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts", flush=True)


if __name__ == "__main__":
    main()
