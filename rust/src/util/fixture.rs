//! Hermetic tiny-model fixtures shared by unit tests, integration
//! tests, and examples (no `make artifacts` needed). Not part of the
//! library's supported API surface.

use std::collections::BTreeMap;

use crate::data::corpus;
use crate::io::weights::{ModelConfig, RawModel};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// A small random TinyLM-shaped model (vocab 128, d_model 16, 2
/// layers) plus a synthetic calibration/eval corpus.
pub fn tiny_raw_model(seed: u64) -> (RawModel, Vec<u8>) {
    let cfg = ModelConfig {
        vocab: 128,
        d_model: 16,
        n_layer: 2,
        n_head: 2,
        n_kv_head: 2,
        d_ff: 24,
        max_seq: 64,
        rope_theta: 10000.0,
    };
    synth_raw_model(seed, cfg)
}

/// A random model of an arbitrary (valid) shape plus a synthetic
/// corpus — the serving benches fall back to this when the trained
/// artifacts are absent, so perf smoke runs stay hermetic.
pub fn synth_raw_model(seed: u64, cfg: ModelConfig) -> (RawModel, Vec<u8>) {
    let mut rng = Rng::new(seed);
    let mut tensors = BTreeMap::new();
    fn add(
        tensors: &mut BTreeMap<String, (Vec<usize>, Vec<f32>)>,
        name: String,
        rows: usize,
        cols: usize,
        rng: &mut Rng,
    ) {
        let m = Matrix::randn(rows, cols, rng).scale(0.2);
        tensors.insert(name, (vec![rows, cols], m.data));
    }
    add(&mut tensors, "emb".into(), cfg.vocab, cfg.d_model, &mut rng);
    tensors.insert("lnf".into(), (vec![cfg.d_model], vec![1.0; cfg.d_model]));
    for i in 0..cfg.n_layer {
        tensors.insert(format!("l{i}.ln1"), (vec![cfg.d_model], vec![1.0; cfg.d_model]));
        tensors.insert(format!("l{i}.ln2"), (vec![cfg.d_model], vec![1.0; cfg.d_model]));
        add(&mut tensors, format!("l{i}.wq"), cfg.d_model, cfg.d_model, &mut rng);
        add(&mut tensors, format!("l{i}.wk"), cfg.kv_dim(), cfg.d_model, &mut rng);
        add(&mut tensors, format!("l{i}.wv"), cfg.kv_dim(), cfg.d_model, &mut rng);
        add(&mut tensors, format!("l{i}.wo"), cfg.d_model, cfg.d_model, &mut rng);
        add(&mut tensors, format!("l{i}.wgate"), cfg.d_ff, cfg.d_model, &mut rng);
        add(&mut tensors, format!("l{i}.wup"), cfg.d_ff, cfg.d_model, &mut rng);
        add(&mut tensors, format!("l{i}.wdown"), cfg.d_model, cfg.d_ff, &mut rng);
    }
    let raw = RawModel { config: cfg, tensors };
    (raw, corpus::generate(4000, 1).into_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Transformer;

    #[test]
    fn fixture_builds_a_runnable_model() {
        let (raw, corpus) = tiny_raw_model(9);
        assert!(!corpus.is_empty());
        let m = Transformer::from_raw(&raw).unwrap();
        let logits = m.forward(&[1, 2, 3]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fixture_is_deterministic_per_seed() {
        let (a, _) = tiny_raw_model(9);
        let (b, _) = tiny_raw_model(9);
        assert_eq!(a.tensors["emb"].1, b.tensors["emb"].1);
        let (c, _) = tiny_raw_model(10);
        assert_ne!(a.tensors["emb"].1, c.tensors["emb"].1);
    }
}
