//! In-repo infrastructure substrates.
//!
//! The offline image vendors only the `xla` crate's dependency tree, so
//! the usual ecosystem crates (rand, clap, serde/toml, criterion,
//! proptest, tokio) are unavailable. Each submodule here is a small,
//! fully-tested replacement covering exactly what this project needs.

pub mod argparse;
pub mod autotune;
pub mod benchkit;
pub mod f16;
pub mod faultpoint;
pub mod fixture;
pub mod log;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod simd;
pub mod toml;
