//! IEEE 754 binary16 ("half") conversion — the shipping precision of
//! every per-row scale (`alpha`/`mu`) in the quantized formats. The
//! vendored-only build has no `half` crate, so this is a minimal,
//! fully-tested software round-trip: `encode` rounds to nearest-even
//! (the IEEE default), `decode` is exact.
//!
//! Invariant relied on by the QLM1 round-trip tests: for every non-NaN
//! half `h`, `encode(decode(h)) == h` — so scales quantized to f16 once
//! survive arbitrarily many save/load cycles bit-identically.

/// f32 -> f16 bits, round-to-nearest-even. Overflow goes to ±inf,
/// underflow to (sub)normals then ±0; NaNs stay NaN (quieted).
pub fn encode(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xff) as i32;
    let man = b & 0x007f_ffff;
    if exp == 0xff {
        // Inf stays inf; NaN keeps (the top of) its payload, quieted so
        // the mantissa can never collapse to the inf encoding.
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7e00 | (man >> 13) as u16 };
    }
    let unbiased = exp - 127;
    if unbiased >= 16 {
        return sign | 0x7c00; // >= 2^16: past the largest half
    }
    if unbiased < -25 {
        return sign; // < half of the smallest subnormal: to zero
    }
    if unbiased < -14 {
        // Subnormal half: value = M * 2^-24 with M = mant24 >> shift.
        let mant24 = man | 0x0080_0000;
        let shift = (-unbiased - 1) as u32; // 14..=24
        let half = mant24 >> shift;
        let rem = mant24 & ((1u32 << shift) - 1);
        let tie = 1u32 << (shift - 1);
        let m = half + u32::from(rem > tie || (rem == tie && half & 1 == 1));
        // A carry out of the mantissa lands exactly on the smallest
        // normal encoding (0x0400) — still correct.
        return sign | m as u16;
    }
    // Normal half.
    let e = (unbiased + 15) as u32; // 1..=31
    let half = (e << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let h = half + u32::from(rem > 0x1000 || (rem == 0x1000 && half & 1 == 1));
    // A mantissa carry bumps the exponent (possibly to inf) — correct.
    sign | h as u16
}

/// f16 bits -> f32 (exact: every half is representable).
pub fn decode(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = (h & 0x3ff) as u32;
    let bits = match exp {
        0 => {
            if man == 0 {
                sign // ±0
            } else {
                // Subnormal: normalize into an f32 exponent.
                let mut e = 127 - 15 + 1;
                let mut m = man;
                while m & 0x400 == 0 {
                    m <<= 1;
                    e -= 1;
                }
                sign | ((e as u32) << 23) | ((m & 0x3ff) << 13)
            }
        }
        0x1f => sign | 0x7f80_0000 | (man << 13), // inf / NaN
        _ => sign | ((exp as u32 + 127 - 15) << 23) | (man << 13),
    };
    f32::from_bits(bits)
}

/// Encode a slice (row scales -> shipped u16s).
pub fn encode_vec(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| encode(x)).collect()
}

/// Decode a slice (shipped u16s -> working f32s).
pub fn decode_vec(hs: &[u16]) -> Vec<f32> {
    hs.iter().map(|&h| decode(h)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(encode(0.0), 0x0000);
        assert_eq!(encode(-0.0), 0x8000);
        assert_eq!(encode(1.0), 0x3c00);
        assert_eq!(encode(-2.0), 0xc000);
        assert_eq!(encode(0.5), 0x3800);
        assert_eq!(encode(65504.0), 0x7bff); // largest finite half
        assert_eq!(encode(f32::INFINITY), 0x7c00);
        assert_eq!(encode(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(decode(0x3c00), 1.0);
        assert_eq!(decode(0x3555), 0.333_251_953_125); // ~1/3
        assert_eq!(decode(0x0001), 2f32.powi(-24)); // smallest subnormal
        assert_eq!(decode(0x0400), 2f32.powi(-14)); // smallest normal
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1 + 2^-10:
        // ties-to-even keeps the even mantissa (1.0).
        assert_eq!(encode(1.0 + 2f32.powi(-11)), 0x3c00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9: rounds up
        // to the even mantissa 2.
        assert_eq!(encode(1.0 + 3.0 * 2f32.powi(-11)), 0x3c02);
        // Just above the tie rounds up.
        assert_eq!(encode(1.0 + 2f32.powi(-11) + 2f32.powi(-20)), 0x3c01);
        // 65520 is halfway between 65504 and 2^16: ties to inf.
        assert_eq!(encode(65520.0), 0x7c00);
        assert_eq!(encode(65519.0), 0x7bff);
        // Subnormal ties: 2^-25 is halfway between 0 and 2^-24 -> 0.
        assert_eq!(encode(2f32.powi(-25)), 0x0000);
        assert_eq!(encode(3.0 * 2f32.powi(-26)), 0x0001); // 0.75 ulp -> up
    }

    #[test]
    fn overflow_and_underflow() {
        assert_eq!(encode(1e9), 0x7c00);
        assert_eq!(encode(-1e9), 0xfc00);
        assert_eq!(encode(1e-10), 0x0000);
        assert_eq!(encode(-1e-10), 0x8000);
        assert!(decode(encode(f32::NAN)).is_nan());
    }

    #[test]
    fn exhaustive_half_roundtrip() {
        // Every non-NaN half must survive decode -> encode exactly;
        // NaNs must stay NaN.
        for h in 0..=u16::MAX {
            let is_nan = h & 0x7c00 == 0x7c00 && h & 0x3ff != 0;
            let f = decode(h);
            if is_nan {
                assert!(f.is_nan(), "h={h:#06x}");
                assert!(decode(encode(f)).is_nan());
            } else {
                assert_eq!(encode(f), h, "h={h:#06x} f={f}");
            }
        }
    }

    #[test]
    fn encode_error_bounded() {
        // Relative error of one f16 rounding is <= 2^-11 for normals.
        for &x in &[1.2345f32, -987.25, 3.0e-3, 7.77e3, 0.1] {
            let y = decode(encode(x));
            assert!(((y - x) / x).abs() <= 2f32.powi(-11), "{x} -> {y}");
        }
    }
}
