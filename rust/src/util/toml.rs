//! Minimal TOML-subset parser for the coordinator's config system
//! (serde/toml are unavailable offline).
//!
//! Supported: `[section]` / `[a.b]` headers, `key = value` with string,
//! integer, float, boolean and flat scalar arrays, `#` comments.
//! Values are exposed flattened as `"section.key"`.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: flattened `section.key -> Value`.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    pub values: BTreeMap<String, Value>,
}

impl Doc {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }
    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }
    pub fn get_int(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_int()).unwrap_or(default)
    }
    pub fn get_float(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_float()).unwrap_or(default)
    }
    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn parse_scalar(s: &str) -> Result<Value, String> {
    let s = s.trim();
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        // Basic strings with simple escapes.
        let inner = &s[1..s.len() - 1];
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("bad escape {other:?}")),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("unparseable value: {s:?}"))
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc, String> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(format!("line {}: bad section header", lineno + 1));
            }
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{}.{}", section, k.trim())
        };
        let vtrim = v.trim();
        let value = if vtrim.starts_with('[') {
            if !vtrim.ends_with(']') {
                return Err(format!("line {}: unterminated array", lineno + 1));
            }
            let inner = &vtrim[1..vtrim.len() - 1];
            let mut items = Vec::new();
            if !inner.trim().is_empty() {
                for part in inner.split(',') {
                    if part.trim().is_empty() {
                        continue; // trailing comma
                    }
                    items.push(parse_scalar(part).map_err(|e| format!("line {}: {e}", lineno + 1))?);
                }
            }
            Value::Array(items)
        } else {
            parse_scalar(vtrim).map_err(|e| format!("line {}: {e}", lineno + 1))?
        };
        doc.values.insert(key, value);
    }
    Ok(doc)
}

/// Parse a config file from disk.
pub fn parse_file(path: &std::path::Path) -> Result<Doc, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_and_scalars() {
        let doc = parse(
            r#"
# top comment
name = "btc"
[server]
port = 8080
rate = 1.5
debug = true
[quant.codebook]
v = 16
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name", ""), "btc");
        assert_eq!(doc.get_int("server.port", 0), 8080);
        assert_eq!(doc.get_float("server.rate", 0.0), 1.5);
        assert!(doc.get_bool("server.debug", false));
        assert_eq!(doc.get_int("quant.codebook.v", 0), 16);
    }

    #[test]
    fn arrays() {
        let doc = parse("bits = [1.11, 0.9, 0.8, 0.7]\nnames = [\"a\", \"b\"]").unwrap();
        match doc.get("bits").unwrap() {
            Value::Array(xs) => {
                assert_eq!(xs.len(), 4);
                assert_eq!(xs[0].as_float(), Some(1.11));
            }
            _ => panic!(),
        }
        match doc.get("names").unwrap() {
            Value::Array(xs) => assert_eq!(xs[1].as_str(), Some("b")),
            _ => panic!(),
        }
    }

    #[test]
    fn string_escapes_and_comments_in_strings() {
        let doc = parse("s = \"a # not comment\\n\" # real comment").unwrap();
        assert_eq!(doc.get_str("s", ""), "a # not comment\n");
    }

    #[test]
    fn errors() {
        assert!(parse("novalue").is_err());
        assert!(parse("[unclosed").is_err());
        assert!(parse("x = @bad").is_err());
        assert!(parse("a = [1, 2").is_err());
    }

    #[test]
    fn defaults_for_missing() {
        let doc = parse("").unwrap();
        assert_eq!(doc.get_int("missing", 7), 7);
    }

    #[test]
    fn underscored_ints_and_negative() {
        let doc = parse("n = 65_536\nm = -3").unwrap();
        assert_eq!(doc.get_int("n", 0), 65536);
        assert_eq!(doc.get_int("m", 0), -3);
    }
}
