//! Microbench autotuner for the serving kernels' tuning constants.
//!
//! Three knobs materially shape single-node throughput and each has a
//! machine-dependent sweet spot:
//!
//! - `gather_tile` — the LUT-GEMM output-tile width
//!   ([`crate::engine::lutgemm`]). Too small wastes the per-tile index
//!   decode; too large spills the f32 accumulator block out of
//!   registers.
//! - `par_min_work` — the spawn-amortization floor gating scoped-thread
//!   parallelism ([`crate::util::parallel`]). The crossover depends on
//!   spawn latency and per-core GEMM throughput.
//! - `prefill_chunk` — how many prompt tokens the decode loop batches
//!   per forward pass. Larger chunks amortize per-call overhead but
//!   raise time-to-first-token; we pick the *smallest* chunk within
//!   tolerance of the best per-token cost (see [`pick_knee`]).
//!
//! [`run`] sweeps each knob with [`benchkit::bench_for_ms`] on
//! synthetic fixtures shaped like the serving hot path, returns an
//! [`AutotuneReport`], and the winner set is persisted as TOML
//! ([`Tuning::to_toml`]) by the `bench_autotune` harness. At serve
//! startup the TOML is re-read ([`Tuning::from_file`]) and applied
//! ([`Tuning::apply`]) — see `serve.tuning_file` / `serve.autotune` in
//! the serve config.
//!
//! Correctness is never at stake: every knob only reshapes the
//! iteration/split schedule, and the kernels are pinned bit-identical
//! across tile widths and thread counts (tests in `engine::lutgemm`,
//! `util::parallel`, and `tests/simd_equivalence.rs`). A bad tuning
//! file can only cost speed.

use crate::engine::lutgemm::{LutGemmEngine, GATHER_TILE_DEFAULT, GATHER_TILE_MAX};
use crate::quant::binarize::BinaryLayer;
use crate::quant::codebook::{collect_vectors, BinaryCodebook, CodebookLayer};
use crate::tensor::Matrix;
use crate::util::benchkit::{bench_for_ms, black_box};
use crate::util::rng::Rng;
use crate::util::toml::Doc;
use crate::util::{parallel, simd, toml};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Default prefill chunk (tokens per forward pass during prompt
/// ingestion). Mirrors `ServeConfig::default().prefill_chunk`; the
/// serve loader keeps the two in sync.
pub const PREFILL_CHUNK_DEFAULT: usize = 32;

/// 0 = use the [`GATHER_TILE_DEFAULT`] compile-time default.
static GATHER_TILE_TUNED: AtomicUsize = AtomicUsize::new(0);

/// The live LUT-GEMM gather tile (tuned override, else
/// [`GATHER_TILE_DEFAULT`]). Engines read this once at construction,
/// so changing it never reshapes an engine already built.
pub fn gather_tile() -> usize {
    match GATHER_TILE_TUNED.load(Ordering::Relaxed) {
        0 => GATHER_TILE_DEFAULT,
        n => n,
    }
}

/// Override the gather tile (`0` resets to the default); values are
/// clamped to `1..=GATHER_TILE_MAX`. Returns the effective value.
pub fn set_gather_tile(tile: usize) -> usize {
    let v = if tile == 0 { 0 } else { tile.clamp(1, GATHER_TILE_MAX) };
    GATHER_TILE_TUNED.store(v, Ordering::Relaxed);
    gather_tile()
}

/// One persisted/applied set of tuned constants. `simd` and `threads`
/// record the environment the sweep ran under (provenance — a tuning
/// file from a different machine class is still *safe*, just possibly
/// slow); the remaining fields are the knobs themselves.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tuning {
    pub simd: String,
    pub threads: usize,
    pub gather_tile: usize,
    pub par_min_work: usize,
    pub prefill_chunk: usize,
}

impl Tuning {
    /// The compile-time defaults (what an untuned process runs with).
    pub fn defaults() -> Tuning {
        Tuning {
            simd: String::new(),
            threads: 0,
            gather_tile: GATHER_TILE_DEFAULT,
            par_min_work: parallel::PAR_MIN_WORK,
            prefill_chunk: PREFILL_CHUNK_DEFAULT,
        }
    }

    /// Render as a TOML document (the in-repo parser has no
    /// serializer, so this is hand-rendered; [`from_doc`] is the
    /// round-trip partner).
    ///
    /// [`from_doc`]: Tuning::from_doc
    pub fn to_toml(&self) -> String {
        format!(
            "# Autotuned kernel constants (cargo bench --bench bench_autotune).\n\
             # Consumed at serve startup via `serve.tuning_file`; safe to\n\
             # carry across machines (knobs only affect speed, never results).\n\
             [tuning]\n\
             simd = \"{}\"\n\
             threads = {}\n\
             gather_tile = {}\n\
             par_min_work = {}\n\
             prefill_chunk = {}\n",
            self.simd, self.threads, self.gather_tile, self.par_min_work, self.prefill_chunk
        )
    }

    /// Read a `[tuning]` section out of a parsed document, validating
    /// ranges. Missing keys fall back to the defaults so partial files
    /// (e.g. hand-written gather_tile-only overrides) work.
    pub fn from_doc(doc: &Doc) -> Result<Tuning, String> {
        let d = Tuning::defaults();
        let t = Tuning {
            simd: doc.get_str("tuning.simd", &d.simd).to_string(),
            threads: read_usize(doc, "tuning.threads", d.threads)?,
            gather_tile: read_usize(doc, "tuning.gather_tile", d.gather_tile)?,
            par_min_work: read_usize(doc, "tuning.par_min_work", d.par_min_work)?,
            prefill_chunk: read_usize(doc, "tuning.prefill_chunk", d.prefill_chunk)?,
        };
        if t.gather_tile == 0 || t.gather_tile > GATHER_TILE_MAX {
            return Err(format!(
                "tuning.gather_tile {} out of range 1..={GATHER_TILE_MAX}",
                t.gather_tile
            ));
        }
        if t.par_min_work == 0 {
            return Err("tuning.par_min_work must be positive".to_string());
        }
        if t.prefill_chunk == 0 {
            return Err("tuning.prefill_chunk must be positive".to_string());
        }
        Ok(t)
    }

    /// Parse and validate a tuning file from disk.
    pub fn from_file(path: &str) -> Result<Tuning, String> {
        let doc = toml::parse_file(std::path::Path::new(path))?;
        Self::from_doc(&doc).map_err(|e| format!("{path}: {e}"))
    }

    /// Install the kernel-level knobs into the process globals. The
    /// `prefill_chunk` knob lives in `ServeConfig`, so the caller
    /// adopts it there (explicit config wins over the tuning file).
    pub fn apply(&self) {
        set_gather_tile(self.gather_tile);
        parallel::set_par_min_work(self.par_min_work);
    }

    /// One-line human summary for startup logs.
    pub fn summary(&self) -> String {
        format!(
            "gather_tile={} par_min_work={} prefill_chunk={}",
            self.gather_tile, self.par_min_work, self.prefill_chunk
        )
    }
}

fn read_usize(doc: &Doc, key: &str, default: usize) -> Result<usize, String> {
    let v = doc.get_int(key, default as i64);
    if v < 0 {
        return Err(format!("{key} must be non-negative, got {v}"));
    }
    Ok(v as usize)
}

/// One measured candidate from a sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub knob: &'static str,
    pub value: usize,
    pub mean_ns: f64,
}

/// The chosen [`Tuning`] plus every candidate measurement (for the
/// bench table / JSON artifact).
#[derive(Debug, Clone)]
pub struct AutotuneReport {
    pub tuning: Tuning,
    pub points: Vec<SweepPoint>,
}

/// From `(value, mean_ns)` candidates, pick the *smallest* value whose
/// cost is within `tol` (fractional, e.g. `0.10`) of the best. Used
/// for prefill chunking, where the smallest near-optimal chunk also
/// minimizes time-to-first-token.
pub fn pick_knee(points: &[(usize, f64)], tol: f64) -> usize {
    assert!(!points.is_empty(), "pick_knee needs candidates");
    let best = points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let mut cands: Vec<&(usize, f64)> =
        points.iter().filter(|p| p.1 <= best * (1.0 + tol)).collect();
    cands.sort_by_key(|p| p.0);
    cands[0].0
}

/// Sweep all three knobs. `quick` shrinks the fixture and budget for
/// CI / startup use (~a second); the full sweep is for the offline
/// `bench_autotune` run. Globals touched during the sweep
/// (`par_min_work`) are restored before returning; the report is
/// *not* applied — callers decide ([`Tuning::apply`]).
pub fn run(quick: bool) -> AutotuneReport {
    run_with(if quick { 25 } else { 120 }, quick)
}

/// [`run`] with an explicit per-candidate budget (milliseconds);
/// exposed so tests can sweep in a few milliseconds.
pub fn run_with(budget_ms: u64, quick: bool) -> AutotuneReport {
    let mut rng = Rng::new(0xA11C);
    let level = simd::active();
    let mut points: Vec<SweepPoint> = Vec::new();

    // --- gather_tile: LUT-GEMM GEMV decode (the m=1 serving shape).
    let (o, n) = if quick { (256, 256) } else { (896, 512) };
    let v = 16usize;
    let c = if quick { 256 } else { 1024 };
    let w = Matrix::randn(o, n, &mut rng);
    let bl = BinaryLayer::quantize(&w);
    let vectors = collect_vectors(&bl, v);
    let (cb, assign, _) = BinaryCodebook::build(&vectors, v, c, 3);
    let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
    let x1 = Matrix::randn(1, n, &mut rng);
    let mut best_tile = (GATHER_TILE_DEFAULT, f64::INFINITY);
    for tile in [8usize, 16, 32, 48, 64] {
        let eng = LutGemmEngine::try_with_ctx(
            &cl,
            &crate::engine::EngineCtx::current().with_level(level).with_gather_tile(tile),
        )
        .expect("fixture is block-aligned");
        let st = bench_for_ms("autotune_gather", budget_ms, 3, || {
            black_box(eng.forward(&x1));
        });
        let m = st.mean_ns();
        points.push(SweepPoint { knob: "gather_tile", value: tile, mean_ns: m });
        if m < best_tile.1 {
            best_tile = (tile, m);
        }
    }

    // --- par_min_work: matmul_bt mix straddling the spawn crossover.
    // Work sizes m*k*n from 16K to 1M scalar ops, so every candidate
    // floor flips at least one shape between serial and parallel.
    let shapes: &[(usize, usize, usize)] =
        &[(1, 256, 64), (1, 256, 256), (4, 256, 128), (8, 512, 256)];
    let mix: Vec<(Matrix, Matrix)> = shapes
        .iter()
        .map(|&(m, k, nn)| (Matrix::randn(m, k, &mut rng), Matrix::randn(nn, k, &mut rng)))
        .collect();
    let orig_floor = parallel::par_min_work();
    let mut best_floor = (orig_floor, f64::INFINITY);
    for floor in [1usize << 13, 1 << 14, 1 << 15, 1 << 16, 1 << 17, 1 << 18] {
        parallel::set_par_min_work(floor);
        let st = bench_for_ms("autotune_floor", budget_ms, 3, || {
            for (a, b) in &mix {
                black_box(a.matmul_bt(b));
            }
        });
        let m = st.mean_ns();
        points.push(SweepPoint { knob: "par_min_work", value: floor, mean_ns: m });
        if m < best_floor.1 {
            best_floor = (floor, m);
        }
    }
    parallel::set_par_min_work(orig_floor);

    // --- prefill_chunk: chunked prompt ingestion proxy. Cost model is
    // per-token mean over a fixed prompt; pick_knee then prefers the
    // smallest chunk within 10% (lower TTFT at equal throughput).
    let t_tokens = if quick { 64 } else { 128 };
    let xfull = Matrix::randn(t_tokens, n, &mut rng);
    let wdense = bl.reconstruct();
    let mut chunk_points: Vec<(usize, f64)> = Vec::new();
    for chunk in [8usize, 16, 32, 64, 128] {
        let st = bench_for_ms("autotune_prefill", budget_ms, 3, || {
            let mut r0 = 0usize;
            while r0 < t_tokens {
                let take = chunk.min(t_tokens - r0);
                let xc =
                    Matrix::from_vec(take, n, xfull.data[r0 * n..(r0 + take) * n].to_vec());
                black_box(xc.matmul_bt(&wdense));
                r0 += take;
            }
        });
        let per_token = st.mean_ns() / t_tokens as f64;
        points.push(SweepPoint { knob: "prefill_chunk", value: chunk, mean_ns: per_token });
        chunk_points.push((chunk, per_token));
    }
    let prefill_chunk = pick_knee(&chunk_points, 0.10);

    AutotuneReport {
        tuning: Tuning {
            simd: level.name().to_string(),
            threads: parallel::threads(),
            gather_tile: best_tile.0,
            par_min_work: best_floor.0,
            prefill_chunk,
        },
        points,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_roundtrip_preserves_tuning() {
        let t = Tuning {
            simd: "avx2".to_string(),
            threads: 8,
            gather_tile: 48,
            par_min_work: 1 << 14,
            prefill_chunk: 16,
        };
        let doc = toml::parse(&t.to_toml()).expect("rendered TOML parses");
        assert_eq!(Tuning::from_doc(&doc).unwrap(), t);
    }

    #[test]
    fn from_doc_defaults_missing_keys() {
        let doc = toml::parse("[tuning]\ngather_tile = 8\n").unwrap();
        let t = Tuning::from_doc(&doc).unwrap();
        assert_eq!(t.gather_tile, 8);
        assert_eq!(t.par_min_work, parallel::PAR_MIN_WORK);
        assert_eq!(t.prefill_chunk, PREFILL_CHUNK_DEFAULT);
    }

    #[test]
    fn from_doc_rejects_bad_ranges() {
        for bad in [
            "[tuning]\ngather_tile = 0\n",
            "[tuning]\ngather_tile = 65\n",
            "[tuning]\npar_min_work = 0\n",
            "[tuning]\nprefill_chunk = 0\n",
            "[tuning]\ngather_tile = -3\n",
        ] {
            let doc = toml::parse(bad).unwrap();
            assert!(Tuning::from_doc(&doc).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn gather_tile_override_clamps_and_resets() {
        // Transiently visible to concurrent tests, which is fine: the
        // tile is read once at engine construction and every tile is
        // bit-identical (pinned in engine::lutgemm tests).
        assert_eq!(set_gather_tile(16), 16);
        assert_eq!(gather_tile(), 16);
        assert_eq!(set_gather_tile(10_000), GATHER_TILE_MAX);
        assert_eq!(set_gather_tile(0), GATHER_TILE_DEFAULT);
        assert_eq!(gather_tile(), GATHER_TILE_DEFAULT);
    }

    #[test]
    fn pick_knee_prefers_smallest_within_tolerance() {
        // 16 is within 10% of the best (100 vs 95) -> knee picks 16.
        let pts = [(8, 130.0), (16, 100.0), (32, 95.0), (64, 94.0 + 7.0)];
        assert_eq!(pick_knee(&pts, 0.10), 16);
        // Tight tolerance falls through to the true argmin.
        assert_eq!(pick_knee(&pts, 0.0), 32);
    }

    #[test]
    fn quick_sweep_produces_valid_tuning() {
        let rep = run_with(2, true);
        let t = &rep.tuning;
        assert!(t.gather_tile >= 1 && t.gather_tile <= GATHER_TILE_MAX);
        assert!(t.par_min_work > 0);
        assert!(t.prefill_chunk > 0);
        assert!(!t.simd.is_empty());
        for knob in ["gather_tile", "par_min_work", "prefill_chunk"] {
            assert!(rep.points.iter().any(|p| p.knob == knob), "missing sweep for {knob}");
        }
        // The sweep must leave the process floor untouched.
        assert!(parallel::par_min_work() > 0);
        // And the rendered winner must round-trip through the parser.
        let doc = toml::parse(&t.to_toml()).unwrap();
        assert_eq!(&Tuning::from_doc(&doc).unwrap(), t);
    }
}
