//! Scoped-thread data parallelism for the serving kernels (no external
//! thread-pool crates — plain `std::thread::scope`).
//!
//! The unit of work is a *range of output rows*: every hot kernel
//! (`matmul_bt`, sign-GEMM, LUT-GEMM gather) writes disjoint rows of a
//! row-major output buffer, so [`par_row_ranges`] splits the buffer
//! into contiguous whole-row chunks and runs one chunk per thread.
//! Each row is computed by exactly the same scalar code in the same
//! order regardless of the split, so parallel results are bit-identical
//! to the single-threaded path (pinned by tests here and in the
//! engines).
//!
//! Thread count resolution: explicit [`set_threads`] (serve config /
//! CLI `--threads`) > `PALLAS_THREADS` env > `available_parallelism`.
//! `0` always means "auto". Kernels gate on [`threads_for`] so tiny
//! problems never pay the spawn cost.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Upper bound on the worker count (sanity clamp for config typos).
pub const MAX_THREADS: usize = 256;

/// Default spawn-amortization floor: kernels with fewer scalar ops
/// than this stay single-threaded — a scoped spawn costs ~10µs, so
/// parallelism below this floor loses. The *live* floor is tunable
/// (see [`par_min_work`] / [`set_par_min_work`]; `util::autotune`
/// sweeps it). Changing the floor only changes which split runs, never
/// the results — every kernel is bit-identical across thread counts
/// at a fixed dispatch level.
pub const PAR_MIN_WORK: usize = 1 << 16;

/// 0 = not yet resolved.
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// 0 = use the [`PAR_MIN_WORK`] default.
static PAR_MIN_WORK_TUNED: AtomicUsize = AtomicUsize::new(0);

/// The live spawn-amortization floor (tuned override, else the
/// [`PAR_MIN_WORK`] default).
pub fn par_min_work() -> usize {
    match PAR_MIN_WORK_TUNED.load(Ordering::Relaxed) {
        0 => PAR_MIN_WORK,
        n => n,
    }
}

/// Override the spawn-amortization floor (`0` resets to the default);
/// returns the effective value. Called by the autotuner / tuning-file
/// loader at serve startup.
pub fn set_par_min_work(floor: usize) -> usize {
    PAR_MIN_WORK_TUNED.store(floor, Ordering::Relaxed);
    par_min_work()
}

fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Validate/resolve a requested thread count: `0` resolves to
/// `PALLAS_THREADS` (if set and positive) else the hardware count;
/// explicit values are clamped to `[1, MAX_THREADS]`.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        if let Ok(s) = std::env::var("PALLAS_THREADS") {
            if let Ok(n) = s.trim().parse::<usize>() {
                if n > 0 {
                    return n.min(MAX_THREADS);
                }
            }
        }
        hardware_threads().min(MAX_THREADS)
    } else {
        requested.clamp(1, MAX_THREADS)
    }
}

/// Set the global worker count (returns the effective, validated
/// value). Called by the server at startup; `0` = auto.
pub fn set_threads(requested: usize) -> usize {
    let n = resolve_threads(requested);
    THREADS.store(n, Ordering::Relaxed);
    n
}

/// The current global worker count (lazily resolved on first use).
pub fn threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => {
            let n = resolve_threads(0);
            THREADS.store(n, Ordering::Relaxed);
            n
        }
        n => n,
    }
}

/// Worker count for a kernel invocation doing ~`work` scalar ops:
/// 1 below the (tunable) spawn-amortization floor, else the global
/// count.
pub fn threads_for(work: usize) -> usize {
    if work < par_min_work() {
        1
    } else {
        threads()
    }
}

/// Split `data` (a row-major buffer of rows of `row_len` elements)
/// into contiguous whole-row chunks and call `f(first_row, chunk)` on
/// each, one chunk per worker. With `nt <= 1` this is a plain call
/// `f(0, data)` — callers write the row loop once and get both paths.
pub fn par_row_ranges_with<T, F>(nt: usize, data: &mut [T], row_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_len > 0, "row_len must be positive");
    debug_assert_eq!(data.len() % row_len, 0, "buffer not a whole number of rows");
    let rows = data.len() / row_len;
    let mut nt = nt.min(rows);
    if nt == 0 {
        nt = 1;
    }
    if nt == 1 {
        f(0, data);
        return;
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut first_row = 0;
        loop {
            let take = (rows_per * row_len).min(rest.len());
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let start = first_row;
            first_row += take / row_len;
            if rest.is_empty() {
                // Final chunk runs on the calling thread — it would
                // only block in the scope join otherwise, and this
                // saves one spawn per invocation.
                f(start, chunk);
                break;
            }
            let fr = &f;
            s.spawn(move || fr(start, chunk));
        }
    });
}

/// [`par_row_ranges_with`] at the global worker count.
pub fn par_row_ranges<T, F>(data: &mut [T], row_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_row_ranges_with(threads(), data, row_len, f);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_clamps_and_defaults() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(1_000_000), MAX_THREADS);
    }

    #[test]
    fn covers_every_row_exactly_once() {
        for nt in [1usize, 2, 3, 7, 16] {
            let rows = 13;
            let row_len = 4;
            let mut data = vec![0u32; rows * row_len];
            par_row_ranges_with(nt, &mut data, row_len, |first_row, chunk| {
                for (i, row) in chunk.chunks_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (first_row + i) as u32 + 1;
                    }
                }
            });
            let expect: Vec<u32> =
                (0..rows).flat_map(|r| std::iter::repeat(r as u32 + 1).take(row_len)).collect();
            assert_eq!(data, expect, "nt={nt}");
        }
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        // Same per-row computation => identical buffers for any split.
        let rows = 29;
        let row_len = 3;
        let run = |nt: usize| {
            let mut data = vec![0f32; rows * row_len];
            par_row_ranges_with(nt, &mut data, row_len, |first_row, chunk| {
                for (i, row) in chunk.chunks_mut(row_len).enumerate() {
                    let r = first_row + i;
                    for (c, v) in row.iter_mut().enumerate() {
                        *v = ((r * 31 + c) as f32).sin() * 0.37 + (r as f32).sqrt();
                    }
                }
            });
            data
        };
        let serial = run(1);
        for nt in [2usize, 4, 8] {
            assert_eq!(run(nt), serial, "nt={nt}");
        }
    }

    #[test]
    fn threads_for_gates_small_work() {
        assert_eq!(threads_for(8), 1);
        assert!(threads_for(PAR_MIN_WORK) >= 1);
    }

    #[test]
    fn par_min_work_override_roundtrip() {
        // The tuned floor shadows the default and 0 restores it.
        // (Transiently visible to concurrently-running tests, which is
        // fine: the floor only selects a split, never changes results.)
        assert_eq!(par_min_work(), PAR_MIN_WORK);
        assert_eq!(set_par_min_work(1 << 14), 1 << 14);
        assert_eq!(par_min_work(), 1 << 14);
        assert_eq!(set_par_min_work(0), PAR_MIN_WORK);
        assert_eq!(par_min_work(), PAR_MIN_WORK);
    }

    #[test]
    fn single_row_buffer_column_split() {
        // row_len == 1 treats each element as a row (column split of a
        // single GEMV output).
        let mut data = vec![0usize; 10];
        par_row_ranges_with(4, &mut data, 1, |first, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = first + i;
            }
        });
        assert_eq!(data, (0..10).collect::<Vec<_>>());
    }
}
