//! Deterministic PRNG: SplitMix64 core (bit-identical to
//! `python/compile/corpus.py::SplitMix64` — pinned by tests on both
//! sides) plus the distribution helpers the quantizers and workload
//! generators need.

/// SplitMix64: tiny, fast, full-period 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)`. Matches the python generator's
    /// simple modulo reduction (bias is irrelevant at our n << 2^64).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        self.next_u64() as f64 / 2f64.powi(64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.uniform()).max(1e-300); // avoid ln(0)
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Random sign in {-1.0, +1.0}.
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Pick a random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Heavy-tailed "LLM-like" weight sample: mostly gaussian with a few
    /// large-magnitude outliers (used by synthetic quantizer tests).
    pub fn heavy_tailed(&mut self, outlier_prob: f64, outlier_scale: f32) -> f32 {
        let base = self.normal();
        if self.uniform() < outlier_prob {
            base * outlier_scale
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Pinned in python/tests/test_corpus.py as well: the two sides
        // must never drift.
        let mut r = Rng::new(42);
        assert_eq!(r.next_u64(), 13679457532755275413);
        assert_eq!(r.next_u64(), 2949826092126892291);
        assert_eq!(r.next_u64(), 5139283748462763858);
    }

    #[test]
    fn determinism() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs = r.normal_vec(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn signs_are_pm_one() {
        let mut r = Rng::new(5);
        let mut seen_pos = false;
        let mut seen_neg = false;
        for _ in 0..100 {
            let s = r.sign();
            assert!(s == 1.0 || s == -1.0);
            seen_pos |= s == 1.0;
            seen_neg |= s == -1.0;
        }
        assert!(seen_pos && seen_neg);
    }
}
