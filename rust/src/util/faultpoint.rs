//! Deterministic, named fault-injection points.
//!
//! Production code crosses named points (`fault_point!("kvpool.alloc")`,
//! [`hit`], [`hit_val`]); a *fault plan* — parsed from the
//! `PALLAS_FAULTS` env var or installed programmatically
//! ([`install`], [`scenario`]) — decides which crossings misbehave.
//! With no plan installed the crossing is one relaxed atomic load, so
//! instrumented hot paths stay effectively free.
//!
//! **Spec grammar** (`;`-separated entries):
//!
//! - `seed=S` — seed for probabilistic triggers (default 0).
//! - `name=panic@N` / `name=err@N` — fire once, on the Nth crossing
//!   of `name` (1-based).
//! - `name=panic%P` / `name=err%P` — fire on each crossing with
//!   probability P% , decided by a per-point deterministic generator
//!   seeded from `seed ^ hash(name)` — the same spec always yields
//!   the same firing pattern.
//! - `name=panic#V` / `name=err#V` — fire whenever the crossing
//!   reports value `V` through [`hit_val`] (content-keyed faults:
//!   "this token id poisons the forward pass").
//!
//! `panic` actions unwind right at the crossing (the containment
//! machinery under test must catch them); `err` actions make [`hit`]
//! return [`Fault::Err`] so the call site takes its error path.
//!
//! **Determinism.** Every trigger is a pure function of the plan and
//! the per-point crossing history — no wall clock, no OS entropy — so
//! a failing chaos run replays exactly from its `PALLAS_FAULTS`
//! string.
//!
//! **Tests.** Fault plans are process-global; concurrent tests in one
//! binary would interfere. [`scenario`] therefore hands out a guard
//! holding a global lock: tests that inject (or must be isolated from
//! injection — pass `""`) serialize, and dropping the guard clears
//! the plan.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, Once, OnceLock};

use crate::util::rng::Rng;

/// Whether any plan is installed — the fast-path gate.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

/// Outcome of crossing a fault point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Proceed normally.
    None,
    /// Take the call site's error path.
    Err,
}

impl Fault {
    pub fn is_err(self) -> bool {
        self == Fault::Err
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Action {
    Panic,
    Err,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Trigger {
    /// Fire once, on the Nth crossing (1-based).
    Nth(u64),
    /// Fire with probability P% per crossing (deterministic).
    Percent(u64),
    /// Fire when `hit_val` reports exactly this value.
    Value(u64),
}

#[derive(Debug)]
struct Point {
    action: Action,
    trigger: Trigger,
    hits: u64,
    rng: Rng,
}

#[derive(Debug, Default)]
struct Plan {
    points: HashMap<String, Point>,
}

fn plan_cell() -> &'static Mutex<Option<Plan>> {
    static PLAN: OnceLock<Mutex<Option<Plan>>> = OnceLock::new();
    PLAN.get_or_init(|| Mutex::new(None))
}

fn scenario_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Survive mutex poisoning: a panic *is* the expected behavior of a
/// `panic`-action point, and must not wedge every later crossing.
fn lock_plan() -> MutexGuard<'static, Option<Plan>> {
    plan_cell().lock().unwrap_or_else(|e| e.into_inner())
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Parse and install a fault plan. An empty spec installs an empty
/// plan (nothing fires, but [`hit`] still consults it). Errors leave
/// the previous plan untouched.
pub fn install(spec: &str) -> Result<(), String> {
    let plan = parse_plan(spec)?;
    *lock_plan() = Some(plan);
    ACTIVE.store(true, Ordering::Release);
    Ok(())
}

/// Check a spec for well-formedness without installing it (config
/// files validate at load time, install at server start).
pub fn validate(spec: &str) -> Result<(), String> {
    parse_plan(spec).map(|_| ())
}

fn parse_plan(spec: &str) -> Result<Plan, String> {
    let mut seed = 0u64;
    let mut entries: Vec<(String, Action, Trigger)> = Vec::new();
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, rhs) = part
            .split_once('=')
            .ok_or_else(|| format!("fault entry {part:?} is not name=action"))?;
        let (name, rhs) = (name.trim(), rhs.trim());
        if name == "seed" {
            seed = rhs.parse::<u64>().map_err(|e| format!("bad seed {rhs:?}: {e}"))?;
            continue;
        }
        let sep = rhs
            .find(['@', '%', '#'])
            .ok_or_else(|| format!("fault action {rhs:?} needs one of @N %P #V"))?;
        let action = match &rhs[..sep] {
            "panic" => Action::Panic,
            "err" => Action::Err,
            other => return Err(format!("unknown fault action {other:?} (panic|err)")),
        };
        let num: u64 = rhs[sep + 1..]
            .parse()
            .map_err(|e| format!("bad fault trigger number in {rhs:?}: {e}"))?;
        let trigger = match rhs.as_bytes()[sep] {
            b'@' => {
                if num == 0 {
                    return Err("@N triggers are 1-based; @0 never fires".into());
                }
                Trigger::Nth(num)
            }
            b'%' => {
                if num > 100 {
                    return Err(format!("%P must be 0..=100, got {num}"));
                }
                Trigger::Percent(num)
            }
            _ => Trigger::Value(num),
        };
        entries.push((name.to_string(), action, trigger));
    }
    let mut points = HashMap::new();
    for (name, action, trigger) in entries {
        let rng = Rng::new(seed ^ fnv1a(&name));
        points.insert(name, Point { action, trigger, hits: 0, rng });
    }
    Ok(Plan { points })
}

/// Remove any installed plan; crossings go back to the free path.
pub fn clear() {
    ACTIVE.store(false, Ordering::Release);
    *lock_plan() = None;
}

/// Crossings of `name` so far under the current plan (diagnostics).
pub fn hits(name: &str) -> u64 {
    lock_plan().as_ref().and_then(|p| p.points.get(name)).map_or(0, |p| p.hits)
}

fn env_init() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("PALLAS_FAULTS") {
            if let Err(e) = install(&spec) {
                // A malformed env spec must not take the process down
                // from an arbitrary fault-point crossing.
                eprintln!("PALLAS_FAULTS ignored: {e}");
            }
        }
    });
}

/// Cross the named fault point. Panics here if the plan says `panic`;
/// returns [`Fault::Err`] if it says `err`; otherwise [`Fault::None`].
pub fn hit(name: &str) -> Fault {
    check(name, None)
}

/// Cross the named fault point, reporting a content value that `#V`
/// triggers match against (e.g. the token id being decoded).
pub fn hit_val(name: &str, val: u64) -> Fault {
    check(name, Some(val))
}

fn check(name: &str, val: Option<u64>) -> Fault {
    env_init();
    if !ACTIVE.load(Ordering::Relaxed) {
        return Fault::None;
    }
    let mut guard = lock_plan();
    let Some(plan) = guard.as_mut() else {
        return Fault::None;
    };
    let Some(point) = plan.points.get_mut(name) else {
        return Fault::None;
    };
    point.hits += 1;
    let fire = match point.trigger {
        Trigger::Nth(n) => point.hits == n,
        Trigger::Percent(p) => point.rng.next_u64() % 100 < p,
        Trigger::Value(v) => val == Some(v),
    };
    if !fire {
        return Fault::None;
    }
    match point.action {
        Action::Err => Fault::Err,
        Action::Panic => {
            drop(guard); // release before unwinding: later crossings must not see a poisoned lock
            panic!("injected fault at {name}");
        }
    }
}

/// RAII scope for tests: serializes against every other scenario in
/// the process (fault plans are global), installs `spec`, and clears
/// the plan on drop. Pass `""` to hold the lock without injecting
/// (isolates a test *from* injection). Panics on a malformed spec.
pub fn scenario(spec: &str) -> FaultGuard {
    let serial = scenario_lock().lock().unwrap_or_else(|e| e.into_inner());
    // Force the one-time PALLAS_FAULTS install to happen *now*: if it
    // fired lazily at the first crossing, it would land mid-test and
    // override the plan installed here.
    env_init();
    install(spec).expect("valid fault spec");
    FaultGuard { _serial: serial }
}

/// Guard returned by [`scenario`]; clears the plan when dropped.
pub struct FaultGuard {
    _serial: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        clear();
    }
}

/// Cross a named fault point. One argument: only `panic` actions can
/// fire (an `err` plan entry is ignored at a panic-only site). Two
/// arguments: on an `err` action, evaluate the second argument —
/// typically `return <error value>` — so the call site takes its
/// normal error path.
#[macro_export]
macro_rules! fault_point {
    ($name:expr) => {
        let _ = $crate::util::faultpoint::hit($name);
    };
    ($name:expr, $on_err:expr) => {
        if $crate::util::faultpoint::hit($name).is_err() {
            $on_err;
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_is_a_noop() {
        let _g = scenario("");
        assert_eq!(hit("test.nowhere"), Fault::None);
        assert_eq!(hit_val("test.nowhere", 7), Fault::None);
    }

    #[test]
    fn nth_trigger_fires_exactly_once() {
        let _g = scenario("test.nth=err@3");
        assert_eq!(hit("test.nth"), Fault::None);
        assert_eq!(hit("test.nth"), Fault::None);
        assert_eq!(hit("test.nth"), Fault::Err);
        assert_eq!(hit("test.nth"), Fault::None, "@N fires once, not from N on");
        assert_eq!(hits("test.nth"), 4);
    }

    #[test]
    fn value_trigger_matches_only_its_value() {
        let _g = scenario("test.val=err#42");
        assert_eq!(hit_val("test.val", 41), Fault::None);
        assert_eq!(hit_val("test.val", 42), Fault::Err);
        assert_eq!(hit_val("test.val", 42), Fault::Err, "value triggers fire every match");
        assert_eq!(hit("test.val"), Fault::None, "no value reported, no match");
    }

    #[test]
    fn percent_trigger_is_deterministic_per_seed() {
        let pattern = |seed: u64| -> Vec<bool> {
            let _g = scenario(&format!("seed={seed};test.pct=err%30"));
            (0..64).map(|_| hit("test.pct").is_err()).collect()
        };
        let a = pattern(7);
        let b = pattern(7);
        assert_eq!(a, b, "same seed, same firing pattern");
        let c = pattern(8);
        assert_ne!(a, c, "different seed, different pattern");
        let fired = a.iter().filter(|&&f| f).count();
        assert!(fired > 0 && fired < 64, "~30% should fire, got {fired}/64");
    }

    #[test]
    fn panic_action_unwinds_at_the_crossing() {
        let _g = scenario("test.boom=panic@1");
        let r = std::panic::catch_unwind(|| hit("test.boom"));
        let msg = *r.expect_err("must panic").downcast::<String>().expect("string payload");
        assert!(msg.contains("injected fault at test.boom"), "{msg}");
        assert_eq!(hit("test.boom"), Fault::None, "plan survives the unwind");
    }

    #[test]
    fn guard_drop_clears_the_plan() {
        {
            let _g = scenario("test.tmp=err@1");
            assert_eq!(hit("test.tmp"), Fault::Err);
        }
        let _g = scenario("");
        assert_eq!(hit("test.tmp"), Fault::None, "cleared on drop");
    }

    #[test]
    fn macro_forms_compile_and_route() {
        fn guarded() -> Result<u32, String> {
            crate::fault_point!("test.macro", return Err("injected".into()));
            Ok(5)
        }
        let _g = scenario("test.macro=err@1");
        assert_eq!(guarded(), Err("injected".into()));
        assert_eq!(guarded(), Ok(5));
        crate::fault_point!("test.macro.panic_only");
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            "noequals",
            "x=frob@1",
            "x=panic",
            "x=panic@zero",
            "x=err%101",
            "x=err@0",
            "seed=banana",
        ] {
            assert!(install(bad).is_err(), "{bad:?} must be rejected");
        }
    }
}
