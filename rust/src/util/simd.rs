//! Runtime CPU-feature dispatch for the SIMD kernel lanes.
//!
//! Every hot kernel (packed-popcount Hamming, the sign-GEMM
//! accumulate, the LUT-GEMM gather, `matmul_bt`'s dot product) keeps
//! its scalar body as the bit-identity oracle and gains vector lanes
//! selected here at runtime:
//!
//! - **x86-64**: AVX2 (+FMA, +POPCNT) via `is_x86_feature_detected!`.
//!   AVX-512 with VPOPCNTDQ is *detected* and reportable as its own
//!   level, but its kernel bodies currently compile against the
//!   stable target-feature whitelist (the AVX-512 attribute set needs
//!   a newer rustc floor than this crate assumes), so the Avx512
//!   level selects the widest stably-compiled lane. When the floor
//!   rises, only the lane bodies change — no call site moves.
//! - **aarch64**: NEON (`vcnt`-based popcount, `fmla` dot lanes).
//! - anywhere else: scalar.
//!
//! `PALLAS_SIMD=scalar|avx2|avx512|neon` force-overrides detection
//! (for CI matrices and A/B benching). A forced level the hardware
//! cannot run falls back down the chain avx512 → avx2 → scalar /
//! neon → scalar instead of crashing on an illegal instruction.
//!
//! The active level is process-global, resolved once on first use;
//! engines additionally capture it at construction so a prepared
//! engine's lane never changes mid-serve. Tests that need a specific
//! lane use the explicit `*_with_level` kernel variants (or the
//! engines' `*_with_level` constructors) rather than mutating the
//! global, so parallel test threads cannot race each other's
//! dispatch; whole-suite forcing goes through the env var (one value
//! per process — the CI matrix legs).

use std::sync::atomic::{AtomicU8, Ordering};

/// A dispatchable kernel lane, ordered roughly by width.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Level {
    /// Portable Rust, no feature gates — the bit-identity oracle.
    Scalar = 0,
    /// x86-64 AVX2 + FMA + POPCNT.
    Avx2 = 1,
    /// x86-64 AVX-512F + VPOPCNTDQ (detection-complete; see module
    /// docs for the current lane-body story).
    Avx512 = 2,
    /// aarch64 NEON (`vcnt`, `fmla`).
    Neon = 3,
}

impl Level {
    /// The `PALLAS_SIMD` spelling of this level (also what `/metrics`
    /// and the startup log report).
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Avx2 => "avx2",
            Level::Avx512 => "avx512",
            Level::Neon => "neon",
        }
    }

    /// Parse a `PALLAS_SIMD` value (case-insensitive).
    pub fn parse(s: &str) -> Result<Level, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(Level::Scalar),
            "avx2" => Ok(Level::Avx2),
            "avx512" => Ok(Level::Avx512),
            "neon" => Ok(Level::Neon),
            other => Err(format!(
                "unknown SIMD level '{other}' (expected scalar|avx2|avx512|neon)"
            )),
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            1 => Level::Avx2,
            2 => Level::Avx512,
            3 => Level::Neon,
            _ => Level::Scalar,
        }
    }

    /// The next-narrower level to try when this one is unsupported.
    fn fallback(self) -> Option<Level> {
        match self {
            Level::Avx512 => Some(Level::Avx2),
            Level::Avx2 | Level::Neon => Some(Level::Scalar),
            Level::Scalar => None,
        }
    }
}

/// Whether the running CPU (and OS) can execute `level`'s lanes.
pub fn detected(level: Level) -> bool {
    match level {
        Level::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => {
            is_x86_feature_detected!("avx2")
                && is_x86_feature_detected!("fma")
                && is_x86_feature_detected!("popcnt")
        }
        #[cfg(target_arch = "x86_64")]
        Level::Avx512 => {
            is_x86_feature_detected!("avx512f")
                && is_x86_feature_detected!("avx512vpopcntdq")
                && detected(Level::Avx2)
        }
        #[cfg(target_arch = "aarch64")]
        Level::Neon => std::arch::is_aarch64_feature_detected!("neon"),
        #[allow(unreachable_patterns)]
        _ => false,
    }
}

/// The widest level this machine supports.
pub fn detect_best() -> Level {
    for l in [Level::Avx512, Level::Avx2, Level::Neon] {
        if detected(l) {
            return l;
        }
    }
    Level::Scalar
}

/// Every level the machine supports (always contains `Scalar`) — the
/// iteration set for the forced-variant equivalence suite.
pub fn supported_levels() -> Vec<Level> {
    let mut out = vec![Level::Scalar];
    for l in [Level::Avx2, Level::Avx512, Level::Neon] {
        if detected(l) {
            out.push(l);
        }
    }
    out
}

/// Clamp a requested level to something the machine can run, walking
/// the fallback chain (avx512 → avx2 → scalar, neon → scalar).
pub fn supported_or_fallback(requested: Level) -> Level {
    let mut cur = requested;
    loop {
        if detected(cur) {
            return cur;
        }
        match cur.fallback() {
            Some(next) => cur = next,
            None => return Level::Scalar,
        }
    }
}

/// Resolve a `PALLAS_SIMD`-style request: `None`/empty = detect,
/// unknown names warn and detect, supported-but-absent hardware walks
/// the fallback chain.
pub fn resolve(requested: Option<&str>) -> Level {
    match requested.map(str::trim).filter(|s| !s.is_empty()) {
        None => detect_best(),
        Some(s) => match Level::parse(s) {
            Ok(l) => supported_or_fallback(l),
            Err(e) => {
                eprintln!("[simd] PALLAS_SIMD ignored: {e}");
                detect_best()
            }
        },
    }
}

const UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

/// The process-global active dispatch level, resolved once from
/// `PALLAS_SIMD` (else detection) on first use.
pub fn active() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        UNSET => {
            let l = resolve(std::env::var("PALLAS_SIMD").ok().as_deref());
            LEVEL.store(l as u8, Ordering::Relaxed);
            l
        }
        v => Level::from_u8(v),
    }
}

/// Force the global level (benches A/B-ing lanes in-process; the
/// serve CLI never calls this). The request is clamped through the
/// fallback chain; the *effective* level is stored and returned.
/// Engines built before this call keep their construction-time level.
pub fn set_level(requested: Level) -> Level {
    let eff = supported_or_fallback(requested);
    LEVEL.store(eff as u8, Ordering::Relaxed);
    eff
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_all_names() {
        for l in [Level::Scalar, Level::Avx2, Level::Avx512, Level::Neon] {
            assert_eq!(Level::parse(l.name()).unwrap(), l);
            assert_eq!(Level::parse(&l.name().to_uppercase()).unwrap(), l);
            assert_eq!(Level::from_u8(l as u8), l);
        }
        assert!(Level::parse("sse9").is_err());
    }

    #[test]
    fn fallback_chain_terminates_at_scalar() {
        for l in [Level::Scalar, Level::Avx2, Level::Avx512, Level::Neon] {
            let mut cur = l;
            let mut steps = 0;
            while let Some(next) = cur.fallback() {
                cur = next;
                steps += 1;
                assert!(steps <= 2, "chain too long from {l:?}");
            }
            assert_eq!(cur, Level::Scalar);
        }
    }

    #[test]
    fn resolve_is_always_supported() {
        // Whatever is asked for, the resolved level must actually run
        // here — the whole point of the fallback chain.
        let reqs = [
            None,
            Some(""),
            Some("scalar"),
            Some("avx2"),
            Some("avx512"),
            Some("neon"),
            Some("bogus"),
        ];
        for req in reqs {
            let l = resolve(req);
            assert!(detected(l), "resolve({req:?}) -> {l:?} not runnable");
        }
        assert_eq!(resolve(Some("scalar")), Level::Scalar);
    }

    #[test]
    fn supported_levels_contains_scalar_and_best() {
        let s = supported_levels();
        assert!(s.contains(&Level::Scalar));
        assert!(s.contains(&detect_best()));
        for l in s {
            assert!(detected(l));
        }
    }

    #[test]
    fn active_is_stable_and_supported() {
        let a = active();
        assert!(detected(a));
        assert_eq!(active(), a, "resolution is sticky");
    }
}
