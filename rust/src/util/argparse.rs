//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `subcommand --key value --flag positional` grammar:
//! the launcher (`rust/src/main.rs`) and every example/bench use this.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token (e.g. `quantize`, `serve`), if any.
    pub subcommand: Option<String>,
    /// `--key value` pairs. `--flag` with no value is stored as "true".
    pub options: HashMap<String, String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable) — `tokens` excludes argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(key) = t.strip_prefix("--") {
                // --key=value or --key value or bare --flag
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.options.insert(key.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.options.insert(key.to_string(), "true".to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse the process command line.
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("quantize --model tinylm_m --bits 0.8 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("quantize"));
        assert_eq!(a.get("model"), Some("tinylm_m"));
        assert_eq!(a.get_f64("bits", 1.0), 0.8);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("eval --bits=0.7 --out=x.txt");
        assert_eq!(a.get("bits"), Some("0.7"));
        assert_eq!(a.get("out"), Some("x.txt"));
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse("bench table1 table2 --quick");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["table1", "table2"]);
        assert!(a.flag("quick"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert!(a.subcommand.is_none());
        assert_eq!(a.get_usize("n", 5), 5);
        assert_eq!(a.get_or("x", "d"), "d");
        assert!(!a.flag("missing"));
    }

    #[test]
    fn flag_before_positional_consumes_value() {
        let a = parse("serve --port 8080");
        assert_eq!(a.get_usize("port", 0), 8080);
        assert!(a.positional.is_empty());
    }
}
