//! Tiny leveled logger (env-controlled via `BTC_LOG=debug|info|warn|error`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_level() -> u8 {
    let lvl = match std::env::var("BTC_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_level();
    }
    level as u8 >= cur
}

/// Override the level programmatically (tests, `--verbose`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{:>10}.{:03} {} {}] {}", t.as_secs(), t.subsec_millis(), tag, module, msg);
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), &format!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), &format!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, module_path!(), &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
