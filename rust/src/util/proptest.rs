//! Property-based testing helper (proptest/quickcheck are unavailable
//! offline). Generates N random cases from a seeded [`Rng`]; on failure
//! reports the case seed so the exact input reproduces with
//! `check_with_seed`. Shrinking is replaced by deterministic replay —
//! adequate for the numeric invariants this crate checks.

use super::rng::Rng;

/// Run `prop` on `cases` random inputs produced by `gen`.
///
/// Panics with the failing case seed + message on the first violation.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        let seed = 0x9E37_79B9u64
            .wrapping_mul(case as u64 + 1)
            .wrapping_add(fxhash(name));
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Re-run a single failing case by seed (for debugging).
pub fn check_with_seed<T, G, P>(name: &str, seed: u64, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    if let Err(msg) = prop(&input) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}\ninput: {input:?}");
    }
}

/// Tiny FNV-style string hash for per-property seed derivation.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("abs_nonneg", 50, |r| r.normal(), |x| {
            if x.abs() >= 0.0 { Ok(()) } else { Err("abs < 0".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn failing_property_panics_with_seed() {
        check("always_fails", 3, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_cases() {
        let mut seen1 = Vec::new();
        check("det", 5, |r| r.next_u64(), |x| {
            seen1.push(*x);
            Ok(())
        });
        let mut seen2 = Vec::new();
        check("det", 5, |r| r.next_u64(), |x| {
            seen2.push(*x);
            Ok(())
        });
        assert_eq!(seen1, seen2);
    }

    #[test]
    fn close_helper() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }
}
