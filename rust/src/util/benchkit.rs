//! In-repo micro/throughput benchmarking harness (criterion is not in
//! the offline vendor set). Used by every `cargo bench` target.
//!
//! Method: warmup, then timed iterations with per-iteration samples;
//! reports min/mean/p50/p95 and derived throughput. Benches print
//! paper-shaped tables via [`Table`] and emit machine-readable
//! `BENCHLINE` rows for EXPERIMENTS.md tooling.
//!
//! The JSON side round-trips: [`JsonReport`] writes `BENCH_<exp>.json`
//! and [`parse_report`] reads it back, so [`compare_reports`] can gate
//! a current run against a committed baseline snapshot (per-metric
//! direction + regression tolerance via [`Gate`]) and render a
//! markdown delta table for CI — see examples/perf_compare.rs.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples_ns: Vec<u64>,
}

impl BenchStats {
    pub fn min_ns(&self) -> u64 {
        *self.samples_ns.iter().min().unwrap_or(&0)
    }
    pub fn mean_ns(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns.iter().sum::<u64>() as f64 / self.samples_ns.len() as f64
    }
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let mut s = self.samples_ns.clone();
        s.sort_unstable();
        percentile_sorted(&s, p)
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns() / 1e6
    }
    /// ops/sec given work per iteration.
    pub fn throughput(&self, work_per_iter: f64) -> f64 {
        work_per_iter / (self.mean_ns() / 1e9)
    }
    pub fn summary(&self) -> String {
        format!(
            "{}: mean {:.3} ms  p50 {:.3} ms  p95 {:.3} ms  min {:.3} ms  (n={})",
            self.name,
            self.mean_ms(),
            self.percentile_ns(0.5) as f64 / 1e6,
            self.percentile_ns(0.95) as f64 / 1e6,
            self.min_ns() as f64 / 1e6,
            self.samples_ns.len()
        )
    }
}

/// Nearest-rank percentile of an already-sorted sample set (0 when
/// empty; `p` clamped to [0, 1]). The single percentile definition
/// shared by benches and the serving metrics.
pub fn percentile_sorted(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    sorted[(((sorted.len() - 1) as f64) * p.clamp(0.0, 1.0)).round() as usize]
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    BenchStats { name: name.to_string(), samples_ns: samples }
}

/// Run `f` repeatedly until ~`budget_ms` of samples collected (at least
/// `min_iters`). Adapts to very fast or very slow bodies.
pub fn bench_for_ms<F: FnMut()>(name: &str, budget_ms: u64, min_iters: usize, mut f: F) -> BenchStats {
    f(); // warmup / lazy init
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_millis() < budget_ms as u128 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
        if samples.len() > 1_000_000 {
            break;
        }
    }
    BenchStats { name: name.to_string(), samples_ns: samples }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer for paper-shaped output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }
    pub fn rowv(&mut self, cells: Vec<String>) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep);
        for row in &self.rows {
            line(row);
        }
    }
}

/// Machine-readable result line (grep-able into EXPERIMENTS.md).
pub fn benchline(exp: &str, kv: &[(&str, String)]) {
    let body: Vec<String> = kv.iter().map(|(k, v)| format!("{k}={v}")).collect();
    println!("BENCHLINE exp={} {}", exp, body.join(" "));
}

/// Accumulates bench rows and writes them as `BENCH_<exp>.json` when
/// the `BENCH_JSON` env var is set (the CI perf-smoke job uploads these
/// as artifacts; committed snapshots seed the perf trajectory).
pub struct JsonReport {
    exp: String,
    rows: Vec<Vec<(String, String)>>,
}

impl JsonReport {
    pub fn new(exp: &str) -> JsonReport {
        JsonReport { exp: exp.to_string(), rows: Vec::new() }
    }

    /// Record one result row (same shape as a [`benchline`] call).
    pub fn row(&mut self, kv: &[(&str, String)]) {
        self.rows.push(kv.iter().map(|(k, v)| (k.to_string(), v.clone())).collect());
    }

    fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("{{\n  \"exp\": \"{}\",\n  \"rows\": [\n", json_escape(&self.exp)));
        for (i, row) in self.rows.iter().enumerate() {
            let cells: Vec<String> = row
                .iter()
                .map(|(k, v)| format!("\"{}\": {}", json_escape(k), json_value(v)))
                .collect();
            s.push_str(&format!("    {{{}}}{}\n", cells.join(", "), if i + 1 < self.rows.len() { "," } else { "" }));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write `BENCH_<exp>.json` into the current directory if the
    /// `BENCH_JSON` env var is set. Returns the path written, if any.
    pub fn write_if_enabled(&self) -> Option<std::path::PathBuf> {
        std::env::var("BENCH_JSON").ok()?;
        let path = std::path::PathBuf::from(format!("BENCH_{}.json", self.exp));
        match std::fs::write(&path, self.render()) {
            Ok(()) => {
                println!("wrote {}", path.display());
                Some(path)
            }
            Err(e) => {
                eprintln!("BENCH_JSON write failed ({}): {e}", path.display());
                None
            }
        }
    }
}

// ---------------------------------------------------------------------
// Compare mode: parse committed BENCH_*.json snapshots back in and gate
// named metrics against a baseline (the CI perf-regression step — see
// examples/perf_compare.rs and benches/baseline/README.md).
// ---------------------------------------------------------------------

/// A scalar cell from a parsed bench report.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonVal {
    Num(f64),
    Str(String),
}

impl JsonVal {
    pub fn as_num(&self) -> Option<f64> {
        match self {
            JsonVal::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Render for row-identity keys: integers without a trailing `.0`.
    fn key_text(&self) -> String {
        match self {
            JsonVal::Str(s) => s.clone(),
            JsonVal::Num(v) if v.fract() == 0.0 && v.abs() < 1e15 => format!("{:.0}", v),
            JsonVal::Num(v) => format!("{v}"),
        }
    }
}

/// A parsed `BENCH_<exp>.json` report (the [`JsonReport`] shape).
#[derive(Debug, Clone)]
pub struct ParsedReport {
    pub exp: String,
    pub rows: Vec<Vec<(String, JsonVal)>>,
}

impl ParsedReport {
    pub fn field<'a>(row: &'a [(String, JsonVal)], name: &str) -> Option<&'a JsonVal> {
        row.iter().find(|(k, _)| k == name).map(|(_, v)| v)
    }
}

enum Node {
    Num(f64),
    Str(String),
    Arr(Vec<Node>),
    Obj(Vec<(String, Node)>),
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8, String> {
        self.ws();
        self.b.get(self.i).copied().ok_or_else(|| "unexpected end of JSON".to_string())
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        let got = self.peek()?;
        if got != c {
            return Err(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, got as char
            ));
        }
        self.i += 1;
        Ok(())
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or("unterminated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("truncated \\u escape")?;
                            self.i += 4;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).ok_or("bad \\u codepoint")?);
                        }
                        other => return Err(format!("unknown escape '\\{}'", other as char)),
                    }
                }
                _ => {
                    // Re-join multi-byte UTF-8 sequences.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| "invalid UTF-8 in string")?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<f64, String> {
        self.ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn node(&mut self) -> Result<Node, String> {
        match self.peek()? {
            b'"' => Ok(Node::Str(self.string()?)),
            b'{' => {
                self.eat(b'{')?;
                let mut fields = Vec::new();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(Node::Obj(fields));
                }
                loop {
                    let key = self.string()?;
                    self.eat(b':')?;
                    fields.push((key, self.node()?));
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(Node::Obj(fields));
                        }
                        c => return Err(format!("expected ',' or '}}', found '{}'", c as char)),
                    }
                }
            }
            b'[' => {
                self.eat(b'[')?;
                let mut items = Vec::new();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(Node::Arr(items));
                }
                loop {
                    items.push(self.node()?);
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(Node::Arr(items));
                        }
                        c => return Err(format!("expected ',' or ']', found '{}'", c as char)),
                    }
                }
            }
            _ => Ok(Node::Num(self.number()?)),
        }
    }
}

/// Parse a `BENCH_<exp>.json` report (the exact subset [`JsonReport`]
/// emits: a top-level object with a string `exp` and a `rows` array of
/// flat string/number objects).
pub fn parse_report(text: &str) -> Result<ParsedReport, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let Node::Obj(top) = p.node()? else {
        return Err("report root must be an object".into());
    };
    let mut exp = None;
    let mut rows = Vec::new();
    for (key, node) in top {
        match (key.as_str(), node) {
            ("exp", Node::Str(s)) => exp = Some(s),
            ("rows", Node::Arr(items)) => {
                for item in items {
                    let Node::Obj(fields) = item else {
                        return Err("each row must be an object".into());
                    };
                    let mut row = Vec::with_capacity(fields.len());
                    for (k, v) in fields {
                        let cell = match v {
                            Node::Num(n) => JsonVal::Num(n),
                            Node::Str(s) => JsonVal::Str(s),
                            _ => return Err(format!("row field '{k}' must be scalar")),
                        };
                        row.push((k, cell));
                    }
                    rows.push(row);
                }
            }
            _ => {} // ignore unknown top-level fields
        }
    }
    Ok(ParsedReport { exp: exp.ok_or("report missing 'exp'")?, rows })
}

/// One gated metric: which direction is good, and how much regression
/// (percent, in the bad direction) the gate tolerates.
#[derive(Debug, Clone)]
pub struct Gate {
    pub metric: String,
    pub higher_is_better: bool,
    pub max_regress_pct: f64,
}

impl Gate {
    pub fn higher(metric: &str, max_regress_pct: f64) -> Gate {
        Gate { metric: metric.to_string(), higher_is_better: true, max_regress_pct }
    }

    pub fn lower(metric: &str, max_regress_pct: f64) -> Gate {
        Gate { metric: metric.to_string(), higher_is_better: false, max_regress_pct }
    }
}

/// One baseline-vs-current metric comparison.
#[derive(Debug, Clone)]
pub struct Delta {
    pub row_key: String,
    pub metric: String,
    pub baseline: f64,
    pub current: f64,
    /// Signed raw change: `(current - baseline) / baseline * 100`.
    pub change_pct: f64,
    /// Movement in the *bad* direction for this gate (>= 0).
    pub regress_pct: f64,
    /// `regress_pct` exceeded the gate's tolerance.
    pub regressed: bool,
}

/// Outcome of comparing one experiment's report pair.
#[derive(Debug, Clone, Default)]
pub struct CompareOutcome {
    pub deltas: Vec<Delta>,
    /// Row keys present only in the current run (new scenarios).
    pub only_in_current: Vec<String>,
    /// Row keys present only in the baseline (dropped scenarios).
    pub only_in_baseline: Vec<String>,
}

impl CompareOutcome {
    pub fn regressions(&self) -> usize {
        self.deltas.iter().filter(|d| d.regressed).count()
    }

    /// GitHub-flavored markdown delta table (for
    /// `$GITHUB_STEP_SUMMARY`).
    pub fn markdown(&self, title: &str) -> String {
        fn num(v: f64) -> String {
            if v.fract() == 0.0 && v.abs() < 1e15 {
                format!("{:.0}", v)
            } else {
                format!("{:.4}", v)
            }
        }
        let mut s = format!("### {title}\n\n");
        if self.deltas.is_empty() {
            s.push_str("_no comparable gated metrics_\n");
        } else {
            s.push_str("| row | metric | baseline | current | change | status |\n");
            s.push_str("|---|---|---|---|---|---|\n");
            for d in &self.deltas {
                s.push_str(&format!(
                    "| {} | {} | {} | {} | {:+.1}% | {} |\n",
                    d.row_key,
                    d.metric,
                    num(d.baseline),
                    num(d.current),
                    d.change_pct,
                    if d.regressed { "❌ regressed" } else { "✅" },
                ));
            }
        }
        for k in &self.only_in_current {
            s.push_str(&format!("\n- `{k}`: new in current run (no baseline row)"));
        }
        for k in &self.only_in_baseline {
            s.push_str(&format!("\n- `{k}`: present in baseline but missing from current run"));
        }
        s.push('\n');
        s
    }
}

/// Identity of a row for baseline matching: the values of `keys` in
/// order (missing fields render as `-`).
pub fn row_key(row: &[(String, JsonVal)], keys: &[&str]) -> String {
    if keys.is_empty() {
        return "all".to_string();
    }
    keys.iter()
        .map(|k| ParsedReport::field(row, k).map(|v| v.key_text()).unwrap_or_else(|| "-".into()))
        .collect::<Vec<_>>()
        .join("/")
}

/// Compare `current` against `baseline`: rows are matched by the
/// `keys` fields, and every [`Gate`]d metric present (as a number) in
/// both matched rows produces a [`Delta`]. Rows with a non-positive
/// baseline value for a metric are skipped (percent change is
/// meaningless).
pub fn compare_reports(
    baseline: &ParsedReport,
    current: &ParsedReport,
    keys: &[&str],
    gates: &[Gate],
) -> CompareOutcome {
    let mut out = CompareOutcome::default();
    let base_keys: Vec<String> = baseline.rows.iter().map(|r| row_key(r, keys)).collect();
    let mut matched_base = vec![false; baseline.rows.len()];
    for crow in &current.rows {
        let ckey = row_key(crow, keys);
        let Some(bi) = base_keys.iter().position(|k| *k == ckey) else {
            out.only_in_current.push(ckey);
            continue;
        };
        matched_base[bi] = true;
        let brow = &baseline.rows[bi];
        for gate in gates {
            let (Some(b), Some(c)) = (
                ParsedReport::field(brow, &gate.metric).and_then(JsonVal::as_num),
                ParsedReport::field(crow, &gate.metric).and_then(JsonVal::as_num),
            ) else {
                continue;
            };
            if b <= 0.0 {
                continue;
            }
            let change_pct = (c - b) / b * 100.0;
            let regress_pct =
                if gate.higher_is_better { -change_pct } else { change_pct }.max(0.0);
            out.deltas.push(Delta {
                row_key: ckey.clone(),
                metric: gate.metric.clone(),
                baseline: b,
                current: c,
                change_pct,
                regress_pct,
                regressed: regress_pct > gate.max_regress_pct,
            });
        }
    }
    for (bi, key) in base_keys.into_iter().enumerate() {
        if !matched_base[bi] {
            out.only_in_baseline.push(key);
        }
    }
    out
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Numbers pass through raw; everything else is a quoted string.
fn json_value(v: &str) -> String {
    match v.parse::<f64>() {
        Ok(x) if x.is_finite() => v.to_string(),
        _ => format!("\"{}\"", json_escape(v)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0u64;
        let stats = bench("t", 2, 10, || n += 1);
        assert_eq!(stats.samples_ns.len(), 10);
        assert_eq!(n, 12);
    }

    #[test]
    fn percentiles_ordered() {
        let stats = BenchStats { name: "x".into(), samples_ns: (1..=100).collect() };
        assert!(stats.percentile_ns(0.5) <= stats.percentile_ns(0.95));
        assert_eq!(stats.min_ns(), 1);
    }

    #[test]
    fn throughput_math() {
        let stats = BenchStats { name: "x".into(), samples_ns: vec![1_000_000_000] };
        let t = stats.throughput(100.0);
        assert!((t - 100.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // must not panic
    }

    #[test]
    fn json_report_renders_numbers_and_strings() {
        let mut r = JsonReport::new("serve");
        r.row(&[("backend", "BTC 0.8".to_string()), ("tokens_per_s", "123.5".to_string())]);
        let s = r.render();
        assert!(s.contains("\"exp\": \"serve\""));
        assert!(s.contains("\"backend\": \"BTC 0.8\""));
        assert!(s.contains("\"tokens_per_s\": 123.5"));
    }

    #[test]
    fn json_escapes_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_value("nan"), "\"nan\"");
        assert_eq!(json_value("-3.25"), "-3.25");
    }

    #[test]
    fn parse_roundtrips_rendered_report() {
        let mut r = JsonReport::new("serve");
        r.row(&[
            ("backend", "BTC 0.8 (LUT)".to_string()),
            ("batch", "4".to_string()),
            ("tokens_per_s", "123.5".to_string()),
        ]);
        r.row(&[("backend", "quote\"s\nand\\slashes".to_string()), ("batch", "1".to_string())]);
        let p = parse_report(&r.render()).expect("parse own output");
        assert_eq!(p.exp, "serve");
        assert_eq!(p.rows.len(), 2);
        assert_eq!(
            ParsedReport::field(&p.rows[0], "backend"),
            Some(&JsonVal::Str("BTC 0.8 (LUT)".into()))
        );
        assert_eq!(ParsedReport::field(&p.rows[0], "batch"), Some(&JsonVal::Num(4.0)));
        assert_eq!(ParsedReport::field(&p.rows[0], "tokens_per_s"), Some(&JsonVal::Num(123.5)));
        assert_eq!(
            ParsedReport::field(&p.rows[1], "backend"),
            Some(&JsonVal::Str("quote\"s\nand\\slashes".into()))
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_report("").is_err());
        assert!(parse_report("[1,2]").is_err());
        assert!(parse_report("{\"rows\": []}").is_err(), "missing exp");
        assert!(parse_report("{\"exp\": \"x\", \"rows\": [{\"a\": [1]}]}").is_err());
    }

    fn report(exp: &str, rows: &[&[(&str, &str)]]) -> ParsedReport {
        let mut r = JsonReport::new(exp);
        for row in rows {
            let kv: Vec<(&str, String)> = row.iter().map(|(k, v)| (*k, v.to_string())).collect();
            r.row(&kv);
        }
        parse_report(&r.render()).unwrap()
    }

    #[test]
    fn compare_flags_regressions_by_direction() {
        let base = report(
            "serve",
            &[
                &[("backend", "FP16"), ("batch", "1"), ("tokens_per_s", "100"), ("p50_ms", "10")],
                &[("backend", "FP16"), ("batch", "4"), ("tokens_per_s", "300"), ("p50_ms", "12")],
            ],
        );
        let cur = report(
            "serve",
            &[
                // tokens/s -40% (regression for higher-is-better),
                // p50 -50% (improvement for lower-is-better).
                &[("backend", "FP16"), ("batch", "1"), ("tokens_per_s", "60"), ("p50_ms", "5")],
                // +10% tokens/s: fine. p50 +60%: regression.
                &[("backend", "FP16"), ("batch", "4"), ("tokens_per_s", "330"), ("p50_ms", "19.2")],
            ],
        );
        let gates = [Gate::higher("tokens_per_s", 25.0), Gate::lower("p50_ms", 25.0)];
        let out = compare_reports(&base, &cur, &["backend", "batch"], &gates);
        assert_eq!(out.deltas.len(), 4);
        assert_eq!(out.regressions(), 2);
        let d0 = &out.deltas[0];
        assert_eq!(d0.row_key, "FP16/1");
        assert!(d0.regressed && (d0.regress_pct - 40.0).abs() < 1e-9);
        let d1 = &out.deltas[1]; // p50 improved
        assert!(!d1.regressed && d1.regress_pct == 0.0);
        let md = out.markdown("serve");
        assert!(md.contains("❌") && md.contains("✅") && md.contains("FP16/4"));
    }

    #[test]
    fn compare_reports_row_mismatches() {
        let base =
            report("m", &[&[("scenario", "a"), ("x", "1")], &[("scenario", "b"), ("x", "1")]]);
        let cur =
            report("m", &[&[("scenario", "a"), ("x", "1")], &[("scenario", "c"), ("x", "2")]]);
        let out = compare_reports(&base, &cur, &["scenario"], &[Gate::lower("x", 10.0)]);
        assert_eq!(out.only_in_current, vec!["c".to_string()]);
        assert_eq!(out.only_in_baseline, vec!["b".to_string()]);
        assert_eq!(out.deltas.len(), 1, "only the matched row compares");
        assert!(!out.deltas[0].regressed);
    }

    #[test]
    fn compare_skips_missing_and_nonpositive_metrics() {
        let base = report("m", &[&[("k", "a"), ("x", "0"), ("y", "5")]]);
        let cur = report("m", &[&[("k", "a"), ("x", "9"), ("z", "1")]]);
        let gates = [Gate::lower("x", 10.0), Gate::lower("y", 10.0), Gate::lower("z", 10.0)];
        let out = compare_reports(&base, &cur, &["k"], &gates);
        // x skipped (baseline 0), y skipped (missing in current),
        // z skipped (missing in baseline).
        assert!(out.deltas.is_empty());
    }
}
