//! In-repo micro/throughput benchmarking harness (criterion is not in
//! the offline vendor set). Used by every `cargo bench` target.
//!
//! Method: warmup, then timed iterations with per-iteration samples;
//! reports min/mean/p50/p95 and derived throughput. Benches print
//! paper-shaped tables via [`Table`] and emit machine-readable
//! `BENCHLINE` rows for EXPERIMENTS.md tooling.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples_ns: Vec<u64>,
}

impl BenchStats {
    pub fn min_ns(&self) -> u64 {
        *self.samples_ns.iter().min().unwrap_or(&0)
    }
    pub fn mean_ns(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns.iter().sum::<u64>() as f64 / self.samples_ns.len() as f64
    }
    pub fn percentile_ns(&self, p: f64) -> u64 {
        if self.samples_ns.is_empty() {
            return 0;
        }
        let mut s = self.samples_ns.clone();
        s.sort_unstable();
        let idx = ((s.len() - 1) as f64 * p).round() as usize;
        s[idx]
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns() / 1e6
    }
    /// ops/sec given work per iteration.
    pub fn throughput(&self, work_per_iter: f64) -> f64 {
        work_per_iter / (self.mean_ns() / 1e9)
    }
    pub fn summary(&self) -> String {
        format!(
            "{}: mean {:.3} ms  p50 {:.3} ms  p95 {:.3} ms  min {:.3} ms  (n={})",
            self.name,
            self.mean_ms(),
            self.percentile_ns(0.5) as f64 / 1e6,
            self.percentile_ns(0.95) as f64 / 1e6,
            self.min_ns() as f64 / 1e6,
            self.samples_ns.len()
        )
    }
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    BenchStats { name: name.to_string(), samples_ns: samples }
}

/// Run `f` repeatedly until ~`budget_ms` of samples collected (at least
/// `min_iters`). Adapts to very fast or very slow bodies.
pub fn bench_for_ms<F: FnMut()>(name: &str, budget_ms: u64, min_iters: usize, mut f: F) -> BenchStats {
    f(); // warmup / lazy init
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_millis() < budget_ms as u128 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
        if samples.len() > 1_000_000 {
            break;
        }
    }
    BenchStats { name: name.to_string(), samples_ns: samples }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer for paper-shaped output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }
    pub fn rowv(&mut self, cells: Vec<String>) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep);
        for row in &self.rows {
            line(row);
        }
    }
}

/// Machine-readable result line (grep-able into EXPERIMENTS.md).
pub fn benchline(exp: &str, kv: &[(&str, String)]) {
    let body: Vec<String> = kv.iter().map(|(k, v)| format!("{k}={v}")).collect();
    println!("BENCHLINE exp={} {}", exp, body.join(" "));
}

/// Accumulates bench rows and writes them as `BENCH_<exp>.json` when
/// the `BENCH_JSON` env var is set (the CI perf-smoke job uploads these
/// as artifacts; committed snapshots seed the perf trajectory).
pub struct JsonReport {
    exp: String,
    rows: Vec<Vec<(String, String)>>,
}

impl JsonReport {
    pub fn new(exp: &str) -> JsonReport {
        JsonReport { exp: exp.to_string(), rows: Vec::new() }
    }

    /// Record one result row (same shape as a [`benchline`] call).
    pub fn row(&mut self, kv: &[(&str, String)]) {
        self.rows.push(kv.iter().map(|(k, v)| (k.to_string(), v.clone())).collect());
    }

    fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("{{\n  \"exp\": \"{}\",\n  \"rows\": [\n", json_escape(&self.exp)));
        for (i, row) in self.rows.iter().enumerate() {
            let cells: Vec<String> = row
                .iter()
                .map(|(k, v)| format!("\"{}\": {}", json_escape(k), json_value(v)))
                .collect();
            s.push_str(&format!("    {{{}}}{}\n", cells.join(", "), if i + 1 < self.rows.len() { "," } else { "" }));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write `BENCH_<exp>.json` into the current directory if the
    /// `BENCH_JSON` env var is set. Returns the path written, if any.
    pub fn write_if_enabled(&self) -> Option<std::path::PathBuf> {
        std::env::var("BENCH_JSON").ok()?;
        let path = std::path::PathBuf::from(format!("BENCH_{}.json", self.exp));
        match std::fs::write(&path, self.render()) {
            Ok(()) => {
                println!("wrote {}", path.display());
                Some(path)
            }
            Err(e) => {
                eprintln!("BENCH_JSON write failed ({}): {e}", path.display());
                None
            }
        }
    }
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Numbers pass through raw; everything else is a quoted string.
fn json_value(v: &str) -> String {
    match v.parse::<f64>() {
        Ok(x) if x.is_finite() => v.to_string(),
        _ => format!("\"{}\"", json_escape(v)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0u64;
        let stats = bench("t", 2, 10, || n += 1);
        assert_eq!(stats.samples_ns.len(), 10);
        assert_eq!(n, 12);
    }

    #[test]
    fn percentiles_ordered() {
        let stats = BenchStats { name: "x".into(), samples_ns: (1..=100).collect() };
        assert!(stats.percentile_ns(0.5) <= stats.percentile_ns(0.95));
        assert_eq!(stats.min_ns(), 1);
    }

    #[test]
    fn throughput_math() {
        let stats = BenchStats { name: "x".into(), samples_ns: vec![1_000_000_000] };
        let t = stats.throughput(100.0);
        assert!((t - 100.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // must not panic
    }

    #[test]
    fn json_report_renders_numbers_and_strings() {
        let mut r = JsonReport::new("serve");
        r.row(&[("backend", "BTC 0.8".to_string()), ("tokens_per_s", "123.5".to_string())]);
        let s = r.render();
        assert!(s.contains("\"exp\": \"serve\""));
        assert!(s.contains("\"backend\": \"BTC 0.8\""));
        assert!(s.contains("\"tokens_per_s\": 123.5"));
    }

    #[test]
    fn json_escapes_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_value("nan"), "\"nan\"");
        assert_eq!(json_value("-3.25"), "-3.25");
    }
}
