//! Shared scaffolding for the `cargo bench` targets that regenerate the
//! paper's tables and figures (see DESIGN.md §4 for the experiment
//! index). Each bench target is a thin `harness = false` binary over
//! these helpers.

use anyhow::{Context, Result};

use crate::data::ByteTokenizer;
use crate::eval::{memory, perplexity, zeroshot};
use crate::io::{load_model, RawModel};
use crate::quant::pipeline::{quantize_model, QuantConfig, QuantizedModel};

/// True when `--quick` was passed or `BTC_QUICK=1` — benches shrink
/// their grids so CI smoke stays fast.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
        || matches!(std::env::var("BTC_QUICK").as_deref(), Ok("1") | Ok("true"))
}

/// cargo bench passes `--bench`; ignore it and other harness flags.
pub fn is_bench_invocation() -> bool {
    true
}

/// Load a model + its eval corpus from artifacts/.
pub struct Workload {
    pub name: String,
    pub raw: RawModel,
    pub eval_tokens: Vec<u16>,
    pub corpus: Vec<u8>,
}

pub fn load_workload(name: &str) -> Result<Workload> {
    let dir = crate::artifacts_dir();
    let raw = load_model(&dir.join(format!("{name}.bin")))
        .with_context(|| format!("{name}.bin missing — run `make artifacts`"))?;
    let corpus = std::fs::read(dir.join("corpus_eval.txt")).context("corpus_eval.txt")?;
    let tok = ByteTokenizer::default();
    let eval_tokens = tok.encode(&String::from_utf8_lossy(&corpus));
    Ok(Workload { name: name.to_string(), raw, eval_tokens, corpus })
}

/// One quantization lane evaluated on one workload.
#[derive(Debug, Clone)]
pub struct LaneResult {
    pub model: String,
    pub method: String,
    pub bits_label: f64,
    /// Paper-convention payload bits (signs/indices/masks).
    pub payload_bits: f64,
    /// Fully measured bits incl. fp16 scales.
    pub measured_bits: f64,
    pub ppl: f64,
    pub mean_acc: Option<f64>,
    pub per_task: Vec<(String, f64)>,
    pub quant_secs: f64,
    pub codebook_overhead: f64,
    pub compression: f64,
}

/// Quantize + evaluate one lane.
pub fn eval_lane(
    w: &Workload,
    cfg: &QuantConfig,
    eval_tokens: usize,
    zeroshot_n: Option<usize>,
) -> Result<LaneResult> {
    let t0 = std::time::Instant::now();
    let qm: QuantizedModel = quantize_model(&w.raw, &w.corpus, cfg)?;
    let quant_secs = t0.elapsed().as_secs_f64();
    let ppl = perplexity::perplexity(&qm.model, &w.eval_tokens, 96, eval_tokens);
    let (per_task, mean_acc) = match zeroshot_n {
        Some(n) => {
            let (pt, m) = zeroshot::run_all(&qm.model, n, 7);
            (pt, Some(m))
        }
        None => (Vec::new(), None),
    };
    let mem = memory::report(&qm.model);
    Ok(LaneResult {
        model: w.name.clone(),
        method: qm.stats.method.clone(),
        bits_label: qm.stats.target_bits,
        payload_bits: if qm.stats.payload_bits > 0.0 { qm.stats.payload_bits } else { 16.0 },
        measured_bits: mem.linear_bits_per_weight,
        ppl,
        mean_acc,
        per_task,
        quant_secs,
        codebook_overhead: mem.codebook_overhead,
        compression: mem.compression,
    })
}

/// Format a float like the paper's tables (2 decimals, e-notation for
/// collapsed values).
pub fn fmt_ppl(p: f64) -> String {
    if p.is_nan() || p.is_infinite() {
        "inf".to_string()
    } else if p >= 1000.0 {
        format!("{:.1e}", p)
    } else {
        format!("{:.2}", p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ppl_matches_paper_style() {
        assert_eq!(fmt_ppl(6.06), "6.06");
        assert_eq!(fmt_ppl(13.064), "13.06");
        assert_eq!(fmt_ppl(23000.0), "2.3e4");
        assert_eq!(fmt_ppl(f64::INFINITY), "inf");
    }

    #[test]
    fn quick_mode_env() {
        // No --quick arg in the test harness; env unset => false (can't
        // assert true case without mutating global env).
        let _ = quick_mode();
    }
}
