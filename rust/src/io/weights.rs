//! TLM1 weight-blob reader/writer.
//!
//! Byte-exact interchange with `python/compile/blob.py` (pinned by
//! tests on both sides):
//!
//! ```text
//! magic b"TLM1"
//! u32   version (=1)
//! u32   vocab, d_model, n_layer, n_head, n_kv_head, d_ff, max_seq
//! f32   rope_theta
//! u32   n_tensors
//! per tensor: u32 name_len; name; u32 ndim; u32 dims[]; f32 data
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context};

use crate::tensor::Matrix;

/// Model hyperparameters (mirrors `python/compile/model.py::ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub n_kv_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_head
    }
    pub fn kv_dim(&self) -> usize {
        self.n_kv_head * self.head_dim()
    }
    /// Total parameter count (embeddings + norms + linears).
    pub fn param_count(&self) -> usize {
        let per_layer = 2 * self.d_model * self.d_model
            + 2 * self.kv_dim() * self.d_model
            + 3 * self.d_model * self.d_ff
            + 2 * self.d_model;
        self.vocab * self.d_model + self.n_layer * per_layer + self.d_model
    }
    /// Parameters in *quantizable* linear layers only (the W-bits base).
    pub fn linear_param_count(&self) -> usize {
        let per_layer = 2 * self.d_model * self.d_model
            + 2 * self.kv_dim() * self.d_model
            + 3 * self.d_model * self.d_ff;
        self.n_layer * per_layer
    }
}

/// A loaded full-precision model: config + named tensors.
#[derive(Debug, Clone)]
pub struct RawModel {
    pub config: ModelConfig,
    /// name -> (dims, row-major data). 1-D tensors have dims.len()==1.
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl RawModel {
    pub fn tensor(&self, name: &str) -> anyhow::Result<&(Vec<usize>, Vec<f32>)> {
        self.tensors.get(name).with_context(|| format!("missing tensor {name}"))
    }

    /// Fetch a 2-D tensor as a Matrix view (copies).
    pub fn matrix(&self, name: &str) -> anyhow::Result<Matrix> {
        let (dims, data) = self.tensor(name)?;
        if dims.len() != 2 {
            bail!("tensor {name} is not 2-D: {dims:?}");
        }
        Ok(Matrix::from_vec(dims[0], dims[1], data.clone()))
    }

    /// Fetch a 1-D tensor.
    pub fn vector(&self, name: &str) -> anyhow::Result<Vec<f32>> {
        let (dims, data) = self.tensor(name)?;
        if dims.len() != 1 {
            bail!("tensor {name} is not 1-D: {dims:?}");
        }
        Ok(data.clone())
    }

    /// Names of the 7 quantizable linear weights of layer `i`.
    pub fn linear_names(i: usize) -> [String; 7] {
        ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"].map(|n| format!("l{i}.{n}"))
    }
}

fn read_u32(r: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32(r: &mut impl Read) -> anyhow::Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

/// Load a TLM1 blob.
pub fn load_model(path: &Path) -> anyhow::Result<RawModel> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != b"TLM1" {
        bail!("{path:?}: bad magic {magic:?}");
    }
    let version = read_u32(&mut r)?;
    if version != 1 {
        bail!("{path:?}: unsupported version {version}");
    }
    let config = ModelConfig {
        vocab: read_u32(&mut r)? as usize,
        d_model: read_u32(&mut r)? as usize,
        n_layer: read_u32(&mut r)? as usize,
        n_head: read_u32(&mut r)? as usize,
        n_kv_head: read_u32(&mut r)? as usize,
        d_ff: read_u32(&mut r)? as usize,
        max_seq: read_u32(&mut r)? as usize,
        rope_theta: read_f32(&mut r)?,
    };
    let n_tensors = read_u32(&mut r)? as usize;
    let mut tensors = BTreeMap::new();
    for _ in 0..n_tensors {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("implausible tensor name length {name_len}");
        }
        let mut name_bytes = vec![0u8; name_len];
        r.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes).context("tensor name utf8")?;
        let ndim = read_u32(&mut r)? as usize;
        if ndim > 4 {
            bail!("tensor {name}: implausible ndim {ndim}");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let n: usize = dims.iter().product::<usize>().max(1);
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.insert(name, (dims, data));
    }
    Ok(RawModel { config, tensors })
}

/// Write a TLM1 blob (tests + tooling; python is the usual writer).
pub fn save_model(path: &Path, model: &RawModel) -> anyhow::Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(b"TLM1")?;
    let c = &model.config;
    for v in [1u32, c.vocab as u32, c.d_model as u32, c.n_layer as u32, c.n_head as u32,
              c.n_kv_head as u32, c.d_ff as u32, c.max_seq as u32] {
        w.write_all(&v.to_le_bytes())?;
    }
    w.write_all(&c.rope_theta.to_le_bytes())?;
    w.write_all(&(model.tensors.len() as u32).to_le_bytes())?;
    for (name, (dims, data)) in &model.tensors {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&(dims.len() as u32).to_le_bytes())?;
        for d in dims {
            w.write_all(&(*d as u32).to_le_bytes())?;
        }
        for x in data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> RawModel {
        let config = ModelConfig {
            vocab: 128, d_model: 8, n_layer: 1, n_head: 2, n_kv_head: 2,
            d_ff: 16, max_seq: 32, rope_theta: 10000.0,
        };
        let mut tensors = BTreeMap::new();
        tensors.insert("emb".into(), (vec![128, 8], vec![0.5; 1024]));
        tensors.insert("lnf".into(), (vec![8], vec![1.0; 8]));
        RawModel { config, tensors }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("btc_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.bin");
        let m = tiny_model();
        save_model(&path, &m).unwrap();
        let m2 = load_model(&path).unwrap();
        assert_eq!(m2.config, m.config);
        assert_eq!(m2.tensors, m.tensors);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("btc_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOPE0000").unwrap();
        assert!(load_model(&path).is_err());
    }

    #[test]
    fn param_count_formula() {
        // tinylm_s numbers pinned against python (344736 params).
        let c = ModelConfig {
            vocab: 128, d_model: 96, n_layer: 3, n_head: 3, n_kv_head: 3,
            d_ff: 256, max_seq: 128, rope_theta: 10000.0,
        };
        assert_eq!(c.param_count(), 344_736);
        assert!(c.linear_param_count() < c.param_count());
    }

    #[test]
    fn matrix_and_vector_accessors() {
        let m = tiny_model();
        assert_eq!(m.matrix("emb").unwrap().rows, 128);
        assert_eq!(m.vector("lnf").unwrap().len(), 8);
        assert!(m.matrix("lnf").is_err());
        assert!(m.tensor("nope").is_err());
    }

    #[test]
    fn gqa_dims() {
        let c = ModelConfig {
            vocab: 128, d_model: 128, n_layer: 4, n_head: 4, n_kv_head: 2,
            d_ff: 320, max_seq: 128, rope_theta: 10000.0,
        };
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.kv_dim(), 64);
    }
}
