//! Little-endian wire helpers shared by the weight containers (TLM1 /
//! QLM1) and by every [`crate::model::WeightBackend`] serializer.
//!
//! All readers are *bounded*: length fields pulled from a file are
//! validated against generous plausibility caps before any allocation,
//! so a corrupt or adversarial container fails with a loud error
//! instead of a multi-gigabyte `Vec` reservation. [`CountingReader`]
//! tracks the byte offset so those errors can say *where* the file went
//! bad.

use std::io::{Read, Write};

use anyhow::{bail, Result};

/// Largest plausible single matrix dimension in a weight container.
pub const MAX_DIM: usize = 1 << 20;
/// Largest plausible element count for one tensor payload.
pub const MAX_ELEMS: usize = 1 << 28;

/// A `Read` adapter that tracks the absolute byte offset, so parse
/// errors can report where in the file they happened.
pub struct CountingReader<R> {
    inner: R,
    pos: u64,
}

impl<R: Read> CountingReader<R> {
    pub fn new(inner: R) -> CountingReader<R> {
        CountingReader { inner, pos: 0 }
    }

    /// Bytes consumed so far.
    pub fn offset(&self) -> u64 {
        self.pos
    }
}

impl<R: Read> Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.pos += n as u64;
        Ok(n)
    }
}

/// Reject implausible (rows, cols) pulled from a file before allocating.
pub fn check_dims(what: &str, rows: usize, cols: usize) -> Result<()> {
    if rows == 0 || cols == 0 || rows > MAX_DIM || cols > MAX_DIM {
        bail!("{what}: implausible shape {rows}x{cols}");
    }
    if rows.saturating_mul(cols) > MAX_ELEMS {
        bail!("{what}: implausible element count {rows}x{cols}");
    }
    Ok(())
}

/// Reject an implausible element count pulled from a file.
pub fn check_len(what: &str, n: usize, max: usize) -> Result<()> {
    if n > max {
        bail!("{what}: implausible length {n} (cap {max})");
    }
    Ok(())
}

pub fn w_u8(w: &mut dyn Write, v: u8) -> Result<()> {
    w.write_all(&[v])?;
    Ok(())
}

pub fn w_u32(w: &mut dyn Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn w_f32s(w: &mut dyn Write, xs: &[f32]) -> Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub fn w_u16s(w: &mut dyn Write, xs: &[u16]) -> Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub fn w_u32s(w: &mut dyn Write, xs: &[u32]) -> Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub fn w_u64s(w: &mut dyn Write, xs: &[u64]) -> Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Length-prefixed (u8) ASCII tag string.
pub fn w_tag(w: &mut dyn Write, tag: &str) -> Result<()> {
    let bytes = tag.as_bytes();
    if bytes.len() > u8::MAX as usize {
        bail!("backend tag {tag:?} too long to serialize");
    }
    w_u8(w, bytes.len() as u8)?;
    w.write_all(bytes)?;
    Ok(())
}

pub fn r_u8(r: &mut dyn Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub fn r_u32(r: &mut dyn Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn r_f32s(r: &mut dyn Read, n: usize) -> Result<Vec<f32>> {
    check_len("f32 payload", n, MAX_ELEMS)?;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

pub fn r_u16s(r: &mut dyn Read, n: usize) -> Result<Vec<u16>> {
    check_len("u16 payload", n, MAX_ELEMS)?;
    let mut bytes = vec![0u8; n * 2];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect())
}

pub fn r_u32s(r: &mut dyn Read, n: usize) -> Result<Vec<u32>> {
    check_len("u32 payload", n, MAX_ELEMS)?;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

pub fn r_u64s(r: &mut dyn Read, n: usize) -> Result<Vec<u64>> {
    check_len("u64 payload", n, MAX_ELEMS)?;
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

/// Length-prefixed (u8) ASCII tag string.
pub fn r_tag(r: &mut dyn Read) -> Result<String> {
    let n = r_u8(r)? as usize;
    let mut bytes = vec![0u8; n];
    r.read_exact(&mut bytes)?;
    String::from_utf8(bytes).map_err(|e| anyhow::anyhow!("backend tag is not utf8: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars_and_slices() {
        let mut buf = Vec::new();
        w_u32(&mut buf, 7).unwrap();
        w_f32s(&mut buf, &[1.5, -2.0]).unwrap();
        w_u16s(&mut buf, &[3, 9]).unwrap();
        w_tag(&mut buf, "binary").unwrap();
        let mut r = CountingReader::new(&buf[..]);
        assert_eq!(r_u32(&mut r).unwrap(), 7);
        assert_eq!(r_f32s(&mut r, 2).unwrap(), vec![1.5, -2.0]);
        assert_eq!(r_u16s(&mut r, 2).unwrap(), vec![3, 9]);
        assert_eq!(r_tag(&mut r).unwrap(), "binary");
        assert_eq!(r.offset(), buf.len() as u64);
    }

    #[test]
    fn bounded_reads_reject_huge_lengths() {
        assert!(check_dims("w", usize::MAX, 2).is_err());
        assert!(check_dims("w", 0, 2).is_err());
        assert!(check_dims("w", 64, 64).is_ok());
        let empty: &[u8] = &[];
        assert!(r_f32s(&mut CountingReader::new(empty), MAX_ELEMS + 1).is_err());
    }
}
