//! Little-endian wire helpers shared by the weight containers (TLM1 /
//! QLM1) and by every [`crate::model::WeightBackend`] serializer.
//!
//! All readers are *bounded*: length fields pulled from a file are
//! validated against generous plausibility caps before any allocation,
//! so a corrupt or adversarial container fails with a loud error
//! instead of a multi-gigabyte `Vec` reservation. [`CountingReader`]
//! tracks the byte offset so those errors can say *where* the file went
//! bad.

use std::io::{Read, Write};

use anyhow::{bail, Result};

/// Largest plausible single matrix dimension in a weight container.
pub const MAX_DIM: usize = 1 << 20;
/// Largest plausible element count for one tensor payload.
pub const MAX_ELEMS: usize = 1 << 28;

/// A `Read` adapter that tracks the absolute byte offset, so parse
/// errors can report where in the file they happened, and accumulates
/// a running [`Crc32`] over everything read — containers with an
/// integrity trailer compare it against the stored checksum.
pub struct CountingReader<R> {
    inner: R,
    pos: u64,
    crc: Crc32,
}

impl<R: Read> CountingReader<R> {
    pub fn new(inner: R) -> CountingReader<R> {
        CountingReader { inner, pos: 0, crc: Crc32::new() }
    }

    /// Bytes consumed so far.
    pub fn offset(&self) -> u64 {
        self.pos
    }

    /// CRC-32 over every byte consumed so far.
    pub fn crc(&self) -> u32 {
        self.crc.value()
    }
}

impl<R: Read> Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc.update(&buf[..n]);
        self.pos += n as u64;
        Ok(n)
    }
}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) — the integrity
// check behind the QLM1 trailer. Hand-rolled: no checksum crate in
// the offline vendor set.
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// Incremental IEEE CRC-32.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s = CRC32_TABLE[((s ^ b as u32) & 0xFF) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    /// The checksum of everything fed so far (does not reset).
    pub fn value(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Crc32 {
        Crc32::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.value()
}

/// A `Write` adapter accumulating a [`Crc32`] over everything written
/// through it — the save-side twin of [`CountingReader::crc`].
pub struct CrcWriter<W> {
    inner: W,
    crc: Crc32,
}

impl<W: Write> CrcWriter<W> {
    pub fn new(inner: W) -> CrcWriter<W> {
        CrcWriter { inner, crc: Crc32::new() }
    }

    /// CRC-32 over every byte written so far.
    pub fn crc(&self) -> u32 {
        self.crc.value()
    }

    pub fn into_inner(self) -> W {
        self.inner
    }
}

impl<W: Write> Write for CrcWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Reject implausible (rows, cols) pulled from a file before allocating.
pub fn check_dims(what: &str, rows: usize, cols: usize) -> Result<()> {
    if rows == 0 || cols == 0 || rows > MAX_DIM || cols > MAX_DIM {
        bail!("{what}: implausible shape {rows}x{cols}");
    }
    if rows.saturating_mul(cols) > MAX_ELEMS {
        bail!("{what}: implausible element count {rows}x{cols}");
    }
    Ok(())
}

/// Reject an implausible element count pulled from a file.
pub fn check_len(what: &str, n: usize, max: usize) -> Result<()> {
    if n > max {
        bail!("{what}: implausible length {n} (cap {max})");
    }
    Ok(())
}

pub fn w_u8(w: &mut dyn Write, v: u8) -> Result<()> {
    w.write_all(&[v])?;
    Ok(())
}

pub fn w_u32(w: &mut dyn Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn w_f32s(w: &mut dyn Write, xs: &[f32]) -> Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub fn w_u16s(w: &mut dyn Write, xs: &[u16]) -> Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub fn w_u32s(w: &mut dyn Write, xs: &[u32]) -> Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub fn w_u64s(w: &mut dyn Write, xs: &[u64]) -> Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Incremental unpadded little-endian bitstream writer for `k`-bit
/// elements — the streaming form of [`w_bits`], for payloads too large
/// to densify first (a 13B-class index plane). Push values one at a
/// time, then call [`BitWriter::finish`] to flush the trailing partial
/// byte. Values are masked to `k` bits.
pub struct BitWriter<'a> {
    w: &'a mut dyn Write,
    k: usize,
    mask: u64,
    acc: u128,
    nbits: usize,
    buf: Vec<u8>,
}

/// Internal staging size for [`BitWriter`] before hitting the sink.
const BIT_WRITER_CHUNK: usize = 8192;

impl<'a> BitWriter<'a> {
    pub fn new(w: &'a mut dyn Write, k: usize) -> Result<BitWriter<'a>> {
        if !(1..=64).contains(&k) {
            bail!("packed payload: bits-per-element {k} out of 1..=64");
        }
        let mask = if k == 64 { u64::MAX } else { (1u64 << k) - 1 };
        Ok(BitWriter { w, k, mask, acc: 0, nbits: 0, buf: Vec::with_capacity(BIT_WRITER_CHUNK) })
    }

    pub fn push(&mut self, v: u64) -> Result<()> {
        self.acc |= ((v & self.mask) as u128) << self.nbits;
        self.nbits += self.k;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xff) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
        if self.buf.len() >= BIT_WRITER_CHUNK {
            self.w.write_all(&self.buf)?;
            self.buf.clear();
        }
        Ok(())
    }

    /// Flush the trailing partial byte and staged bytes to the sink.
    pub fn finish(mut self) -> Result<()> {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xff) as u8);
        }
        self.w.write_all(&self.buf)?;
        Ok(())
    }
}

/// Write `vals` as an unpadded little-endian bitstream of `k`-bit
/// elements (`ceil(n*k/8)` bytes — the sub-byte payloads of QLM1 v3:
/// codebook centroids, index planes, group ids, sigma sign bitmaps).
/// Values are masked to `k` bits.
pub fn w_bits(w: &mut dyn Write, k: usize, vals: &[u64]) -> Result<()> {
    let mut bw = BitWriter::new(w, k)?;
    for &v in vals {
        bw.push(v)?;
    }
    bw.finish()
}

/// Bounded reader matching [`w_bits`]: `n` `k`-bit elements.
pub fn r_bits(r: &mut dyn Read, n: usize, k: usize) -> Result<Vec<u64>> {
    if !(1..=64).contains(&k) {
        bail!("packed payload: bits-per-element {k} out of 1..=64");
    }
    check_len("packed payload", n, MAX_ELEMS)?;
    let mut bytes = vec![0u8; (n * k).div_ceil(8)];
    r.read_exact(&mut bytes)?;
    let mask = if k == 64 { u64::MAX } else { (1u64 << k) - 1 };
    let mut out = Vec::with_capacity(n);
    let mut acc: u128 = 0;
    let mut nbits = 0usize;
    let mut bi = 0usize;
    for _ in 0..n {
        while nbits < k {
            acc |= (bytes[bi] as u128) << nbits;
            bi += 1;
            nbits += 8;
        }
        out.push((acc as u64) & mask);
        acc >>= k;
        nbits -= k;
    }
    Ok(out)
}

/// [`w_bits`] over u32 values (index planes, group ids).
pub fn w_packed_u32s(w: &mut dyn Write, k: usize, vals: &[u32]) -> Result<()> {
    if k > 32 {
        bail!("packed u32 payload: bits-per-element {k} out of 1..=32");
    }
    let wide: Vec<u64> = vals.iter().map(|&v| v as u64).collect();
    w_bits(w, k, &wide)
}

/// Bounded reader matching [`w_packed_u32s`].
pub fn r_packed_u32s(r: &mut dyn Read, n: usize, k: usize) -> Result<Vec<u32>> {
    if !(1..=32).contains(&k) {
        bail!("packed u32 payload: bits-per-element {k} out of 1..=32");
    }
    Ok(r_bits(r, n, k)?.into_iter().map(|v| v as u32).collect())
}

/// A `Write` sink that only counts bytes — used to measure a
/// backend's wire footprint without serializing anywhere.
#[derive(Default)]
pub struct CountingWriter {
    pub bytes: usize,
}

impl Write for CountingWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.bytes += buf.len();
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Length-prefixed (u8) ASCII tag string.
pub fn w_tag(w: &mut dyn Write, tag: &str) -> Result<()> {
    let bytes = tag.as_bytes();
    if bytes.len() > u8::MAX as usize {
        bail!("backend tag {tag:?} too long to serialize");
    }
    w_u8(w, bytes.len() as u8)?;
    w.write_all(bytes)?;
    Ok(())
}

pub fn r_u8(r: &mut dyn Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub fn r_u32(r: &mut dyn Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn r_f32s(r: &mut dyn Read, n: usize) -> Result<Vec<f32>> {
    check_len("f32 payload", n, MAX_ELEMS)?;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

pub fn r_u16s(r: &mut dyn Read, n: usize) -> Result<Vec<u16>> {
    check_len("u16 payload", n, MAX_ELEMS)?;
    let mut bytes = vec![0u8; n * 2];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect())
}

pub fn r_u32s(r: &mut dyn Read, n: usize) -> Result<Vec<u32>> {
    check_len("u32 payload", n, MAX_ELEMS)?;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

pub fn r_u64s(r: &mut dyn Read, n: usize) -> Result<Vec<u64>> {
    check_len("u64 payload", n, MAX_ELEMS)?;
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

/// Length-prefixed (u8) ASCII tag string.
pub fn r_tag(r: &mut dyn Read) -> Result<String> {
    let n = r_u8(r)? as usize;
    let mut bytes = vec![0u8; n];
    r.read_exact(&mut bytes)?;
    String::from_utf8(bytes).map_err(|e| anyhow::anyhow!("backend tag is not utf8: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars_and_slices() {
        let mut buf = Vec::new();
        w_u32(&mut buf, 7).unwrap();
        w_f32s(&mut buf, &[1.5, -2.0]).unwrap();
        w_u16s(&mut buf, &[3, 9]).unwrap();
        w_tag(&mut buf, "binary").unwrap();
        let mut r = CountingReader::new(&buf[..]);
        assert_eq!(r_u32(&mut r).unwrap(), 7);
        assert_eq!(r_f32s(&mut r, 2).unwrap(), vec![1.5, -2.0]);
        assert_eq!(r_u16s(&mut r, 2).unwrap(), vec![3, 9]);
        assert_eq!(r_tag(&mut r).unwrap(), "binary");
        assert_eq!(r.offset(), buf.len() as u64);
    }

    #[test]
    fn packed_bits_roundtrip_property() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        for _ in 0..40 {
            let k = 1 + rng.below(64);
            let n = 1 + rng.below(90);
            let mask = if k == 64 { u64::MAX } else { (1u64 << k) - 1 };
            let vals: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
            let mut buf = Vec::new();
            w_bits(&mut buf, k, &vals).unwrap();
            assert_eq!(buf.len(), (n * k).div_ceil(8), "tight bitstream, k={k} n={n}");
            let back = r_bits(&mut CountingReader::new(&buf[..]), n, k).unwrap();
            assert_eq!(back, vals, "k={k} n={n}");
        }
    }

    #[test]
    fn bit_writer_streams_across_chunk_flushes() {
        // Enough 13-bit values to force several mid-stream buffer
        // flushes (~65 KB of output vs the 8 KB staging chunk).
        let vals: Vec<u64> =
            (0..40_000u64).map(|i| i.wrapping_mul(2654435761) & 0x1fff).collect();
        let mut buf = Vec::new();
        w_bits(&mut buf, 13, &vals).unwrap();
        assert_eq!(buf.len(), (40_000usize * 13).div_ceil(8));
        let back = r_bits(&mut CountingReader::new(&buf[..]), 40_000, 13).unwrap();
        assert_eq!(back, vals);
    }

    #[test]
    fn packed_u32_wrappers_roundtrip_and_reject_wide_k() {
        let vals: Vec<u32> = (0..37).map(|i| (i * 613) % (1 << 13)).collect();
        let mut buf = Vec::new();
        w_packed_u32s(&mut buf, 13, &vals).unwrap();
        let back = r_packed_u32s(&mut CountingReader::new(&buf[..]), 37, 13).unwrap();
        assert_eq!(back, vals);
        let mut sink: Vec<u8> = Vec::new();
        assert!(w_packed_u32s(&mut sink, 33, &vals).is_err());
        let empty: &[u8] = &[];
        assert!(r_packed_u32s(&mut CountingReader::new(empty), 1, 0).is_err());
        assert!(r_bits(&mut CountingReader::new(empty), MAX_ELEMS + 1, 8).is_err());
    }

    #[test]
    fn counting_writer_counts() {
        let mut cw = CountingWriter::default();
        w_u32(&mut cw, 9).unwrap();
        w_bits(&mut cw, 3, &[1, 2, 3]).unwrap(); // 9 bits -> 2 bytes
        assert_eq!(cw.bytes, 6);
    }

    #[test]
    fn crc32_reference_vector() {
        // The canonical IEEE CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // Incremental == one-shot.
        let mut c = Crc32::new();
        c.update(b"1234");
        c.update(b"56789");
        assert_eq!(c.value(), 0xCBF4_3926);
    }

    #[test]
    fn crc_writer_and_counting_reader_agree() {
        let mut w = CrcWriter::new(Vec::new());
        w_u32(&mut w, 0xdead_beef).unwrap();
        w_tag(&mut w, "binary").unwrap();
        w_f32s(&mut w, &[1.0, -0.5]).unwrap();
        let crc_written = w.crc();
        let bytes = w.into_inner();
        assert_eq!(crc_written, crc32(&bytes));
        let mut r = CountingReader::new(&bytes[..]);
        let _ = r_u32(&mut r).unwrap();
        let _ = r_tag(&mut r).unwrap();
        let _ = r_f32s(&mut r, 2).unwrap();
        assert_eq!(r.crc(), crc_written, "read-side CRC mirrors the write side");
        // A single flipped bit changes the checksum.
        let mut bad = bytes.clone();
        bad[3] ^= 0x10;
        assert_ne!(crc32(&bad), crc_written);
    }

    #[test]
    fn bounded_reads_reject_huge_lengths() {
        assert!(check_dims("w", usize::MAX, 2).is_err());
        assert!(check_dims("w", 0, 2).is_err());
        assert!(check_dims("w", 64, 64).is_ok());
        let empty: &[u8] = &[];
        assert!(r_f32s(&mut CountingReader::new(empty), MAX_ELEMS + 1).is_err());
    }
}
