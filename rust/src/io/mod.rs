//! Artifact IO: the TLM1 weight-blob reader/writer (interchange with
//! `python/compile/blob.py`) and the QLM1 quantized-model container.

pub mod qweights;
pub mod weights;
pub mod wire;

pub use weights::{load_model, ModelConfig, RawModel};
