//! QLM1 quantized-model container: serialize any quantized model so
//! `btc-llm quantize` output can be shipped to `btc-llm serve` without
//! re-running the pipeline.
//!
//! v4 layout (little-endian) — file bytes equal the accounted storage
//! bits (sub-byte payloads ship as unpadded bitstreams, scales as
//! IEEE f16) plus an 8-byte integrity trailer:
//! ```text
//! magic b"QLM1", u32 version = 4
//! TLM1-style model config block
//! u8 has_codebook; codebook: u32 v, u32 c, then c v-bit centroids
//!   packed (wire::w_bits — c*v bits, not c u64 words)
//! u32 n_linears; per linear:
//!   u32 layer; u8 slot (0..7)
//!   u8 tag_len; tag bytes            (stable WeightBackend::tag)
//!   u8 has_transform; transform: u32 dim,n1,n2;
//!     u8 sigma_packed; sigma as a dim-bit ±1 bitmap (1) or f32[dim]
//!     (0, for non-sign diagonals); f32 p1[n1²], p2[n2²]
//!   u8 has_act_quant; act-quant: u32 bits, u32 n, f32 scale[n]
//!   backend payload                  (WeightBackend::write_payload;
//!     the codebook backend writes packed index planes + u16 scales)
//! trailer: magic b"QCRC", u32 crc    (IEEE CRC-32 of every byte
//!     before the trailer; mandatory from v4 on — a flipped bit or a
//!     truncated tail anywhere in the container fails the load)
//! ```
//! Older containers still load: v1 (one-byte numeric tags, no
//! act-quant block), v2 (string tags, u64 codebook words, f32
//! sigma, dense u32 codebook indices + f32 scales — layout pinned by
//! the committed golden fixture in `rust/tests/fixtures/`) and v3
//! (the v4 record layout without the checksum trailer). One
//! deliberate semantic change on pre-v3 codebook payloads: their f32
//! alpha/mu are rounded **once** to f16 at load (nearest-even), the
//! shipping precision the storage accounting always claimed — scales
//! that were already f16-representable (anything written by this
//! crate's pipeline, whose layers round at quantization) reload
//! bit-identically. v4 is always written. Backend payloads round-trip through the
//! [`crate::model::register_backend`] registry, so **every** lane —
//! not just BTC — ships, including custom backends registered at
//! runtime (a [`BackendIoCtx::version`] tells them which container
//! revision they are reading). Norms/embeddings stay fp32 in the
//! companion TLM1 blob; this file carries only the quantized linears
//! (the paper's W-bits subject).
//!
//! All reads are bounded (see [`crate::io::wire`]): a corrupt file
//! fails with the offending value and byte offset, never a huge
//! allocation.

use std::io::{BufReader, Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::io::wire::{self, CountingReader};
use crate::model::{backend_reader, backend_tags, BackendIoCtx, Linear, Transformer};
use crate::quant::actquant::ActQuant;
use crate::quant::codebook::BinaryCodebook;
use crate::quant::transform::Transform;
use crate::tensor::Matrix;

const SLOTS: [&str; 7] = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];
/// Current QLM1 container version (written by [`save`]; [`load_into`]
/// reads every version back to 1).
pub const QLM_VERSION: u32 = 4;
const VERSION: u32 = QLM_VERSION;
/// Magic of the integrity trailer appended from v4 on.
const CRC_MAGIC: &[u8; 4] = b"QCRC";

/// Save a quantized model. Works for every backend whose tag has a
/// registered deserializer — i.e. all built-in lanes and any custom
/// backend registered via [`crate::model::register_backend`].
pub fn save(path: &Path, model: &Transformer) -> Result<()> {
    crate::fault_point!("io.write", bail!("injected fault at io.write"));
    let mut w = wire::CrcWriter::new(std::io::BufWriter::new(std::fs::File::create(path)?));
    w.write_all(b"QLM1")?;
    wire::w_u32(&mut w, VERSION)?;
    let c = &model.cfg;
    for v in [c.vocab, c.d_model, c.n_layer, c.n_head, c.n_kv_head, c.d_ff, c.max_seq] {
        wire::w_u32(&mut w, v as u32)?;
    }
    w.write_all(&c.rope_theta.to_le_bytes())?;

    // Shared codebook (first one found; the build produces exactly one).
    let mut shared: Option<Arc<BinaryCodebook>> = None;
    'outer: for b in &model.blocks {
        for (_, lin) in b.linears() {
            if let Some(cb) = lin.backend.shared_codebook() {
                shared = Some(cb);
                break 'outer;
            }
        }
    }
    match &shared {
        Some(cb) => {
            wire::w_u8(&mut w, 1)?;
            wire::w_u32(&mut w, cb.v as u32)?;
            wire::w_u32(&mut w, cb.c() as u32)?;
            // v3: centroids ship at their true v bits each (v2 wrote
            // one u64 per centroid — up to 8x the accounted size).
            wire::w_bits(&mut w, cb.v, &cb.words)?;
        }
        None => wire::w_u8(&mut w, 0)?,
    }

    let n_linears = model.blocks.len() * SLOTS.len();
    wire::w_u32(&mut w, n_linears as u32)?;
    for (li, block) in model.blocks.iter().enumerate() {
        for (slot, (_, lin)) in block.linears().iter().enumerate() {
            wire::w_u32(&mut w, li as u32)?;
            wire::w_u8(&mut w, slot as u8)?;
            let tag = lin.backend.tag();
            if backend_reader(tag).is_none() {
                bail!(
                    "backend {tag:?} has no registered deserializer; \
                     register_backend({tag:?}, ..) before saving"
                );
            }
            // The container carries ONE shared codebook: a model whose
            // layers reference different codebooks would reload
            // silently corrupted, so refuse loudly.
            if let Some(cb) = lin.backend.shared_codebook() {
                let header_cb = shared.as_ref().expect("codebook scan covered all linears");
                if !Arc::ptr_eq(&cb, header_cb) {
                    bail!(
                        "linear (layer {li}, slot {slot}) references a different shared \
                         codebook than the container header; QLM1 carries exactly one"
                    );
                }
            }
            wire::w_tag(&mut w, tag)?;
            match &lin.transform {
                Some(t) => {
                    wire::w_u8(&mut w, 1)?;
                    wire::w_u32(&mut w, t.dim() as u32)?;
                    wire::w_u32(&mut w, t.p1.rows as u32)?;
                    wire::w_u32(&mut w, t.p2.rows as u32)?;
                    // sigma is a ±1 diagonal in every fitted transform:
                    // ship it as a 1-bit-per-entry sign bitmap (v3).
                    // Anything else (custom transforms) falls back to
                    // f32, flagged.
                    if t.sigma.iter().all(|&s| s == 1.0 || s == -1.0) {
                        wire::w_u8(&mut w, 1)?;
                        let bits: Vec<u64> =
                            t.sigma.iter().map(|&s| u64::from(s == 1.0)).collect();
                        wire::w_bits(&mut w, 1, &bits)?;
                    } else {
                        wire::w_u8(&mut w, 0)?;
                        wire::w_f32s(&mut w, &t.sigma)?;
                    }
                    wire::w_f32s(&mut w, &t.p1.data)?;
                    wire::w_f32s(&mut w, &t.p2.data)?;
                }
                None => wire::w_u8(&mut w, 0)?,
            }
            match &lin.act_quant {
                Some(aq) => {
                    wire::w_u8(&mut w, 1)?;
                    wire::w_u32(&mut w, aq.bits)?;
                    wire::w_u32(&mut w, aq.scale.len() as u32)?;
                    wire::w_f32s(&mut w, &aq.scale)?;
                }
                None => wire::w_u8(&mut w, 0)?,
            }
            lin.backend.write_payload(&mut w)?;
        }
    }
    // Integrity trailer: CRC of everything written so far (the
    // checksum covers the whole payload, not itself).
    let crc = w.crc();
    w.write_all(CRC_MAGIC)?;
    wire::w_u32(&mut w, crc)?;
    // BufWriter drop swallows flush errors — surface them here so a
    // full disk can't yield a truncated container reported as success.
    w.flush()?;
    Ok(())
}

fn read_transform(r: &mut dyn Read, version: u32) -> Result<Option<Transform>> {
    if wire::r_u8(r)? != 1 {
        return Ok(None);
    }
    let dim = wire::r_u32(r)? as usize;
    let n1 = wire::r_u32(r)? as usize;
    let n2 = wire::r_u32(r)? as usize;
    if dim == 0 || dim > wire::MAX_DIM {
        bail!("transform: implausible dim {dim}");
    }
    if n1 == 0 || n2 == 0 || n1.saturating_mul(n2) != dim {
        bail!("transform: Kronecker factors {n1}x{n2} do not cover dim {dim}");
    }
    let sigma = if version >= 3 && wire::r_u8(r)? == 1 {
        // v3 sign bitmap: bit 1 = +1, bit 0 = -1 (exact ±1 round-trip).
        wire::r_bits(r, dim, 1)?.into_iter().map(|b| if b == 1 { 1.0 } else { -1.0 }).collect()
    } else {
        // v1/v2 layout, or a v3 non-sign diagonal (flag byte 0).
        wire::r_f32s(r, dim)?
    };
    let p1 = Matrix::from_vec(n1, n1, wire::r_f32s(r, n1 * n1)?);
    let p2 = Matrix::from_vec(n2, n2, wire::r_f32s(r, n2 * n2)?);
    Ok(Some(Transform { sigma, p1, p2 }))
}

fn read_act_quant(r: &mut dyn Read) -> Result<Option<ActQuant>> {
    if wire::r_u8(r)? != 1 {
        return Ok(None);
    }
    let bits = wire::r_u32(r)?;
    if !(2..=16).contains(&bits) {
        bail!("act-quant: implausible bits {bits}");
    }
    let n = wire::r_u32(r)? as usize;
    if n > wire::MAX_DIM {
        bail!("act-quant: implausible channel count {n}");
    }
    let scale = wire::r_f32s(r, n)?;
    // Enforce the ActQuant invariant on untrusted wire data: bits=16
    // with scales would otherwise silently quantize.
    ActQuant::checked(bits, scale).map(Some).map_err(|e| anyhow::anyhow!("act-quant: {e}"))
}

/// Load quantized linears into a model previously built from the
/// companion TLM1 blob (embeddings/norms come from there).
pub fn load_into(path: &Path, model: &mut Transformer) -> Result<()> {
    crate::fault_point!("io.read", bail!("injected fault at io.read"));
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = CountingReader::new(BufReader::new(file));
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != b"QLM1" {
        bail!("bad QLM1 magic {magic:?}");
    }
    let version = wire::r_u32(&mut r)?;
    if !(1..=VERSION).contains(&version) {
        bail!("unsupported QLM1 version {version} (this build reads 1..={VERSION})");
    }
    let mut hdr = [0usize; 7];
    for h in hdr.iter_mut() {
        *h = wire::r_u32(&mut r)? as usize;
    }
    let mut theta = [0u8; 4];
    r.read_exact(&mut theta)?;
    let expect = [
        ("vocab", model.cfg.vocab),
        ("d_model", model.cfg.d_model),
        ("n_layer", model.cfg.n_layer),
        ("n_head", model.cfg.n_head),
        ("n_kv_head", model.cfg.n_kv_head),
        ("d_ff", model.cfg.d_ff),
        ("max_seq", model.cfg.max_seq),
    ];
    for (got, (field, want)) in hdr.iter().zip(expect.iter()) {
        if got != want {
            bail!("QLM1 config mismatch with loaded model: {field} is {got} in file, {want} in model");
        }
    }

    let ctx = if wire::r_u8(&mut r)? == 1 {
        let v = wire::r_u32(&mut r)? as usize;
        let c = wire::r_u32(&mut r)? as usize;
        if !(1..=64).contains(&v) {
            bail!("shared codebook: sub-vector length v={v} out of 1..=64 (offset {})", r.offset());
        }
        if c == 0 || c > 1 << 22 {
            bail!("shared codebook: implausible size c={c} (offset {})", r.offset());
        }
        let words = if version >= 3 {
            wire::r_bits(&mut r, c, v)? // packed v-bit centroids
        } else {
            wire::r_u64s(&mut r, c)? // v1/v2: one u64 per centroid
        };
        BackendIoCtx { codebook: Some(Arc::new(BinaryCodebook { v, words })), version }
    } else {
        BackendIoCtx { codebook: None, version }
    };

    let n = wire::r_u32(&mut r)? as usize;
    let max_linears = model.blocks.len() * SLOTS.len();
    if n > max_linears {
        bail!("QLM1 claims {n} linears but the model has only {max_linears}");
    }
    for _ in 0..n {
        let li = wire::r_u32(&mut r)? as usize;
        let slot = wire::r_u8(&mut r)? as usize;
        if li >= model.blocks.len() || slot >= SLOTS.len() {
            bail!("linear ({li}, {slot}) out of range (offset {})", r.offset());
        }
        let tag: String = if version == 1 {
            // v1 wrote a one-byte numeric tag.
            match wire::r_u8(&mut r)? {
                0 => "dense".to_string(),
                1 => "binary".to_string(),
                2 => "codebook".to_string(),
                t => bail!("unknown v1 backend tag {t} at byte offset {}", r.offset()),
            }
        } else {
            wire::r_tag(&mut r)?
        };
        let tag_offset = r.offset();
        let transform = read_transform(&mut r, version)?;
        let act_quant = if version >= 2 { read_act_quant(&mut r)? } else { None };
        let reader = backend_reader(&tag).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown backend tag {tag:?} at byte offset {tag_offset} \
                 (registered: {:?}); custom backends must call \
                 register_backend before loading",
                backend_tags()
            )
        })?;
        let payload_offset = r.offset();
        let backend = reader(&mut r, &ctx)
            .with_context(|| format!("backend {tag:?} payload at offset {payload_offset}"))?;
        let block = &mut model.blocks[li];
        for (nm, lin) in block.linears_mut() {
            if nm == SLOTS[slot] {
                // Fail at load, not at first forward: the record must
                // match the linear it replaces.
                let want = lin.backend.shape();
                let got = backend.shape();
                if got != want {
                    bail!(
                        "linear ({li}, {}): backend shape {got:?} does not match model \
                         shape {want:?}",
                        SLOTS[slot]
                    );
                }
                if let Some(t) = &transform {
                    if t.dim() != want.1 {
                        bail!(
                            "linear ({li}, {}): transform dim {} does not match in_features {}",
                            SLOTS[slot],
                            t.dim(),
                            want.1
                        );
                    }
                }
                if let Some(aq) = &act_quant {
                    if !aq.scale.is_empty() && aq.scale.len() != want.1 {
                        bail!(
                            "linear ({li}, {}): act-quant has {} channels, expected {}",
                            SLOTS[slot],
                            aq.scale.len(),
                            want.1
                        );
                    }
                }
                let mut new_lin = Linear::new(backend);
                new_lin.transform = transform;
                new_lin.act_quant = act_quant;
                *lin = new_lin;
                break;
            }
        }
    }
    // Integrity trailer: mandatory from v4 (its absence means the
    // tail was cut off), absent in anything older.
    if version >= 4 {
        let payload_crc = r.crc();
        let mut trailer = [0u8; 8];
        r.read_exact(&mut trailer).with_context(|| {
            format!("QLM1 checksum trailer missing or truncated at offset {}", r.offset())
        })?;
        if &trailer[..4] != CRC_MAGIC {
            bail!("bad QLM1 trailer magic {:?} at offset {}", &trailer[..4], r.offset());
        }
        let want = u32::from_le_bytes([trailer[4], trailer[5], trailer[6], trailer[7]]);
        if want != payload_crc {
            bail!(
                "QLM1 checksum mismatch: trailer says {want:#010x}, payload is \
                 {payload_crc:#010x} — the container is corrupted"
            );
        }
        let mut extra = [0u8; 1];
        if r.read(&mut extra)? != 0 {
            bail!("trailing bytes after the QLM1 checksum trailer (offset {})", r.offset());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus;
    use crate::quant::pipeline::{quantize_model, QuantConfig};
    use crate::util::proptest::assert_close;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("btc_qlm_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_btc_model() {
        // Quantize the pipeline fixture, save, reload, compare logits.
        let (raw, text) = crate::quant::pipeline::tests::fixture_public();
        let cfg = QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            transform_outer: 2,
            arb_iters: 4,
            v: 8,
            ..QuantConfig::btc(0.8)
        };
        let qm = quantize_model(&raw, &text, &cfg).unwrap();
        let path = tmp("m.qlm");
        save(&path, &qm.model).unwrap();

        let mut reloaded = Transformer::from_raw(&raw).unwrap();
        load_into(&path, &mut reloaded).unwrap();
        reloaded.cache_dense_all();
        let toks = corpus::generate(200, 3)
            .bytes()
            .take(16)
            .map(|b| b as u16)
            .collect::<Vec<_>>();
        let a = qm.model.forward(&toks);
        let b = reloaded.forward(&toks);
        assert_close(&a.data, &b.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn act_quant_roundtrips_in_v2() {
        let (raw, text) = crate::quant::pipeline::tests::fixture_public();
        let cfg = QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            transform_outer: 1,
            arb_iters: 2,
            v: 8,
            act_bits: 8,
            ..QuantConfig::btc(0.8)
        };
        let qm = quantize_model(&raw, &text, &cfg).unwrap();
        assert!(qm.model.blocks[0].wq.act_quant.is_some());
        let path = tmp("actquant.qlm");
        save(&path, &qm.model).unwrap();
        let mut reloaded = Transformer::from_raw(&raw).unwrap();
        load_into(&path, &mut reloaded).unwrap();
        let aq = reloaded.blocks[0].wq.act_quant.as_ref().expect("act_quant restored");
        let orig = qm.model.blocks[0].wq.act_quant.as_ref().unwrap();
        assert_eq!(aq.bits, orig.bits);
        assert_eq!(aq.scale, orig.scale);
        reloaded.cache_dense_all();
        let toks = [3u16, 1, 4, 1, 5];
        assert_eq!(
            qm.model.forward(&toks).data,
            reloaded.forward(&toks).data,
            "A8 logits must be bit-identical after reload"
        );
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("bad.qlm");
        std::fs::write(&path, b"NOPE....").unwrap();
        let (raw, _) = crate::quant::pipeline::tests::fixture_public();
        let mut m = Transformer::from_raw(&raw).unwrap();
        assert!(load_into(&path, &mut m).is_err());
    }

    #[test]
    fn unknown_tag_error_names_tag_and_offset() {
        // Write a valid container, then corrupt the first tag string.
        let (raw, text) = crate::quant::pipeline::tests::fixture_public();
        let cfg = QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            arb_iters: 2,
            ..QuantConfig::naive()
        };
        let qm = quantize_model(&raw, &text, &cfg).unwrap();
        let path = tmp("tagged.qlm");
        save(&path, &qm.model).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // First per-linear record starts after magic(4)+ver(4)+cfg(7*4)+
        // theta(4)+has_cb(1)+n(4) = 45; tag begins at 45+4+1 = 50.
        assert_eq!(bytes[50], b"binary".len() as u8);
        assert_eq!(&bytes[51..57], b"binary");
        bytes[51..57].copy_from_slice(b"bogus!");
        let bad = tmp("bogus_tag.qlm");
        std::fs::write(&bad, &bytes).unwrap();
        let mut m = Transformer::from_raw(&raw).unwrap();
        let err = load_into(&bad, &mut m).unwrap_err().to_string();
        assert!(err.contains("bogus!"), "{err}");
        assert!(err.contains("offset"), "{err}");
    }

    #[test]
    fn corrupt_codebook_header_fails_loudly_without_huge_alloc() {
        let (raw, text) = crate::quant::pipeline::tests::fixture_public();
        let cfg = QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            transform_outer: 1,
            arb_iters: 2,
            v: 8,
            ..QuantConfig::btc(0.8)
        };
        let qm = quantize_model(&raw, &text, &cfg).unwrap();
        let path = tmp("cb.qlm");
        save(&path, &qm.model).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Codebook block: has_cb at 40, v at 41..45, c at 45..49.
        assert_eq!(bytes[40], 1);
        bytes[45..49].copy_from_slice(&u32::MAX.to_le_bytes()); // c = 4B
        let bad = tmp("huge_cb.qlm");
        std::fs::write(&bad, &bytes).unwrap();
        let mut m = Transformer::from_raw(&raw).unwrap();
        let err = load_into(&bad, &mut m).unwrap_err().to_string();
        assert!(err.contains("implausible size"), "{err}");

        // Also: implausible v.
        let mut bytes2 = std::fs::read(&path).unwrap();
        bytes2[41..45].copy_from_slice(&100u32.to_le_bytes()); // v = 100 > 64
        let bad2 = tmp("huge_v.qlm");
        std::fs::write(&bad2, &bytes2).unwrap();
        let err2 = load_into(&bad2, &mut m).unwrap_err().to_string();
        assert!(err2.contains("v=100"), "{err2}");
    }

    #[test]
    fn trailer_detects_flips_legacy_v3_still_loads() {
        // A v4 container with a flipped bit deep in a payload (past
        // every semantic check) is caught by the CRC; stripping the
        // trailer and rewriting the version as 3 loads fine (legacy).
        let (raw, text) = crate::quant::pipeline::tests::fixture_public();
        let cfg = QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            arb_iters: 2,
            ..QuantConfig::naive()
        };
        let qm = quantize_model(&raw, &text, &cfg).unwrap();
        let path = tmp("trailer.qlm");
        save(&path, &qm.model).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[bytes.len() - 8..][..4], b"QCRC", "v4 trailer present");

        // Flip one sign bit in the last backend payload: numerics
        // change silently without a checksum.
        let mut flipped = bytes.clone();
        let n = flipped.len();
        flipped[n - 20] ^= 0x01;
        let bad = tmp("flipped.qlm");
        std::fs::write(&bad, &flipped).unwrap();
        let mut m = Transformer::from_raw(&raw).unwrap();
        let err = load_into(&bad, &mut m).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");

        // Same container as legacy v3: no trailer, no checksum — the
        // old format keeps loading.
        let mut legacy = bytes[..bytes.len() - 8].to_vec();
        legacy[4..8].copy_from_slice(&3u32.to_le_bytes());
        let v3 = tmp("legacy_v3.qlm");
        std::fs::write(&v3, &legacy).unwrap();
        let mut m = Transformer::from_raw(&raw).unwrap();
        load_into(&v3, &mut m).unwrap();
        assert_eq!(m.blocks[0].wq.backend_name(), "binary");
    }

    #[test]
    fn corruption_property_flips_and_truncations_yield_err_never_panic() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let (raw, text) = crate::quant::pipeline::tests::fixture_public();
        let cfg = QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            arb_iters: 2,
            ..QuantConfig::naive()
        };
        let qm = quantize_model(&raw, &text, &cfg).unwrap();
        let path = tmp("golden_corrupt.qlm");
        save(&path, &qm.model).unwrap();
        let golden = std::fs::read(&path).unwrap();

        // Reused across attempts: corrupted loads only ever fail, and
        // a fresh model per attempt would dominate the test's runtime.
        let mut m = Transformer::from_raw(&raw).unwrap();
        load_into(&path, &mut m).unwrap();

        let target = tmp("corrupted.qlm");
        let mut try_load = |bytes: &[u8], what: String| {
            std::fs::write(&target, bytes).unwrap();
            match catch_unwind(AssertUnwindSafe(|| load_into(&target, &mut m))) {
                Ok(res) => assert!(res.is_err(), "{what}: corrupted container loaded"),
                Err(_) => panic!("{what}: loader panicked instead of returning Err"),
            }
        };
        // A bit flip at every byte offset must fail the load: CRC-32
        // detects every single-bit error, and the bounded semantic
        // checks may reject it even earlier. Never a panic.
        let mut rng = crate::util::rng::Rng::new(0xC0FFEE);
        for off in 0..golden.len() {
            let mut bad = golden.clone();
            bad[off] ^= 1 << rng.below(8);
            try_load(&bad, format!("bit flip at offset {off}"));
        }
        // Every truncation must fail: the v4 trailer is mandatory, so
        // even a cut that strips exactly the trailer is caught.
        for cut in 0..golden.len() {
            try_load(&golden[..cut], format!("truncation to {cut} bytes"));
        }
    }

    #[test]
    fn v1_files_still_load() {
        // Hand-write a v1 container (numeric tags) with one binary
        // linear and check it loads into slot wq of layer 0.
        use crate::quant::binarize::{write_binary_payload, BinaryLayer};
        let (raw, _) = crate::quant::pipeline::tests::fixture_public();
        let mut m = Transformer::from_raw(&raw).unwrap();
        let w0 = m.blocks[0].wq.backend.reconstruct();
        let bl = BinaryLayer::quantize(&w0);

        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"QLM1");
        wire::w_u32(&mut buf, 1).unwrap(); // version 1
        let c = &m.cfg;
        for v in [c.vocab, c.d_model, c.n_layer, c.n_head, c.n_kv_head, c.d_ff, c.max_seq] {
            wire::w_u32(&mut buf, v as u32).unwrap();
        }
        buf.extend_from_slice(&c.rope_theta.to_le_bytes());
        wire::w_u8(&mut buf, 0).unwrap(); // no shared codebook
        wire::w_u32(&mut buf, 1).unwrap(); // one linear
        wire::w_u32(&mut buf, 0).unwrap(); // layer 0
        wire::w_u8(&mut buf, 0).unwrap(); // slot wq
        wire::w_u8(&mut buf, 1).unwrap(); // v1 numeric tag: binary
        wire::w_u8(&mut buf, 0).unwrap(); // no transform
        write_binary_payload(&mut buf, &bl).unwrap();

        let path = tmp("v1.qlm");
        std::fs::write(&path, &buf).unwrap();
        load_into(&path, &mut m).unwrap();
        assert_eq!(m.blocks[0].wq.backend_name(), "binary");
        let rec = m.blocks[0].wq.backend.reconstruct();
        assert_close(&rec.data, &bl.reconstruct().data, 1e-6, 1e-6).unwrap();
    }
}
