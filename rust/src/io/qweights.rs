//! QLM1 quantized-model container: serialize a BTC-quantized model
//! (binary / codebook backends + transforms + scales) so `btc-llm
//! quantize` output can be shipped to `btc-llm serve` without
//! re-running the pipeline.
//!
//! Layout (little-endian):
//! ```text
//! magic b"QLM1", u32 version
//! TLM1-style model config block
//! u8 has_codebook; codebook: u32 v, u32 c, u64 words[c]
//! u32 n_linears; per linear:
//!   u32 layer; u8 slot (0..7); u8 backend_tag (0 dense,1 binary,2 codebook)
//!   u8 has_transform; transform: u32 dim,n1,n2; f32 sigma[dim],p1,p2
//!   backend payload (see read/write_backend)
//! ```
//! Norms/embeddings stay fp32 in the companion TLM1 blob; this file
//! carries only the quantized linears (the paper's W-bits subject).

use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::bitops::BitMatrix;
use crate::model::{Linear, LinearBackend, Transformer};
use crate::quant::binarize::BinaryLayer;
use crate::quant::codebook::{BinaryCodebook, CodebookLayer};
use crate::quant::transform::Transform;
use crate::tensor::Matrix;

const SLOTS: [&str; 7] = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];

fn w_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}
fn w_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}
fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
fn r_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}
fn r_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn write_binary(w: &mut impl Write, b: &BinaryLayer) -> Result<()> {
    w_u32(w, b.rows as u32)?;
    w_u32(w, b.cols as u32)?;
    w_u32(w, b.n_groups as u32)?;
    for word in &b.b.data {
        w.write_all(&word.to_le_bytes())?;
    }
    w_f32s(w, &b.alpha)?;
    w_f32s(w, &b.mu)?;
    for g in &b.col_group {
        w.write_all(&g.to_le_bytes())?;
    }
    Ok(())
}

fn read_binary(r: &mut impl Read) -> Result<BinaryLayer> {
    let rows = r_u32(r)? as usize;
    let cols = r_u32(r)? as usize;
    let n_groups = r_u32(r)? as usize;
    let mut b = BitMatrix::zeros(rows, cols);
    let mut bytes = vec![0u8; b.data.len() * 8];
    r.read_exact(&mut bytes)?;
    for (i, c) in bytes.chunks_exact(8).enumerate() {
        b.data[i] = u64::from_le_bytes(c.try_into().unwrap());
    }
    let alpha = r_f32s(r, rows * n_groups)?;
    let mu = r_f32s(r, rows)?;
    let mut gb = vec![0u8; cols * 2];
    r.read_exact(&mut gb)?;
    let col_group = gb.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect();
    Ok(BinaryLayer { rows, cols, b, alpha, mu, col_group, n_groups })
}

/// Save a quantized model. Backends other than Dense/Binary/Codebook
/// (baseline-only lanes) are rejected — they are not deployment formats.
pub fn save(path: &Path, model: &Transformer) -> Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(b"QLM1")?;
    w_u32(&mut w, 1)?;
    let c = &model.cfg;
    for v in [c.vocab, c.d_model, c.n_layer, c.n_head, c.n_kv_head, c.d_ff, c.max_seq] {
        w_u32(&mut w, v as u32)?;
    }
    w.write_all(&c.rope_theta.to_le_bytes())?;

    // Shared codebook (first one found).
    let mut shared: Option<Arc<BinaryCodebook>> = None;
    for b in &model.blocks {
        for (_, lin) in b.linears() {
            if let LinearBackend::Codebook(cl) = &lin.backend {
                shared = Some(cl.codebook.clone());
                break;
            }
        }
    }
    match &shared {
        Some(cb) => {
            w.write_all(&[1u8])?;
            w_u32(&mut w, cb.v as u32)?;
            w_u32(&mut w, cb.c() as u32)?;
            for word in &cb.words {
                w.write_all(&word.to_le_bytes())?;
            }
        }
        None => w.write_all(&[0u8])?,
    }

    let n_linears = model.blocks.len() * 7;
    w_u32(&mut w, n_linears as u32)?;
    for (li, block) in model.blocks.iter().enumerate() {
        for (slot, (name, lin)) in block.linears().iter().enumerate() {
            let _ = name;
            w_u32(&mut w, li as u32)?;
            w.write_all(&[slot as u8])?;
            let tag: u8 = match &lin.backend {
                LinearBackend::Dense(_) => 0,
                LinearBackend::Binary(_) => 1,
                LinearBackend::Codebook(_) => 2,
                other => bail!("backend {:?} is not a deployment format", std::mem::discriminant(other)),
            };
            w.write_all(&[tag])?;
            match &lin.transform {
                Some(t) => {
                    w.write_all(&[1u8])?;
                    w_u32(&mut w, t.dim() as u32)?;
                    w_u32(&mut w, t.p1.rows as u32)?;
                    w_u32(&mut w, t.p2.rows as u32)?;
                    w_f32s(&mut w, &t.sigma)?;
                    w_f32s(&mut w, &t.p1.data)?;
                    w_f32s(&mut w, &t.p2.data)?;
                }
                None => w.write_all(&[0u8])?,
            }
            match &lin.backend {
                LinearBackend::Dense(m) => {
                    w_u32(&mut w, m.rows as u32)?;
                    w_u32(&mut w, m.cols as u32)?;
                    w_f32s(&mut w, &m.data)?;
                }
                LinearBackend::Binary(b) => write_binary(&mut w, b)?,
                LinearBackend::Codebook(cl) => {
                    w_u32(&mut w, cl.rows as u32)?;
                    w_u32(&mut w, cl.cols as u32)?;
                    w_u32(&mut w, cl.n_groups as u32)?;
                    for &i in &cl.idx {
                        w_u32(&mut w, i)?;
                    }
                    w_f32s(&mut w, &cl.alpha)?;
                    w_f32s(&mut w, &cl.mu)?;
                    for g in &cl.col_group {
                        w.write_all(&g.to_le_bytes())?;
                    }
                }
                _ => unreachable!(),
            }
        }
    }
    Ok(())
}

/// Load quantized linears into a model previously built from the
/// companion TLM1 blob (embeddings/norms come from there).
pub fn load_into(path: &Path, model: &mut Transformer) -> Result<()> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != b"QLM1" {
        bail!("bad QLM1 magic");
    }
    if r_u32(&mut r)? != 1 {
        bail!("unsupported QLM1 version");
    }
    let mut hdr = [0usize; 7];
    for h in hdr.iter_mut() {
        *h = r_u32(&mut r)? as usize;
    }
    let mut theta = [0u8; 4];
    r.read_exact(&mut theta)?;
    if hdr[0] != model.cfg.vocab || hdr[1] != model.cfg.d_model || hdr[2] != model.cfg.n_layer {
        bail!("QLM1 config mismatch with loaded model");
    }

    let shared: Option<Arc<BinaryCodebook>> = if r_u8(&mut r)? == 1 {
        let v = r_u32(&mut r)? as usize;
        let c = r_u32(&mut r)? as usize;
        let mut bytes = vec![0u8; c * 8];
        r.read_exact(&mut bytes)?;
        let words = bytes.chunks_exact(8).map(|b| u64::from_le_bytes(b.try_into().unwrap())).collect();
        Some(Arc::new(BinaryCodebook { v, words }))
    } else {
        None
    };

    let n = r_u32(&mut r)? as usize;
    for _ in 0..n {
        let li = r_u32(&mut r)? as usize;
        let slot = r_u8(&mut r)? as usize;
        let tag = r_u8(&mut r)?;
        let transform = if r_u8(&mut r)? == 1 {
            let dim = r_u32(&mut r)? as usize;
            let n1 = r_u32(&mut r)? as usize;
            let n2 = r_u32(&mut r)? as usize;
            let sigma = r_f32s(&mut r, dim)?;
            let p1 = Matrix::from_vec(n1, n1, r_f32s(&mut r, n1 * n1)?);
            let p2 = Matrix::from_vec(n2, n2, r_f32s(&mut r, n2 * n2)?);
            Some(Transform { sigma, p1, p2 })
        } else {
            None
        };
        let backend = match tag {
            0 => {
                let rows = r_u32(&mut r)? as usize;
                let cols = r_u32(&mut r)? as usize;
                LinearBackend::Dense(Matrix::from_vec(rows, cols, r_f32s(&mut r, rows * cols)?))
            }
            1 => LinearBackend::Binary(read_binary(&mut r)?),
            2 => {
                let cb = shared.clone().context("codebook layer without shared codebook")?;
                let rows = r_u32(&mut r)? as usize;
                let cols = r_u32(&mut r)? as usize;
                let n_groups = r_u32(&mut r)? as usize;
                let n_idx = rows * cols.div_ceil(cb.v);
                let mut idx = Vec::with_capacity(n_idx);
                for _ in 0..n_idx {
                    idx.push(r_u32(&mut r)?);
                }
                let alpha = r_f32s(&mut r, rows * n_groups)?;
                let mu = r_f32s(&mut r, rows)?;
                let mut gb = vec![0u8; cols * 2];
                r.read_exact(&mut gb)?;
                let col_group =
                    gb.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect();
                LinearBackend::Codebook(CodebookLayer {
                    rows,
                    cols,
                    v: cb.v,
                    idx,
                    codebook: cb,
                    alpha,
                    mu,
                    col_group,
                    n_groups,
                })
            }
            t => bail!("unknown backend tag {t}"),
        };
        if li >= model.blocks.len() || slot >= 7 {
            bail!("linear ({li}, {slot}) out of range");
        }
        let block = &mut model.blocks[li];
        for (nm, lin) in block.linears_mut() {
            if nm == SLOTS[slot] {
                let mut new_lin = Linear::new(backend);
                new_lin.transform = transform;
                *lin = new_lin;
                break;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus;
    use crate::quant::pipeline::{quantize_model, QuantConfig};
    use crate::util::proptest::assert_close;

    #[test]
    fn roundtrip_btc_model() {
        // Quantize the pipeline fixture, save, reload, compare logits.
        let (raw, text) = crate::quant::pipeline::tests::fixture_public();
        let cfg = QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            transform_outer: 2,
            arb_iters: 4,
            v: 8,
            ..QuantConfig::btc(0.8)
        };
        let qm = quantize_model(&raw, &text, &cfg).unwrap();
        let dir = std::env::temp_dir().join("btc_qlm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.qlm");
        save(&path, &qm.model).unwrap();

        let mut reloaded = Transformer::from_raw(&raw).unwrap();
        load_into(&path, &mut reloaded).unwrap();
        reloaded.cache_dense_all();
        let toks = corpus::generate(200, 3)
            .bytes()
            .take(16)
            .map(|b| b as u16)
            .collect::<Vec<_>>();
        let a = qm.model.forward(&toks);
        let b = reloaded.forward(&toks);
        assert_close(&a.data, &b.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("btc_qlm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.qlm");
        std::fs::write(&path, b"NOPE....").unwrap();
        let (raw, _) = crate::quant::pipeline::tests::fixture_public();
        let mut m = Transformer::from_raw(&raw).unwrap();
        assert!(load_into(&path, &mut m).is_err());
    }
}
