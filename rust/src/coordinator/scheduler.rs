//! In-flight continuous-batching scheduler: owns the active request
//! set and advances it one *round* at a time, admitting new arrivals
//! between rounds instead of running each admitted batch to
//! completion (no head-of-line blocking behind a long generation).
//!
//! A round is: (1) requests still in their prompt phase advance
//! through [`Transformer::prefill`] (which supports chunked prefill
//! from `cache.len()`) within a *shared* budget of `prefill_chunk`
//! prompt tokens per round, so even a burst of long prompts never
//! stalls in-flight decoders for more than one bounded chunk;
//! (2) every decoding request contributes its next token to one
//! fused [`Transformer::decode_batch`] forward; (3) finished requests
//! are swap-compacted out and their responses (and streaming channels)
//! flushed. The [`Server`](super::server::Server) worker drives this
//! loop, draining its request channel non-blockingly before each round
//! (see [`Scheduler::admit_ready`]) up to `max_batch` in-flight slots.
//!
//! **Determinism contract:** with greedy sampling (temperature 0) a
//! request's output tokens are bit-identical regardless of what else
//! is in flight: every kernel on the path computes output rows
//! independently (see DESIGN.md §6), chunked prefill appends exactly
//! the K/V a whole-prompt prefill would, and `decode_batch` row `b` is
//! bit-identical to a solo `decode_step`. Pinned by tests here and in
//! `rust/tests/scheduling.rs`.

use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::server::{FinishReason, GenRequest, GenResponse};
use crate::model::kvcache::KvCache;
use crate::model::Transformer;
use crate::util::rng::Rng;

/// Where one in-flight request stands in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    /// Prompt tokens `0..consumed` are in the KV cache; more to feed.
    Prefill { consumed: usize },
    /// Prompt done; `next` is the sampled-but-not-yet-fed token.
    Decode { next: u16 },
    /// Finished this round; response flushed at the next compaction.
    Done(FinishReason),
}

/// One in-flight request: its KV cache lives inside the slot and is
/// lent to [`Transformer::decode_batch`] for the duration of a round
/// (cheap `Vec`-header moves — no K/V data is copied).
struct Slot {
    req: GenRequest,
    cache: KvCache,
    /// Prompt + generated tokens (the response payload).
    tokens: Vec<u16>,
    state: SlotState,
    /// Submit → slot admission.
    queue_wait: Duration,
    /// Submit → first generated token (zero until the first token).
    ttft: Duration,
    /// When the previous token was accepted (inter-token gaps).
    last_token_at: Option<Instant>,
}

/// Continuous-batching scheduler. [`Server`](super::server::Server)
/// owns one inside its worker thread; it is also usable directly for
/// custom serving loops (admit + step until idle).
pub struct Scheduler {
    model: Transformer,
    metrics: Arc<Metrics>,
    max_batch: usize,
    prefill_chunk: usize,
    slots: Vec<Slot>,
}

impl Scheduler {
    /// `max_batch` bounds the in-flight slot count; `prefill_chunk`
    /// bounds how many prompt tokens may be prefilled per round in
    /// total, across all prefilling slots (both clamped to at
    /// least 1).
    pub fn new(
        model: Transformer,
        metrics: Arc<Metrics>,
        max_batch: usize,
        prefill_chunk: usize,
    ) -> Scheduler {
        Scheduler {
            model,
            metrics,
            max_batch: max_batch.max(1),
            prefill_chunk: prefill_chunk.max(1),
            slots: Vec::new(),
        }
    }

    /// No requests in flight.
    pub fn is_idle(&self) -> bool {
        self.slots.is_empty()
    }

    /// In-flight request count.
    pub fn in_flight(&self) -> usize {
        self.slots.len()
    }

    /// Free in-flight slots.
    pub fn free_slots(&self) -> usize {
        self.max_batch - self.slots.len().min(self.max_batch)
    }

    /// Admit one request into a fresh slot (records its queue wait).
    pub fn admit(&mut self, req: GenRequest) {
        let now = Instant::now();
        let queue_wait = now.duration_since(req.submitted);
        self.metrics.record_admission(queue_wait.as_micros() as u64);
        let cache = self.model.new_cache(req.prompt.len() + req.max_new_tokens + 1);
        let tokens = req.prompt.clone();
        self.slots.push(Slot {
            req,
            cache,
            tokens,
            state: SlotState::Prefill { consumed: 0 },
            queue_wait,
            ttft: Duration::ZERO,
            last_token_at: None,
        });
    }

    /// Drain `rx` non-blockingly into free slots (the between-rounds
    /// admission path). Returns `false` once the channel is
    /// disconnected — no further arrivals will ever come.
    pub fn admit_ready(&mut self, rx: &Receiver<GenRequest>) -> bool {
        while self.free_slots() > 0 {
            match rx.try_recv() {
                Ok(req) => self.admit(req),
                Err(TryRecvError::Empty) => return true,
                Err(TryRecvError::Disconnected) => return false,
            }
        }
        true
    }

    /// One scheduling round: bounded prefill chunks, one fused decode,
    /// retirements compacted out. Does nothing when idle.
    pub fn step(&mut self, rng: &mut Rng) {
        self.prefill_round(rng);
        self.retire_done();
        self.decode_round(rng);
        self.retire_done();
    }

    /// Advance prefilling slots within a shared per-round budget of
    /// `prefill_chunk` prompt tokens — shared, not per-slot, so a
    /// burst of concurrent new prompts still stalls in-flight decoders
    /// by at most one chunk per round. A slot that consumes its last
    /// prompt token samples its first output token from the chunk's
    /// logits (prefill returns the last position's logits) and joins
    /// the decode set this same round; slots past the budget simply
    /// wait for the next round (prompts are finite, so none starves).
    fn prefill_round(&mut self, rng: &mut Rng) {
        let mut budget = self.prefill_chunk;
        for i in 0..self.slots.len() {
            if budget == 0 {
                break;
            }
            let SlotState::Prefill { consumed } = self.slots[i].state else {
                continue;
            };
            let slot = &mut self.slots[i];
            let plen = slot.req.prompt.len();
            let n = (plen - consumed).min(budget);
            budget -= n;
            let t0 = Instant::now();
            if consumed + n >= plen {
                // Final chunk: its logits seed the first output token.
                let logits =
                    self.model.prefill(&slot.req.prompt[consumed..consumed + n], &mut slot.cache);
                self.metrics.record_prefill(n, t0.elapsed().as_micros() as u64);
                let next = sample(&logits, slot.req.temperature, rng);
                self.accept(i, next);
            } else {
                // Mid-prompt chunk: nobody reads these logits — skip
                // the lm-head projection entirely.
                self.model
                    .prefill_extend(&slot.req.prompt[consumed..consumed + n], &mut slot.cache);
                self.metrics.record_prefill(n, t0.elapsed().as_micros() as u64);
                slot.state = SlotState::Prefill { consumed: consumed + n };
            }
        }
    }

    /// One fused decode forward over every decoding slot.
    fn decode_round(&mut self, rng: &mut Rng) {
        let ids: Vec<usize> = (0..self.slots.len())
            .filter(|&i| matches!(self.slots[i].state, SlotState::Decode { .. }))
            .collect();
        if ids.is_empty() {
            return;
        }
        self.metrics.record_batch(ids.len());
        let toks: Vec<u16> = ids
            .iter()
            .map(|&i| match self.slots[i].state {
                SlotState::Decode { next } => next,
                _ => unreachable!("filtered to Decode slots"),
            })
            .collect();
        // decode_batch needs a contiguous `&mut [KvCache]`: lend it the
        // active slots' caches for the round.
        let mut caches: Vec<KvCache> = ids
            .iter()
            .map(|&i| std::mem::replace(&mut self.slots[i].cache, KvCache::new(0, 0, 0)))
            .collect();
        let t0 = Instant::now();
        let logits = self.model.decode_batch(&toks, &mut caches);
        self.metrics.record_decode(toks.len(), t0.elapsed().as_micros() as u64);
        for (j, cache) in caches.into_iter().enumerate() {
            self.slots[ids[j]].cache = cache;
        }
        for (b, &i) in ids.iter().enumerate() {
            let next = sample(logits.row(b), self.slots[i].req.temperature, rng);
            self.accept(i, next);
        }
    }

    /// Accept a sampled token into slot `i`: append it, stream it,
    /// stamp TTFT / inter-token gaps, and apply the stop conditions
    /// (the stop/EOS token itself is included in the output, exactly
    /// as the pre-scheduler loop did with `'\n'`).
    fn accept(&mut self, i: usize, next: u16) {
        let slot = &mut self.slots[i];
        let now = Instant::now();
        slot.tokens.push(next);
        if let Some(stream) = &slot.req.stream {
            let _ = stream.send(next); // client may have hung up
        }
        match slot.last_token_at {
            None => {
                slot.ttft = now.duration_since(slot.req.submitted);
                self.metrics.record_ttft(slot.ttft.as_micros() as u64);
            }
            Some(prev) => self.metrics.record_itl(now.duration_since(prev).as_micros() as u64),
        }
        slot.last_token_at = Some(now);
        let produced = slot.tokens.len() - slot.req.prompt.len();
        slot.state = match slot.req.stop.classify(next) {
            Some(reason) => SlotState::Done(reason),
            None if produced >= slot.req.max_new_tokens => SlotState::Done(FinishReason::Length),
            None => SlotState::Decode { next },
        };
    }

    /// Swap-compact every finished slot out, flushing its response.
    fn retire_done(&mut self) {
        let mut i = 0;
        while i < self.slots.len() {
            if matches!(self.slots[i].state, SlotState::Done(_)) {
                let slot = self.slots.swap_remove(i);
                self.finish(slot);
            } else {
                i += 1;
            }
        }
    }

    fn finish(&self, slot: Slot) {
        let SlotState::Done(finish) = slot.state else {
            unreachable!("finish() called on unfinished slot");
        };
        let produced = slot.tokens.len() - slot.req.prompt.len();
        let latency = slot.req.submitted.elapsed();
        let seq = self.metrics.record_completion(produced, latency.as_micros() as u64);
        // Dropping `slot.req` afterwards closes the streaming channel,
        // so a streaming client sees all tokens, then the response,
        // then end-of-stream.
        let _ = slot.req.respond.send(GenResponse {
            tokens: slot.tokens,
            prompt_len: slot.req.prompt.len(),
            latency,
            queue_wait: slot.queue_wait,
            ttft: slot.ttft,
            finish,
            seq,
        });
    }
}

/// Sample a token from logits: greedy argmax at temperature <= 0
/// (NaN-safe: NaNs are skipped, ties break low, empty logits degrade
/// to token 0 — a bad forward must never panic the worker that owns
/// the model), else softmax sampling at the given temperature.
pub(crate) fn sample(logits: &[f32], temperature: f64, rng: &mut Rng) -> u16 {
    if logits.is_empty() {
        return 0;
    }
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_nan())
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as u16)
            .unwrap_or(0);
    }
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let probs: Vec<f64> =
        logits.iter().map(|&v| (((v - max) as f64) / temperature).exp()).collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i as u16;
        }
    }
    (probs.len() - 1) as u16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{Server, ServerOptions, StopSet};
    use crate::model::transformer::tests::tiny_model;

    fn opts(max_batch: usize, prefill_chunk: usize) -> ServerOptions {
        ServerOptions {
            max_batch,
            prefill_chunk,
            batch_wait: Duration::from_millis(1),
            seed: 7,
            ..ServerOptions::default()
        }
    }

    fn run_one(server: &Server, prompt: Vec<u16>, max_new: usize, stop: StopSet) -> GenResponse {
        let rx = server.submit_with(prompt, max_new, 0.0, stop, None).expect("submit");
        rx.recv_timeout(Duration::from_secs(60)).expect("response")
    }

    #[test]
    fn sampling_respects_temperature_zero() {
        let mut rng = Rng::new(1);
        let logits = vec![0.0f32, 5.0, 1.0];
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn greedy_sampling_survives_nan_logits() {
        let mut rng = Rng::new(1);
        // NaN must neither panic nor be selected.
        assert_eq!(sample(&[1.0, f32::NAN, 5.0, f32::NAN], 0.0, &mut rng), 2);
        // All-NaN and empty degenerate to token 0.
        assert_eq!(sample(&[f32::NAN, f32::NAN], 0.0, &mut rng), 0);
        assert_eq!(sample(&[], 0.0, &mut rng), 0);
        assert_eq!(sample(&[], 1.0, &mut rng), 0);
    }

    #[test]
    fn chunked_prefill_matches_whole_prefill() {
        // The same request must generate identical tokens whether its
        // prompt is prefilled in 1-, 2- or whole-prompt chunks.
        let m = tiny_model(11, 4);
        let prompt: Vec<u16> = vec![3, 9, 1, 7, 5, 2, 8];
        let runs: Vec<Vec<u16>> = [1usize, 2, 64]
            .iter()
            .map(|&chunk| {
                let server = Server::start_with_opts(m.clone(), opts(2, chunk));
                let r = run_one(&server, prompt.clone(), 6, StopSet::none());
                server.shutdown();
                r.tokens
            })
            .collect();
        assert_eq!(runs[0], runs[1], "chunk=1 vs chunk=2");
        assert_eq!(runs[1], runs[2], "chunk=2 vs whole-prompt");
    }

    fn request(
        prompt: Vec<u16>,
        max_new: usize,
        respond: std::sync::mpsc::Sender<GenResponse>,
    ) -> GenRequest {
        GenRequest {
            prompt,
            max_new_tokens: max_new,
            temperature: 0.0,
            stop: StopSet::none(),
            stream: None,
            respond,
            submitted: Instant::now(),
        }
    }

    #[test]
    fn short_request_overtakes_long() {
        // No head-of-line blocking: a short request admitted *while a
        // long one is mid-decode* must retire first (strictly smaller
        // completion sequence number). Driving the scheduler directly
        // makes the interleaving deterministic — no wall-clock races.
        let m = tiny_model(2, 4);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(m, metrics, 2, 4);
        let mut rng = Rng::new(7);
        let (ltx, lrx) = std::sync::mpsc::channel();
        sched.admit(request(vec![1, 2, 3], 48, ltx));
        // The long request decodes for three rounds before the short
        // one arrives — exactly the mid-flight admission case.
        for _ in 0..3 {
            sched.step(&mut rng);
        }
        assert_eq!(sched.in_flight(), 1, "long still decoding");
        let (stx, srx) = std::sync::mpsc::channel();
        sched.admit(request(vec![4, 5], 2, stx));
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000, "scheduler failed to drain");
        }
        let long = lrx.try_recv().expect("long finished");
        let short = srx.try_recv().expect("short finished");
        assert!(
            short.seq < long.seq,
            "short (seq {}) must retire before long (seq {})",
            short.seq,
            long.seq
        );
        assert_eq!(long.tokens.len() - long.prompt_len, 48);
        assert_eq!(short.tokens.len() - short.prompt_len, 2);
    }

    #[test]
    fn greedy_identical_with_and_without_cotraffic() {
        // Determinism contract: greedy outputs are bit-identical no
        // matter what else is in flight.
        let m = tiny_model(5, 4);
        let prompt: Vec<u16> = vec![6, 1, 9];
        let solo = {
            let server = Server::start_with_opts(m.clone(), opts(1, 64));
            let r = run_one(&server, prompt.clone(), 8, StopSet::none());
            server.shutdown();
            r.tokens
        };
        let busy = {
            let server = Server::start_with_opts(m.clone(), opts(4, 2));
            // Background traffic: one long and one mid request.
            let bg1 = server
                .submit_with(vec![2, 3, 4, 5, 6], 48, 0.0, StopSet::none(), None)
                .expect("submit");
            let bg2 = server.submit_with(vec![7], 20, 0.0, StopSet::none(), None).expect("submit");
            let r = run_one(&server, prompt.clone(), 8, StopSet::none());
            bg1.recv_timeout(Duration::from_secs(60)).unwrap();
            bg2.recv_timeout(Duration::from_secs(60)).unwrap();
            server.shutdown();
            r.tokens
        };
        assert_eq!(solo, busy);
    }

    #[test]
    fn streamed_tokens_match_final_response() {
        let m = tiny_model(8, 4);
        let server = Server::start_with_opts(m, opts(2, 4));
        let (stream, rx) = server.submit_streaming(vec![1, 2, 3, 4, 5], 6, 0.0).expect("submit");
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("response");
        // The sender side is dropped at retirement, so the stream
        // iterator terminates after the last token.
        let streamed: Vec<u16> = stream.iter().collect();
        assert_eq!(streamed, resp.tokens[resp.prompt_len..].to_vec());
        assert!(resp.ttft <= resp.latency);
        server.shutdown();
    }

    #[test]
    fn eos_token_stops_generation() {
        let m = tiny_model(4, 4);
        // Learn the first greedy token, then declare it the EOS.
        let first = {
            let server = Server::start_with_opts(m.clone(), opts(1, 64));
            let r = run_one(&server, vec![3, 1], 1, StopSet::none());
            server.shutdown();
            r.tokens[r.prompt_len]
        };
        let server = Server::start_with_opts(m, opts(1, 64));
        let r = run_one(&server, vec![3, 1], 10, StopSet::none().with_eos(first));
        assert_eq!(r.tokens.len() - r.prompt_len, 1, "EOS after the first token");
        assert_eq!(r.finish, FinishReason::Eos);
        server.shutdown();
    }

    #[test]
    fn length_cap_reports_finish_reason() {
        let m = tiny_model(6, 4);
        let server = Server::start_with_opts(m, opts(1, 64));
        let r = run_one(&server, vec![2, 4], 5, StopSet::none());
        assert_eq!(r.tokens.len() - r.prompt_len, 5);
        assert_eq!(r.finish, FinishReason::Length);
        assert!(r.queue_wait <= r.ttft && r.ttft <= r.latency);
        server.shutdown();
    }

    #[test]
    fn ttft_and_itl_metrics_populated() {
        let m = tiny_model(9, 4);
        let server = Server::start_with_opts(m, opts(2, 4));
        let r = run_one(&server, vec![1, 2, 3], 6, StopSet::none());
        assert_eq!(r.tokens.len() - r.prompt_len, 6);
        let mt = &server.metrics;
        assert!(mt.ttft_percentile_us(0.5) > 0, "TTFT recorded");
        // ITL gaps on a tiny model can floor to 0µs in release; the
        // reservoir behavior itself is pinned in metrics.rs tests.
        let s = mt.summary();
        assert!(s.contains("ttft_p50=") && s.contains("itl_p50="), "summary carries TTFT/ITL: {s}");
        server.shutdown();
    }
}
