//! In-flight continuous-batching scheduler: owns the active request
//! set **and the KV block pool**, and advances both one *round* at a
//! time, admitting new arrivals between rounds instead of running each
//! admitted batch to completion (no head-of-line blocking behind a
//! long generation).
//!
//! A round is: (1) requests still in their prompt phase advance
//! through [`Transformer::prefill_paged`] (chunked from
//! `cache.len()`) within a *shared* budget of `prefill_chunk` prompt
//! tokens per round; (2) every decoding request contributes its next
//! token to one fused [`Transformer::decode_batch_paged`] forward;
//! (3) finished requests are swap-compacted out and their responses
//! (and streaming channels) flushed. The
//! [`Server`](super::server::Server) worker drives this loop, draining
//! its request channel non-blockingly before each round (see
//! [`Scheduler::admit_ready`]) up to `max_batch` in-flight slots.
//!
//! **Multi-tenant admission (DESIGN.md §9).** Requests wait in
//! [`PendingQueues`] until a slot is free **and** the pool has free
//! blocks for their prompt. Under the default FIFO policy this is the
//! PR 4/5 behavior exactly; under weighted round-robin each tenant
//! queues separately and admission drains the most urgent priority
//! class first, weight-proportionally within it — a flooding tenant
//! deepens only its own queue. Admission still reserves the *prompt*
//! footprint only: generation headroom is allocated incrementally
//! (the oversubscription that beats worst-case reservation).
//!
//! **Memory pressure (DESIGN.md §8).** When growth exhausts the pool
//! mid-flight, the [`EvictionPolicy`] picks a victim: the eligible
//! slot with the *largest eviction key*, and only if that key is
//! strictly greater than the requester's own — so the minimum-key
//! slot is unevictable and some request always makes progress, under
//! any policy. The default `newest` policy reproduces PR 5's
//! newest-slot rule bit for bit. Preemption releases the victim's
//! blocks and resets it to re-prefill its accumulated tokens
//! (recompute, not swap); a full pool defers admission rather than
//! panicking. Prompts that share a token prefix share refcounted pool
//! blocks.
//!
//! **Speculative decoding (DESIGN.md §13).** When [`Scheduler::set_spec`]
//! arms a draft model, a greedy slot's decode round may be replaced
//! by a draft/verify round: the (sub-1-bit) draft proposes up to
//! `spec_k` tokens on its own cache in the same pool, ONE batched
//! target forward scores all k+1 positions, and the longest agreeing
//! prefix plus the bonus token from the first disagreeing row are
//! accepted — bit-identical to plain greedy decoding, because row i
//! of the verify forward computes exactly the logits sequential
//! decoding would (prefill ≡ decode). Rejected positions roll back
//! via [`KvPool::truncate`]; draft blocks count toward the slot's
//! eviction footprint; a draft-model fault degrades the slot to
//! plain decoding (never quarantine) — speculation is an
//! optimization, never a correctness dependency.
//!
//! **Determinism contract:** with greedy sampling (temperature 0) a
//! request's output tokens are bit-identical regardless of what else
//! is in flight — including across preemption/re-prefill (prefill ≡
//! repeated decode, so recompute reproduces the dropped state
//! exactly) and prefix sharing (a shared block holds exactly the
//! bytes the attaching request would have computed). QoS reorders
//! *which* request runs when, never *what* a request computes. Pinned
//! by tests here and in `rust/tests/scheduling.rs` /
//! `rust/tests/batch_equivalence.rs`.

use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::qos::{EvictionPolicy, PendingQueues, QosState, SlotView};
use super::server::{FinishReason, GenRequest, GenResponse};
use crate::model::kvcache::{KvPool, PagedKvCache, PoolConfig};
use crate::model::Transformer;
use crate::util::rng::Rng;

/// Where one in-flight request stands in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    /// Tokens `0..consumed` are in the KV cache; more to feed. The
    /// prefill source is `Slot::tokens` — the prompt on first
    /// admission, prompt + generated-so-far after a preemption.
    Prefill { consumed: usize },
    /// Prompt done; `next` is the sampled-but-not-yet-fed token.
    Decode { next: u16 },
    /// Finished this round; response flushed at the next compaction.
    Done(FinishReason),
}

/// One in-flight request. Its K/V lives in the shared pool; the slot
/// holds the paged handle, lent to the fused forwards per round
/// (cheap header moves — no K/V data is copied).
struct Slot {
    req: GenRequest,
    cache: PagedKvCache,
    /// Prompt + generated tokens (the response payload, and the
    /// re-prefill source after a preemption).
    tokens: Vec<u16>,
    state: SlotState,
    /// Effective generation cap (request's `max_new_tokens`, clamped
    /// so the sequence can always fit the pool alone).
    max_new: usize,
    /// Admission order (unique — the eviction keys' tiebreaker).
    admitted: u64,
    /// Resolved tenant index (clamped into the tenant table).
    tenant: usize,
    /// The tenant's priority class (0 = most urgent), for
    /// [`EvictionPolicy`] keys.
    priority: u8,
    /// Submit → slot admission.
    queue_wait: Duration,
    /// Submit → first generated token (zero until the first token).
    ttft: Duration,
    /// When the previous token was accepted (inter-token gaps).
    last_token_at: Option<Instant>,
    /// Draft-model KV cache for speculative decoding (lazily created
    /// at the slot's first spec round; `None` when speculation is off
    /// or degraded). Lives in the same block pool as `cache` — its
    /// blocks count toward this slot's eviction footprint.
    draft: Option<PagedKvCache>,
    /// Per-slot draft depth (adaptive: halves on full rejection,
    /// grows on full-acceptance streaks; 0 = not yet initialized).
    spec_k: usize,
    /// Consecutive fully-accepted spec rounds (adaptive-k growth).
    spec_streak: u32,
    /// Cleared when a draft-model fault degrades this slot to plain
    /// decoding for the rest of its lifetime.
    spec_on: bool,
}

fn view(s: &Slot) -> SlotView {
    let kv_blocks = s.cache.blocks() + s.draft.as_ref().map_or(0, |d| d.blocks());
    SlotView { admitted: s.admitted, priority: s.priority, kv_blocks }
}

/// Speculative-decoding state shared by every slot: the draft model
/// plus the adaptive-k bounds (DESIGN.md §13).
struct SpecState {
    draft: Transformer,
    /// Initial per-slot draft depth.
    k0: usize,
    /// Adaptive-k ceiling.
    max_k: usize,
}

/// Continuous-batching scheduler. [`Server`](super::server::Server)
/// owns one inside its worker thread; it is also usable directly for
/// custom serving loops (admit + step until idle).
pub struct Scheduler {
    model: Transformer,
    metrics: Arc<Metrics>,
    max_batch: usize,
    prefill_chunk: usize,
    pool: KvPool,
    slots: Vec<Slot>,
    /// Requests waiting for a slot + pool memory, ordered by the
    /// admission policy (FIFO or per-tenant WRR).
    pending: PendingQueues,
    /// Shared QoS state (tenant table + pending-depth counters the
    /// server's submit path bounds against).
    qos: Arc<QosState>,
    /// Preemption victim selection under pool pressure.
    evict: Box<dyn EvictionPolicy>,
    /// Speculative decoding (draft model + adaptive-k bounds); `None`
    /// means plain decoding only.
    spec: Option<SpecState>,
    admit_seq: u64,
    /// The queue head is currently parked on pool memory — dedupes
    /// the admission-deferral counter to one event per parked
    /// stretch, however many times the admission loop re-checks it.
    head_deferred: bool,
}

impl Scheduler {
    /// `max_batch` bounds the in-flight slot count; `prefill_chunk`
    /// bounds how many prompt tokens may be prefilled per round in
    /// total, across all prefilling slots (both clamped to at
    /// least 1). The KV pool defaults to worst-case-equivalent
    /// capacity (lazily allocated), so behavior matches the old flat
    /// reservation unless a tighter [`PoolConfig`] is given via
    /// [`Scheduler::with_pool`].
    pub fn new(
        model: Transformer,
        metrics: Arc<Metrics>,
        max_batch: usize,
        prefill_chunk: usize,
    ) -> Scheduler {
        Self::with_pool(model, metrics, max_batch, prefill_chunk, PoolConfig::default())
    }

    /// [`Scheduler::new`] with an explicit KV pool shape. A
    /// `budget_blocks` of 0 auto-sizes to `max_batch` worst-case
    /// sequences. Default QoS: single tenant, FIFO, newest-slot
    /// eviction.
    pub fn with_pool(
        model: Transformer,
        metrics: Arc<Metrics>,
        max_batch: usize,
        prefill_chunk: usize,
        pool_cfg: PoolConfig,
    ) -> Scheduler {
        Self::with_qos(model, metrics, max_batch, prefill_chunk, pool_cfg, Arc::new(QosState::default()))
    }

    /// Fully-explicit construction: pool shape plus shared QoS state
    /// (tenant table, admission policy, eviction policy). The server
    /// shares `qos` with its submit path; direct users may pass a
    /// fresh `QosState`.
    pub fn with_qos(
        model: Transformer,
        metrics: Arc<Metrics>,
        max_batch: usize,
        prefill_chunk: usize,
        pool_cfg: PoolConfig,
        qos: Arc<QosState>,
    ) -> Scheduler {
        let max_batch = max_batch.max(1);
        let pool = model.new_pool(&pool_cfg, max_batch);
        let pending = PendingQueues::new(&qos.config);
        let evict = qos.config.eviction.policy();
        let s = Scheduler {
            model,
            metrics,
            max_batch,
            prefill_chunk: prefill_chunk.max(1),
            pool,
            slots: Vec::new(),
            pending,
            qos,
            evict,
            spec: None,
            admit_seq: 0,
            head_deferred: false,
        };
        s.publish_kv_metrics();
        s
    }

    /// Arm speculative decoding: greedy slots draft up to `k` tokens
    /// per round with `draft` (per-slot adaptive, up to `max_k`, both
    /// clamped to at least 1/`k`) and verify them in one batched
    /// target forward. The draft must share the target's
    /// [`ModelConfig`](crate::io::weights::ModelConfig) — the two
    /// caches live in one pool whose block geometry is the target's.
    /// [`Server`](super::server::Server) validates this (and the
    /// draft artifact itself) at start time; direct users get a
    /// debug assertion.
    pub fn set_spec(&mut self, draft: Transformer, k: usize, max_k: usize) {
        debug_assert_eq!(draft.cfg, self.model.cfg, "draft/target ModelConfig mismatch");
        let k = k.max(1);
        self.spec = Some(SpecState { draft, k0: k, max_k: max_k.max(k) });
    }

    /// No requests in flight or pending.
    pub fn is_idle(&self) -> bool {
        self.slots.is_empty() && self.pending.is_empty()
    }

    /// In-flight request count (slotted; excludes the pending queue).
    pub fn in_flight(&self) -> usize {
        self.slots.len()
    }

    /// Requests waiting for a slot or for pool memory.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Free in-flight slots.
    pub fn free_slots(&self) -> usize {
        self.max_batch - self.slots.len().min(self.max_batch)
    }

    /// The KV block pool (diagnostics / tests / benches).
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// Enqueue one request; it enters a slot immediately if a slot and
    /// pool memory are available, otherwise at a later round.
    pub fn admit(&mut self, req: GenRequest) {
        self.pending.push(req);
        self.try_admit_pending();
    }

    /// Drain `rx` non-blockingly into the pending queues and admit
    /// what fits (the between-rounds admission path). Returns `false`
    /// once the channel is disconnected — no further arrivals will
    /// ever come.
    pub fn admit_ready(&mut self, rx: &Receiver<GenRequest>) -> bool {
        let mut open = true;
        loop {
            match rx.try_recv() {
                Ok(req) => self.pending.push(req),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        self.try_admit_pending();
        open
    }

    /// Move pending requests into slots while both a slot and enough
    /// free blocks for their prompt exist. The *policy* picks who is
    /// next; a memory-blocked head still defers everyone behind it
    /// (deliberate: skipping a parked request would starve it).
    /// Admission checks — and reserves — the *prompt* footprint only;
    /// generation headroom is allocated incrementally, which is
    /// exactly the oversubscription that lets the pool hold more
    /// in-flight requests than worst-case reservation would.
    fn try_admit_pending(&mut self) {
        while self.slots.len() < self.max_batch {
            let plen = match self.pending.peek() {
                Some(req) => req.prompt.len(),
                None => break,
            };
            if plen + 1 > self.seq_position_cap() {
                // Can never be served — the whole pool or the RoPE
                // table couldn't hold it: fail fast instead of
                // wedging the queue (or panicking the worker mid-
                // forward on a rope-table overrun).
                let req = self.pending.pop().unwrap();
                self.head_deferred = false;
                self.note_dequeued(&req);
                self.complete_unserved(req, FinishReason::Length);
                continue;
            }
            if !self.pool.can_fit_new(plen + 1) {
                if !self.head_deferred {
                    self.head_deferred = true;
                    self.metrics.record_kv_admission_deferral();
                }
                break;
            }
            let req = self.pending.pop().unwrap();
            self.head_deferred = false;
            self.note_dequeued(&req);
            self.admit_slot(req);
        }
    }

    /// Resolve a request's tenant index (clamped into the table).
    fn tenant_of(&self, req: &GenRequest) -> usize {
        (req.tenant as usize).min(self.qos.config.tenants.len() - 1)
    }

    /// Maintain the shared pending-depth counter the submit path
    /// bounds against.
    fn note_dequeued(&self, req: &GenRequest) {
        self.qos.note_dequeued(self.tenant_of(req));
    }

    fn admit_slot(&mut self, req: GenRequest) {
        let now = Instant::now();
        let queue_wait = now.duration_since(req.submitted);
        let tenant = self.tenant_of(&req);
        let priority = self.qos.config.tenants[tenant].priority;
        self.metrics.record_admission(queue_wait.as_micros() as u64);
        self.metrics
            .record_tenant_admission(&self.qos.config.tenants[tenant].id, queue_wait.as_micros() as u64);
        let mut cache = self.pool.new_cache();
        // Prefix sharing: attach whatever full prompt blocks are
        // already resident; prefill starts after them.
        let shared = self.pool.attach_prefix(&mut cache, &req.prompt);
        // Reserve the prompt footprint (+1 for the first decode
        // position) NOW, so the admission gate's free-block check is
        // real: a same-round burst cannot all be admitted against the
        // same free count and then thrash on preemption during
        // prefill. Cannot fail — the gate checked the unshared worst
        // case against the same single-threaded pool.
        let need = (req.prompt.len() + 1).saturating_sub(cache.len());
        let reserved = self.pool.ensure_append(&mut cache, need);
        debug_assert!(reserved, "admission gate checked free blocks");
        // Feasibility clamp: a sequence must always be able to finish
        // alone in the pool (the preemption progress guarantee) AND
        // stay inside the RoPE table (no mid-forward panic).
        let max_new = req.max_new_tokens.min(self.seq_position_cap() - req.prompt.len());
        let tokens = req.prompt.clone();
        self.admit_seq += 1;
        self.slots.push(Slot {
            req,
            cache,
            tokens,
            state: SlotState::Prefill { consumed: shared },
            max_new,
            admitted: self.admit_seq,
            tenant,
            priority,
            queue_wait,
            ttft: Duration::ZERO,
            last_token_at: None,
            draft: None,
            spec_k: self.spec.as_ref().map_or(0, |s| s.k0),
            spec_streak: 0,
            spec_on: true,
        });
        self.metrics.record_in_flight(self.slots.len());
    }

    /// Hard per-sequence position bound: one sequence can never
    /// exceed the whole pool's budget, nor the model's RoPE table.
    fn seq_position_cap(&self) -> usize {
        self.pool.position_capacity().min(self.model.max_positions())
    }

    /// Complete a request without serving it: zero generated tokens,
    /// explicit finish reason. Used for prompts that can never fit
    /// (`Length`) and for drain-time cancellation (`Cancelled`).
    fn complete_unserved(&self, req: GenRequest, finish: FinishReason) {
        let GenRequest { prompt, respond, submitted, .. } = req;
        let latency = submitted.elapsed();
        let seq = self.metrics.record_completion(0, latency.as_micros() as u64);
        let prompt_len = prompt.len();
        let _ = respond.send(GenResponse {
            tokens: prompt,
            prompt_len,
            latency,
            queue_wait: latency,
            ttft: Duration::ZERO,
            finish,
            seq,
        });
    }

    /// Cancel a request that never reached the pending queues (the
    /// server's drain path pulls these straight off its channel):
    /// decrement its tenant's pending depth and answer `Cancelled`.
    pub fn cancel_submitted(&mut self, req: GenRequest) {
        self.note_dequeued(&req);
        self.complete_unserved(req, FinishReason::Cancelled);
    }

    /// Cancel everything still waiting in the pending queues with an
    /// explicit `Cancelled` response (bounded-drain shutdown).
    pub fn cancel_pending(&mut self) {
        let reqs = self.pending.drain_all();
        self.head_deferred = false;
        for req in reqs {
            self.note_dequeued(&req);
            self.complete_unserved(req, FinishReason::Cancelled);
        }
    }

    /// Mark every in-flight slot `Cancelled`; the next `step` retires
    /// them, delivering partial outputs (tokens generated so far) and
    /// closing their streams (drain-deadline shutdown).
    pub fn cancel_in_flight(&mut self) {
        for slot in &mut self.slots {
            if !matches!(slot.state, SlotState::Done(_)) {
                slot.state = SlotState::Done(FinishReason::Cancelled);
            }
        }
    }

    /// One scheduling round: expiry/cancellation reaping, admissions,
    /// bounded prefill chunks, one fused decode, retirements compacted
    /// out, cold blocks re-encoded, pool gauges published. Does
    /// nothing when idle.
    pub fn step(&mut self, rng: &mut Rng) {
        self.reap_expired();
        self.retire_done();
        self.try_admit_pending();
        self.prefill_round(rng);
        self.retire_done();
        self.decode_round(rng);
        self.retire_done();
        self.try_admit_pending();
        self.housekeep();
    }

    /// Retire requests whose client hung up ([`CancelToken`]
    /// tripped → `Cancelled`) or whose wall-clock deadline passed
    /// (`DeadlineExceeded`), both in-flight and still pending. Runs at
    /// the top of every round, so either signal takes effect within
    /// one decode round: in-flight slots deliver their partial output
    /// and free their KV blocks at the `retire_done` that follows;
    /// pending requests answer immediately without ever taking a slot.
    fn reap_expired(&mut self) {
        let now = Instant::now();
        for slot in &mut self.slots {
            if matches!(slot.state, SlotState::Done(_)) {
                continue;
            }
            if slot.req.cancel.is_cancelled() {
                self.metrics.record_disconnect_cancel();
                slot.state = SlotState::Done(FinishReason::Cancelled);
            } else if slot.req.deadline.is_some_and(|d| now >= d) {
                self.metrics.record_deadline_cancel();
                slot.state = SlotState::Done(FinishReason::DeadlineExceeded);
            }
        }
        let dead = self.pending.extract_where(|req| {
            req.cancel.is_cancelled() || req.deadline.is_some_and(|d| now >= d)
        });
        if !dead.is_empty() {
            // The parked head may be among the extracted: re-evaluate.
            self.head_deferred = false;
        }
        for req in dead {
            self.note_dequeued(&req);
            if req.cancel.is_cancelled() {
                self.metrics.record_disconnect_cancel();
                self.complete_unserved(req, FinishReason::Cancelled);
            } else {
                self.metrics.record_deadline_cancel();
                self.complete_unserved(req, FinishReason::DeadlineExceeded);
            }
        }
    }

    /// Post-panic recovery (the server's supervisor calls this after
    /// catching a panic that escaped round-level containment): every
    /// in-flight request is answered with [`FinishReason::Failed`] and
    /// its KV blocks are released; the pending queue is preserved so
    /// queued requests are served by the restarted loop.
    pub fn recover(&mut self) {
        for slot in &mut self.slots {
            if !matches!(slot.state, SlotState::Done(_)) {
                slot.state = SlotState::Done(FinishReason::Failed);
            }
        }
        self.retire_done();
        self.head_deferred = false;
        self.publish_kv_metrics();
    }

    /// Ensure slot `i` can append `extra` positions, preempting slots
    /// the [`EvictionPolicy`] ranks strictly above it (largest key
    /// first) until it fits. Returns `false` when `i` should defer
    /// instead — every other slot ranks at or below it and will
    /// retire first. Capacity is *reserved* (not just checked), so a
    /// later slot's check cannot steal it.
    fn ensure_capacity_for(&mut self, i: usize, extra: usize) -> bool {
        loop {
            if self.pool.ensure_append(&mut self.slots[i].cache, extra) {
                return true;
            }
            let my_key = self.evict.key(&view(&self.slots[i]));
            let mut victim: Option<(usize, (u64, u64))> = None;
            for (j, s) in self.slots.iter().enumerate() {
                if j == i || view(s).kv_blocks == 0 || matches!(s.state, SlotState::Done(_)) {
                    continue;
                }
                let k = self.evict.key(&view(s));
                if k > my_key && victim.map_or(true, |(_, vk)| k > vk) {
                    victim = Some((j, k));
                }
            }
            match victim {
                Some((j, _)) => self.preempt(j),
                None => return false,
            }
        }
    }

    /// Evict slot `j`'s K/V (refcounts drop; shared blocks survive
    /// under their other holders) and reset it to re-prefill its
    /// accumulated tokens once memory frees up. Greedy outputs are
    /// unaffected: re-prefilling `tokens` reproduces the dropped K/V
    /// and the pending next token bit-identically.
    fn preempt(&mut self, j: usize) {
        self.metrics.record_kv_preemption();
        self.pool.release(&mut self.slots[j].cache);
        self.release_draft(j);
        self.slots[j].state = SlotState::Prefill { consumed: 0 };
    }

    /// Return slot `j`'s draft cache (if any) to the pool. Safe to
    /// call repeatedly; the slot re-warms a fresh draft cache at its
    /// next spec round (unless degraded).
    fn release_draft(&mut self, j: usize) {
        if let Some(mut d) = self.slots[j].draft.take() {
            self.pool.release(&mut d);
        }
    }

    /// Advance prefilling slots within a shared per-round budget of
    /// `prefill_chunk` prompt tokens — shared, not per-slot, so a
    /// burst of concurrent new prompts still stalls in-flight decoders
    /// by at most one chunk per round. A slot that consumes its last
    /// prompt token samples its first output token from the chunk's
    /// logits and joins the decode set this same round; slots past the
    /// budget (or waiting for pool memory) simply wait for a later
    /// round. Chunks shrink to the memory actually available before
    /// any preemption is considered.
    fn prefill_round(&mut self, rng: &mut Rng) {
        let mut budget = self.prefill_chunk;
        for i in 0..self.slots.len() {
            if budget == 0 {
                break;
            }
            let SlotState::Prefill { consumed } = self.slots[i].state else {
                continue;
            };
            let plen = self.slots[i].tokens.len();
            let mut n = (plen - consumed).min(budget);
            if n > 0 {
                let fit = self.pool.max_append(&self.slots[i].cache).min(n);
                if fit > 0 {
                    n = fit;
                } else if self.ensure_capacity_for(i, 1) {
                    // Preemption freed memory; take what fits now.
                    n = self.pool.max_append(&self.slots[i].cache).min(n).max(1);
                } else {
                    self.metrics.record_kv_round_deferral();
                    continue;
                }
                // Reserve before the forward so it cannot fail.
                if !self.pool.ensure_append(&mut self.slots[i].cache, n) {
                    debug_assert!(false, "capacity was just measured as available");
                    self.metrics.record_kv_round_deferral();
                    continue;
                }
            }
            budget -= n;
            let t0 = Instant::now();
            // Containment: prefill is per-slot, so a panicking forward
            // (poisoned prompt, injected fault) is attributable to
            // exactly this request — quarantine it with an explicit
            // `Failed` response and keep serving everyone else. The
            // forwards advance `cache.len()` only at the very end, so
            // a mid-forward unwind leaves the cache consistent.
            if consumed + n >= plen {
                // Final chunk: its logits seed the next output token.
                let slot = &mut self.slots[i];
                let (model, pool) = (&self.model, &mut self.pool);
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    crate::fault_point!("sched.prefill");
                    let toks = &slot.tokens[consumed..consumed + n];
                    model.prefill_paged(toks, &mut slot.cache, pool)
                }));
                let Ok(logits) = run else {
                    self.quarantine(i);
                    continue;
                };
                self.metrics.record_prefill(n, t0.elapsed().as_micros() as u64);
                self.pool
                    .register_prompt_blocks(&self.slots[i].cache, &self.slots[i].req.prompt);
                let next = sample(&logits, self.slots[i].req.temperature, rng);
                self.accept(i, next);
            } else {
                // Mid-prompt chunk: nobody reads these logits — skip
                // the lm-head projection entirely.
                let slot = &mut self.slots[i];
                let (model, pool) = (&self.model, &mut self.pool);
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    crate::fault_point!("sched.prefill");
                    let toks = &slot.tokens[consumed..consumed + n];
                    model.prefill_extend_paged(toks, &mut slot.cache, pool);
                }));
                if run.is_err() {
                    self.quarantine(i);
                    continue;
                }
                self.metrics.record_prefill(n, t0.elapsed().as_micros() as u64);
                self.slots[i].state = SlotState::Prefill { consumed: consumed + n };
                self.pool
                    .register_prompt_blocks(&self.slots[i].cache, &self.slots[i].req.prompt);
            }
        }
    }

    /// Contain a panic to slot `i`: count it, mark the slot `Failed`
    /// (the next `retire_done` answers the client and releases its KV
    /// blocks), and leave every other slot untouched.
    fn quarantine(&mut self, i: usize) {
        self.metrics.record_panic_caught();
        self.metrics.record_quarantine();
        self.slots[i].state = SlotState::Done(FinishReason::Failed);
    }

    /// One fused decode forward over every decoding slot that has (or
    /// can get) room for one more position. Slots eligible for
    /// speculation run a draft/verify round instead and skip the
    /// fused batch; a spec round that refuses (no headroom, no
    /// memory, draft fault) falls back to the plain path below.
    fn decode_round(&mut self, rng: &mut Rng) {
        let mut ready: Vec<usize> = Vec::new();
        for i in 0..self.slots.len() {
            if !matches!(self.slots[i].state, SlotState::Decode { .. }) {
                continue;
            }
            if self.spec_eligible(i) && self.spec_slot_round(i, rng) {
                continue;
            }
            if self.ensure_capacity_for(i, 1) {
                ready.push(i);
            } else {
                // A stuck slot must never be wedged by its *own*
                // draft cache: drop it (speculation re-warms when
                // memory frees up) and retry before deferring —
                // preserves the progress guarantee that the
                // minimum-key slot can always finish alone.
                if self.slots[i].draft.as_ref().is_some_and(|d| d.blocks() > 0) {
                    self.release_draft(i);
                    if self.ensure_capacity_for(i, 1) {
                        ready.push(i);
                        continue;
                    }
                }
                self.metrics.record_kv_round_deferral();
            }
        }
        // A later slot's preemption may have reset an earlier "ready"
        // slot back to Prefill: keep only the still-decoding ones.
        ready.retain(|&i| matches!(self.slots[i].state, SlotState::Decode { .. }));
        if ready.is_empty() {
            return;
        }
        self.metrics.record_batch(ready.len());
        let toks: Vec<u16> = ready
            .iter()
            .map(|&i| match self.slots[i].state {
                SlotState::Decode { next } => next,
                _ => unreachable!("filtered to Decode slots"),
            })
            .collect();
        // decode_batch_paged needs a contiguous `&mut [PagedKvCache]`:
        // lend it the active slots' handles for the round.
        let mut caches: Vec<PagedKvCache> =
            ready.iter().map(|&i| std::mem::take(&mut self.slots[i].cache)).collect();
        let t0 = Instant::now();
        // Containment: the fused forward mixes every decoding slot, so
        // a panic in it (poisoned token, injected fault) is not
        // attributable from here. Catch it, put the caches back (the
        // forward advances `cache.len()` only at the very end, so an
        // unwind leaves them consistent), and isolate the culprit by
        // replaying each slot solo below.
        let (model, pool) = (&self.model, &mut self.pool);
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            crate::fault_point!("sched.decode");
            for &t in &toks {
                let _ = crate::util::faultpoint::hit_val("decode.token", t as u64);
            }
            model.decode_batch_paged(&toks, &mut caches, pool)
        }));
        match run {
            Ok(logits) => {
                self.metrics.record_decode(toks.len(), t0.elapsed().as_micros() as u64);
                for (j, cache) in caches.into_iter().enumerate() {
                    self.slots[ready[j]].cache = cache;
                }
                for (b, &i) in ready.iter().enumerate() {
                    let next = sample(logits.row(b), self.slots[i].req.temperature, rng);
                    self.accept(i, next);
                }
            }
            Err(_) => {
                self.metrics.record_panic_caught();
                for (j, cache) in caches.into_iter().enumerate() {
                    self.slots[ready[j]].cache = cache;
                }
                self.replay_solo(&ready, &toks, rng);
            }
        }
    }

    /// Speculation applies only to greedy slots (temperature > 0
    /// bypasses it — acceptance would change the sampling
    /// distribution) that have not been degraded by a draft fault.
    fn spec_eligible(&self, i: usize) -> bool {
        self.spec.is_some() && self.slots[i].spec_on && self.slots[i].req.temperature <= 0.0
    }

    /// One speculative draft/verify round for slot `i` (DESIGN.md
    /// §13). The draft model catches its cache up to the target's
    /// frontier (ending with the pending token) and proposes up to
    /// `spec_k` greedy tokens; ONE batched target forward over
    /// `[pending, d1..dk]` scores all k+1 positions; the longest
    /// agreeing prefix plus the bonus token from the first
    /// disagreeing (or final) row are accepted — each exactly the
    /// token plain greedy decoding would produce — and both caches
    /// are truncated back to the accepted frontier.
    ///
    /// Returns `true` when the slot advanced (a successful round
    /// always accepts at least the bonus token, so speculation never
    /// falls behind plain decoding). `false` means "use the plain
    /// fused decode this round": not enough generation headroom, no
    /// free pool capacity (speculation never preempts a neighbor),
    /// or a panic — a draft fault degrades the slot to plain
    /// decoding for the rest of its lifetime; a target fault during
    /// verify rolls back and lets the plain path attribute it (solo
    /// replay → quarantine if genuinely poisoned).
    fn spec_slot_round(&mut self, i: usize, rng: &mut Rng) -> bool {
        let SlotState::Decode { next: t0 } = self.slots[i].state else {
            return false;
        };
        let max_k = match &self.spec {
            Some(s) => s.max_k,
            None => return false,
        };
        if self.slots[i].spec_k == 0 {
            // Slot was admitted before `set_spec` armed speculation.
            self.slots[i].spec_k = self.spec.as_ref().expect("checked above").k0;
        }
        let produced = self.slots[i].tokens.len() - self.slots[i].req.prompt.len();
        let remaining = self.slots[i].max_new - produced;
        if remaining < 2 {
            // The round could accept at most one token — plain
            // decoding does that without the drafting overhead.
            return false;
        }
        let k_eff = self.slots[i].spec_k.min(remaining - 1);
        let l = self.slots[i].cache.len();
        debug_assert_eq!(l + 1, self.slots[i].tokens.len(), "Decode slot cache invariant");
        if self.slots[i].draft.is_none() {
            self.slots[i].draft = Some(self.pool.new_cache());
        }
        let t_round = Instant::now();

        let Scheduler { model, spec, slots, pool, metrics, .. } = self;
        let spec_state = spec.as_ref().expect("speculation armed");
        let slot = &mut slots[i];
        let dcache = slot.draft.as_mut().expect("created above");
        // Reserve BOTH appends up front, preempting nobody: the
        // draft catches up `gap` positions (>= 1 — its cache is
        // always truncated strictly behind the pending token) plus
        // k_eff - 1 drafted ones; the target verifies k_eff + 1. On
        // refusal, reclaim the uncommitted tail reservations and
        // fall back to plain decoding (which may preempt under its
        // own policy).
        let gap = l + 1 - dcache.len();
        if !pool.ensure_append(dcache, gap + (k_eff - 1))
            || !pool.ensure_append(&mut slot.cache, k_eff + 1)
        {
            let (dl, tl) = (dcache.len(), slot.cache.len());
            pool.truncate(dcache, dl);
            pool.truncate(&mut slot.cache, tl);
            return false;
        }

        // Draft phase, contained: a draft-model panic costs this
        // slot its speculation, never its correctness (and never a
        // quarantine — the target model is healthy).
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            crate::fault_point!("spec.draft");
            let mut drafts: Vec<u16> = Vec::with_capacity(k_eff);
            let catchup = &slot.tokens[dcache.len()..l + 1];
            let logits = spec_state.draft.prefill_paged(catchup, dcache, pool);
            drafts.push(sample(&logits, 0.0, rng));
            while drafts.len() < k_eff {
                let t = *drafts.last().expect("seeded above");
                let lg = spec_state
                    .draft
                    .decode_batch_paged(&[t], std::slice::from_mut(dcache), pool);
                drafts.push(sample(lg.row(0), 0.0, rng));
            }
            drafts
        }));
        let drafts = match run {
            Ok(d) => d,
            Err(_) => {
                metrics.record_panic_caught();
                metrics.record_spec_degrade();
                if let Some(mut d) = slot.draft.take() {
                    pool.release(&mut d);
                }
                slot.spec_on = false;
                return false;
            }
        };

        // Verify: one batched target forward over all k+1 positions.
        let mut fed = Vec::with_capacity(k_eff + 1);
        fed.push(t0);
        fed.extend_from_slice(&drafts);
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            model.verify_paged(&fed, &mut slot.cache, pool)
        }));
        let logits = match run {
            Ok(lg) => lg,
            Err(_) => {
                // The forward advances `len` only at the very end, so
                // the cache is consistent at the round's start;
                // truncating there also reclaims the reservation.
                metrics.record_panic_caught();
                metrics.record_spec_degrade();
                pool.truncate(&mut slot.cache, l);
                if let Some(mut d) = slot.draft.take() {
                    pool.release(&mut d);
                }
                slot.spec_on = false;
                return false;
            }
        };

        // Greedy acceptance: row r of the verify logits is the
        // target's distribution after consuming fed[0..=r], so each
        // accepted token is bit-identical to sequential decoding
        // (pinned in rust/tests/speculation.rs). Stop conditions
        // apply per token inside accept(), exactly as the plain path.
        let mut greedy = Vec::with_capacity(fed.len());
        for r in 0..fed.len() {
            greedy.push(sample(logits.row(r), 0.0, rng));
        }
        let mut agree = 0;
        while agree < k_eff && drafts[agree] == greedy[agree] {
            agree += 1;
        }
        let mut emitted = 0;
        for &g in &greedy[..=agree] {
            self.accept(i, g);
            emitted += 1;
            if matches!(self.slots[i].state, SlotState::Done(_)) {
                break;
            }
        }

        // Roll both caches back to the accepted frontier: the target
        // keeps `emitted` of its k_eff + 1 new positions; the draft
        // keeps positions whose K/V belongs to accepted tokens
        // (position l holds the pending token, l + j holds draft j
        // for j <= agree) and always stays strictly behind the new
        // pending token so the next catch-up feeds at least one row.
        let new_len = l + emitted;
        let Scheduler { slots, pool, metrics, .. } = self;
        let slot = &mut slots[i];
        pool.truncate(&mut slot.cache, new_len);
        if let Some(d) = slot.draft.as_mut() {
            let valid = (l + 1 + agree.min(k_eff - 1)).min(new_len).min(d.len());
            pool.truncate(d, valid);
        }
        metrics.record_spec_round(k_eff, emitted);
        metrics.record_decode(emitted, t_round.elapsed().as_micros() as u64);
        // Adaptive depth: two consecutive fully-accepted rounds grow
        // k by one (up to max_k); a fully-rejected round halves it
        // (floor 1) so an adversarial draft costs ~2 extra forwards
        // per round at worst, not k.
        if agree == k_eff {
            slot.spec_streak += 1;
            if slot.spec_streak >= 2 {
                slot.spec_k = (slot.spec_k + 1).min(max_k);
                slot.spec_streak = 0;
            }
        } else {
            slot.spec_streak = 0;
            if agree == 0 {
                slot.spec_k = (slot.spec_k / 2).max(1);
            }
        }
        true
    }

    /// Isolate the culprit(s) of a fused-decode panic: replay each
    /// participating slot as a batch of one, feeding the same pending
    /// token it would have contributed to the fused round. Slots whose
    /// solo forward succeeds accept their sampled token exactly as the
    /// fused path would have (solo ≡ fused bit-identically — pinned by
    /// `rust/tests/batch_equivalence.rs`), so survivors of a
    /// quarantined neighbor stay deterministic. Slots that panic again
    /// are quarantined with [`FinishReason::Failed`].
    fn replay_solo(&mut self, ready: &[usize], toks: &[u16], rng: &mut Rng) {
        for (j, &i) in ready.iter().enumerate() {
            let tok = toks[j];
            let t0 = Instant::now();
            let slot = &mut self.slots[i];
            let (model, pool) = (&self.model, &mut self.pool);
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = crate::util::faultpoint::hit_val("decode.token", tok as u64);
                model.decode_batch_paged(&[tok], std::slice::from_mut(&mut slot.cache), pool)
            }));
            match run {
                Ok(logits) => {
                    self.metrics.record_decode(1, t0.elapsed().as_micros() as u64);
                    let next = sample(logits.row(0), self.slots[i].req.temperature, rng);
                    self.accept(i, next);
                }
                Err(_) => self.quarantine(i),
            }
        }
    }

    /// Accept a sampled token into slot `i`: append it, stream it,
    /// stamp TTFT / inter-token gaps (global and per-tenant), and
    /// apply the stop conditions (the stop/EOS token itself is
    /// included in the output, exactly as the pre-scheduler loop did
    /// with `'\n'`).
    fn accept(&mut self, i: usize, next: u16) {
        let slot = &mut self.slots[i];
        let now = Instant::now();
        slot.tokens.push(next);
        if let Some(stream) = &slot.req.stream {
            let _ = stream.send(next); // client may have hung up
        }
        let tenant_id = &self.qos.config.tenants[slot.tenant].id;
        match slot.last_token_at {
            None => {
                slot.ttft = now.duration_since(slot.req.submitted);
                self.metrics.record_ttft(slot.ttft.as_micros() as u64);
                self.metrics.record_tenant_ttft(tenant_id, slot.ttft.as_micros() as u64);
            }
            Some(prev) => {
                let gap = now.duration_since(prev).as_micros() as u64;
                self.metrics.record_itl(gap);
                self.metrics.record_tenant_itl(tenant_id, gap);
            }
        }
        slot.last_token_at = Some(now);
        let produced = slot.tokens.len() - slot.req.prompt.len();
        slot.state = match slot.req.stop.classify(next) {
            Some(reason) => SlotState::Done(reason),
            None if produced >= slot.max_new => SlotState::Done(FinishReason::Length),
            None => SlotState::Decode { next },
        };
    }

    /// Swap-compact every finished slot out, flushing its response and
    /// returning its blocks to the pool.
    fn retire_done(&mut self) {
        let mut i = 0;
        while i < self.slots.len() {
            if matches!(self.slots[i].state, SlotState::Done(_)) {
                let slot = self.slots.swap_remove(i);
                self.finish(slot);
            } else {
                i += 1;
            }
        }
    }

    fn finish(&mut self, mut slot: Slot) {
        let SlotState::Done(finish) = slot.state else {
            unreachable!("finish() called on unfinished slot");
        };
        self.pool.release(&mut slot.cache);
        if let Some(mut d) = slot.draft.take() {
            self.pool.release(&mut d);
        }
        let produced = slot.tokens.len() - slot.req.prompt.len();
        let latency = slot.req.submitted.elapsed();
        let seq = self.metrics.record_completion(produced, latency.as_micros() as u64);
        self.metrics.record_tenant_completion(&self.qos.config.tenants[slot.tenant].id);
        // Dropping `slot.req` afterwards closes the streaming channel,
        // so a streaming client sees all tokens, then the response,
        // then end-of-stream.
        let _ = slot.req.respond.send(GenResponse {
            tokens: slot.tokens,
            prompt_len: slot.req.prompt.len(),
            latency,
            queue_wait: slot.queue_wait,
            ttft: slot.ttft,
            finish,
            seq,
        });
    }

    /// Post-round maintenance: re-encode cold blocks and publish the
    /// pool gauges.
    fn housekeep(&mut self) {
        for i in 0..self.slots.len() {
            self.pool.quantize_cold(&self.slots[i].cache);
            if let Some(d) = &self.slots[i].draft {
                self.pool.quantize_cold(d);
            }
        }
        self.publish_kv_metrics();
    }

    fn publish_kv_metrics(&self) {
        self.metrics.set_kv_pool(&self.pool.stats());
    }
}

/// Sample a token from logits: greedy argmax at temperature <= 0
/// (NaN-safe: NaNs are skipped, ties break low, empty logits degrade
/// to token 0 — a bad forward must never panic the worker that owns
/// the model), else softmax sampling at the given temperature.
pub(crate) fn sample(logits: &[f32], temperature: f64, rng: &mut Rng) -> u16 {
    if logits.is_empty() {
        return 0;
    }
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_nan())
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as u16)
            .unwrap_or(0);
    }
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let probs: Vec<f64> =
        logits.iter().map(|&v| (((v - max) as f64) / temperature).exp()).collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i as u16;
        }
    }
    (probs.len() - 1) as u16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::qos::{AdmitPolicy, EvictionKind, QosConfig, TenantSpec};
    use crate::coordinator::server::{CancelToken, Server, ServerOptions, StopSet};
    use crate::model::transformer::tests::tiny_model;
    use crate::quant::kvquant::KvQuantConfig;

    fn opts(max_batch: usize, prefill_chunk: usize) -> ServerOptions {
        ServerOptions {
            max_batch,
            prefill_chunk,
            batch_wait: Duration::from_millis(1),
            seed: 7,
            ..ServerOptions::default()
        }
    }

    fn run_one(server: &Server, prompt: Vec<u16>, max_new: usize, stop: StopSet) -> GenResponse {
        let rx = server.submit_with(prompt, max_new, 0.0, stop, None).expect("submit");
        rx.recv_timeout(Duration::from_secs(60)).expect("response")
    }

    #[test]
    fn sampling_respects_temperature_zero() {
        let mut rng = Rng::new(1);
        let logits = vec![0.0f32, 5.0, 1.0];
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn greedy_sampling_survives_nan_logits() {
        let mut rng = Rng::new(1);
        // NaN must neither panic nor be selected.
        assert_eq!(sample(&[1.0, f32::NAN, 5.0, f32::NAN], 0.0, &mut rng), 2);
        // All-NaN and empty degenerate to token 0.
        assert_eq!(sample(&[f32::NAN, f32::NAN], 0.0, &mut rng), 0);
        assert_eq!(sample(&[], 0.0, &mut rng), 0);
        assert_eq!(sample(&[], 1.0, &mut rng), 0);
    }

    #[test]
    fn chunked_prefill_matches_whole_prefill() {
        // The same request must generate identical tokens whether its
        // prompt is prefilled in 1-, 2- or whole-prompt chunks.
        let m = tiny_model(11, 4);
        let prompt: Vec<u16> = vec![3, 9, 1, 7, 5, 2, 8];
        let runs: Vec<Vec<u16>> = [1usize, 2, 64]
            .iter()
            .map(|&chunk| {
                let server = Server::start_with_opts(m.clone(), opts(2, chunk));
                let r = run_one(&server, prompt.clone(), 6, StopSet::none());
                server.shutdown();
                r.tokens
            })
            .collect();
        assert_eq!(runs[0], runs[1], "chunk=1 vs chunk=2");
        assert_eq!(runs[1], runs[2], "chunk=2 vs whole-prompt");
    }

    fn request(
        prompt: Vec<u16>,
        max_new: usize,
        respond: std::sync::mpsc::Sender<GenResponse>,
    ) -> GenRequest {
        request_t(0, prompt, max_new, respond)
    }

    fn request_t(
        tenant: u32,
        prompt: Vec<u16>,
        max_new: usize,
        respond: std::sync::mpsc::Sender<GenResponse>,
    ) -> GenRequest {
        GenRequest {
            prompt,
            max_new_tokens: max_new,
            temperature: 0.0,
            stop: StopSet::none(),
            stream: None,
            respond,
            submitted: Instant::now(),
            tenant,
            deadline: None,
            cancel: CancelToken::default(),
        }
    }

    #[test]
    fn short_request_overtakes_long() {
        // No head-of-line blocking: a short request admitted *while a
        // long one is mid-decode* must retire first (strictly smaller
        // completion sequence number). Driving the scheduler directly
        // makes the interleaving deterministic — no wall-clock races.
        let m = tiny_model(2, 4);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(m, metrics, 2, 4);
        let mut rng = Rng::new(7);
        let (ltx, lrx) = std::sync::mpsc::channel();
        sched.admit(request(vec![1, 2, 3], 48, ltx));
        // The long request decodes for three rounds before the short
        // one arrives — exactly the mid-flight admission case.
        for _ in 0..3 {
            sched.step(&mut rng);
        }
        assert_eq!(sched.in_flight(), 1, "long still decoding");
        let (stx, srx) = std::sync::mpsc::channel();
        sched.admit(request(vec![4, 5], 2, stx));
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000, "scheduler failed to drain");
        }
        let long = lrx.try_recv().expect("long finished");
        let short = srx.try_recv().expect("short finished");
        assert!(
            short.seq < long.seq,
            "short (seq {}) must retire before long (seq {})",
            short.seq,
            long.seq
        );
        assert_eq!(long.tokens.len() - long.prompt_len, 48);
        assert_eq!(short.tokens.len() - short.prompt_len, 2);
    }

    #[test]
    fn greedy_identical_with_and_without_cotraffic() {
        // Determinism contract: greedy outputs are bit-identical no
        // matter what else is in flight.
        let m = tiny_model(5, 4);
        let prompt: Vec<u16> = vec![6, 1, 9];
        let solo = {
            let server = Server::start_with_opts(m.clone(), opts(1, 64));
            let r = run_one(&server, prompt.clone(), 8, StopSet::none());
            server.shutdown();
            r.tokens
        };
        let busy = {
            let server = Server::start_with_opts(m.clone(), opts(4, 2));
            // Background traffic: one long and one mid request.
            let bg1 = server
                .submit_with(vec![2, 3, 4, 5, 6], 48, 0.0, StopSet::none(), None)
                .expect("submit");
            let bg2 = server.submit_with(vec![7], 20, 0.0, StopSet::none(), None).expect("submit");
            let r = run_one(&server, prompt.clone(), 8, StopSet::none());
            bg1.recv_timeout(Duration::from_secs(60)).unwrap();
            bg2.recv_timeout(Duration::from_secs(60)).unwrap();
            server.shutdown();
            r.tokens
        };
        assert_eq!(solo, busy);
    }

    #[test]
    fn streamed_tokens_match_final_response() {
        let m = tiny_model(8, 4);
        let server = Server::start_with_opts(m, opts(2, 4));
        let (stream, rx) = server.submit_streaming(vec![1, 2, 3, 4, 5], 6, 0.0).expect("submit");
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("response");
        // The sender side is dropped at retirement, so the stream
        // iterator terminates after the last token.
        let streamed: Vec<u16> = stream.iter().collect();
        assert_eq!(streamed, resp.tokens[resp.prompt_len..].to_vec());
        assert!(resp.ttft <= resp.latency);
        server.shutdown();
    }

    #[test]
    fn eos_token_stops_generation() {
        let m = tiny_model(4, 4);
        // Learn the first greedy token, then declare it the EOS.
        let first = {
            let server = Server::start_with_opts(m.clone(), opts(1, 64));
            let r = run_one(&server, vec![3, 1], 1, StopSet::none());
            server.shutdown();
            r.tokens[r.prompt_len]
        };
        let server = Server::start_with_opts(m, opts(1, 64));
        let r = run_one(&server, vec![3, 1], 10, StopSet::none().with_eos(first));
        assert_eq!(r.tokens.len() - r.prompt_len, 1, "EOS after the first token");
        assert_eq!(r.finish, FinishReason::Eos);
        server.shutdown();
    }

    #[test]
    fn length_cap_reports_finish_reason() {
        let m = tiny_model(6, 4);
        let server = Server::start_with_opts(m, opts(1, 64));
        let r = run_one(&server, vec![2, 4], 5, StopSet::none());
        assert_eq!(r.tokens.len() - r.prompt_len, 5);
        assert_eq!(r.finish, FinishReason::Length);
        assert!(r.queue_wait <= r.ttft && r.ttft <= r.latency);
        server.shutdown();
    }

    #[test]
    fn ttft_and_itl_metrics_populated() {
        let m = tiny_model(9, 4);
        let server = Server::start_with_opts(m, opts(2, 4));
        let r = run_one(&server, vec![1, 2, 3], 6, StopSet::none());
        assert_eq!(r.tokens.len() - r.prompt_len, 6);
        let mt = &server.metrics;
        assert!(mt.ttft_percentile_us(0.5) > 0, "TTFT recorded");
        // ITL gaps on a tiny model can floor to 0µs in release; the
        // reservoir behavior itself is pinned in metrics.rs tests.
        let s = mt.summary();
        assert!(s.contains("ttft_p50=") && s.contains("itl_p50="), "summary carries TTFT/ITL: {s}");
        server.shutdown();
    }

    // -- memory-aware scheduling --------------------------------------------

    fn tight_pool(block_size: usize, budget_blocks: usize) -> PoolConfig {
        PoolConfig { block_size, budget_blocks, quant: KvQuantConfig::off() }
    }

    /// Reference outputs from an ample-pool scheduler, one job at a
    /// time.
    fn solo_tokens(m: &Transformer, jobs: &[(Vec<u16>, usize)]) -> Vec<Vec<u16>> {
        jobs.iter()
            .map(|(p, max_new)| {
                let metrics = Arc::new(Metrics::new());
                let mut sched = Scheduler::new(m.clone(), metrics, 1, 64);
                let mut rng = Rng::new(7);
                let (tx, rx) = std::sync::mpsc::channel();
                sched.admit(request(p.clone(), *max_new, tx));
                let mut rounds = 0;
                while !sched.is_idle() {
                    sched.step(&mut rng);
                    rounds += 1;
                    assert!(rounds < 1000, "solo run failed to drain");
                }
                rx.try_recv().expect("solo response").tokens
            })
            .collect()
    }

    #[test]
    fn pool_exhaustion_defers_preempts_and_drains() {
        // 8 blocks x 4 positions = 32 total; each request grows to
        // prompt 6 + 10 generated = 16 positions (4 blocks). Worst-case
        // flat reservation (prompt + max_new + 1 = 17 -> 5 blocks)
        // would admit ONE request at a time; the memory-aware pool
        // runs all four concurrently and resolves the oversubscription
        // by preempting the newest slot — no panic, every request
        // retires, and (greedy) every output is bit-identical to its
        // solo run even across preempt/re-prefill.
        let m = tiny_model(12, 4);
        let jobs: Vec<(Vec<u16>, usize)> = (0..4u16)
            .map(|k| ((0..6).map(|j| (j * 3 + k * 7 + 1) as u16 % 30).collect(), 10))
            .collect();
        let solo = solo_tokens(&m, &jobs);
        let metrics = Arc::new(Metrics::new());
        let mut sched =
            Scheduler::with_pool(m, metrics.clone(), 4, 8, tight_pool(4, 8));
        let mut rng = Rng::new(7);
        let rxs: Vec<_> = jobs
            .iter()
            .map(|(p, max_new)| {
                let (tx, rx) = std::sync::mpsc::channel();
                sched.admit(request(p.clone(), *max_new, tx));
                rx
            })
            .collect();
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 5000, "exhausted pool must still drain");
        }
        assert_eq!(sched.pool().blocks_in_use(), 0, "all blocks returned");
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.try_recv().expect("response despite pool pressure");
            assert_eq!(r.tokens.len() - r.prompt_len, 10, "request {i} ran to its cap");
            assert_eq!(r.tokens, solo[i], "request {i} diverged under memory pressure");
        }
        use std::sync::atomic::Ordering::Relaxed;
        // Strictly more concurrency than worst-case reservation (1).
        assert!(
            metrics.peak_in_flight.load(Relaxed) > 1,
            "oversubscription must beat worst-case reservation"
        );
        // Memory pressure actually bit: growth had to preempt.
        assert!(metrics.kv_preemptions.load(Relaxed) > 0, "preemption path exercised");
        assert!(
            sched.pool().peak_blocks() <= 8,
            "budget respected: peak {}",
            sched.pool().peak_blocks()
        );
    }

    #[test]
    fn admission_defers_until_memory_frees() {
        // Pool of 4 blocks x 4 = 16 positions. First request occupies
        // ~3 blocks; the second's prompt needs 3 — more than the free
        // blocks — so its admission must wait (not panic, not drop)
        // until the first retires.
        let m = tiny_model(3, 4);
        let metrics = Arc::new(Metrics::new());
        let mut sched =
            Scheduler::with_pool(m, metrics.clone(), 4, 32, tight_pool(4, 4));
        let mut rng = Rng::new(7);
        let (tx1, rx1) = std::sync::mpsc::channel();
        sched.admit(request(vec![1, 2, 3, 4, 5, 6, 7, 8], 4, tx1));
        sched.step(&mut rng); // prefill: 8 positions -> 2 blocks + growth
        let (tx2, rx2) = std::sync::mpsc::channel();
        sched.admit(request(vec![9, 8, 7, 6, 5, 4, 3, 2, 1, 10, 11], 3, tx2));
        assert_eq!(sched.in_flight(), 1, "second request parked in the pending queue");
        assert_eq!(sched.pending_len(), 1);
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000, "deferred admission must still drain");
        }
        assert!(rx1.try_recv().is_ok());
        let r2 = rx2.try_recv().expect("deferred request served");
        assert_eq!(r2.tokens.len() - r2.prompt_len, 3);
        use std::sync::atomic::Ordering::Relaxed;
        assert!(metrics.kv_admission_deferrals.load(Relaxed) > 0, "deferral recorded");
    }

    #[test]
    fn prefix_sharing_skips_recompute_across_requests() {
        // Two requests with the same prompt: the second attaches the
        // first's full prompt blocks (metrics-visible) and generates
        // the identical greedy continuation.
        let m = tiny_model(15, 4);
        let metrics = Arc::new(Metrics::new());
        let mut sched =
            Scheduler::with_pool(m, metrics.clone(), 4, 64, tight_pool(4, 64));
        let mut rng = Rng::new(7);
        let prompt: Vec<u16> = vec![5, 9, 1, 30, 7, 2, 18, 4, 22, 13, 6, 27];
        let (tx1, rx1) = std::sync::mpsc::channel();
        sched.admit(request(prompt.clone(), 5, tx1));
        sched.step(&mut rng); // A's prompt fully prefilled + registered
        let (tx2, rx2) = std::sync::mpsc::channel();
        sched.admit(request(prompt.clone(), 5, tx2));
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000);
        }
        let a = rx1.try_recv().expect("first response");
        let b = rx2.try_recv().expect("second response");
        assert_eq!(a.tokens, b.tokens, "shared prefix must not change greedy output");
        // (12 - 1) / 4 = 2 full blocks = 8 positions served from the
        // prefix map instead of recomputation.
        assert_eq!(sched.pool().stats().shared_positions, 8);
        // The shared positions were *not* re-prefilled: total prefill
        // work is strictly less than two full prompts.
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(
            sched.metrics.prefill_tokens.load(Relaxed),
            (2 * prompt.len() - 8) as u64
        );
    }

    #[test]
    fn oversized_prompt_fails_fast_without_wedging_the_queue() {
        // A prompt bigger than the whole pool completes immediately
        // with zero generated tokens; requests behind it still run.
        let m = tiny_model(4, 4);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::with_pool(m, metrics, 2, 32, tight_pool(4, 2));
        let mut rng = Rng::new(7);
        let (tx1, rx1) = std::sync::mpsc::channel();
        sched.admit(request((0..20).map(|i| i as u16).collect(), 4, tx1));
        let (tx2, rx2) = std::sync::mpsc::channel();
        sched.admit(request(vec![1, 2], 2, tx2));
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000);
        }
        let r1 = rx1.try_recv().expect("oversized prompt still answered");
        assert_eq!(r1.tokens.len(), r1.prompt_len, "zero tokens generated");
        assert_eq!(r1.finish, FinishReason::Length);
        let r2 = rx2.try_recv().expect("queue not wedged");
        assert_eq!(r2.tokens.len() - r2.prompt_len, 2);
    }

    #[test]
    fn rope_bound_rejects_instead_of_panicking_the_worker() {
        // With the generous auto pool (1088 positions here) a
        // 600-token prompt still exceeds the model's 512-entry RoPE
        // table: it must fail fast at admission — not pass the pool
        // check and panic Rope::apply mid-forward.
        let m = tiny_model(8, 4);
        assert_eq!(m.max_positions(), 512);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(m, metrics, 2, 64);
        let mut rng = Rng::new(7);
        let (tx, rx) = std::sync::mpsc::channel();
        sched.admit(request((0..600).map(|i| (i % 30) as u16).collect(), 4, tx));
        let r = rx.try_recv().expect("rejected immediately");
        assert_eq!(r.tokens.len(), r.prompt_len, "zero tokens generated");
        assert_eq!(r.finish, FinishReason::Length);
        assert!(sched.is_idle());
        // The worker survives: a feasible request still serves.
        let (tx2, rx2) = std::sync::mpsc::channel();
        sched.admit(request(vec![1, 2], 3, tx2));
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 100);
        }
        let r2 = rx2.try_recv().expect("follow-up served");
        assert_eq!(r2.tokens.len() - r2.prompt_len, 3);
    }

    #[test]
    fn generation_cap_clamped_to_pool_capacity() {
        // max_new_tokens larger than the pool can ever hold is clamped
        // (the preemption progress guarantee); the request finishes
        // with Length instead of looping forever.
        let m = tiny_model(7, 4);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::with_pool(m, metrics, 1, 32, tight_pool(4, 3));
        let mut rng = Rng::new(7);
        let (tx, rx) = std::sync::mpsc::channel();
        sched.admit(request(vec![1, 2, 3], 1000, tx));
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000, "clamped request must terminate");
        }
        let r = rx.try_recv().expect("response");
        assert_eq!(r.finish, FinishReason::Length);
        // position_capacity 12 - prompt 3 = 9 generated tokens.
        assert_eq!(r.tokens.len() - r.prompt_len, 9);
    }

    // -- multi-tenant QoS ---------------------------------------------------

    fn qos_state(
        admission: AdmitPolicy,
        eviction: EvictionKind,
        tenants: Vec<TenantSpec>,
    ) -> Arc<QosState> {
        Arc::new(QosState::new(QosConfig { admission, eviction, tenants }))
    }

    fn tenant(id: &str, weight: u32, priority: u8) -> TenantSpec {
        TenantSpec { id: id.into(), weight, priority, max_pending: 0 }
    }

    #[test]
    fn wrr_admission_interleaves_a_flooded_queue() {
        // Tenant 0 floods six requests before tenant 1 submits two;
        // with one slot, WRR must interleave admissions so tenant 1's
        // work retires before the flood's backlog — FIFO would serve
        // it last.
        let m = tiny_model(13, 4);
        let metrics = Arc::new(Metrics::new());
        let qos = qos_state(
            AdmitPolicy::WeightedRoundRobin,
            EvictionKind::Newest,
            vec![tenant("flood", 1, 0), tenant("polite", 1, 0)],
        );
        let mut sched =
            Scheduler::with_qos(m, metrics, 1, 64, PoolConfig::default(), qos);
        let mut rng = Rng::new(7);
        let flood_rx: Vec<_> = (0..6)
            .map(|i| {
                let (tx, rx) = std::sync::mpsc::channel();
                sched.admit(request_t(0, vec![i as u16 + 1, 2], 2, tx));
                rx
            })
            .collect();
        let polite_rx: Vec<_> = (0..2)
            .map(|i| {
                let (tx, rx) = std::sync::mpsc::channel();
                sched.admit(request_t(1, vec![10 + i as u16], 2, tx));
                rx
            })
            .collect();
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 2000);
        }
        let flood_seqs: Vec<u64> =
            flood_rx.into_iter().map(|rx| rx.try_recv().expect("flood response").seq).collect();
        let polite_seqs: Vec<u64> =
            polite_rx.into_iter().map(|rx| rx.try_recv().expect("polite response").seq).collect();
        let polite_max = *polite_seqs.iter().max().unwrap();
        let flood_max = *flood_seqs.iter().max().unwrap();
        assert!(
            polite_max < flood_max,
            "WRR must finish the polite tenant (max seq {polite_max}) before the flood backlog \
             (max seq {flood_max})"
        );
    }

    #[test]
    fn lowest_priority_eviction_inverts_the_newest_rule() {
        // Bulk (class 1) admitted FIRST, urgent (class 0) second, in a
        // pool too small for both. Under `newest`, urgent is the only
        // evictable slot, so bulk retires first. Under
        // `lowest-priority`, bulk is the victim and urgent retires
        // first. Outputs stay bit-identical to solo runs either way.
        let m = tiny_model(12, 4);
        let bulk_job: (Vec<u16>, usize) = ((0..6).map(|i| (i * 3 + 1) as u16).collect(), 8);
        let urgent_job: (Vec<u16>, usize) = ((0..6).map(|i| (i * 5 + 2) as u16).collect(), 8);
        let solo = solo_tokens(&m, &[bulk_job.clone(), urgent_job.clone()]);
        let run = |eviction: EvictionKind| {
            let metrics = Arc::new(Metrics::new());
            let qos = qos_state(
                AdmitPolicy::Fifo,
                eviction,
                vec![tenant("urgent", 1, 0), tenant("bulk", 1, 1)],
            );
            let mut sched =
                Scheduler::with_qos(m.clone(), metrics.clone(), 2, 64, tight_pool(4, 4), qos);
            let mut rng = Rng::new(7);
            let (btx, brx) = std::sync::mpsc::channel();
            sched.admit(request_t(1, bulk_job.0.clone(), bulk_job.1, btx));
            let (utx, urx) = std::sync::mpsc::channel();
            sched.admit(request_t(0, urgent_job.0.clone(), urgent_job.1, utx));
            let mut rounds = 0;
            while !sched.is_idle() {
                sched.step(&mut rng);
                rounds += 1;
                assert!(rounds < 5000, "pressured pool must drain");
            }
            use std::sync::atomic::Ordering::Relaxed;
            assert!(metrics.kv_preemptions.load(Relaxed) > 0, "pressure actually bit");
            (brx.try_recv().expect("bulk"), urx.try_recv().expect("urgent"))
        };
        let (bulk_n, urgent_n) = run(EvictionKind::Newest);
        assert!(bulk_n.seq < urgent_n.seq, "newest policy keeps the older bulk slot");
        let (bulk_p, urgent_p) = run(EvictionKind::LowestPriority);
        assert!(
            urgent_p.seq < bulk_p.seq,
            "lowest-priority policy lets the urgent class finish first"
        );
        for r in [&bulk_n, &bulk_p] {
            assert_eq!(r.tokens, solo[0], "bulk output diverged");
        }
        for r in [&urgent_n, &urgent_p] {
            assert_eq!(r.tokens, solo[1], "urgent output diverged");
        }
    }

    #[test]
    fn largest_kv_eviction_stays_deterministic_under_pressure() {
        // Same oversubscribed workload as the pool-exhaustion test but
        // under `largest-kv`: the policy frees the most memory per
        // preemption and every output still matches its solo run.
        let m = tiny_model(12, 4);
        let jobs: Vec<(Vec<u16>, usize)> = (0..4u16)
            .map(|k| ((0..6).map(|j| (j * 3 + k * 7 + 1) as u16 % 30).collect(), 10))
            .collect();
        let solo = solo_tokens(&m, &jobs);
        let metrics = Arc::new(Metrics::new());
        let qos = qos_state(
            AdmitPolicy::Fifo,
            EvictionKind::LargestKv,
            vec![tenant("default", 1, 0)],
        );
        let mut sched =
            Scheduler::with_qos(m, metrics.clone(), 4, 8, tight_pool(4, 8), qos);
        let mut rng = Rng::new(7);
        let rxs: Vec<_> = jobs
            .iter()
            .map(|(p, max_new)| {
                let (tx, rx) = std::sync::mpsc::channel();
                sched.admit(request(p.clone(), *max_new, tx));
                rx
            })
            .collect();
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 5000, "largest-kv policy must drain");
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.try_recv().expect("response");
            assert_eq!(r.tokens, solo[i], "request {i} diverged under largest-kv eviction");
        }
        use std::sync::atomic::Ordering::Relaxed;
        assert!(metrics.kv_preemptions.load(Relaxed) > 0, "eviction path exercised");
    }

    // -- fault containment & request lifecycle ------------------------------

    #[test]
    fn poisoned_prefill_is_quarantined_not_fatal() {
        // Token 999 is out of the tiny model's vocab (32): its prefill
        // panics on the embedding lookup. The panic must be contained
        // to that slot — Failed response, blocks released — while a
        // concurrently-admitted healthy request generates exactly its
        // solo output.
        let m = tiny_model(12, 4);
        let healthy_job: (Vec<u16>, usize) = (vec![3, 1, 4, 1, 5], 6);
        let solo = solo_tokens(&m, &[healthy_job.clone()]);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(m, metrics.clone(), 4, 64);
        let mut rng = Rng::new(7);
        let (ptx, prx) = std::sync::mpsc::channel();
        sched.admit(request(vec![999], 4, ptx));
        let (htx, hrx) = std::sync::mpsc::channel();
        sched.admit(request(healthy_job.0.clone(), healthy_job.1, htx));
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000, "poisoned batch must still drain");
        }
        let poisoned = prx.try_recv().expect("poisoned request answered");
        assert_eq!(poisoned.finish, FinishReason::Failed);
        assert_eq!(poisoned.tokens.len(), poisoned.prompt_len, "no tokens generated");
        let healthy = hrx.try_recv().expect("healthy request answered");
        assert_eq!(healthy.tokens, solo[0], "survivor must match its solo run");
        use std::sync::atomic::Ordering::Relaxed;
        assert!(metrics.panics_caught.load(Relaxed) >= 1);
        assert_eq!(metrics.quarantines.load(Relaxed), 1);
        assert_eq!(sched.pool().blocks_in_use(), 0, "quarantined slot returned its blocks");
    }

    #[test]
    fn reap_answers_cancelled_and_expired_requests_within_a_round() {
        // One slot: request A decodes, B waits pending with an
        // already-expired deadline, C waits pending and gets cancelled
        // by its client. One step later both are answered without ever
        // taking a slot, and A proceeds unharmed.
        let m = tiny_model(10, 4);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(m, metrics.clone(), 1, 64);
        let mut rng = Rng::new(7);
        let (atx, arx) = std::sync::mpsc::channel();
        sched.admit(request(vec![1, 2, 3], 6, atx));
        sched.step(&mut rng); // A slotted + decoding
        let (btx, brx) = std::sync::mpsc::channel();
        let mut b = request(vec![4, 5], 8, btx);
        b.deadline = Some(Instant::now() - Duration::from_millis(1));
        sched.admit(b);
        let (ctx, crx) = std::sync::mpsc::channel();
        let c = request(vec![6, 7], 8, ctx);
        let c_cancel = c.cancel.clone();
        sched.admit(c);
        assert_eq!(sched.pending_len(), 2);
        c_cancel.cancel();
        sched.step(&mut rng);
        let rb = brx.try_recv().expect("expired pending request answered");
        assert_eq!(rb.finish, FinishReason::DeadlineExceeded);
        assert_eq!(rb.tokens.len(), rb.prompt_len);
        let rc = crx.try_recv().expect("cancelled pending request answered");
        assert_eq!(rc.finish, FinishReason::Cancelled);
        assert_eq!(sched.pending_len(), 0);
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000);
        }
        let ra = arx.try_recv().expect("healthy request unaffected");
        assert_eq!(ra.tokens.len() - ra.prompt_len, 6);
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(metrics.deadline_cancels.load(Relaxed), 1);
        assert_eq!(metrics.disconnect_cancels.load(Relaxed), 1);
        assert_eq!(sched.pool().blocks_in_use(), 0);
    }

    #[test]
    fn recover_fails_in_flight_but_preserves_the_pending_queue() {
        // recover() is the supervisor's half of worker-restart: slots
        // answer Failed and release memory; pending requests survive
        // to be served by the restarted loop.
        let m = tiny_model(11, 4);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(m, metrics, 1, 64);
        let mut rng = Rng::new(7);
        let (atx, arx) = std::sync::mpsc::channel();
        sched.admit(request(vec![1, 2, 3], 32, atx));
        sched.step(&mut rng); // A slotted + decoding
        let (btx, brx) = std::sync::mpsc::channel();
        sched.admit(request(vec![4, 5], 3, btx));
        assert_eq!((sched.in_flight(), sched.pending_len()), (1, 1));
        sched.recover();
        let ra = arx.try_recv().expect("in-flight answered on recover");
        assert_eq!(ra.finish, FinishReason::Failed);
        assert!(ra.tokens.len() > ra.prompt_len, "partial output preserved");
        assert_eq!(sched.pool().blocks_in_use(), 0, "recover releases every block");
        assert_eq!(sched.pending_len(), 1, "pending queue preserved");
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000);
        }
        let rb = brx.try_recv().expect("queued request served after recovery");
        assert_eq!(rb.tokens.len() - rb.prompt_len, 3);
    }

    #[test]
    fn cancellation_paths_answer_every_request() {
        // cancel_pending answers queued requests with Cancelled and
        // zero tokens; cancel_in_flight delivers the partial output.
        let m = tiny_model(10, 4);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(m, metrics, 1, 64);
        let mut rng = Rng::new(7);
        let (tx1, rx1) = std::sync::mpsc::channel();
        sched.admit(request(vec![1, 2, 3], 64, tx1));
        let (tx2, rx2) = std::sync::mpsc::channel();
        sched.admit(request(vec![4, 5], 8, tx2));
        for _ in 0..3 {
            sched.step(&mut rng); // first slotted + decoding; second pending
        }
        assert_eq!(sched.in_flight(), 1);
        assert_eq!(sched.pending_len(), 1);
        sched.cancel_pending();
        let r2 = rx2.try_recv().expect("pending request answered on cancel");
        assert_eq!(r2.finish, FinishReason::Cancelled);
        assert_eq!(r2.tokens.len(), r2.prompt_len, "never ran: no generated tokens");
        sched.cancel_in_flight();
        sched.step(&mut rng); // retires the cancelled slot
        let r1 = rx1.try_recv().expect("in-flight request answered on cancel");
        assert_eq!(r1.finish, FinishReason::Cancelled);
        assert!(
            r1.tokens.len() > r1.prompt_len,
            "partial output delivered (it had been decoding)"
        );
        assert!(sched.is_idle());
        assert_eq!(sched.pool().blocks_in_use(), 0, "cancelled slots return their blocks");
    }

    // -- speculative decoding -----------------------------------------------

    #[test]
    fn spec_with_agreeing_draft_matches_solo_and_returns_blocks() {
        // Draft == target: every draft token agrees, so each round
        // accepts k+1 tokens, outputs stay bit-identical to the plain
        // solo runs, and every block (target AND draft caches) comes
        // back to the pool.
        let m = tiny_model(5, 4);
        let jobs: Vec<(Vec<u16>, usize)> = vec![(vec![6, 1, 9], 12), (vec![2, 3], 9)];
        let solo = solo_tokens(&m, &jobs);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(m.clone(), metrics.clone(), 2, 64);
        sched.set_spec(m, 3, 6);
        let mut rng = Rng::new(7);
        let rxs: Vec<_> = jobs
            .iter()
            .map(|(p, n)| {
                let (tx, rx) = std::sync::mpsc::channel();
                sched.admit(request(p.clone(), *n, tx));
                rx
            })
            .collect();
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000, "speculating scheduler must drain");
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.try_recv().expect("response");
            assert_eq!(r.tokens, solo[i], "request {i} diverged under speculation");
        }
        use std::sync::atomic::Ordering::Relaxed;
        let spec_rounds = metrics.spec_rounds.load(Relaxed);
        let accepted = metrics.spec_accepted.load(Relaxed);
        assert!(spec_rounds > 0, "speculation actually ran");
        assert!(
            accepted >= 2 * spec_rounds,
            "an identical draft must average well over 2 tokens/round \
             ({accepted} over {spec_rounds} rounds)"
        );
        assert!(metrics.spec_drafted.load(Relaxed) >= spec_rounds);
        assert_eq!(sched.pool().blocks_in_use(), 0, "draft caches released");
    }

    #[test]
    fn spec_under_pool_pressure_falls_back_and_stays_deterministic() {
        // The pool-exhaustion workload with speculation armed: spec
        // rounds that cannot reserve memory refuse (never preempt a
        // neighbor) and fall back to plain decoding; preemption of a
        // speculating slot releases its draft cache too. Outputs
        // still match the plain solo runs and nothing leaks.
        let m = tiny_model(12, 4);
        let jobs: Vec<(Vec<u16>, usize)> = (0..4u16)
            .map(|k| ((0..6).map(|j| (j * 3 + k * 7 + 1) as u16 % 30).collect(), 10))
            .collect();
        let solo = solo_tokens(&m, &jobs);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::with_pool(m.clone(), metrics.clone(), 4, 8, tight_pool(4, 8));
        sched.set_spec(m, 4, 8);
        let mut rng = Rng::new(7);
        let rxs: Vec<_> = jobs
            .iter()
            .map(|(p, max_new)| {
                let (tx, rx) = std::sync::mpsc::channel();
                sched.admit(request(p.clone(), *max_new, tx));
                rx
            })
            .collect();
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 5000, "pressured speculating pool must drain");
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.try_recv().expect("response despite pool pressure");
            assert_eq!(r.tokens, solo[i], "request {i} diverged under pressure + speculation");
        }
        assert_eq!(sched.pool().blocks_in_use(), 0, "all blocks returned");
        assert!(
            sched.pool().peak_blocks() <= 8,
            "budget respected with draft caches: peak {}",
            sched.pool().peak_blocks()
        );
    }

    #[test]
    fn spec_adaptive_k_grows_on_streaks_and_respects_max_new() {
        // An identical draft fully accepts every round, so spec_k
        // grows toward max_k; the generation cap is still exact.
        let m = tiny_model(9, 4);
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(m.clone(), metrics.clone(), 1, 64);
        sched.set_spec(m, 2, 8);
        let mut rng = Rng::new(7);
        let (tx, rx) = std::sync::mpsc::channel();
        sched.admit(request(vec![4, 2, 7], 31, tx));
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000);
        }
        let r = rx.try_recv().expect("response");
        assert_eq!(r.tokens.len() - r.prompt_len, 31, "exact generation cap under spec");
        assert_eq!(r.finish, FinishReason::Length);
        use std::sync::atomic::Ordering::Relaxed;
        // Full acceptance at growing k: strictly fewer rounds than
        // tokens proves multi-token acceptance; the k gauge moved.
        assert!(metrics.spec_rounds.load(Relaxed) * 2 < 31);
        assert!(metrics.spec_accepted.load(Relaxed) >= 24);
    }

    #[test]
    fn spec_respects_stop_tokens_mid_round() {
        // Learn the 3rd greedy token, declare it EOS, then run with
        // speculation: generation must stop at exactly that token
        // even when the spec round had more accepted tokens queued.
        let m = tiny_model(4, 4);
        let jobs: Vec<(Vec<u16>, usize)> = vec![(vec![3, 1], 8)];
        let solo = solo_tokens(&m, &jobs);
        let gen = &solo[0][2..]; // prompt_len 2
        // First generated token with no earlier occurrence, so the
        // EOS fires at exactly that position.
        let pos = (1..gen.len())
            .find(|&p| !gen[..p].contains(&gen[p]))
            .expect("some non-repeating generated token");
        let eos = gen[pos];
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(m.clone(), metrics, 1, 64);
        sched.set_spec(m, 4, 8);
        let mut rng = Rng::new(7);
        let (tx, rx) = std::sync::mpsc::channel();
        let mut req = request(vec![3, 1], 8, tx);
        req.stop = StopSet::none().with_eos(eos);
        sched.admit(req);
        let mut rounds = 0;
        while !sched.is_idle() {
            sched.step(&mut rng);
            rounds += 1;
            assert!(rounds < 1000);
        }
        let r = rx.try_recv().expect("response");
        assert_eq!(r.finish, FinishReason::Eos);
        assert_eq!(r.tokens.len() - r.prompt_len, pos + 1, "stops at the EOS token exactly");
        assert_eq!(&r.tokens[r.prompt_len..], &gen[..=pos]);
        assert_eq!(sched.pool().blocks_in_use(), 0);
    }
}
