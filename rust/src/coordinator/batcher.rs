//! Idle-side admission: drains the request queue into batches of up
//! to `max_batch`, waiting at most `wait` for stragglers once the
//! first request arrives. The serving worker uses this only when
//! nothing is in flight; once busy, the [`Scheduler`] drains the
//! queue non-blockingly between decode rounds instead (see
//! [`Scheduler::admit_ready`]).
//!
//! [`Scheduler`]: super::scheduler::Scheduler
//! [`Scheduler::admit_ready`]: super::scheduler::Scheduler::admit_ready

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Drain up to `max_batch` items from `rx`, waiting at most `wait`
/// after the first item. Returns an empty vec when the channel is
/// closed and drained.
pub fn collect_batch<T>(rx: &Receiver<T>, max_batch: usize, wait: Duration) -> Vec<T> {
    let mut batch = Vec::new();
    // Block for the first item (or closure).
    match rx.recv() {
        Ok(item) => batch.push(item),
        Err(_) => return batch,
    }
    let deadline = Instant::now() + wait;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    batch
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn collects_available_items_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let b = collect_batch(&rx, 3, Duration::from_millis(1));
        assert_eq!(b, vec![0, 1, 2]);
        let b2 = collect_batch(&rx, 8, Duration::from_millis(1));
        assert_eq!(b2, vec![3, 4]);
    }

    #[test]
    fn empty_on_closed_channel() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(collect_batch(&rx, 4, Duration::from_millis(1)).is_empty());
    }

    #[test]
    fn waits_for_stragglers() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            let _ = tx.send(2);
        });
        let b = collect_batch(&rx, 4, Duration::from_millis(200));
        handle.join().unwrap();
        assert_eq!(b, vec![1, 2]);
    }
}
