//! Multi-tenant QoS: the tenant table, pluggable admission policies
//! (global FIFO vs weighted round-robin within priority classes), and
//! the pluggable eviction policy the scheduler preempts with under
//! KV-pool pressure.
//!
//! **Admission.** The scheduler's single FIFO pending queue becomes a
//! [`PendingQueues`] value: under [`AdmitPolicy::Fifo`] it behaves
//! exactly as before (global arrival order, tenant-blind — the bench's
//! fairness control); under [`AdmitPolicy::WeightedRoundRobin`] each
//! tenant gets its own queue and the drain order is: most urgent
//! priority class with waiting work first, then deficit-style weighted
//! round-robin across that class's tenants. A flooding tenant can
//! therefore fill the queue *behind* itself but never starve a
//! well-behaved peer: the peer's next request is at the front of its
//! own queue and the round-robin cursor reaches it within one
//! weight-cycle.
//!
//! **Backpressure.** Each tenant may bound its pending depth
//! (`max_pending`); the server rejects overflow at submit time with
//! `ServeError::TenantOverloaded` (HTTP 429 on the wire) instead of
//! buffering without bound. The shared [`QosState`] counters make that
//! check O(1) on the submit path without locking the scheduler.
//!
//! **Eviction.** PR 5's hard-coded newest-slot preemption generalizes
//! to the [`EvictionPolicy`] trait: a policy maps each in-flight slot
//! to a strictly-totally-ordered *eviction key*, and the scheduler
//! preempts the eligible slot with the **largest** key — but only if
//! that key is strictly greater than the requesting slot's own key.
//! The slot with the minimum key can therefore never be preempted, so
//! some request always makes progress and the pool can never
//! live-lock, whatever the policy (the same progress guarantee the
//! newest-slot rule gave, now an invariant of the key ordering).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};

use super::server::GenRequest;

/// One tenant's service contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSpec {
    /// Tenant id (the `tenant` field of a wire request).
    pub id: String,
    /// Weighted-round-robin weight within the priority class (>= 1):
    /// a weight-3 tenant drains three requests per cycle for every
    /// one of a weight-1 peer.
    pub weight: u32,
    /// Priority class, 0 = most urgent. Admission always serves the
    /// most urgent class with waiting work; classes do not share.
    pub priority: u8,
    /// Max requests queued (submitted but not yet slotted); 0 =
    /// unbounded. Overflow is rejected at submit time (429 on the
    /// wire), not buffered.
    pub max_pending: usize,
}

impl TenantSpec {
    /// A weight-1, class-0, unbounded tenant.
    pub fn new(id: &str) -> TenantSpec {
        TenantSpec { id: id.to_string(), weight: 1, priority: 0, max_pending: 0 }
    }
}

/// How pending requests are drained into scheduler slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmitPolicy {
    /// Global arrival order, tenant-blind (the pre-QoS behavior; kept
    /// selectable as the fairness baseline the bench compares
    /// against).
    #[default]
    Fifo,
    /// Most urgent priority class first; weighted round-robin across
    /// tenants within the class.
    WeightedRoundRobin,
}

impl AdmitPolicy {
    pub fn parse(s: &str) -> Result<AdmitPolicy, String> {
        match s {
            "fifo" => Ok(AdmitPolicy::Fifo),
            "wrr" | "weighted-round-robin" => Ok(AdmitPolicy::WeightedRoundRobin),
            other => Err(format!("unknown admission policy {other:?} (expected fifo|wrr)")),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            AdmitPolicy::Fifo => "fifo",
            AdmitPolicy::WeightedRoundRobin => "wrr",
        }
    }
}

/// Which eviction policy the scheduler preempts with when a slot needs
/// KV blocks and the pool is out.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvictionKind {
    /// Evict the most recently admitted slot (PR 5's rule).
    #[default]
    Newest,
    /// Evict the least urgent (highest `priority` value) slot; ties
    /// break newest-first.
    LowestPriority,
    /// Evict the slot holding the most KV blocks (frees the most
    /// memory per preemption); ties break newest-first.
    LargestKv,
}

impl EvictionKind {
    pub fn parse(s: &str) -> Result<EvictionKind, String> {
        match s {
            "newest" => Ok(EvictionKind::Newest),
            "lowest-priority" => Ok(EvictionKind::LowestPriority),
            "largest-kv" => Ok(EvictionKind::LargestKv),
            other => Err(format!(
                "unknown eviction policy {other:?} (expected newest|lowest-priority|largest-kv)"
            )),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            EvictionKind::Newest => "newest",
            EvictionKind::LowestPriority => "lowest-priority",
            EvictionKind::LargestKv => "largest-kv",
        }
    }

    /// Instantiate the policy.
    pub fn policy(self) -> Box<dyn EvictionPolicy> {
        match self {
            EvictionKind::Newest => Box::new(EvictNewest),
            EvictionKind::LowestPriority => Box::new(EvictLowestPriority),
            EvictionKind::LargestKv => Box::new(EvictLargestKv),
        }
    }
}

/// What an eviction policy sees of one in-flight slot.
#[derive(Debug, Clone, Copy)]
pub struct SlotView {
    /// Admission sequence number (unique per slot — the tiebreaker
    /// that makes every key ordering strict).
    pub admitted: u64,
    /// Priority class of the slot's tenant (0 = most urgent).
    pub priority: u8,
    /// KV blocks the slot currently holds.
    pub kv_blocks: usize,
}

/// Maps a slot to its eviction key. The scheduler preempts the
/// eligible slot with the largest key, and only when that key is
/// strictly greater than the requester's: because `admitted` is unique
/// the ordering is strict, the minimum-key slot is unevictable, and
/// progress is guaranteed under any policy.
pub trait EvictionPolicy: Send {
    fn name(&self) -> &'static str;
    /// Larger key = evicted sooner. The second component must make
    /// ties impossible (conventionally `admitted`).
    fn key(&self, s: &SlotView) -> (u64, u64);
}

/// PR 5's rule: newest admission goes first.
pub struct EvictNewest;

impl EvictionPolicy for EvictNewest {
    fn name(&self) -> &'static str {
        "newest"
    }
    fn key(&self, s: &SlotView) -> (u64, u64) {
        (0, s.admitted)
    }
}

/// Least urgent tenant goes first; newest-first within a class.
pub struct EvictLowestPriority;

impl EvictionPolicy for EvictLowestPriority {
    fn name(&self) -> &'static str {
        "lowest-priority"
    }
    fn key(&self, s: &SlotView) -> (u64, u64) {
        (s.priority as u64, s.admitted)
    }
}

/// Biggest KV footprint goes first (most memory freed per preemption);
/// newest-first among equals.
pub struct EvictLargestKv;

impl EvictionPolicy for EvictLargestKv {
    fn name(&self) -> &'static str {
        "largest-kv"
    }
    fn key(&self, s: &SlotView) -> (u64, u64) {
        (s.kv_blocks as u64, s.admitted)
    }
}

/// The full QoS configuration a server runs with.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QosConfig {
    pub admission: AdmitPolicy,
    pub eviction: EvictionKind,
    /// Tenant table; requests resolve against it by id (unknown ids
    /// ride tenant 0). Never empty after validation.
    pub tenants: Vec<TenantSpec>,
}

impl Default for QosConfig {
    /// Single anonymous tenant, FIFO, newest-slot eviction — exactly
    /// the pre-QoS behavior.
    fn default() -> QosConfig {
        QosConfig {
            admission: AdmitPolicy::Fifo,
            eviction: EvictionKind::Newest,
            tenants: vec![TenantSpec::new("default")],
        }
    }
}

impl QosConfig {
    /// Reject configurations the scheduler cannot serve correctly:
    /// no tenants at all, empty ids, duplicate ids, zero weights (a
    /// zero-weight tenant would never earn WRR credit and starve).
    pub fn validate(&self) -> Result<(), String> {
        if self.tenants.is_empty() {
            return Err("qos: at least one tenant is required".to_string());
        }
        for (i, t) in self.tenants.iter().enumerate() {
            if t.id.trim().is_empty() {
                return Err(format!("qos: tenant #{i} has an empty id"));
            }
            if t.weight == 0 {
                return Err(format!("qos: tenant {:?} has zero weight", t.id));
            }
            if self.tenants[..i].iter().any(|u| u.id == t.id) {
                return Err(format!("qos: duplicate tenant id {:?}", t.id));
            }
        }
        Ok(())
    }

    /// Index of `id` in the tenant table.
    pub fn tenant_index(&self, id: &str) -> Option<usize> {
        self.tenants.iter().position(|t| t.id == id)
    }
}

/// QoS state shared between the submit path (server handle threads)
/// and the scheduler (worker thread): the immutable config plus the
/// per-tenant pending-depth counters behind the `max_pending` bound.
#[derive(Debug)]
pub struct QosState {
    pub config: QosConfig,
    /// Requests submitted but not yet slotted (incremented at submit,
    /// decremented when the scheduler dequeues), one per tenant.
    pub queued: Vec<AtomicU64>,
}

impl QosState {
    pub fn new(config: QosConfig) -> QosState {
        let queued = config.tenants.iter().map(|_| AtomicU64::new(0)).collect();
        QosState { config, queued }
    }

    /// Current pending depth for tenant index `t` (clamped in-range).
    pub fn queued_for(&self, t: usize) -> u64 {
        self.queued[t.min(self.queued.len() - 1)].load(Ordering::Relaxed)
    }

    /// Count one dequeue (slot admission, rejection or cancellation)
    /// for tenant index `t`. Saturates at zero: requests admitted
    /// directly into a bare `Scheduler` never went through the submit
    /// path's increment.
    pub fn note_dequeued(&self, t: usize) {
        let c = &self.queued[t.min(self.queued.len() - 1)];
        let _ = c.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }
}

impl Default for QosState {
    fn default() -> QosState {
        QosState::new(QosConfig::default())
    }
}

/// The scheduler's pending set, drained according to the admission
/// policy. Single-threaded (owned by the scheduler); the cross-thread
/// surface is [`QosState`].
pub struct PendingQueues {
    policy: AdmitPolicy,
    weights: Vec<u64>,
    priorities: Vec<u8>,
    /// FIFO mode: one global arrival-ordered queue.
    fifo: VecDeque<GenRequest>,
    /// WRR mode: one queue per tenant.
    queues: Vec<VecDeque<GenRequest>>,
    /// Deficit credits, replenished a weight per cycle; reset to zero
    /// when a tenant's queue drains so idle tenants cannot hoard
    /// credit and burst later.
    credits: Vec<u64>,
    /// Round-robin cursor: the tenant index the next scan starts from.
    cursor: usize,
    count: usize,
}

impl PendingQueues {
    pub fn new(cfg: &QosConfig) -> PendingQueues {
        let n = cfg.tenants.len().max(1);
        PendingQueues {
            policy: cfg.admission,
            weights: cfg.tenants.iter().map(|t| t.weight as u64).collect(),
            priorities: cfg.tenants.iter().map(|t| t.priority).collect(),
            fifo: VecDeque::new(),
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            credits: vec![0; n],
            cursor: 0,
            count: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    fn tenant_of(&self, req: &GenRequest) -> usize {
        (req.tenant as usize).min(self.queues.len() - 1)
    }

    pub fn push(&mut self, req: GenRequest) {
        self.count += 1;
        match self.policy {
            AdmitPolicy::Fifo => self.fifo.push_back(req),
            AdmitPolicy::WeightedRoundRobin => {
                let t = self.tenant_of(&req);
                self.queues[t].push_back(req);
            }
        }
    }

    /// The tenant the next `pop` will serve. Deterministic in the
    /// queue state: calling it twice (or `peek` then `pop`) selects
    /// the same tenant, because replenishment is idempotent once a
    /// tenant in the urgent class holds credit.
    fn select(&mut self) -> Option<usize> {
        if self.count == 0 {
            return None;
        }
        let cls = (0..self.queues.len())
            .filter(|&t| !self.queues[t].is_empty())
            .map(|t| self.priorities[t])
            .min()?;
        let n = self.queues.len();
        // Pass 1 with current credits; if the whole class is out,
        // replenish once and pass 2 must hit (weights are >= 1).
        for round in 0..2 {
            for k in 0..n {
                let t = (self.cursor + k) % n;
                if self.priorities[t] == cls && !self.queues[t].is_empty() && self.credits[t] > 0 {
                    return Some(t);
                }
            }
            if round == 0 {
                for t in 0..n {
                    if self.priorities[t] != cls {
                        continue;
                    }
                    self.credits[t] = if self.queues[t].is_empty() {
                        0
                    } else {
                        self.credits[t].saturating_add(self.weights[t])
                    };
                }
            }
        }
        None
    }

    /// Next request under the policy, without removing it.
    pub fn peek(&mut self) -> Option<&GenRequest> {
        match self.policy {
            AdmitPolicy::Fifo => self.fifo.front(),
            AdmitPolicy::WeightedRoundRobin => {
                let t = self.select()?;
                self.queues[t].front()
            }
        }
    }

    /// Remove and return the next request under the policy.
    pub fn pop(&mut self) -> Option<GenRequest> {
        match self.policy {
            AdmitPolicy::Fifo => {
                let req = self.fifo.pop_front()?;
                self.count -= 1;
                Some(req)
            }
            AdmitPolicy::WeightedRoundRobin => {
                let t = self.select()?;
                let req = self.queues[t].pop_front()?;
                self.credits[t] = self.credits[t].saturating_sub(1);
                if self.queues[t].is_empty() {
                    self.credits[t] = 0;
                }
                if self.credits[t] == 0 {
                    // Cycle on: the next scan starts at the next
                    // tenant, so equal-weight peers alternate.
                    self.cursor = (t + 1) % self.queues.len();
                }
                self.count -= 1;
                Some(req)
            }
        }
    }

    /// Remove and return every request matching `pred`, from every
    /// queue, preserving relative order among the survivors (the
    /// deadline/disconnect reaping path). Credits of queues emptied by
    /// the extraction re-zero, matching `pop`'s no-hoarding rule.
    pub fn extract_where(&mut self, mut pred: impl FnMut(&GenRequest) -> bool) -> Vec<GenRequest> {
        let mut out = Vec::new();
        let mut take = |q: &mut VecDeque<GenRequest>, out: &mut Vec<GenRequest>| {
            let mut kept = VecDeque::with_capacity(q.len());
            for req in q.drain(..) {
                if pred(&req) {
                    out.push(req);
                } else {
                    kept.push_back(req);
                }
            }
            *q = kept;
        };
        take(&mut self.fifo, &mut out);
        for q in &mut self.queues {
            take(q, &mut out);
        }
        self.count -= out.len();
        for (t, q) in self.queues.iter().enumerate() {
            if q.is_empty() {
                self.credits[t] = 0;
            }
        }
        out
    }

    /// Remove everything (graceful-drain cancellation path).
    pub fn drain_all(&mut self) -> Vec<GenRequest> {
        let mut out: Vec<GenRequest> = self.fifo.drain(..).collect();
        for q in &mut self.queues {
            out.extend(q.drain(..));
        }
        self.count = 0;
        self.credits.iter_mut().for_each(|c| *c = 0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::StopSet;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn req(tenant: u32, tag: u16) -> GenRequest {
        let (tx, _rx) = channel();
        GenRequest {
            prompt: vec![tag],
            max_new_tokens: 1,
            temperature: 0.0,
            stop: StopSet::none(),
            stream: None,
            respond: tx,
            submitted: Instant::now(),
            tenant,
            deadline: None,
            cancel: crate::coordinator::server::CancelToken::default(),
        }
    }

    fn cfg(tenants: Vec<TenantSpec>, admission: AdmitPolicy) -> QosConfig {
        QosConfig { admission, eviction: EvictionKind::Newest, tenants }
    }

    fn tenant(id: &str, weight: u32, priority: u8) -> TenantSpec {
        TenantSpec { id: id.into(), weight, priority, max_pending: 0 }
    }

    #[test]
    fn validation_rejects_bad_tables() {
        assert!(QosConfig::default().validate().is_ok());
        let empty = QosConfig { tenants: vec![], ..QosConfig::default() };
        assert!(empty.validate().unwrap_err().contains("at least one"));
        let zero = cfg(vec![tenant("a", 0, 0)], AdmitPolicy::Fifo);
        assert!(zero.validate().unwrap_err().contains("zero weight"));
        let dup = cfg(vec![tenant("a", 1, 0), tenant("a", 2, 0)], AdmitPolicy::Fifo);
        assert!(dup.validate().unwrap_err().contains("duplicate"));
        let blank = cfg(vec![tenant("  ", 1, 0)], AdmitPolicy::Fifo);
        assert!(blank.validate().unwrap_err().contains("empty id"));
    }

    #[test]
    fn fifo_preserves_arrival_order_across_tenants() {
        let c = cfg(vec![tenant("a", 1, 0), tenant("b", 1, 0)], AdmitPolicy::Fifo);
        let mut q = PendingQueues::new(&c);
        q.push(req(1, 10));
        q.push(req(0, 20));
        q.push(req(1, 30));
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek().unwrap().prompt, vec![10]);
        let order: Vec<u16> = std::iter::from_fn(|| q.pop()).map(|r| r.prompt[0]).collect();
        assert_eq!(order, vec![10, 20, 30]);
        assert!(q.is_empty());
    }

    #[test]
    fn wrr_alternates_equal_weights() {
        let c = cfg(
            vec![tenant("a", 1, 0), tenant("b", 1, 0)],
            AdmitPolicy::WeightedRoundRobin,
        );
        let mut q = PendingQueues::new(&c);
        // Tenant 0 floods; tenant 1 queues two.
        for i in 0..4 {
            q.push(req(0, i));
        }
        q.push(req(1, 100));
        q.push(req(1, 101));
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|r| r.tenant).collect();
        // Alternation until tenant 1 drains, then tenant 0's backlog.
        assert_eq!(order, vec![0, 1, 0, 1, 0, 0]);
    }

    #[test]
    fn wrr_weights_bias_the_cycle() {
        let c = cfg(
            vec![tenant("heavy", 3, 0), tenant("light", 1, 0)],
            AdmitPolicy::WeightedRoundRobin,
        );
        let mut q = PendingQueues::new(&c);
        for i in 0..6 {
            q.push(req(0, i));
        }
        for i in 0..2 {
            q.push(req(1, 100 + i));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|r| r.tenant).collect();
        // 3:1 within each cycle while both queues are non-empty.
        assert_eq!(order, vec![0, 0, 0, 1, 0, 0, 0, 1]);
    }

    #[test]
    fn priority_class_preempts_lower_class() {
        let c = cfg(
            vec![tenant("bulk", 9, 1), tenant("urgent", 1, 0)],
            AdmitPolicy::WeightedRoundRobin,
        );
        let mut q = PendingQueues::new(&c);
        for i in 0..3 {
            q.push(req(0, i));
        }
        assert_eq!(q.pop().unwrap().tenant, 0, "bulk serves while urgent is idle");
        q.push(req(1, 100));
        q.push(req(1, 101));
        // Urgent (class 0) drains completely before bulk resumes,
        // regardless of bulk's weight.
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|r| r.tenant).collect();
        assert_eq!(order, vec![1, 1, 0, 0]);
    }

    #[test]
    fn peek_and_pop_agree() {
        let c = cfg(
            vec![tenant("a", 2, 0), tenant("b", 1, 0)],
            AdmitPolicy::WeightedRoundRobin,
        );
        let mut q = PendingQueues::new(&c);
        for i in 0..3 {
            q.push(req(0, i));
            q.push(req(1, 100 + i));
        }
        while !q.is_empty() {
            let want = q.peek().unwrap().prompt.clone();
            let got = q.pop().unwrap();
            assert_eq!(got.prompt, want, "peek must predict pop");
        }
    }

    #[test]
    fn extract_where_removes_matches_and_preserves_order() {
        let c = cfg(
            vec![tenant("a", 1, 0), tenant("b", 1, 0)],
            AdmitPolicy::WeightedRoundRobin,
        );
        let mut q = PendingQueues::new(&c);
        for i in 0..4 {
            q.push(req(0, i));
        }
        q.push(req(1, 100));
        // Pull the even-tagged requests of tenant 0.
        let dead = q.extract_where(|r| r.tenant == 0 && r.prompt[0] % 2 == 0);
        assert_eq!(dead.len(), 2);
        assert_eq!(q.len(), 3);
        let order: Vec<u16> = std::iter::from_fn(|| q.pop()).map(|r| r.prompt[0]).collect();
        // Survivors keep their relative order under the WRR drain.
        assert_eq!(order, vec![1, 100, 3]);
        // Extracting nothing is a no-op; extracting from empty too.
        assert!(q.extract_where(|_| true).is_empty());
        // FIFO mode walks the global queue the same way.
        let cf = cfg(vec![tenant("a", 1, 0)], AdmitPolicy::Fifo);
        let mut qf = PendingQueues::new(&cf);
        for i in 0..3 {
            qf.push(req(0, i));
        }
        let dead = qf.extract_where(|r| r.prompt[0] == 1);
        assert_eq!(dead.len(), 1);
        let order: Vec<u16> = std::iter::from_fn(|| qf.pop()).map(|r| r.prompt[0]).collect();
        assert_eq!(order, vec![0, 2]);
    }

    #[test]
    fn drain_all_empties_every_queue() {
        let c = cfg(
            vec![tenant("a", 1, 0), tenant("b", 1, 1)],
            AdmitPolicy::WeightedRoundRobin,
        );
        let mut q = PendingQueues::new(&c);
        for i in 0..3 {
            q.push(req(i % 2, i as u16));
        }
        assert_eq!(q.drain_all().len(), 3);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn unknown_tenant_index_rides_the_last_queue() {
        // Out-of-range indices clamp instead of panicking (direct
        // Scheduler users can construct GenRequest by hand).
        let c = cfg(vec![tenant("only", 1, 0)], AdmitPolicy::WeightedRoundRobin);
        let mut q = PendingQueues::new(&c);
        q.push(req(999, 1));
        assert_eq!(q.pop().unwrap().prompt, vec![1]);
    }

    #[test]
    fn eviction_keys_order_as_documented() {
        let older_small_urgent = SlotView { admitted: 1, priority: 0, kv_blocks: 2 };
        let newer_big_bulk = SlotView { admitted: 5, priority: 2, kv_blocks: 7 };
        let newest_mid = SlotView { admitted: 9, priority: 1, kv_blocks: 4 };
        let newest = EvictionKind::Newest.policy();
        assert!(newest.key(&newest_mid) > newest.key(&newer_big_bulk));
        assert!(newest.key(&newer_big_bulk) > newest.key(&older_small_urgent));
        let prio = EvictionKind::LowestPriority.policy();
        assert!(prio.key(&newer_big_bulk) > prio.key(&newest_mid), "class outranks recency");
        assert!(prio.key(&newest_mid) > prio.key(&older_small_urgent));
        let kv = EvictionKind::LargestKv.policy();
        assert!(kv.key(&newer_big_bulk) > kv.key(&newest_mid), "footprint outranks recency");
        assert_eq!(kv.name(), "largest-kv");
        assert_eq!(EvictionKind::parse("lowest-priority"), Ok(EvictionKind::LowestPriority));
        assert!(EvictionKind::parse("nope").is_err());
        assert_eq!(AdmitPolicy::parse("wrr"), Ok(AdmitPolicy::WeightedRoundRobin));
        assert!(AdmitPolicy::parse("nope").is_err());
    }

    #[test]
    fn qos_state_counters_saturate_at_zero() {
        let s = QosState::new(cfg(vec![tenant("a", 1, 0)], AdmitPolicy::Fifo));
        s.note_dequeued(0); // never incremented: must not underflow
        assert_eq!(s.queued_for(0), 0);
        s.queued[0].store(2, Ordering::Relaxed);
        s.note_dequeued(0);
        assert_eq!(s.queued_for(0), 1);
    }
}
