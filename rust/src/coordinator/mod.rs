//! L3 coordinator: config system, continuous-batching serving loop,
//! and metrics. The paper's contribution lives at L1/L2 (kernel +
//! quantization algorithm), so per DESIGN.md this layer is a thin but
//! real deployment front-end, all on std threads + channels (tokio is
//! not in the offline vendor set):
//!
//! request queue → in-flight scheduler → quantized engine → per-token
//! streams + responses.
//!
//! The [`Scheduler`] admits requests *between decode rounds* (no
//! head-of-line blocking behind a long generation), prefills prompts
//! in bounded chunks interleaved with in-flight decoding, applies stop
//! conditions (EOS + stop sets, [`StopSet`]) and delivers tokens as
//! they are accepted over optional streaming channels. It also owns
//! the block-paged KV pool (`model/kvcache.rs`): admission is
//! memory-aware (free blocks for the prompt, no worst-case
//! reservation), prompts sharing a token prefix share refcounted
//! blocks, and cold blocks optionally store packed int K/V
//! (`serve.kv_bits`) — see DESIGN.md §8. [`Metrics`] tracks queue
//! wait, time-to-first-token and inter-token latency alongside the
//! per-phase prefill/decode rates and the KV-pool gauges. With greedy
//! sampling each request's output is bit-identical regardless of
//! co-traffic — see DESIGN.md §6 for the determinism contract.
//!
//! [`Metrics`]: metrics::Metrics

pub mod batcher;
pub mod config;
pub mod metrics;
pub mod scheduler;
pub mod server;

pub use config::ServeConfig;
pub use scheduler::Scheduler;
pub use server::{
    FinishReason, GenRequest, GenResponse, ServeError, Server, ServerOptions, StopSet,
};
