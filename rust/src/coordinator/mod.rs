//! L3 coordinator: config system, network front-end, multi-tenant
//! QoS, continuous-batching serving loop, and metrics. The paper's
//! contribution lives at L1/L2 (kernel + quantization algorithm), so
//! per DESIGN.md this layer is a thin but real deployment front-end,
//! all on std threads + channels + `std::net` (tokio is not in the
//! offline vendor set). The layering, outside in:
//!
//! TCP listener (`net`) → HTTP/SSE bridge → submit path (`server`,
//! per-tenant admission bounds) → QoS pending queues (`qos`) →
//! in-flight scheduler (`scheduler`) → quantized engine → per-token
//! streams back out over the same path.
//!
//! [`NetServer`] is a dependency-free HTTP/1.1 front-end: it parses
//! generate requests (token ids, sampling knobs, tenant id), bridges
//! each connection onto the server's in-process streaming channels
//! (chunked SSE out), and maps QoS rejections onto wire status codes
//! (429 for a tenant over its pending bound, 503 while draining) —
//! see DESIGN.md §9.
//!
//! The [`Scheduler`] admits requests *between decode rounds* (no
//! head-of-line blocking behind a long generation), prefills prompts
//! in bounded chunks interleaved with in-flight decoding, applies stop
//! conditions (EOS + stop sets, [`StopSet`]) and delivers tokens as
//! they are accepted over optional streaming channels. Its pending set
//! is policy-ordered (`qos`): global FIFO by default, or per-tenant
//! weighted round-robin within priority classes, so one flooding
//! tenant cannot starve a well-behaved peer. It also owns the
//! block-paged KV pool (`model/kvcache.rs`): admission is memory-aware
//! (free blocks for the prompt, no worst-case reservation), prompts
//! sharing a token prefix share refcounted blocks, cold blocks
//! optionally store packed int K/V (`serve.kv_bits`), and preemption
//! under pool pressure picks its victim through the pluggable
//! [`EvictionPolicy`] (newest / lowest-priority / largest-KV) — see
//! DESIGN.md §8–9. [`Metrics`] tracks queue wait, time-to-first-token
//! and inter-token latency — globally and per tenant — alongside the
//! per-phase prefill/decode rates and the KV-pool gauges. With greedy
//! sampling each request's output is bit-identical regardless of
//! co-traffic — see DESIGN.md §6 for the determinism contract; the
//! network layer preserves it bit for bit (`rust/tests/serving.rs`).
//!
//! **Speculative decoding** (DESIGN.md §13) slots into the scheduler's
//! round structure: when a [`SpecConfig`] arms a draft model (a
//! cheaper quantization of the same checkpoint, e.g. btc-0.8 under an
//! fp16 target), each greedy decode slot drafts up to k tokens on its
//! own draft KV cache — allocated from the *same* pool, so admission
//! and preemption accounting stay memory-honest — then verifies all
//! k+1 positions in one batched target forward, accepting the longest
//! agreeing prefix. Acceptance is greedy-exact: outputs are
//! bit-identical to plain decoding, speculation only changes how many
//! tokens one round yields. Rejection rolls the caches back via
//! `PagedKvCache::truncate`; per-slot k adapts to the observed
//! acceptance rate; temperature > 0 requests bypass the whole path. A
//! draft-model fault degrades the slot to plain decoding (speculation
//! is an optimization, never a correctness dependency).
//!
//! **Fault isolation** (DESIGN.md §10) wraps that pipeline at three
//! levels. Per request: a panic inside a model call is caught at the
//! slot boundary — the scheduler replays the decode batch solo to
//! attribute the culprit, quarantines it ([`FinishReason::Failed`]),
//! releases its KV blocks and keeps serving the survivors
//! bit-identically. Per worker: the serving thread is a supervisor
//! loop with a bounded restart budget; a crash outside containment
//! fails only the in-flight slots and preserves the pending queue.
//! Per lifecycle: requests carry an optional wall-clock deadline and
//! a [`CancelToken`] (tripped by client disconnect at the network
//! layer), both honored between decode rounds with partial output.
//! The deterministic fault-injection harness behind the chaos tests
//! lives in `util/faultpoint.rs`.
//!
//! [`Metrics`]: metrics::Metrics
//! [`EvictionPolicy`]: qos::EvictionPolicy

pub mod batcher;
pub mod config;
pub mod metrics;
pub mod net;
pub mod qos;
pub mod scheduler;
pub mod server;

pub use config::ServeConfig;
pub use net::{NetOptions, NetServer};
pub use qos::{AdmitPolicy, EvictionKind, EvictionPolicy, QosConfig, TenantSpec};
pub use scheduler::Scheduler;
pub use server::{
    CancelToken, FinishReason, GenRequest, GenResponse, ServeError, Server, ServerOptions,
    SpecConfig, StopSet,
};
