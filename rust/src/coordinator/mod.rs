//! L3 coordinator: config system, serving loop with dynamic batching,
//! and metrics. The paper's contribution lives at L1/L2 (kernel +
//! quantization algorithm), so per DESIGN.md this layer is a thin but
//! real deployment front-end: request queue → batcher → quantized
//! engine → token streams, all on std threads + channels (tokio is not
//! in the offline vendor set).

pub mod batcher;
pub mod config;
pub mod metrics;
pub mod server;

pub use config::ServeConfig;
pub use server::{GenRequest, GenResponse, Server};
