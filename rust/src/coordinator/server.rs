//! The serving front-end: a worker thread owns the (quantized) model
//! and drives a continuous-batching [`Scheduler`]; clients submit via
//! a channel handle and receive per-token streams and/or a completed
//! response on per-request channels. The network layer
//! (`coordinator/net.rs`) is a thin bridge onto exactly this surface.
//!
//! Unlike a batch-to-completion loop, new requests are admitted
//! *between decode rounds* (up to `max_batch` in-flight slots), so a
//! short request submitted behind a long-running generation overtakes
//! it instead of queueing until the whole batch drains. Prompts are
//! prefilled in bounded chunks so a long prompt can't stall in-flight
//! decoders either. See `coordinator/scheduler.rs` and DESIGN.md §6.
//!
//! **Multi-tenant QoS.** Every submission is attributed to a tenant
//! (anonymous submits ride tenant 0). Per-tenant pending bounds are
//! enforced here on the submit path ([`ServeError::TenantOverloaded`]
//! — a 429 on the wire) while queue *ordering* is the scheduler's
//! admission policy (`coordinator/qos.rs`).
//!
//! **Fault isolation.** Per-request faults (a poisoned prompt, an
//! injected panic) are contained by the scheduler: the culprit slot is
//! quarantined and answered with [`FinishReason::Failed`] while
//! concurrent requests keep decoding, bit-identical to a fault-free
//! run. Panics that escape that containment are absorbed by a
//! supervisor around the worker loop, which recovers the scheduler and
//! restarts under a bounded budget. Requests also carry an optional
//! wall-clock deadline and a [`CancelToken`] (client disconnect); both
//! take effect between decode rounds. See DESIGN.md §10.
//!
//! **Shutdown.** [`Server::shutdown`] keeps the historical contract:
//! close the queue and serve everything already submitted to
//! completion. [`Server::shutdown_within`] is the bounded drain:
//! admission stops immediately (pending requests complete with
//! [`FinishReason::Cancelled`]), in-flight requests keep decoding
//! until the deadline, then are cancelled too — every client gets a
//! response and then its streaming channel closes; nobody blocks
//! forever. Dropping the `Server` equals `shutdown()`.

use std::fmt;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::collect_batch;
use super::config::ServeConfig;
use super::metrics::Metrics;
use super::qos::{QosConfig, QosState};
use super::scheduler::Scheduler;
use crate::model::kvcache::PoolConfig;
use crate::model::Transformer;
use crate::quant::actquant::ActQuant;
use crate::quant::kvquant::KvQuantConfig;
use crate::util::parallel;
use crate::util::rng::Rng;

/// Why a generation finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens`.
    Length,
    /// Emitted a token from the stop set.
    Stop,
    /// Emitted the EOS token.
    Eos,
    /// Cut short by a bounded server drain (`shutdown_within`) or a
    /// client disconnect: the response carries whatever was generated
    /// before the cut.
    Cancelled,
    /// The request ran past its wall-clock deadline (`deadline_ms`):
    /// the response carries the tokens generated so far.
    DeadlineExceeded,
    /// The request's own forward pass panicked (poisoned input,
    /// injected fault) and the slot was quarantined. The response
    /// carries whatever was generated before the fault; concurrent
    /// requests are unaffected.
    Failed,
}

/// Cooperative cancellation handle for one request. The submit paths
/// hand one back; [`CancelToken::cancel`] (e.g. on client disconnect)
/// makes the scheduler retire the request between decode rounds with
/// [`FinishReason::Cancelled`], freeing its KV blocks immediately.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation; takes effect within one decode round.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Stop conditions for one request: an optional EOS token id plus a
/// set of stop tokens. The matched token is still appended to the
/// output (historical behavior of the `'\n'` sentence terminator).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StopSet {
    /// End-of-sequence token id, if any.
    pub eos: Option<u16>,
    /// Additional stop-token ids (small set; scanned linearly).
    pub stops: Vec<u16>,
}

impl StopSet {
    /// No stop conditions: generation runs to `max_new_tokens`.
    pub fn none() -> StopSet {
        StopSet { eos: None, stops: Vec::new() }
    }

    /// The historical default: `'\n'` ends a "sentence" in the
    /// tinywiki world.
    pub fn newline() -> StopSet {
        StopSet { eos: None, stops: vec![b'\n' as u16] }
    }

    /// Builder-style EOS assignment.
    pub fn with_eos(mut self, eos: u16) -> StopSet {
        self.eos = Some(eos);
        self
    }

    /// Builder-style extra stop token.
    pub fn with_stop(mut self, token: u16) -> StopSet {
        self.stops.push(token);
        self
    }

    /// Does `token` end the generation, and why? EOS wins over the
    /// stop set when a token is both.
    pub fn classify(&self, token: u16) -> Option<FinishReason> {
        if self.eos == Some(token) {
            return Some(FinishReason::Eos);
        }
        if self.stops.contains(&token) {
            return Some(FinishReason::Stop);
        }
        None
    }
}

impl Default for StopSet {
    fn default() -> StopSet {
        StopSet::newline()
    }
}

/// A generation request (what the scheduler consumes). Built by the
/// [`Server::submit`] family; constructible directly for custom
/// scheduling loops.
#[derive(Debug)]
pub struct GenRequest {
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    pub temperature: f64,
    /// Stop conditions (EOS + stop tokens).
    pub stop: StopSet,
    /// Per-token streaming channel: every generated token is sent as
    /// soon as it is accepted; the channel closes after the final
    /// response is delivered.
    pub stream: Option<Sender<u16>>,
    pub respond: Sender<GenResponse>,
    /// When the client submitted (queue wait / TTFT clock origin).
    pub submitted: Instant,
    /// Index into the server's tenant table (out-of-range clamps to
    /// the last tenant; 0 for anonymous submits).
    pub tenant: u32,
    /// Absolute wall-clock deadline; past it the scheduler retires the
    /// request with [`FinishReason::DeadlineExceeded`]. `None` = run
    /// to completion.
    pub deadline: Option<Instant>,
    /// Cooperative cancellation (client disconnect); checked between
    /// decode rounds.
    pub cancel: CancelToken,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// Prompt + generated tokens.
    pub tokens: Vec<u16>,
    pub prompt_len: usize,
    /// Submit → completion (includes queue wait).
    pub latency: Duration,
    /// Submit → admission into an in-flight slot.
    pub queue_wait: Duration,
    /// Submit → first generated token.
    pub ttft: Duration,
    pub finish: FinishReason,
    /// Server-global completion sequence number (0-based): request A
    /// finished before request B iff `A.seq < B.seq`.
    pub seq: u64,
}

/// Why a submission (or server start) was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The worker thread is gone (it panicked — e.g. a poisoned model
    /// — or the server was shut down).
    WorkerGone,
    /// A bounded drain is in progress; no new work is accepted.
    ShuttingDown,
    /// The tenant's `max_pending` queue bound is full (HTTP 429 on
    /// the wire): shed load instead of buffering without bound.
    TenantOverloaded { tenant: String },
    /// The configuration was rejected at start time (bad listen
    /// address, zero tenant weight, duplicate tenant id, …) — instead
    /// of panicking later in the worker thread.
    InvalidConfig(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::WorkerGone => {
                write!(f, "server worker is gone (panicked or shut down); request not accepted")
            }
            ServeError::ShuttingDown => {
                write!(f, "server is draining for shutdown; request not accepted")
            }
            ServeError::TenantOverloaded { tenant } => {
                write!(f, "tenant {tenant:?} has reached its max_pending bound; request rejected")
            }
            ServeError::InvalidConfig(msg) => write!(f, "invalid serve configuration: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Speculative decoding: a second (cheaper) model drafts tokens that
/// the target model verifies in one batched forward per round. Greedy
/// requests stay **bit-identical** to plain decoding; temperature > 0
/// requests bypass speculation. See DESIGN.md §13.
#[derive(Clone)]
pub struct SpecConfig {
    /// The draft model. Must share the target's `ModelConfig`
    /// (typically the same weights at a lower bit-width, e.g. a
    /// btc-0.8 draft under an fp16 or btc-1.11 target).
    pub draft: Transformer,
    /// Short tag for the startup log and `/metrics` `spec=` field
    /// (the QLM1 file stem when loaded from disk).
    pub tag: String,
    /// Initial per-slot draft length (tokens drafted per round).
    pub k: usize,
    /// Upper bound the adaptive policy may grow a slot's k to.
    pub max_k: usize,
}

impl fmt::Debug for SpecConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SpecConfig")
            .field("tag", &self.tag)
            .field("k", &self.k)
            .field("max_k", &self.max_k)
            .finish_non_exhaustive()
    }
}

impl SpecConfig {
    pub fn new(draft: Transformer, tag: &str, k: usize, max_k: usize) -> SpecConfig {
        SpecConfig { draft, tag: tag.to_string(), k, max_k }
    }

    /// Load a draft model from a QLM1 artifact. `raw` must be the same
    /// raw checkpoint the target was quantized from: the QLM1 header
    /// self-validates against the model shape, so a corrupt file or a
    /// vocab/d_model mismatch surfaces here as
    /// [`ServeError::InvalidConfig`] — at start time, not mid-round.
    pub fn load(
        path: &Path,
        raw: &crate::io::weights::RawModel,
        k: usize,
        max_k: usize,
    ) -> Result<SpecConfig, ServeError> {
        let mut draft = Transformer::from_raw(raw)
            .map_err(|e| ServeError::InvalidConfig(format!("draft_model: {e}")))?;
        crate::io::qweights::load_into(path, &mut draft).map_err(|e| {
            ServeError::InvalidConfig(format!("draft_model {}: {e:#}", path.display()))
        })?;
        let tag = path.file_stem().and_then(|s| s.to_str()).unwrap_or("draft").to_string();
        Ok(SpecConfig { draft, tag, k, max_k })
    }
}

/// Tunables for [`Server::start_with_opts`].
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Max in-flight requests (fused into one decode round).
    pub max_batch: usize,
    /// How long an *idle* worker lingers for co-arrivals after the
    /// first request before starting a round. Once busy, admission is
    /// non-blocking between rounds and never waits.
    pub batch_wait: Duration,
    /// Sampling seed (temperature > 0 lanes).
    pub seed: u64,
    /// Kernel worker threads (0 = keep the current global setting,
    /// resolving it if unset). Validated/clamped at start.
    pub threads: usize,
    /// Max prompt tokens prefilled per scheduling round, shared
    /// across all newly-admitted requests (bounds how long new
    /// prompts — even a burst of them — can stall in-flight
    /// decoders).
    pub prefill_chunk: usize,
    /// Default stop conditions applied by [`Server::submit`] /
    /// [`Server::submit_streaming`].
    pub stop: StopSet,
    /// KV-pool block size (positions per block).
    pub kv_block: usize,
    /// KV-pool budget in blocks; 0 = auto (worst-case-equivalent
    /// capacity per in-flight slot — default configs behave exactly
    /// like the old flat reservation, just allocated lazily).
    pub kv_pool_blocks: usize,
    /// Bits for cold KV blocks (2..=8; >= 16 keeps everything f32 —
    /// the default, preserving bit-identical outputs).
    pub kv_bits: u32,
    /// Trailing positions kept f32 when `kv_bits` is active.
    pub kv_local_window: usize,
    /// Activation bits at the engine boundary (2..=8 arms the per-row
    /// W1A8 integer lanes on linears whose engines support them;
    /// >= 16 keeps activations f32 — the default, bit-identical to the
    /// pre-int-path server). Sanitized at start with the kv_bits clamp
    /// convention.
    pub act_bits: u32,
    /// Tenant table + admission/eviction policies. The default is a
    /// single anonymous tenant with FIFO admission and newest-slot
    /// eviction — the pre-QoS behavior, bit for bit.
    pub qos: QosConfig,
    /// Default per-request deadline in milliseconds (0 = none).
    /// Applied at submit time when the request carries no explicit
    /// deadline and its tenant has no override.
    pub deadline_ms: u64,
    /// Per-tenant deadline defaults, parallel to `qos.tenants`
    /// (0 = inherit `deadline_ms`; missing entries inherit too).
    pub tenant_deadline_ms: Vec<u64>,
    /// Fault-injection plan installed in the worker thread at start
    /// (`util::faultpoint` grammar). Empty = disabled.
    pub faults: String,
    /// Speculative decoding (draft model + k); `None` = off. Validated
    /// at start: `k >= 1`, `max_k >= k`, draft/target config match,
    /// and `kv_bits` must stay 16 (cold-KV quantization timing differs
    /// between speculative and plain schedules, which would break the
    /// bit-identity contract).
    pub spec: Option<SpecConfig>,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            max_batch: 8,
            batch_wait: Duration::from_millis(2),
            seed: 42,
            threads: 0,
            prefill_chunk: 32,
            stop: StopSet::newline(),
            kv_block: 32,
            kv_pool_blocks: 0,
            kv_bits: 16,
            kv_local_window: 16,
            act_bits: 16,
            qos: QosConfig::default(),
            deadline_ms: 0,
            tenant_deadline_ms: Vec::new(),
            faults: String::new(),
            spec: None,
        }
    }
}

impl From<&ServeConfig> for ServerOptions {
    fn from(c: &ServeConfig) -> ServerOptions {
        ServerOptions {
            max_batch: c.max_batch.max(1),
            batch_wait: Duration::from_millis(c.batch_wait_ms),
            seed: c.seed,
            threads: c.threads,
            prefill_chunk: c.prefill_chunk.max(1),
            stop: c.stop_set(),
            kv_block: c.kv_block.max(1),
            kv_pool_blocks: c.kv_pool_blocks,
            kv_bits: c.kv_bits,
            kv_local_window: c.kv_local_window,
            act_bits: c.act_bits,
            qos: c.qos_config(),
            deadline_ms: c.deadline_ms,
            tenant_deadline_ms: c.tenant_deadline_ms.clone(),
            faults: c.faults.clone(),
            // The draft model is a loaded artifact, not a config
            // value: `main.rs` resolves `c.draft_model` against the
            // raw checkpoint and fills this in.
            spec: None,
        }
    }
}

/// Shared drain signal: submit paths check `draining` (reject new
/// work), the worker checks it each round and cancels in-flight slots
/// once `deadline` passes.
#[derive(Debug, Default)]
struct DrainSignal {
    draining: AtomicBool,
    deadline: Mutex<Option<Instant>>,
}

impl DrainSignal {
    fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    fn deadline_passed(&self) -> bool {
        self.draining()
            && self
                .deadline
                .lock()
                .unwrap()
                .map(|d| Instant::now() >= d)
                .unwrap_or(false)
    }

    fn start(&self, deadline: Option<Instant>) {
        *self.deadline.lock().unwrap() = deadline;
        self.draining.store(true, Ordering::SeqCst);
    }
}

/// How many worker-loop panics the supervisor absorbs before
/// declaring the server unrecoverable. Round-level containment in the
/// scheduler already quarantines per-request faults; a panic that
/// reaches the supervisor means containment itself failed, so the
/// budget is deliberately small.
const RESTART_BUDGET: u32 = 3;

/// One scheduling life: admit + step until the submit channel closes
/// and everything drains (or a bounded drain completes). Returning
/// normally is clean shutdown; a panic escaping this function is
/// caught by the supervisor in [`Server::try_start_with_opts`], which
/// recovers the scheduler and calls back in.
fn worker_loop(
    sched: &mut Scheduler,
    rng: &mut Rng,
    rx: &Receiver<GenRequest>,
    drain: &DrainSignal,
    max_batch: usize,
    batch_wait: Duration,
) {
    loop {
        crate::fault_point!("worker.round");
        let draining = drain.draining();
        if sched.is_idle() {
            if draining {
                return;
            }
            // Nothing in flight: block for work (and linger
            // `batch_wait` for co-arrivals, as the batch-mode loop
            // always did).
            let batch = collect_batch(rx, max_batch, batch_wait);
            if batch.is_empty() {
                return; // channel closed and drained
            }
            if drain.draining() {
                // Drain began while we were blocked: these arrivals
                // get explicit Cancelled responses.
                for req in batch {
                    sched.cancel_submitted(req);
                }
                return;
            }
            for req in batch {
                sched.admit(req);
            }
            // Pull in whatever else already arrived, so the admission
            // order is the QoS policy's, not the channel's.
            let _ = sched.admit_ready(rx);
        } else if draining {
            // Bounded drain: stop admitting, cancel everything still
            // queued; in-flight slots keep decoding until the
            // deadline, then are cancelled too.
            while let Ok(req) = rx.try_recv() {
                sched.cancel_submitted(req);
            }
            sched.cancel_pending();
            if drain.deadline_passed() {
                sched.cancel_in_flight();
            }
        } else {
            // Busy: admit whatever is already queued, without waiting
            // — in-flight requests keep decoding.
            let _ = sched.admit_ready(rx);
        }
        sched.step(rng);
    }
}

/// Handle to a running server. Shutdown takes `&self`, so the handle
/// can sit behind an `Arc` shared with the network front-end.
pub struct Server {
    tx: Mutex<Option<Sender<GenRequest>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    drain: Arc<DrainSignal>,
    qos: Arc<QosState>,
    pub metrics: Arc<Metrics>,
    /// Effective worker-thread count the kernels run with.
    pub threads: usize,
    /// Default stop conditions for [`Server::submit`].
    stop: StopSet,
    /// Global default deadline (ms; 0 = none).
    deadline_ms: u64,
    /// Per-tenant deadline defaults (0/missing = inherit).
    tenant_deadline_ms: Vec<u64>,
}

impl Server {
    /// Spawn the worker thread owning `model` with default scheduling
    /// options (newline stop set, default prefill chunk, kernel thread
    /// count resolved automatically).
    pub fn start(model: Transformer, max_batch: usize, batch_wait: Duration, seed: u64) -> Server {
        Self::start_with_opts(
            model,
            ServerOptions { max_batch, batch_wait, seed, ..ServerOptions::default() },
        )
    }

    /// [`Server::start`] with an explicit kernel thread count
    /// (`0` = keep the current global setting, resolving it if unset).
    pub fn start_with_threads(
        model: Transformer,
        max_batch: usize,
        batch_wait: Duration,
        seed: u64,
        threads: usize,
    ) -> Server {
        Self::start_with_opts(
            model,
            ServerOptions { max_batch, batch_wait, seed, threads, ..ServerOptions::default() },
        )
    }

    /// [`Server::try_start_with_opts`], panicking on an invalid
    /// configuration (the defaults are always valid — existing
    /// callers keep their infallible signature).
    pub fn start_with_opts(model: Transformer, opts: ServerOptions) -> Server {
        Self::try_start_with_opts(model, opts).expect("invalid ServerOptions")
    }

    /// Spawn the worker thread owning `model`. The QoS table is
    /// validated *here* — a zero-weight or duplicate tenant is an
    /// [`ServeError::InvalidConfig`] at start time, not a worker-
    /// thread panic later. The thread count is validated/clamped
    /// (0 must not clobber a count a library user already set via
    /// `parallel::set_threads` — only an explicit value overrides),
    /// and serving engines are prepared on any linear that lacks one,
    /// so callers can hand over a freshly-quantized model directly.
    pub fn try_start_with_opts(
        mut model: Transformer,
        opts: ServerOptions,
    ) -> Result<Server, ServeError> {
        opts.qos.validate().map_err(ServeError::InvalidConfig)?;
        if let Some(s) = &opts.spec {
            if s.k == 0 {
                return Err(ServeError::InvalidConfig("spec_k must be >= 1".into()));
            }
            if s.max_k < s.k {
                return Err(ServeError::InvalidConfig(format!(
                    "spec_max_k {} must be >= spec_k {}",
                    s.max_k, s.k
                )));
            }
            if s.draft.cfg != model.cfg {
                return Err(ServeError::InvalidConfig(format!(
                    "draft model shape mismatch: draft vocab={} d_model={} n_layer={} \
                     vs target vocab={} d_model={} n_layer={}",
                    s.draft.cfg.vocab,
                    s.draft.cfg.d_model,
                    s.draft.cfg.n_layer,
                    model.cfg.vocab,
                    model.cfg.d_model,
                    model.cfg.n_layer
                )));
            }
            if KvQuantConfig::sanitize_bits(opts.kv_bits) < 16 {
                return Err(ServeError::InvalidConfig(
                    "speculative decoding requires kv_bits = 16: cold-KV quantization \
                     timing differs between speculative and plain schedules, breaking \
                     the bit-identity contract"
                        .into(),
                ));
            }
        }
        let threads = if opts.threads == 0 {
            parallel::threads()
        } else {
            parallel::set_threads(opts.threads)
        };
        // Validate the activation width at start (same clamp convention
        // as kv_bits) and arm the per-row integer lanes: linears that
        // carry no calibrated quantizer get a scale-free ActQuant so
        // int-capable engines switch to W1A8; a pipeline-calibrated
        // quantizer (if present) keeps its own width.
        let act_bits = KvQuantConfig::sanitize_bits(opts.act_bits);
        if act_bits < 16 {
            for b in model.blocks.iter_mut() {
                for (_, lin) in b.linears_mut() {
                    if lin.act_quant.is_none() {
                        lin.act_quant = Some(ActQuant { bits: act_bits, scale: Vec::new() });
                    }
                }
            }
        }
        model.ensure_engines();
        let metrics = Arc::new(Metrics::new());
        metrics.act_bits.store(act_bits as u64, Ordering::Relaxed);
        let (tx, rx): (Sender<GenRequest>, Receiver<GenRequest>) = channel();
        let m = metrics.clone();
        let ServerOptions {
            max_batch,
            batch_wait,
            seed,
            prefill_chunk,
            stop,
            kv_block,
            kv_pool_blocks,
            kv_bits,
            kv_local_window,
            qos,
            deadline_ms,
            tenant_deadline_ms,
            faults,
            mut spec,
            ..
        } = opts;
        if let Some(s) = spec.as_mut() {
            s.draft.ensure_engines();
            metrics.set_spec(&s.tag, s.k);
        }
        let pool_cfg = PoolConfig {
            block_size: kv_block.max(1),
            budget_blocks: kv_pool_blocks,
            quant: KvQuantConfig { bits: kv_bits, local_window: kv_local_window },
        };
        let qos_state = Arc::new(QosState::new(qos));
        let drain = Arc::new(DrainSignal::default());
        let worker_qos = qos_state.clone();
        let worker_drain = drain.clone();
        let worker = std::thread::spawn(move || {
            if !faults.is_empty() {
                // Validated at config load; install is process-global,
                // doing it here just scopes it to server lifetime.
                if let Err(e) = crate::util::faultpoint::install(&faults) {
                    eprintln!("[serve] fault plan ignored: {e}");
                }
            }
            let mut rng = Rng::new(seed);
            let mut sched = Scheduler::with_qos(
                model,
                m.clone(),
                max_batch,
                prefill_chunk,
                pool_cfg,
                worker_qos,
            );
            if let Some(s) = spec {
                sched.set_spec(s.draft, s.k, s.max_k);
            }
            // Supervisor: round-level containment inside the scheduler
            // absorbs per-request faults; a panic that still unwinds
            // out of the loop means containment itself failed. Catch
            // it, recover the scheduler (in-flight slots answer
            // `Failed`, the pending queue survives untouched), back
            // off, and restart — up to `RESTART_BUDGET` times, after
            // which every remaining client is answered and the thread
            // exits (later submits see `WorkerGone`).
            let mut restarts = 0u32;
            loop {
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    worker_loop(&mut sched, &mut rng, &rx, &worker_drain, max_batch, batch_wait);
                }));
                match run {
                    Ok(()) => break,
                    Err(_) if restarts < RESTART_BUDGET => {
                        restarts += 1;
                        m.record_worker_restart();
                        sched.recover();
                        std::thread::sleep(Duration::from_millis(5u64 << restarts.min(8)));
                    }
                    Err(_) => {
                        sched.recover();
                        sched.cancel_pending();
                        break;
                    }
                }
            }
            // Clients that raced shutdown (or the restart-budget
            // exhaustion) and are still sitting in the channel get an
            // explicit response, not a dropped sender.
            while let Ok(req) = rx.try_recv() {
                sched.cancel_submitted(req);
            }
        });
        Ok(Server {
            tx: Mutex::new(Some(tx)),
            worker: Mutex::new(Some(worker)),
            drain,
            qos: qos_state,
            metrics,
            threads,
            stop,
            deadline_ms,
            tenant_deadline_ms,
        })
    }

    /// The QoS configuration this server runs with.
    pub fn qos(&self) -> &QosConfig {
        &self.qos.config
    }

    /// Submit a request with the server's default stop conditions;
    /// returns the response receiver, or [`ServeError::WorkerGone`] if
    /// the worker thread died (a poisoned model must not take down
    /// callers).
    pub fn submit(
        &self,
        prompt: Vec<u16>,
        max_new_tokens: usize,
        temperature: f64,
    ) -> Result<Receiver<GenResponse>, ServeError> {
        self.submit_with(prompt, max_new_tokens, temperature, self.stop.clone(), None)
    }

    /// Submit with per-token streaming delivery: returns the token
    /// stream (closed after the final token) and the response
    /// receiver.
    pub fn submit_streaming(
        &self,
        prompt: Vec<u16>,
        max_new_tokens: usize,
        temperature: f64,
    ) -> Result<(Receiver<u16>, Receiver<GenResponse>), ServeError> {
        self.submit_streaming_with(prompt, max_new_tokens, temperature, self.stop.clone())
    }

    /// [`Server::submit_streaming`] with explicit stop conditions.
    pub fn submit_streaming_with(
        &self,
        prompt: Vec<u16>,
        max_new_tokens: usize,
        temperature: f64,
        stop: StopSet,
    ) -> Result<(Receiver<u16>, Receiver<GenResponse>), ServeError> {
        let (stx, srx) = channel();
        let rrx = self.submit_with(prompt, max_new_tokens, temperature, stop, Some(stx))?;
        Ok((srx, rrx))
    }

    /// Fully-explicit submission: stop conditions and an optional
    /// streaming sender. Rides tenant 0.
    pub fn submit_with(
        &self,
        prompt: Vec<u16>,
        max_new_tokens: usize,
        temperature: f64,
        stop: StopSet,
        stream: Option<Sender<u16>>,
    ) -> Result<Receiver<GenResponse>, ServeError> {
        let (rrx, _cancel) =
            self.submit_indexed(0, prompt, max_new_tokens, temperature, stop, stream, None)?;
        Ok(rrx)
    }

    /// Tenant-attributed submission (the network front-end's entry
    /// point). `tenant` resolves against the QoS table; unknown ids
    /// ride tenant 0. `stop: None` uses the server default. Enforces
    /// the tenant's `max_pending` bound and the drain gate.
    pub fn submit_qos(
        &self,
        tenant: &str,
        prompt: Vec<u16>,
        max_new_tokens: usize,
        temperature: f64,
        stop: Option<StopSet>,
        stream: Option<Sender<u16>>,
    ) -> Result<Receiver<GenResponse>, ServeError> {
        let (rrx, _cancel) = self.submit_qos_cancellable(
            tenant,
            prompt,
            max_new_tokens,
            temperature,
            stop,
            stream,
            None,
        )?;
        Ok(rrx)
    }

    /// [`Server::submit_qos`] returning the request's [`CancelToken`]
    /// alongside the response receiver, with an optional explicit
    /// deadline. `deadline_ms: None` inherits the tenant default, then
    /// the global default; `Some(0)` explicitly disables the deadline.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_qos_cancellable(
        &self,
        tenant: &str,
        prompt: Vec<u16>,
        max_new_tokens: usize,
        temperature: f64,
        stop: Option<StopSet>,
        stream: Option<Sender<u16>>,
        deadline_ms: Option<u64>,
    ) -> Result<(Receiver<GenResponse>, CancelToken), ServeError> {
        let t = self.qos.config.tenant_index(tenant).unwrap_or(0);
        let stop = stop.unwrap_or_else(|| self.stop.clone());
        self.submit_indexed(t, prompt, max_new_tokens, temperature, stop, stream, deadline_ms)
    }

    #[allow(clippy::too_many_arguments)]
    fn submit_indexed(
        &self,
        t: usize,
        prompt: Vec<u16>,
        max_new_tokens: usize,
        temperature: f64,
        stop: StopSet,
        stream: Option<Sender<u16>>,
        deadline_ms: Option<u64>,
    ) -> Result<(Receiver<GenResponse>, CancelToken), ServeError> {
        if self.drain.draining() {
            return Err(ServeError::ShuttingDown);
        }
        let spec = &self.qos.config.tenants[t];
        if spec.max_pending > 0 && self.qos.queued_for(t) >= spec.max_pending as u64 {
            self.metrics.record_tenant_rejection(&spec.id);
            return Err(ServeError::TenantOverloaded { tenant: spec.id.clone() });
        }
        // Effective deadline: explicit beats the tenant default beats
        // the global default; 0 at any level means "none" there.
        let default_ms = self
            .tenant_deadline_ms
            .get(t)
            .copied()
            .filter(|&d| d > 0)
            .unwrap_or(self.deadline_ms);
        let ms = deadline_ms.unwrap_or(default_ms);
        let deadline = (ms > 0).then(|| Instant::now() + Duration::from_millis(ms));
        let cancel = CancelToken::new();
        let (rtx, rrx) = channel();
        let req = GenRequest {
            prompt,
            max_new_tokens,
            temperature,
            stop,
            stream,
            respond: rtx,
            submitted: Instant::now(),
            tenant: t as u32,
            deadline,
            cancel: cancel.clone(),
        };
        let guard = self.tx.lock().unwrap();
        let tx = guard.as_ref().ok_or(ServeError::WorkerGone)?;
        self.qos.queued[t].fetch_add(1, Ordering::Relaxed);
        if tx.send(req).is_err() {
            self.qos.note_dequeued(t);
            return Err(ServeError::WorkerGone);
        }
        self.metrics.record_request();
        Ok((rrx, cancel))
    }

    /// Graceful shutdown: close the queue and join the worker (which
    /// finishes everything already submitted first). Idempotent.
    pub fn shutdown(&self) {
        self.close_and_join();
    }

    /// Bounded drain: reject new submissions immediately, complete
    /// pending (unslotted) requests with [`FinishReason::Cancelled`]
    /// right away, let in-flight requests decode until `deadline`
    /// elapses, then cancel those too. Every accepted request gets a
    /// response before its streaming channel closes; the worker is
    /// joined before this returns.
    pub fn shutdown_within(&self, deadline: Duration) {
        self.drain.start(Some(Instant::now() + deadline));
        self.close_and_join();
    }

    fn close_and_join(&self) {
        drop(self.tx.lock().unwrap().take());
        let worker = self.worker.lock().unwrap().take();
        if let Some(w) = worker {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::qos::TenantSpec;
    use crate::model::transformer::tests::tiny_model;

    #[test]
    fn serves_single_request() {
        let server = Server::start(tiny_model(1, 4), 4, Duration::from_millis(1), 7);
        let rx = server.submit(vec![1, 2, 3], 5, 0.0).expect("submit");
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.prompt_len, 3);
        assert!(resp.tokens.len() > 3 && resp.tokens.len() <= 8);
        server.shutdown();
    }

    #[test]
    fn serves_concurrent_batch() {
        let server = Server::start(tiny_model(2, 4), 4, Duration::from_millis(20), 7);
        let rxs: Vec<_> =
            (0..4).map(|i| server.submit(vec![i as u16 + 1, 2], 4, 0.0).expect("submit")).collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.tokens.len() >= 3);
        }
        assert_eq!(server.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 4);
        assert!(server.metrics.mean_batch_size() >= 1.0);
        server.shutdown();
    }

    #[test]
    fn greedy_decode_deterministic() {
        let m = tiny_model(3, 4);
        let run = || {
            let server = Server::start(m.clone(), 1, Duration::from_millis(1), 7);
            let rx = server.submit(vec![5, 6, 7], 6, 0.0).expect("submit");
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            server.shutdown();
            r.tokens
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn batched_equals_single_request_greedy() {
        // The fused decode path must generate exactly what each request
        // would get served alone (greedy; per-request determinism).
        let m = tiny_model(9, 4);
        let prompts: Vec<Vec<u16>> = vec![vec![5, 6, 7], vec![1, 2], vec![9, 3, 4, 8], vec![12]];
        let solo: Vec<Vec<u16>> = prompts
            .iter()
            .map(|p| {
                let server = Server::start(m.clone(), 1, Duration::from_millis(1), 7);
                let rx = server.submit(p.clone(), 6, 0.0).expect("submit");
                let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
                server.shutdown();
                r.tokens
            })
            .collect();
        let server = Server::start(m.clone(), 4, Duration::from_millis(50), 7);
        let rxs: Vec<_> =
            prompts.iter().map(|p| server.submit(p.clone(), 6, 0.0).expect("submit")).collect();
        for (rx, expect) in rxs.into_iter().zip(solo) {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(r.tokens, expect);
        }
        server.shutdown();
    }

    #[test]
    fn records_per_phase_timing() {
        use std::sync::atomic::Ordering::Relaxed;
        let server = Server::start(tiny_model(4, 4), 2, Duration::from_millis(1), 7);
        let rx = server.submit(vec![1, 2, 3, 4], 4, 0.0).expect("submit");
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let produced = resp.tokens.len() - resp.prompt_len;
        let m = &server.metrics;
        assert_eq!(m.prefill_tokens.load(Relaxed), 4, "all prompt tokens prefilled");
        // Token 1 comes from the prefill logits; each further token is
        // one decode-round participation.
        assert_eq!(m.decode_tokens.load(Relaxed) as usize, produced - 1);
        server.shutdown();
    }

    #[test]
    fn start_validates_thread_count() {
        let server =
            Server::start_with_threads(tiny_model(5, 4), 1, Duration::from_millis(1), 7, 1_000_000);
        assert!(server.threads >= 1 && server.threads <= crate::util::parallel::MAX_THREADS);
        let rx = server.submit(vec![1, 2], 3, 0.0).expect("submit");
        assert!(rx.recv_timeout(Duration::from_secs(30)).is_ok());
        server.shutdown();
        // Restore auto so concurrently-running tests don't inherit the
        // clamped-but-huge count for the rest of the process.
        crate::util::parallel::set_threads(0);
    }

    #[test]
    fn serves_with_quantized_kv_cache() {
        use std::sync::atomic::Ordering::Relaxed;
        // kv_bits=4 with a small block + window: cold blocks really
        // re-encode mid-flight and the request still completes.
        let server = Server::start_with_opts(
            tiny_model(6, 4),
            ServerOptions {
                max_batch: 2,
                batch_wait: Duration::from_millis(1),
                seed: 7,
                kv_bits: 4,
                kv_local_window: 4,
                kv_block: 4,
                ..ServerOptions::default()
            },
        );
        let rx = server
            .submit_with(vec![1, 2, 3, 4, 5, 6, 7, 8], 12, 0.0, StopSet::none(), None)
            .expect("submit");
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.tokens.len() - r.prompt_len, 12);
        assert!(
            server.metrics.kv_quant_blocks_peak.load(Relaxed) >= 1,
            "cold blocks were quantized in flight"
        );
        assert!(server.metrics.kv_resident_peak_bytes.load(Relaxed) > 0);
        server.shutdown();
    }

    #[test]
    fn serves_with_act_bits_armed_and_reported() {
        use std::sync::atomic::Ordering::Relaxed;
        // act_bits=8 on a dense model: the knob plumbs through
        // (sanitized, reported in /metrics) and serving still
        // completes; dense engines simply stay on the f32 path.
        let server = Server::start_with_opts(
            tiny_model(6, 4),
            ServerOptions { act_bits: 8, ..ServerOptions::default() },
        );
        assert_eq!(server.metrics.act_bits.load(Relaxed), 8);
        assert!(server.metrics.summary().contains("act_bits=8"));
        let rx = server
            .submit_with(vec![1, 2, 3], 4, 0.0, StopSet::none(), None)
            .expect("submit");
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.tokens.len() - r.prompt_len, 4);
        server.shutdown();
        // Out-of-range widths sanitize at start, not in the worker.
        let server = Server::start_with_opts(
            tiny_model(6, 4),
            ServerOptions { act_bits: 12, ..ServerOptions::default() },
        );
        assert_eq!(server.metrics.act_bits.load(Relaxed), 8);
        server.shutdown();
    }

    #[test]
    fn stop_set_classification() {
        let s = StopSet::newline().with_eos(2).with_stop(7);
        assert_eq!(s.classify(2), Some(FinishReason::Eos));
        assert_eq!(s.classify(7), Some(FinishReason::Stop));
        assert_eq!(s.classify(b'\n' as u16), Some(FinishReason::Stop));
        assert_eq!(s.classify(1), None);
        assert_eq!(StopSet::none().classify(b'\n' as u16), None);
        // EOS wins when a token is in both sets.
        assert_eq!(StopSet::none().with_eos(7).with_stop(7).classify(7), Some(FinishReason::Eos));
    }

    #[test]
    fn submit_fails_after_worker_death_instead_of_panicking() {
        use std::sync::atomic::Ordering::Relaxed;
        // Token 999 is out of the tiny model's vocab (32): its forward
        // pass panics on the embedding lookup. Historical contract:
        // the worker died and later submits saw WorkerGone. New
        // contract: the panic is contained — the poisoned request gets
        // an explicit Failed response, the worker survives, and later
        // submits are served normally.
        let server = Server::start(tiny_model(7, 4), 2, Duration::from_millis(1), 7);
        let poisoned = server.submit(vec![999], 3, 0.0).expect("queue accepts the poison");
        let r = poisoned
            .recv_timeout(Duration::from_secs(30))
            .expect("poisoned request gets an explicit response, not a dropped channel");
        assert_eq!(r.finish, FinishReason::Failed);
        assert_eq!(r.tokens.len(), r.prompt_len, "no tokens generated past the fault");
        // The server survived and keeps serving.
        let rx = server.submit(vec![1, 2], 3, 0.0).expect("server is still alive");
        let ok = rx.recv_timeout(Duration::from_secs(30)).expect("healthy request completes");
        assert!(ok.tokens.len() > ok.prompt_len);
        assert!(server.metrics.panics_caught.load(Relaxed) >= 1);
        assert!(server.metrics.quarantines.load(Relaxed) >= 1);
        server.shutdown();
    }

    #[test]
    fn deadline_returns_partial_output() {
        use std::sync::atomic::Ordering::Relaxed;
        // A deliberately long generation with a short deadline: the
        // response arrives with whatever was decoded before the cut
        // and FinishReason::DeadlineExceeded — within one decode
        // round, not after max_new_tokens.
        let server = Server::start(tiny_model(2, 4), 2, Duration::from_millis(1), 7);
        let (rx, _cancel) = server
            .submit_qos_cancellable(
                "default",
                vec![1, 2, 3],
                400,
                0.0,
                Some(StopSet::none()),
                None,
                Some(80),
            )
            .expect("submit");
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("deadline forces a response");
        assert_eq!(r.finish, FinishReason::DeadlineExceeded);
        assert!(r.tokens.len() < 3 + 400, "partial output, not a full run");
        assert!(server.metrics.deadline_cancels.load(Relaxed) >= 1);
        server.shutdown();
    }

    #[test]
    fn cancel_token_stops_generation_between_rounds() {
        use std::sync::atomic::Ordering::Relaxed;
        // Cancel mid-generation (the disconnect path): the request
        // retires with Cancelled and partial output instead of
        // decoding to max_new_tokens.
        let server = Server::start(tiny_model(3, 4), 2, Duration::from_millis(1), 7);
        let (rx, cancel) = server
            .submit_qos_cancellable(
                "default",
                vec![4, 5],
                400,
                0.0,
                Some(StopSet::none()),
                None,
                None,
            )
            .expect("submit");
        std::thread::sleep(Duration::from_millis(50)); // let decoding start
        cancel.cancel();
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("cancel forces a response");
        assert_eq!(r.finish, FinishReason::Cancelled);
        assert!(r.tokens.len() < 2 + 400, "partial output, not a full run");
        assert!(server.metrics.disconnect_cancels.load(Relaxed) >= 1);
        server.shutdown();
    }

    #[test]
    fn invalid_qos_rejected_at_start_not_in_worker() {
        let mut opts = ServerOptions::default();
        opts.qos.tenants = vec![
            TenantSpec { id: "a".into(), weight: 1, priority: 0, max_pending: 0 },
            TenantSpec { id: "a".into(), weight: 1, priority: 0, max_pending: 0 },
        ];
        match Server::try_start_with_opts(tiny_model(1, 4), opts) {
            Err(ServeError::InvalidConfig(msg)) => assert!(msg.contains("duplicate"), "{msg}"),
            other => panic!("duplicate tenant id must be rejected, got {:?}", other.is_ok()),
        }
        let mut opts = ServerOptions::default();
        opts.qos.tenants[0].weight = 0;
        assert!(matches!(
            Server::try_start_with_opts(tiny_model(1, 4), opts),
            Err(ServeError::InvalidConfig(_))
        ));
    }

    #[test]
    fn tenant_bound_rejects_with_429_semantics() {
        // max_pending=1 and a long request hogging the single slot: the
        // submit path must shed load with TenantOverloaded, and the
        // rejection must be visible in the per-tenant metrics.
        let mut opts = ServerOptions {
            max_batch: 1,
            batch_wait: Duration::from_millis(1),
            seed: 7,
            ..ServerOptions::default()
        };
        opts.qos.tenants =
            vec![TenantSpec { id: "bounded".into(), weight: 1, priority: 0, max_pending: 1 }];
        let server = Server::start_with_opts(tiny_model(2, 4), opts);
        let first = server
            .submit_qos("bounded", vec![1, 2, 3], 64, 0.0, Some(StopSet::none()), None)
            .expect("first request accepted");
        // Saturate the pending bound: at most one more is accepted;
        // keep pushing until the bound trips (the scheduler may have
        // slotted earlier ones in between).
        let mut rejected = false;
        let mut accepted = vec![first];
        for _ in 0..50 {
            match server.submit_qos("bounded", vec![1, 2], 64, 0.0, Some(StopSet::none()), None) {
                Ok(rx) => accepted.push(rx),
                Err(ServeError::TenantOverloaded { tenant }) => {
                    assert_eq!(tenant, "bounded");
                    rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(rejected, "the max_pending bound must eventually shed load");
        assert!(server.metrics.tenant_rejected("bounded") >= 1);
        for rx in accepted {
            assert!(rx.recv_timeout(Duration::from_secs(60)).is_ok(), "accepted requests finish");
        }
        server.shutdown();
    }

    #[test]
    fn bounded_drain_cancels_and_never_blocks_clients() {
        // A deep queue of long generations, then shutdown_within a
        // short deadline: every client gets a response (some
        // Cancelled), every stream closes — nobody blocks forever.
        let server = Server::start_with_opts(
            tiny_model(8, 4),
            ServerOptions {
                max_batch: 2,
                batch_wait: Duration::from_millis(1),
                seed: 7,
                ..ServerOptions::default()
            },
        );
        let subs: Vec<_> = (0..6)
            .map(|i| {
                server
                    .submit_streaming_with(vec![i as u16 + 1, 2, 3], 400, 0.0, StopSet::none())
                    .expect("submit")
            })
            .collect();
        // Let generation actually start before draining.
        std::thread::sleep(Duration::from_millis(30));
        let t0 = Instant::now();
        server.shutdown_within(Duration::from_millis(50));
        assert!(t0.elapsed() < Duration::from_secs(20), "drain is bounded");
        let mut cancelled = 0;
        for (stream, rx) in subs {
            let r = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("every accepted request gets a response");
            if r.finish == FinishReason::Cancelled {
                cancelled += 1;
            }
            // The stream terminates (sender dropped after the
            // response): iterating must not block.
            let streamed: Vec<u16> = stream.try_iter().collect();
            assert_eq!(streamed.len(), r.tokens.len() - r.prompt_len);
        }
        assert!(cancelled >= 1, "a 400-token generation cannot finish in a 50ms drain");
        // Post-drain submissions are refused.
        assert!(matches!(
            server.submit(vec![1], 1, 0.0),
            Err(ServeError::ShuttingDown) | Err(ServeError::WorkerGone)
        ));
    }

    #[test]
    fn invalid_spec_rejected_at_start_not_in_worker() {
        let reject = |opts: ServerOptions, needle: &str| {
            match Server::try_start_with_opts(tiny_model(1, 4), opts) {
                Err(ServeError::InvalidConfig(msg)) => {
                    assert!(msg.contains(needle), "expected {needle:?} in {msg:?}")
                }
                other => panic!("{needle}: must be rejected, got ok={}", other.is_ok()),
            }
        };
        reject(
            ServerOptions {
                spec: Some(SpecConfig::new(tiny_model(1, 4), "d", 0, 4)),
                ..ServerOptions::default()
            },
            "spec_k",
        );
        reject(
            ServerOptions {
                spec: Some(SpecConfig::new(tiny_model(1, 4), "d", 4, 2)),
                ..ServerOptions::default()
            },
            "spec_max_k",
        );
        // A draft with a different shape (n_kv_head 2 vs 4) is a
        // config mismatch, not a mid-round panic.
        reject(
            ServerOptions {
                spec: Some(SpecConfig::new(tiny_model(1, 2), "d", 2, 4)),
                ..ServerOptions::default()
            },
            "shape mismatch",
        );
        // Speculation is incompatible with cold-KV quantization (it
        // would change *when* blocks go cold, breaking bit-identity).
        reject(
            ServerOptions {
                spec: Some(SpecConfig::new(tiny_model(1, 4), "d", 2, 4)),
                kv_bits: 4,
                ..ServerOptions::default()
            },
            "kv_bits",
        );
    }

    #[test]
    fn spec_load_surfaces_missing_file_as_config_error() {
        use crate::io::weights::ModelConfig;
        use crate::util::fixture::synth_raw_model;
        let cfg = ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layer: 2,
            n_head: 4,
            n_kv_head: 4,
            d_ff: 24,
            max_seq: 64,
            rope_theta: 10000.0,
        };
        let (raw, _) = synth_raw_model(3, cfg);
        let err = SpecConfig::load(Path::new("/nonexistent/draft.qlm"), &raw, 4, 8)
            .err()
            .expect("missing draft file must fail");
        match err {
            ServeError::InvalidConfig(msg) => assert!(msg.contains("draft_model"), "{msg}"),
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn spec_serving_matches_plain_and_reports() {
        use std::sync::atomic::Ordering::Relaxed;
        let m = tiny_model(9, 4);
        let prompts: Vec<Vec<u16>> = vec![vec![5, 6, 7], vec![1, 2], vec![9, 3, 4, 8]];
        let solo: Vec<Vec<u16>> = prompts
            .iter()
            .map(|p| {
                let server = Server::start(m.clone(), 1, Duration::from_millis(1), 7);
                let rx = server
                    .submit_with(p.clone(), 8, 0.0, StopSet::none(), None)
                    .expect("submit");
                let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
                server.shutdown();
                r.tokens
            })
            .collect();
        // Draft == target: every draft agrees, acceptance is maximal —
        // and the outputs must still be bit-identical to plain runs.
        let server = Server::start_with_opts(
            m.clone(),
            ServerOptions {
                max_batch: 2,
                batch_wait: Duration::from_millis(20),
                seed: 7,
                spec: Some(SpecConfig::new(m.clone(), "twin", 3, 6)),
                ..ServerOptions::default()
            },
        );
        assert!(server.metrics.summary().contains("spec=twin:k=3"), "{}", server.metrics.summary());
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| {
                server.submit_with(p.clone(), 8, 0.0, StopSet::none(), None).expect("submit")
            })
            .collect();
        for (rx, expect) in rxs.into_iter().zip(solo) {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(r.tokens, expect, "speculative output must be bit-identical");
        }
        assert!(server.metrics.spec_rounds.load(Relaxed) >= 1, "speculation actually ran");
        assert!(server.metrics.mean_spec_accepted() > 1.0, "agreeing draft accepts > 1/round");
        server.shutdown();
    }

    #[test]
    fn drop_mid_stream_never_leaves_client_blocked() {
        // Regression for the satellite: dropping the Server mid-stream
        // must close every client channel (the legacy full drain keeps
        // serving until done — but the client must never hang).
        let server = Server::start(tiny_model(3, 4), 1, Duration::from_millis(1), 7);
        let (stream, rx) = server.submit_streaming(vec![1, 2, 3], 32, 0.0).expect("submit");
        drop(server); // full drain + join
        let r = rx.recv_timeout(Duration::from_secs(60)).expect("response delivered");
        let streamed: Vec<u16> = stream.iter().collect(); // terminates: sender dropped
        assert_eq!(streamed.len(), r.tokens.len() - r.prompt_len);
    }
}
