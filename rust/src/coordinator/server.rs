//! The serving loop: a worker thread owns the (quantized) model and
//! processes dynamically-formed batches of generation requests;
//! clients submit via a channel handle and receive completed responses
//! on per-request channels.
//!
//! Decode is greedy (temperature 0) or softmax-sampled. Prefill runs
//! each prompt through the batched full-sequence path (one (s, d)
//! GEMM per linear, K/V appended to the request's cache); decode
//! rounds then stack the active requests' next tokens into one fused
//! [`Transformer::decode_batch`] forward per round, compacting the
//! active set as requests retire (continuous batching at token
//! granularity with no bubbles).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::collect_batch;
use super::metrics::Metrics;
use crate::model::kvcache::KvCache;
use crate::model::Transformer;
use crate::util::parallel;
use crate::util::rng::Rng;

/// A generation request.
#[derive(Debug)]
pub struct GenRequest {
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    pub temperature: f64,
    pub respond: Sender<GenResponse>,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub tokens: Vec<u16>,
    pub prompt_len: usize,
    pub latency: Duration,
}

/// Handle to a running server.
pub struct Server {
    tx: Option<Sender<GenRequest>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    /// Effective worker-thread count the kernels run with.
    pub threads: usize,
}

impl Server {
    /// Spawn the worker thread owning `model`, with the kernel thread
    /// count resolved automatically (`PALLAS_THREADS` env, else the
    /// hardware parallelism).
    pub fn start(model: Transformer, max_batch: usize, batch_wait: Duration, seed: u64) -> Server {
        Self::start_with_threads(model, max_batch, batch_wait, seed, 0)
    }

    /// [`Server::start`] with an explicit kernel thread count
    /// (`0` = keep the current global setting, resolving it if unset).
    /// The count is validated/clamped, and serving engines are
    /// prepared on any linear that lacks one, so callers can hand over
    /// a freshly-quantized model directly.
    pub fn start_with_threads(
        mut model: Transformer,
        max_batch: usize,
        batch_wait: Duration,
        seed: u64,
        threads: usize,
    ) -> Server {
        // 0 must not clobber a count a library user already set via
        // `parallel::set_threads` — only an explicit value overrides.
        let threads =
            if threads == 0 { parallel::threads() } else { parallel::set_threads(threads) };
        model.ensure_engines();
        let metrics = Arc::new(Metrics::new());
        let (tx, rx): (Sender<GenRequest>, Receiver<GenRequest>) = channel();
        let m = metrics.clone();
        let worker = std::thread::spawn(move || {
            let mut rng = Rng::new(seed);
            loop {
                let batch = collect_batch(&rx, max_batch, batch_wait);
                if batch.is_empty() {
                    break; // channel closed
                }
                m.record_batch(batch.len());
                run_batch(&model, batch, &m, &mut rng);
            }
        });
        Server { tx: Some(tx), worker: Some(worker), metrics, threads }
    }

    /// Submit a request; returns the response receiver.
    pub fn submit(&self, prompt: Vec<u16>, max_new_tokens: usize, temperature: f64) -> Receiver<GenResponse> {
        let (rtx, rrx) = channel();
        self.metrics.record_request();
        self.tx
            .as_ref()
            .expect("server stopped")
            .send(GenRequest { prompt, max_new_tokens, temperature, respond: rtx })
            .expect("server worker gone");
        rrx
    }

    /// Graceful shutdown: close the queue and join the worker.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// One in-flight request in the decode loop. Caches live in a parallel
/// `Vec<KvCache>` so [`Transformer::decode_batch`] sees a contiguous
/// slice.
struct Active {
    req: GenRequest,
    tokens: Vec<u16>,
    started: Instant,
    /// Next token to feed (sampled from the last logits).
    next: u16,
}

fn sample(logits: &[f32], temperature: f64, rng: &mut Rng) -> u16 {
    if logits.is_empty() {
        return 0;
    }
    if temperature <= 0.0 {
        // NaN-safe greedy: NaN logits are skipped (a NaN must never
        // panic the worker that owns the model), ties break low.
        return logits
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_nan())
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as u16)
            .unwrap_or(0);
    }
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let probs: Vec<f64> =
        logits.iter().map(|&v| (((v - max) as f64) / temperature).exp()).collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i as u16;
        }
    }
    (probs.len() - 1) as u16
}

fn finish(a: Active, metrics: &Metrics) {
    let produced = a.tokens.len() - a.req.prompt.len();
    let latency = a.started.elapsed();
    metrics.record_completion(produced, latency.as_micros() as u64);
    let _ = a.req.respond.send(GenResponse {
        tokens: a.tokens,
        prompt_len: a.req.prompt.len(),
        latency,
    });
}

fn run_batch(model: &Transformer, batch: Vec<GenRequest>, metrics: &Metrics, rng: &mut Rng) {
    let mut active: Vec<Active> = Vec::with_capacity(batch.len());
    let mut caches: Vec<KvCache> = Vec::with_capacity(batch.len());

    // Batched prefill: the full prompt in one sequence-level forward
    // per request (one GEMM per linear), K/V appended as it goes.
    // Latency clocks start at batch admission (queueing behind other
    // prefills in the batch counts, as it always did).
    let admitted = Instant::now();
    for req in batch {
        let cap = req.prompt.len() + req.max_new_tokens + 1;
        let mut cache = model.new_cache(cap);
        let t0 = Instant::now();
        let logits = model.prefill(&req.prompt, &mut cache);
        metrics.record_prefill(req.prompt.len(), t0.elapsed().as_micros() as u64);
        let next = sample(&logits, req.temperature, rng);
        active.push(Active { tokens: req.prompt.clone(), started: admitted, next, req });
        caches.push(cache);
    }

    // Fused decode: each round stacks every active request's token
    // into one (B, d) forward. Retired requests are swap-compacted out
    // (with their caches) so later rounds carry no bubbles.
    loop {
        let mut i = 0;
        while i < active.len() {
            let a = &mut active[i];
            a.tokens.push(a.next);
            let produced = a.tokens.len() - a.req.prompt.len();
            // '\n' ends a "sentence" in the tinywiki world.
            if produced >= a.req.max_new_tokens || a.next == b'\n' as u16 {
                finish(active.swap_remove(i), metrics);
                caches.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if active.is_empty() {
            break;
        }
        let toks: Vec<u16> = active.iter().map(|a| a.next).collect();
        let t0 = Instant::now();
        let logits = model.decode_batch(&toks, &mut caches);
        metrics.record_decode(toks.len(), t0.elapsed().as_micros() as u64);
        for (b, a) in active.iter_mut().enumerate() {
            a.next = sample(logits.row(b), a.req.temperature, rng);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::tests::tiny_model;

    #[test]
    fn serves_single_request() {
        let server = Server::start(tiny_model(1, 4), 4, Duration::from_millis(1), 7);
        let rx = server.submit(vec![1, 2, 3], 5, 0.0);
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.prompt_len, 3);
        assert!(resp.tokens.len() > 3 && resp.tokens.len() <= 8);
        server.shutdown();
    }

    #[test]
    fn serves_concurrent_batch() {
        let server = Server::start(tiny_model(2, 4), 4, Duration::from_millis(20), 7);
        let rxs: Vec<_> = (0..4).map(|i| server.submit(vec![i as u16 + 1, 2], 4, 0.0)).collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.tokens.len() >= 3);
        }
        assert_eq!(server.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 4);
        assert!(server.metrics.mean_batch_size() >= 1.0);
        server.shutdown();
    }

    #[test]
    fn greedy_decode_deterministic() {
        let m = tiny_model(3, 4);
        let run = || {
            let server = Server::start(m.clone(), 1, Duration::from_millis(1), 7);
            let rx = server.submit(vec![5, 6, 7], 6, 0.0);
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            server.shutdown();
            r.tokens
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn batched_equals_single_request_greedy() {
        // The fused decode path must generate exactly what each request
        // would get served alone (greedy; per-request determinism).
        let m = tiny_model(9, 4);
        let prompts: Vec<Vec<u16>> = vec![vec![5, 6, 7], vec![1, 2], vec![9, 3, 4, 8], vec![12]];
        let solo: Vec<Vec<u16>> = prompts
            .iter()
            .map(|p| {
                let server = Server::start(m.clone(), 1, Duration::from_millis(1), 7);
                let rx = server.submit(p.clone(), 6, 0.0);
                let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
                server.shutdown();
                r.tokens
            })
            .collect();
        let server = Server::start(m.clone(), 4, Duration::from_millis(50), 7);
        let rxs: Vec<_> = prompts.iter().map(|p| server.submit(p.clone(), 6, 0.0)).collect();
        for (rx, expect) in rxs.into_iter().zip(solo) {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(r.tokens, expect);
        }
        server.shutdown();
    }

    #[test]
    fn records_per_phase_timing() {
        use std::sync::atomic::Ordering::Relaxed;
        let server = Server::start(tiny_model(4, 4), 2, Duration::from_millis(1), 7);
        let rx = server.submit(vec![1, 2, 3, 4], 4, 0.0);
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let produced = resp.tokens.len() - resp.prompt_len;
        let m = &server.metrics;
        assert_eq!(m.prefill_tokens.load(Relaxed), 4, "all prompt tokens prefilled");
        // Token 1 comes from the prefill logits; each further token is
        // one decode-round participation.
        assert_eq!(m.decode_tokens.load(Relaxed) as usize, produced - 1);
        server.shutdown();
    }

    #[test]
    fn start_validates_thread_count() {
        let server =
            Server::start_with_threads(tiny_model(5, 4), 1, Duration::from_millis(1), 7, 1_000_000);
        assert!(server.threads >= 1 && server.threads <= crate::util::parallel::MAX_THREADS);
        let rx = server.submit(vec![1, 2], 3, 0.0);
        assert!(rx.recv_timeout(Duration::from_secs(30)).is_ok());
        server.shutdown();
        // Restore auto so concurrently-running tests don't inherit the
        // clamped-but-huge count for the rest of the process.
        crate::util::parallel::set_threads(0);
    }

    #[test]
    fn sampling_respects_temperature_zero() {
        let mut rng = Rng::new(1);
        let logits = vec![0.0f32, 5.0, 1.0];
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn greedy_sampling_survives_nan_logits() {
        let mut rng = Rng::new(1);
        // NaN must neither panic nor be selected.
        assert_eq!(sample(&[1.0, f32::NAN, 5.0, f32::NAN], 0.0, &mut rng), 2);
        // All-NaN and empty degenerate to token 0.
        assert_eq!(sample(&[f32::NAN, f32::NAN], 0.0, &mut rng), 0);
        assert_eq!(sample(&[], 0.0, &mut rng), 0);
        assert_eq!(sample(&[], 1.0, &mut rng), 0);
    }
}
