//! The serving loop: a worker thread owns the (quantized) model and
//! processes dynamically-formed batches of generation requests;
//! clients submit via a channel handle and receive completed responses
//! on per-request channels.
//!
//! Decode is greedy (temperature 0) or softmax-sampled. Prefill runs
//! per request through the incremental path (the KV cache); decode
//! steps for the batch are interleaved round-robin so short requests
//! retire early (continuous batching at token granularity).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::collect_batch;
use super::metrics::Metrics;
use crate::model::Transformer;
use crate::util::rng::Rng;

/// A generation request.
#[derive(Debug)]
pub struct GenRequest {
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    pub temperature: f64,
    pub respond: Sender<GenResponse>,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub tokens: Vec<u16>,
    pub prompt_len: usize,
    pub latency: Duration,
}

/// Handle to a running server.
pub struct Server {
    tx: Option<Sender<GenRequest>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Spawn the worker thread owning `model`.
    pub fn start(model: Transformer, max_batch: usize, batch_wait: Duration, seed: u64) -> Server {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx): (Sender<GenRequest>, Receiver<GenRequest>) = channel();
        let m = metrics.clone();
        let worker = std::thread::spawn(move || {
            let mut rng = Rng::new(seed);
            loop {
                let batch = collect_batch(&rx, max_batch, batch_wait);
                if batch.is_empty() {
                    break; // channel closed
                }
                m.record_batch(batch.len());
                run_batch(&model, batch, &m, &mut rng);
            }
        });
        Server { tx: Some(tx), worker: Some(worker), metrics }
    }

    /// Submit a request; returns the response receiver.
    pub fn submit(&self, prompt: Vec<u16>, max_new_tokens: usize, temperature: f64) -> Receiver<GenResponse> {
        let (rtx, rrx) = channel();
        self.metrics.record_request();
        self.tx
            .as_ref()
            .expect("server stopped")
            .send(GenRequest { prompt, max_new_tokens, temperature, respond: rtx })
            .expect("server worker gone");
        rrx
    }

    /// Graceful shutdown: close the queue and join the worker.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

struct Active {
    req: GenRequest,
    cache: crate::model::kvcache::KvCache,
    tokens: Vec<u16>,
    started: Instant,
    done: bool,
}

fn sample(logits: &[f32], temperature: f64, rng: &mut Rng) -> u16 {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u16)
            .unwrap_or(0);
    }
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let probs: Vec<f64> =
        logits.iter().map(|&v| (((v - max) as f64) / temperature).exp()).collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i as u16;
        }
    }
    (probs.len() - 1) as u16
}

fn run_batch(model: &Transformer, batch: Vec<GenRequest>, metrics: &Metrics, rng: &mut Rng) {
    let mut active: Vec<Active> = batch
        .into_iter()
        .map(|req| {
            let cap = req.prompt.len() + req.max_new_tokens + 1;
            Active {
                cache: model.new_cache(cap),
                tokens: req.prompt.clone(),
                started: Instant::now(),
                done: false,
                req,
            }
        })
        .collect();

    // Prefill (per request; the engine amortizes within the request).
    let mut next: Vec<u16> = Vec::with_capacity(active.len());
    for a in active.iter_mut() {
        let mut logits = Vec::new();
        for &t in &a.req.prompt {
            logits = model.decode_step(t, &mut a.cache);
        }
        next.push(sample(&logits, a.req.temperature, rng));
    }

    // Interleaved decode: one token per active request per round.
    loop {
        let mut any = false;
        for (i, a) in active.iter_mut().enumerate() {
            if a.done {
                continue;
            }
            a.tokens.push(next[i]);
            let produced = a.tokens.len() - a.req.prompt.len();
            // '\n' ends a "sentence" in the tinywiki world.
            if produced >= a.req.max_new_tokens || next[i] == b'\n' as u16 {
                a.done = true;
                let latency = a.started.elapsed();
                metrics.record_completion(produced, latency.as_micros() as u64);
                let _ = a.req.respond.send(GenResponse {
                    tokens: a.tokens.clone(),
                    prompt_len: a.req.prompt.len(),
                    latency,
                });
                continue;
            }
            let logits = model.decode_step(next[i], &mut a.cache);
            next[i] = sample(&logits, a.req.temperature, rng);
            any = true;
        }
        if !any {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::tests::tiny_model;

    #[test]
    fn serves_single_request() {
        let server = Server::start(tiny_model(1, 4), 4, Duration::from_millis(1), 7);
        let rx = server.submit(vec![1, 2, 3], 5, 0.0);
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.prompt_len, 3);
        assert!(resp.tokens.len() > 3 && resp.tokens.len() <= 8);
        server.shutdown();
    }

    #[test]
    fn serves_concurrent_batch() {
        let server = Server::start(tiny_model(2, 4), 4, Duration::from_millis(20), 7);
        let rxs: Vec<_> = (0..4).map(|i| server.submit(vec![i as u16 + 1, 2], 4, 0.0)).collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.tokens.len() >= 3);
        }
        assert_eq!(server.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 4);
        assert!(server.metrics.mean_batch_size() >= 1.0);
        server.shutdown();
    }

    #[test]
    fn greedy_decode_deterministic() {
        let m = tiny_model(3, 4);
        let run = || {
            let server = Server::start(m.clone(), 1, Duration::from_millis(1), 7);
            let rx = server.submit(vec![5, 6, 7], 6, 0.0);
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            server.shutdown();
            r.tokens
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn sampling_respects_temperature_zero() {
        let mut rng = Rng::new(1);
        let logits = vec![0.0f32, 5.0, 1.0];
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
    }
}
