//! Coordinator configuration, loaded from the TOML-subset config files
//! (`configs/*.toml`) with CLI overrides.

use std::path::Path;

use super::server::StopSet;
use crate::util::toml::{Doc, Value};

/// Serving + quantization deployment configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Model name (artifacts/<name>.bin).
    pub model: String,
    /// Quantization lane: any method-registry key ("fp16", "btc",
    /// "arb-llm", "stbllm", …; "binary" is kept as an alias for the
    /// ARB-LLM binary lane).
    pub backend: String,
    /// Bits target passed to the method preset.
    pub bits: f64,
    /// Max in-flight requests fused into one decode round.
    pub max_batch: usize,
    /// How long an idle worker lingers for co-arrivals (ms); once
    /// busy, admission between decode rounds never waits.
    pub batch_wait_ms: u64,
    /// Max prompt tokens prefilled per scheduling round, shared
    /// across newly-admitted requests (bounds how long new prompts
    /// stall in-flight decoders).
    pub prefill_chunk: usize,
    /// Per-request default max new tokens.
    pub max_new_tokens: usize,
    /// EOS token id; negative = no EOS.
    pub eos_token: i64,
    /// Stop-token ids (generation ends after emitting one).
    pub stop_tokens: Vec<u16>,
    /// Greedy (0) vs sampled decoding temperature.
    pub temperature: f64,
    pub seed: u64,
    /// Kernel worker threads (0 = auto: `PALLAS_THREADS` env, else the
    /// hardware parallelism). Validated/clamped at server start.
    pub threads: usize,
    /// Bits for cold KV-cache blocks (2..=8; >= 16 = off, pure f32 —
    /// the default, so existing configs are byte-for-byte unchanged).
    pub kv_bits: u32,
    /// Trailing positions kept f32 when `kv_bits` is active.
    pub kv_local_window: usize,
    /// KV-pool block size (positions per block).
    pub kv_block: usize,
    /// KV-pool budget in blocks (0 = auto: worst-case-equivalent
    /// capacity per in-flight slot, allocated lazily).
    pub kv_pool_blocks: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "tinylm_s".into(),
            backend: "btc".into(),
            bits: 0.8,
            max_batch: 8,
            batch_wait_ms: 5,
            prefill_chunk: 32,
            max_new_tokens: 32,
            eos_token: -1,
            stop_tokens: vec![b'\n' as u16],
            temperature: 0.0,
            seed: 42,
            threads: 0,
            kv_bits: 16,
            kv_local_window: 16,
            kv_block: 32,
            kv_pool_blocks: 0,
        }
    }
}

impl ServeConfig {
    /// Parse from a TOML doc (section `[serve]` + `[quant]`).
    pub fn from_doc(doc: &Doc) -> ServeConfig {
        let d = ServeConfig::default();
        ServeConfig {
            model: doc.get_str("serve.model", &d.model).to_string(),
            backend: doc.get_str("quant.backend", &d.backend).to_string(),
            bits: doc.get_float("quant.bits", d.bits),
            max_batch: doc.get_int("serve.max_batch", d.max_batch as i64) as usize,
            batch_wait_ms: doc.get_int("serve.batch_wait_ms", d.batch_wait_ms as i64) as u64,
            prefill_chunk: doc.get_int("serve.prefill_chunk", d.prefill_chunk as i64).max(1)
                as usize,
            max_new_tokens: doc.get_int("serve.max_new_tokens", d.max_new_tokens as i64) as usize,
            eos_token: doc.get_int("serve.eos_token", d.eos_token),
            stop_tokens: match doc.get("serve.stop_tokens") {
                Some(Value::Array(items)) => items
                    .iter()
                    .filter_map(|v| v.as_int())
                    .filter(|t| (0..=u16::MAX as i64).contains(t))
                    .map(|t| t as u16)
                    .collect(),
                _ => d.stop_tokens.clone(),
            },
            temperature: doc.get_float("serve.temperature", d.temperature),
            seed: doc.get_int("serve.seed", d.seed as i64) as u64,
            threads: doc.get_int("serve.threads", d.threads as i64).max(0) as usize,
            kv_bits: crate::quant::kvquant::KvQuantConfig::sanitize_bits(
                doc.get_int("serve.kv_bits", d.kv_bits as i64).max(0) as u32,
            ),
            kv_local_window: doc
                .get_int("serve.kv_local_window", d.kv_local_window as i64)
                .max(0) as usize,
            kv_block: doc.get_int("serve.kv_block", d.kv_block as i64).max(1) as usize,
            kv_pool_blocks: doc
                .get_int("serve.kv_pool_blocks", d.kv_pool_blocks as i64)
                .max(0) as usize,
        }
    }

    pub fn from_file(path: &Path) -> Result<ServeConfig, String> {
        Ok(Self::from_doc(&crate::util::toml::parse_file(path)?))
    }

    /// The stop conditions this config describes (EOS id + stop set).
    pub fn stop_set(&self) -> StopSet {
        let eos = if (0..=u16::MAX as i64).contains(&self.eos_token) {
            Some(self.eos_token as u16)
        } else {
            None
        };
        StopSet { eos, stops: self.stop_tokens.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml::parse;

    #[test]
    fn defaults_when_empty() {
        let c = ServeConfig::from_doc(&parse("").unwrap());
        assert_eq!(c.model, "tinylm_s");
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.prefill_chunk, 32);
        // Historical behavior: no EOS, '\n' in the stop set.
        assert_eq!(c.eos_token, -1);
        assert_eq!(c.stop_tokens, vec![b'\n' as u16]);
        let s = c.stop_set();
        assert_eq!(s.eos, None);
        assert_eq!(s.stops, vec![b'\n' as u16]);
    }

    #[test]
    fn stop_conditions_from_toml() {
        let doc = parse(
            "[serve]\nprefill_chunk = 8\neos_token = 2\nstop_tokens = [10, 46]\n",
        )
        .unwrap();
        let c = ServeConfig::from_doc(&doc);
        assert_eq!(c.prefill_chunk, 8);
        let s = c.stop_set();
        assert_eq!(s.eos, Some(2));
        assert_eq!(s.stops, vec![10, 46]);
        // Out-of-range ids are dropped, not wrapped.
        let doc = parse("[serve]\nstop_tokens = [70000, 5]\n").unwrap();
        assert_eq!(ServeConfig::from_doc(&doc).stop_tokens, vec![5]);
    }

    #[test]
    fn overrides_from_toml() {
        let doc = parse(
            "[serve]\nmodel = \"tinylm_m\"\nmax_batch = 4\nthreads = 3\n[quant]\nbackend = \"binary\"\nbits = 1.0\n",
        )
        .unwrap();
        let c = ServeConfig::from_doc(&doc);
        assert_eq!(c.model, "tinylm_m");
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.backend, "binary");
        assert_eq!(c.bits, 1.0);
        assert_eq!(c.threads, 3);
    }

    #[test]
    fn threads_defaults_to_auto() {
        let c = ServeConfig::from_doc(&parse("").unwrap());
        assert_eq!(c.threads, 0);
    }

    #[test]
    fn kv_quant_defaults_off_and_parses() {
        // Defaults: quantization off, auto pool — existing configs
        // behave exactly as before.
        let c = ServeConfig::from_doc(&parse("").unwrap());
        assert_eq!((c.kv_bits, c.kv_local_window), (16, 16));
        assert_eq!((c.kv_block, c.kv_pool_blocks), (32, 0));
        let doc = parse(
            "[serve]\nkv_bits = 4\nkv_local_window = 8\nkv_block = 16\nkv_pool_blocks = 256\n",
        )
        .unwrap();
        let c = ServeConfig::from_doc(&doc);
        assert_eq!((c.kv_bits, c.kv_local_window), (4, 8));
        assert_eq!((c.kv_block, c.kv_pool_blocks), (16, 256));
        // Out-of-range bits clamp instead of wrapping; the formatless
        // 9..=15 band snaps down to 8 rather than panicking the
        // worker at the first cold block; 0 means off (the auto/off
        // convention of threads/kv_pool_blocks), not int2.
        let c = ServeConfig::from_doc(&parse("[serve]\nkv_bits = 1\n").unwrap());
        assert_eq!(c.kv_bits, 2);
        let c = ServeConfig::from_doc(&parse("[serve]\nkv_bits = 12\n").unwrap());
        assert_eq!(c.kv_bits, 8);
        let c = ServeConfig::from_doc(&parse("[serve]\nkv_bits = 32\n").unwrap());
        assert_eq!(c.kv_bits, 16);
        let c = ServeConfig::from_doc(&parse("[serve]\nkv_bits = 0\n").unwrap());
        assert_eq!(c.kv_bits, 16);
    }
}
