//! Coordinator configuration, loaded from the TOML-subset config files
//! (`configs/*.toml`) with CLI overrides. Malformed serve/QoS tables
//! (bad listen address, zero tenant weight, duplicate tenant id,
//! mismatched `[tenants]` arrays) surface as `Err` here — at load
//! time — instead of panicking the worker thread later.

use std::path::Path;

use super::qos::{AdmitPolicy, EvictionKind, QosConfig, TenantSpec};
use super::server::StopSet;
use crate::util::toml::{Doc, Value};

/// Serving + quantization deployment configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Model name (artifacts/<name>.bin).
    pub model: String,
    /// Quantization lane: any method-registry key ("fp16", "btc",
    /// "arb-llm", "stbllm", …; "binary" is kept as an alias for the
    /// ARB-LLM binary lane).
    pub backend: String,
    /// Bits target passed to the method preset.
    pub bits: f64,
    /// Max in-flight requests fused into one decode round.
    pub max_batch: usize,
    /// How long an idle worker lingers for co-arrivals (ms); once
    /// busy, admission between decode rounds never waits.
    pub batch_wait_ms: u64,
    /// Max prompt tokens prefilled per scheduling round, shared
    /// across newly-admitted requests (bounds how long new prompts
    /// stall in-flight decoders).
    pub prefill_chunk: usize,
    /// Per-request default max new tokens.
    pub max_new_tokens: usize,
    /// EOS token id; negative = no EOS.
    pub eos_token: i64,
    /// Stop-token ids (generation ends after emitting one).
    pub stop_tokens: Vec<u16>,
    /// Greedy (0) vs sampled decoding temperature.
    pub temperature: f64,
    pub seed: u64,
    /// Kernel worker threads (0 = auto: `PALLAS_THREADS` env, else the
    /// hardware parallelism). Validated/clamped at server start.
    pub threads: usize,
    /// Bits for cold KV-cache blocks (2..=8; >= 16 = off, pure f32 —
    /// the default, so existing configs are byte-for-byte unchanged).
    pub kv_bits: u32,
    /// Activation bits at the engine boundary (2..=8 arms the per-row
    /// W1A8 integer lanes; >= 16 = off, f32 activations — the default,
    /// so existing configs are unchanged).
    pub act_bits: u32,
    /// Trailing positions kept f32 when `kv_bits` is active.
    pub kv_local_window: usize,
    /// KV-pool block size (positions per block).
    pub kv_block: usize,
    /// KV-pool budget in blocks (0 = auto: worst-case-equivalent
    /// capacity per in-flight slot, allocated lazily).
    pub kv_pool_blocks: usize,
    /// TCP listen address for the network front-end (`[serve] listen`,
    /// e.g. "127.0.0.1:8090"; port 0 = OS-assigned). `None` keeps the
    /// in-process-only server.
    pub listen: Option<String>,
    /// Pending-queue admission policy (`[serve] admission`): "fifo"
    /// (default, the PR 4/5 behavior) or "wrr".
    pub admission: AdmitPolicy,
    /// Preemption victim selection (`[serve] eviction`): "newest"
    /// (default), "lowest-priority", or "largest-kv".
    pub eviction: EvictionKind,
    /// Tenant table from `[tenants]` parallel arrays (`ids`,
    /// `weights`, `priorities`, `max_pending`); empty = the implicit
    /// single "default" tenant.
    pub tenants: Vec<TenantSpec>,
    /// Default wall-clock deadline per request in milliseconds
    /// (`[serve] deadline_ms`); 0 = no deadline. Requests past their
    /// deadline finish with partial output and
    /// `FinishReason::DeadlineExceeded`.
    pub deadline_ms: u64,
    /// Per-tenant deadline overrides from the `[tenants] deadline_ms`
    /// parallel array (0 = inherit the global `deadline_ms`). Always
    /// the same length as `tenants`.
    pub tenant_deadline_ms: Vec<u64>,
    /// Fault-injection plan (`[serve] faults`), same grammar as the
    /// `PALLAS_FAULTS` env var (see `util::faultpoint`). Empty =
    /// disabled; production configs never set this.
    pub faults: String,
    /// Path to an autotuner TOML (`[serve] tuning_file`, written by
    /// `cargo bench --bench bench_autotune`). Loaded and applied at
    /// serve startup; empty = run with the compile-time defaults.
    /// An explicit `prefill_chunk` in this config wins over the file.
    pub tuning_file: String,
    /// Run the quick in-process microbench sweep at startup
    /// (`[serve] autotune`) and apply its winners. Applied *after*
    /// `tuning_file`, so it refines a stale file on new hardware.
    pub autotune: bool,
    /// Path to a QLM1 draft-model artifact (`[serve] draft_model`)
    /// for speculative decoding; empty = speculation off. The draft
    /// must share the target's raw checkpoint shape — a mismatch is a
    /// `ServeError::InvalidConfig` at start, not a mid-round panic.
    pub draft_model: String,
    /// Initial speculative draft length per round
    /// (`[serve] spec_k`; must be >= 1 when `draft_model` is set).
    pub spec_k: usize,
    /// Upper bound the adaptive policy may grow a slot's k to
    /// (`[serve] spec_max_k`; must be >= `spec_k`).
    pub spec_max_k: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "tinylm_s".into(),
            backend: "btc".into(),
            bits: 0.8,
            max_batch: 8,
            batch_wait_ms: 5,
            prefill_chunk: 32,
            max_new_tokens: 32,
            eos_token: -1,
            stop_tokens: vec![b'\n' as u16],
            temperature: 0.0,
            seed: 42,
            threads: 0,
            kv_bits: 16,
            act_bits: 16,
            kv_local_window: 16,
            kv_block: 32,
            kv_pool_blocks: 0,
            listen: None,
            admission: AdmitPolicy::Fifo,
            eviction: EvictionKind::Newest,
            tenants: Vec::new(),
            deadline_ms: 0,
            tenant_deadline_ms: Vec::new(),
            faults: String::new(),
            tuning_file: String::new(),
            autotune: false,
            draft_model: String::new(),
            spec_k: 4,
            spec_max_k: 8,
        }
    }
}

/// `[tenants]` is parallel scalar arrays (the TOML subset has no
/// table arrays): `ids` is required when the section is present;
/// `weights`/`priorities`/`max_pending` are optional but must match
/// `ids` in length when given.
fn parse_tenants(doc: &Doc) -> Result<(Vec<TenantSpec>, Vec<u64>), String> {
    let ids: Vec<String> = match doc.get("tenants.ids") {
        Some(Value::Array(items)) => {
            let mut out = Vec::new();
            for v in items {
                match v.as_str() {
                    Some(s) => out.push(s.to_string()),
                    None => return Err("[tenants] ids must be strings".into()),
                }
            }
            out
        }
        Some(_) => return Err("[tenants] ids must be an array of strings".into()),
        None => {
            for k in [
                "tenants.weights",
                "tenants.priorities",
                "tenants.max_pending",
                "tenants.deadline_ms",
            ] {
                if doc.get(k).is_some() {
                    return Err(format!("[tenants] has {k} but no ids array"));
                }
            }
            return Ok((Vec::new(), Vec::new()));
        }
    };
    let ints = |key: &str, default: i64| -> Result<Vec<i64>, String> {
        match doc.get(key) {
            Some(Value::Array(items)) => {
                if items.len() != ids.len() {
                    return Err(format!(
                        "[tenants] {key} has {} entries but ids has {}",
                        items.len(),
                        ids.len()
                    ));
                }
                items
                    .iter()
                    .map(|v| v.as_int().ok_or_else(|| format!("[tenants] {key} must be integers")))
                    .collect()
            }
            Some(_) => Err(format!("[tenants] {key} must be an array of integers")),
            None => Ok(vec![default; ids.len()]),
        }
    };
    let weights = ints("tenants.weights", 1)?;
    let priorities = ints("tenants.priorities", 0)?;
    let max_pending = ints("tenants.max_pending", 0)?;
    let deadline_ms = ints("tenants.deadline_ms", 0)?;
    let mut tenants = Vec::with_capacity(ids.len());
    for i in 0..ids.len() {
        if !(1..=u32::MAX as i64).contains(&weights[i]) {
            return Err(format!(
                "[tenants] tenant '{}' has weight {} (must be >= 1)",
                ids[i], weights[i]
            ));
        }
        if !(0..=u8::MAX as i64).contains(&priorities[i]) {
            return Err(format!(
                "[tenants] tenant '{}' has priority {} (must be 0..=255)",
                ids[i], priorities[i]
            ));
        }
        if max_pending[i] < 0 {
            return Err(format!(
                "[tenants] tenant '{}' has max_pending {} (must be >= 0; 0 = unbounded)",
                ids[i], max_pending[i]
            ));
        }
        if deadline_ms[i] < 0 {
            return Err(format!(
                "[tenants] tenant '{}' has deadline_ms {} (must be >= 0; 0 = inherit)",
                ids[i], deadline_ms[i]
            ));
        }
        tenants.push(TenantSpec {
            id: ids[i].clone(),
            weight: weights[i] as u32,
            priority: priorities[i] as u8,
            max_pending: max_pending[i] as usize,
        });
    }
    Ok((tenants, deadline_ms.iter().map(|&d| d as u64).collect()))
}

impl ServeConfig {
    /// Parse from a TOML doc (sections `[serve]`, `[quant]`,
    /// `[tenants]`). Structural QoS errors — unparseable listen
    /// address, bad policy name, malformed tenant table — are `Err`,
    /// not worker panics.
    pub fn from_doc(doc: &Doc) -> Result<ServeConfig, String> {
        let d = ServeConfig::default();
        let listen = match doc.get("serve.listen") {
            Some(v) => match v.as_str() {
                Some(s) => {
                    s.parse::<std::net::SocketAddr>()
                        .map_err(|e| format!("[serve] listen '{s}': {e}"))?;
                    Some(s.to_string())
                }
                None => return Err("[serve] listen must be a string address".into()),
            },
            None => None,
        };
        let admission = match doc.get("serve.admission") {
            Some(v) => {
                let s =
                    v.as_str().ok_or_else(|| "[serve] admission must be a string".to_string())?;
                AdmitPolicy::parse(s).map_err(|e| format!("[serve] admission: {e}"))?
            }
            None => d.admission,
        };
        let eviction = match doc.get("serve.eviction") {
            Some(v) => {
                let s =
                    v.as_str().ok_or_else(|| "[serve] eviction must be a string".to_string())?;
                EvictionKind::parse(s).map_err(|e| format!("[serve] eviction: {e}"))?
            }
            None => d.eviction,
        };
        let (tenants, tenant_deadline_ms) = parse_tenants(doc)?;
        let faults = match doc.get("serve.faults") {
            Some(v) => {
                let s = v.as_str().ok_or_else(|| "[serve] faults must be a string".to_string())?;
                // Validate the spec at load time without installing it;
                // installation happens at server start.
                crate::util::faultpoint::validate(s)
                    .map_err(|e| format!("[serve] faults: {e}"))?;
                s.to_string()
            }
            None => d.faults.clone(),
        };
        let cfg = ServeConfig {
            model: doc.get_str("serve.model", &d.model).to_string(),
            backend: doc.get_str("quant.backend", &d.backend).to_string(),
            bits: doc.get_float("quant.bits", d.bits),
            max_batch: doc.get_int("serve.max_batch", d.max_batch as i64) as usize,
            batch_wait_ms: doc.get_int("serve.batch_wait_ms", d.batch_wait_ms as i64) as u64,
            prefill_chunk: doc.get_int("serve.prefill_chunk", d.prefill_chunk as i64).max(1)
                as usize,
            max_new_tokens: doc.get_int("serve.max_new_tokens", d.max_new_tokens as i64) as usize,
            eos_token: doc.get_int("serve.eos_token", d.eos_token),
            stop_tokens: match doc.get("serve.stop_tokens") {
                Some(Value::Array(items)) => items
                    .iter()
                    .filter_map(|v| v.as_int())
                    .filter(|t| (0..=u16::MAX as i64).contains(t))
                    .map(|t| t as u16)
                    .collect(),
                _ => d.stop_tokens.clone(),
            },
            temperature: doc.get_float("serve.temperature", d.temperature),
            seed: doc.get_int("serve.seed", d.seed as i64) as u64,
            threads: doc.get_int("serve.threads", d.threads as i64).max(0) as usize,
            kv_bits: crate::quant::kvquant::KvQuantConfig::sanitize_bits(
                doc.get_int("serve.kv_bits", d.kv_bits as i64).max(0) as u32,
            ),
            act_bits: crate::quant::kvquant::KvQuantConfig::sanitize_bits(
                doc.get_int("serve.act_bits", d.act_bits as i64).max(0) as u32,
            ),
            kv_local_window: doc
                .get_int("serve.kv_local_window", d.kv_local_window as i64)
                .max(0) as usize,
            kv_block: doc.get_int("serve.kv_block", d.kv_block as i64).max(1) as usize,
            kv_pool_blocks: doc
                .get_int("serve.kv_pool_blocks", d.kv_pool_blocks as i64)
                .max(0) as usize,
            listen,
            admission,
            eviction,
            tenants,
            deadline_ms: doc.get_int("serve.deadline_ms", d.deadline_ms as i64).max(0) as u64,
            tenant_deadline_ms,
            faults,
            tuning_file: doc.get_str("serve.tuning_file", &d.tuning_file).to_string(),
            autotune: doc.get_bool("serve.autotune", d.autotune),
            draft_model: doc.get_str("serve.draft_model", &d.draft_model).to_string(),
            spec_k: doc.get_int("serve.spec_k", d.spec_k as i64).max(0) as usize,
            spec_max_k: doc.get_int("serve.spec_max_k", d.spec_max_k as i64).max(0) as usize,
        };
        // Speculation knobs are validated whenever a draft model is
        // configured, so a bad file fails at load time.
        if !cfg.draft_model.is_empty() {
            if cfg.spec_k == 0 {
                return Err("[serve] spec_k must be >= 1 when draft_model is set".into());
            }
            if cfg.spec_max_k < cfg.spec_k {
                return Err(format!(
                    "[serve] spec_max_k {} must be >= spec_k {}",
                    cfg.spec_max_k, cfg.spec_k
                ));
            }
        }
        // Semantic QoS validation (duplicate/empty ids) lives in
        // QosConfig::validate — run it here so a bad file fails at
        // load, not at Server start.
        cfg.qos_config().validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<ServeConfig, String> {
        Self::from_doc(&crate::util::toml::parse_file(path)?)
    }

    /// The stop conditions this config describes (EOS id + stop set).
    pub fn stop_set(&self) -> StopSet {
        let eos = if (0..=u16::MAX as i64).contains(&self.eos_token) {
            Some(self.eos_token as u16)
        } else {
            None
        };
        StopSet { eos, stops: self.stop_tokens.clone() }
    }

    /// The QoS policy bundle this config describes. An empty
    /// `[tenants]` table yields the implicit single "default" tenant,
    /// so single-tenant deployments never have to write one.
    pub fn qos_config(&self) -> QosConfig {
        let tenants = if self.tenants.is_empty() {
            vec![TenantSpec::new("default")]
        } else {
            self.tenants.clone()
        };
        QosConfig { admission: self.admission, eviction: self.eviction, tenants }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml::parse;

    fn from_str(s: &str) -> Result<ServeConfig, String> {
        ServeConfig::from_doc(&parse(s).unwrap())
    }

    #[test]
    fn defaults_when_empty() {
        let c = from_str("").unwrap();
        assert_eq!(c.model, "tinylm_s");
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.prefill_chunk, 32);
        // Historical behavior: no EOS, '\n' in the stop set.
        assert_eq!(c.eos_token, -1);
        assert_eq!(c.stop_tokens, vec![b'\n' as u16]);
        let s = c.stop_set();
        assert_eq!(s.eos, None);
        assert_eq!(s.stops, vec![b'\n' as u16]);
        // QoS defaults: no listener, FIFO, newest-slot eviction, the
        // implicit single tenant.
        assert_eq!(c.listen, None);
        assert_eq!(c.admission, AdmitPolicy::Fifo);
        assert_eq!(c.eviction, EvictionKind::Newest);
        assert!(c.tenants.is_empty());
        let q = c.qos_config();
        assert_eq!(q.tenants.len(), 1);
        assert_eq!(q.tenants[0].id, "default");
        q.validate().unwrap();
    }

    #[test]
    fn stop_conditions_from_toml() {
        let c = from_str("[serve]\nprefill_chunk = 8\neos_token = 2\nstop_tokens = [10, 46]\n")
            .unwrap();
        assert_eq!(c.prefill_chunk, 8);
        let s = c.stop_set();
        assert_eq!(s.eos, Some(2));
        assert_eq!(s.stops, vec![10, 46]);
        // Out-of-range ids are dropped, not wrapped.
        let c = from_str("[serve]\nstop_tokens = [70000, 5]\n").unwrap();
        assert_eq!(c.stop_tokens, vec![5]);
    }

    #[test]
    fn overrides_from_toml() {
        let c = from_str(
            "[serve]\nmodel = \"tinylm_m\"\nmax_batch = 4\nthreads = 3\n[quant]\nbackend = \"binary\"\nbits = 1.0\n",
        )
        .unwrap();
        assert_eq!(c.model, "tinylm_m");
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.backend, "binary");
        assert_eq!(c.bits, 1.0);
        assert_eq!(c.threads, 3);
    }

    #[test]
    fn tuning_knobs_parse_with_defaults() {
        let c = from_str("").unwrap();
        assert!(c.tuning_file.is_empty());
        assert!(!c.autotune);
        let c = from_str("[serve]\ntuning_file = \"tuning.toml\"\nautotune = true\n").unwrap();
        assert_eq!(c.tuning_file, "tuning.toml");
        assert!(c.autotune);
    }

    #[test]
    fn threads_defaults_to_auto() {
        let c = from_str("").unwrap();
        assert_eq!(c.threads, 0);
    }

    #[test]
    fn kv_quant_defaults_off_and_parses() {
        // Defaults: quantization off, auto pool — existing configs
        // behave exactly as before.
        let c = from_str("").unwrap();
        assert_eq!((c.kv_bits, c.kv_local_window), (16, 16));
        assert_eq!((c.kv_block, c.kv_pool_blocks), (32, 0));
        let c = from_str(
            "[serve]\nkv_bits = 4\nkv_local_window = 8\nkv_block = 16\nkv_pool_blocks = 256\n",
        )
        .unwrap();
        assert_eq!((c.kv_bits, c.kv_local_window), (4, 8));
        assert_eq!((c.kv_block, c.kv_pool_blocks), (16, 256));
        // Out-of-range bits clamp instead of wrapping; the formatless
        // 9..=15 band snaps down to 8 rather than panicking the
        // worker at the first cold block; 0 means off (the auto/off
        // convention of threads/kv_pool_blocks), not int2.
        assert_eq!(from_str("[serve]\nkv_bits = 1\n").unwrap().kv_bits, 2);
        assert_eq!(from_str("[serve]\nkv_bits = 12\n").unwrap().kv_bits, 8);
        assert_eq!(from_str("[serve]\nkv_bits = 32\n").unwrap().kv_bits, 16);
        assert_eq!(from_str("[serve]\nkv_bits = 0\n").unwrap().kv_bits, 16);
    }

    #[test]
    fn act_bits_defaults_off_and_sanitizes() {
        // Default off: existing configs keep f32 activations.
        assert_eq!(from_str("").unwrap().act_bits, 16);
        assert_eq!(from_str("[serve]\nact_bits = 8\n").unwrap().act_bits, 8);
        // Same clamp convention as kv_bits.
        assert_eq!(from_str("[serve]\nact_bits = 1\n").unwrap().act_bits, 2);
        assert_eq!(from_str("[serve]\nact_bits = 12\n").unwrap().act_bits, 8);
        assert_eq!(from_str("[serve]\nact_bits = 0\n").unwrap().act_bits, 16);
    }

    #[test]
    fn spec_knobs_parse_and_validate() {
        // Defaults: speculation off, ready-to-use k values.
        let c = from_str("").unwrap();
        assert!(c.draft_model.is_empty());
        assert_eq!((c.spec_k, c.spec_max_k), (4, 8));
        let c = from_str(
            "[serve]\ndraft_model = \"artifacts/tinylm_s.btc0.8.qlm\"\nspec_k = 3\nspec_max_k = 6\n",
        )
        .unwrap();
        assert_eq!(c.draft_model, "artifacts/tinylm_s.btc0.8.qlm");
        assert_eq!((c.spec_k, c.spec_max_k), (3, 6));
        // Invalid k values fail at load time — but only when a draft
        // model is actually configured.
        let e = from_str("[serve]\ndraft_model = \"d.qlm\"\nspec_k = 0\n").unwrap_err();
        assert!(e.contains("spec_k"), "{e}");
        let e =
            from_str("[serve]\ndraft_model = \"d.qlm\"\nspec_k = 5\nspec_max_k = 2\n").unwrap_err();
        assert!(e.contains("spec_max_k"), "{e}");
        assert!(from_str("[serve]\nspec_k = 0\n").is_ok());
    }

    #[test]
    fn listen_and_policies_parse() {
        let c = from_str(
            "[serve]\nlisten = \"127.0.0.1:0\"\nadmission = \"wrr\"\neviction = \"largest-kv\"\n",
        )
        .unwrap();
        assert_eq!(c.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(c.admission, AdmitPolicy::WeightedRoundRobin);
        assert_eq!(c.eviction, EvictionKind::LargestKv);
    }

    #[test]
    fn tenant_table_parses_with_defaults() {
        let c = from_str(
            "[tenants]\nids = [\"alice\", \"bob\", \"flood\"]\nweights = [2, 2, 1]\n\
             priorities = [0, 0, 1]\nmax_pending = [0, 0, 8]\n",
        )
        .unwrap();
        assert_eq!(c.tenants.len(), 3);
        assert_eq!(c.tenants[0].id, "alice");
        assert_eq!(c.tenants[2].weight, 1);
        assert_eq!(c.tenants[2].priority, 1);
        assert_eq!(c.tenants[2].max_pending, 8);
        assert_eq!(c.qos_config().tenants.len(), 3);
        // ids alone: weight 1, class 0, unbounded for everyone.
        let c = from_str("[tenants]\nids = [\"a\", \"b\"]\n").unwrap();
        assert_eq!(c.tenants[1].weight, 1);
        assert_eq!(c.tenants[1].priority, 0);
        assert_eq!(c.tenants[1].max_pending, 0);
    }

    #[test]
    fn deadlines_and_faults_parse() {
        // Defaults: no deadlines, no fault plan.
        let c = from_str("").unwrap();
        assert_eq!(c.deadline_ms, 0);
        assert!(c.tenant_deadline_ms.is_empty());
        assert!(c.faults.is_empty());
        // Global deadline plus per-tenant overrides (0 = inherit).
        let c = from_str(
            "[serve]\ndeadline_ms = 5000\n[tenants]\nids = [\"a\", \"b\"]\n\
             deadline_ms = [250, 0]\n",
        )
        .unwrap();
        assert_eq!(c.deadline_ms, 5000);
        assert_eq!(c.tenant_deadline_ms, vec![250, 0]);
        // Omitted per-tenant array defaults to all-inherit.
        let c = from_str("[tenants]\nids = [\"a\"]\n").unwrap();
        assert_eq!(c.tenant_deadline_ms, vec![0]);
        // A valid fault spec is carried through; a malformed one is a
        // load-time error, not a worker surprise.
        let c = from_str("[serve]\nfaults = \"kvpool.alloc=err%10;seed=3\"\n").unwrap();
        assert_eq!(c.faults, "kvpool.alloc=err%10;seed=3");
        let e = from_str("[serve]\nfaults = \"kvpool.alloc=frob@1\"\n").unwrap_err();
        assert!(e.contains("faults"), "{e}");
        // Negative per-tenant deadlines and orphan arrays are errors.
        assert!(from_str("[tenants]\nids = [\"a\"]\ndeadline_ms = [-1]\n").is_err());
        assert!(from_str("[tenants]\ndeadline_ms = [5]\n").is_err());
    }

    #[test]
    fn config_errors_surface_at_load_time() {
        // Bad listen address.
        let e = from_str("[serve]\nlisten = \"not-an-addr\"\n").unwrap_err();
        assert!(e.contains("listen"), "{e}");
        // Unknown policy names.
        assert!(from_str("[serve]\nadmission = \"lifo\"\n").is_err());
        assert!(from_str("[serve]\neviction = \"oldest\"\n").is_err());
        // Zero weight.
        let e = from_str("[tenants]\nids = [\"a\"]\nweights = [0]\n").unwrap_err();
        assert!(e.contains("weight"), "{e}");
        // Duplicate tenant id (semantic check via QosConfig).
        let e = from_str("[tenants]\nids = [\"a\", \"a\"]\n").unwrap_err();
        assert!(e.contains("duplicate"), "{e}");
        // Length mismatch between parallel arrays.
        let e = from_str("[tenants]\nids = [\"a\", \"b\"]\nweights = [1]\n").unwrap_err();
        assert!(e.contains("entries"), "{e}");
        // Satellite arrays without ids.
        assert!(from_str("[tenants]\nweights = [1]\n").is_err());
    }
}
