//! Coordinator configuration, loaded from the TOML-subset config files
//! (`configs/*.toml`) with CLI overrides.

use std::path::Path;

use crate::util::toml::Doc;

/// Serving + quantization deployment configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Model name (artifacts/<name>.bin).
    pub model: String,
    /// Quantization lane: any method-registry key ("fp16", "btc",
    /// "arb-llm", "stbllm", …; "binary" is kept as an alias for the
    /// ARB-LLM binary lane).
    pub backend: String,
    /// Bits target passed to the method preset.
    pub bits: f64,
    /// Max requests fused into one decode batch.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch (ms).
    pub batch_wait_ms: u64,
    /// Per-request default max new tokens.
    pub max_new_tokens: usize,
    /// Greedy (0) vs sampled decoding temperature.
    pub temperature: f64,
    pub seed: u64,
    /// Kernel worker threads (0 = auto: `PALLAS_THREADS` env, else the
    /// hardware parallelism). Validated/clamped at server start.
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "tinylm_s".into(),
            backend: "btc".into(),
            bits: 0.8,
            max_batch: 8,
            batch_wait_ms: 5,
            max_new_tokens: 32,
            temperature: 0.0,
            seed: 42,
            threads: 0,
        }
    }
}

impl ServeConfig {
    /// Parse from a TOML doc (section `[serve]` + `[quant]`).
    pub fn from_doc(doc: &Doc) -> ServeConfig {
        let d = ServeConfig::default();
        ServeConfig {
            model: doc.get_str("serve.model", &d.model).to_string(),
            backend: doc.get_str("quant.backend", &d.backend).to_string(),
            bits: doc.get_float("quant.bits", d.bits),
            max_batch: doc.get_int("serve.max_batch", d.max_batch as i64) as usize,
            batch_wait_ms: doc.get_int("serve.batch_wait_ms", d.batch_wait_ms as i64) as u64,
            max_new_tokens: doc.get_int("serve.max_new_tokens", d.max_new_tokens as i64) as usize,
            temperature: doc.get_float("serve.temperature", d.temperature),
            seed: doc.get_int("serve.seed", d.seed as i64) as u64,
            threads: doc.get_int("serve.threads", d.threads as i64).max(0) as usize,
        }
    }

    pub fn from_file(path: &Path) -> Result<ServeConfig, String> {
        Ok(Self::from_doc(&crate::util::toml::parse_file(path)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml::parse;

    #[test]
    fn defaults_when_empty() {
        let c = ServeConfig::from_doc(&parse("").unwrap());
        assert_eq!(c.model, "tinylm_s");
        assert_eq!(c.max_batch, 8);
    }

    #[test]
    fn overrides_from_toml() {
        let doc = parse(
            "[serve]\nmodel = \"tinylm_m\"\nmax_batch = 4\nthreads = 3\n[quant]\nbackend = \"binary\"\nbits = 1.0\n",
        )
        .unwrap();
        let c = ServeConfig::from_doc(&doc);
        assert_eq!(c.model, "tinylm_m");
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.backend, "binary");
        assert_eq!(c.bits, 1.0);
        assert_eq!(c.threads, 3);
    }

    #[test]
    fn threads_defaults_to_auto() {
        let c = ServeConfig::from_doc(&parse("").unwrap());
        assert_eq!(c.threads, 0);
    }
}
