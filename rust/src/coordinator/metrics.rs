//! Serving metrics: counters + latency reservoir, shared across the
//! coordinator threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub batches: AtomicU64,
    pub batch_size_sum: AtomicU64,
    /// Per-phase accounting: prompt tokens prefilled / decode forwards
    /// run, and the wall time spent in each phase.
    pub prefill_tokens: AtomicU64,
    pub prefill_us: AtomicU64,
    pub decode_tokens: AtomicU64,
    pub decode_us: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size_sum.fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn record_completion(&self, tokens: usize, latency_us: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens as u64, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().push(latency_us);
    }

    /// `tokens` prompt tokens prefilled in `us` wall-microseconds.
    pub fn record_prefill(&self, tokens: usize, us: u64) {
        self.prefill_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        self.prefill_us.fetch_add(us, Ordering::Relaxed);
    }

    /// One decode round producing `tokens` next-token logits in `us`.
    pub fn record_decode(&self, tokens: usize, us: u64) {
        self.decode_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        self.decode_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Mean prefill cost per prompt token (µs); 0 before any prefill.
    pub fn prefill_us_per_token(&self) -> f64 {
        let t = self.prefill_tokens.load(Ordering::Relaxed);
        if t == 0 {
            return 0.0;
        }
        self.prefill_us.load(Ordering::Relaxed) as f64 / t as f64
    }

    /// Mean decode cost per generated token (µs); 0 before any decode.
    pub fn decode_us_per_token(&self) -> f64 {
        let t = self.decode_tokens.load(Ordering::Relaxed);
        if t == 0 {
            return 0.0;
        }
        self.decode_us.load(Ordering::Relaxed) as f64 / t as f64
    }

    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let mut l = self.latencies_us.lock().unwrap().clone();
        if l.is_empty() {
            return 0;
        }
        l.sort_unstable();
        l[(((l.len() - 1) as f64) * p.clamp(0.0, 1.0)).round() as usize]
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} completed={} tokens={} batches={} mean_batch={:.2} p50={}us p99={}us \
             prefill={:.0}us/tok decode={:.0}us/tok",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.latency_percentile_us(0.5),
            self.latency_percentile_us(0.99),
            self.prefill_us_per_token(),
            self.decode_us_per_token(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(2);
        m.record_completion(10, 1000);
        m.record_completion(20, 3000);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.tokens_generated.load(Ordering::Relaxed), 30);
        assert_eq!(m.mean_batch_size(), 2.0);
        assert_eq!(m.latency_percentile_us(0.0), 1000);
        assert_eq!(m.latency_percentile_us(1.0), 3000);
        assert!(!m.summary().is_empty());
    }

    #[test]
    fn empty_percentile_is_zero() {
        assert_eq!(Metrics::new().latency_percentile_us(0.5), 0);
    }

    #[test]
    fn per_phase_rates() {
        let m = Metrics::new();
        assert_eq!(m.prefill_us_per_token(), 0.0);
        assert_eq!(m.decode_us_per_token(), 0.0);
        m.record_prefill(10, 500);
        m.record_prefill(10, 300);
        m.record_decode(4, 100);
        assert_eq!(m.prefill_us_per_token(), 40.0);
        assert_eq!(m.decode_us_per_token(), 25.0);
        assert!(m.summary().contains("prefill=40us/tok"));
    }
}
