//! Serving metrics: counters + bounded latency reservoirs, shared
//! across the coordinator threads. Besides end-to-end latency, the
//! scheduler records per-request queue wait (submit → slot admission),
//! time-to-first-token (submit → first generated token) and the
//! inter-token gaps between consecutive generated tokens — the
//! numbers that matter once admission is in-flight rather than
//! batch-to-completion. Reservoirs are capped (Algorithm R uniform
//! sampling) so a long-running server holds constant memory per
//! metric no matter how many tokens it serves.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::model::kvcache::KvPoolStats;
use crate::util::benchkit::percentile_sorted;

/// Per-reservoir sample cap: enough for stable p50/p95/p99 estimates,
/// constant memory for a server generating billions of tokens.
const RESERVOIR_CAP: usize = 4096;

/// Capacity-bounded uniform sample of a stream (Vitter's Algorithm R):
/// the first `RESERVOIR_CAP` observations are kept verbatim, then each
/// n-th observation replaces a random slot with probability cap/n, so
/// the retained set stays a uniform sample of everything offered.
#[derive(Debug, Default)]
struct Reservoir {
    samples: Vec<u64>,
    /// Total observations ever offered (>= samples.len()).
    seen: u64,
    /// Cheap deterministic LCG state for slot selection.
    lcg: u64,
}

impl Reservoir {
    fn offer(&mut self, v: u64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(v);
            return;
        }
        self.lcg =
            self.lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let j = (self.lcg >> 33) % self.seen;
        if (j as usize) < RESERVOIR_CAP {
            self.samples[j as usize] = v;
        }
    }
}

/// Shared metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    /// Activation bits at the engine boundary (16 = f32 activations,
    /// 2..=8 = per-row integer lanes armed). Set once at server start.
    pub act_bits: AtomicU64,
    /// Decode rounds run (continuous batching: one "batch" per round).
    pub batches: AtomicU64,
    pub batch_size_sum: AtomicU64,
    /// Per-phase accounting: prompt tokens prefilled / decode forwards
    /// run, and the wall time spent in each phase.
    pub prefill_tokens: AtomicU64,
    pub prefill_us: AtomicU64,
    pub decode_tokens: AtomicU64,
    pub decode_us: AtomicU64,
    /// Most requests ever simultaneously slotted (the concurrency the
    /// memory-aware admission actually sustained).
    pub peak_in_flight: AtomicU64,
    /// KV pool gauges, republished by the scheduler each round
    /// (`set_kv_pool`): the bounded block budget, current/peak blocks
    /// in use, measured resident bytes (f32 + quantized payloads, with
    /// a monotone peak), quantized-block count and prompt positions
    /// served from the prefix map.
    pub kv_blocks_total: AtomicU64,
    pub kv_blocks_in_use: AtomicU64,
    pub kv_blocks_peak: AtomicU64,
    pub kv_resident_bytes: AtomicU64,
    pub kv_resident_peak_bytes: AtomicU64,
    pub kv_quant_blocks: AtomicU64,
    /// Sticky: most quantized blocks ever resident at once (gauges
    /// drain to zero once requests retire; the peak proves the cold
    /// path ran).
    pub kv_quant_blocks_peak: AtomicU64,
    pub kv_shared_positions: AtomicU64,
    /// Counters: admissions parked for lack of free blocks, in-round
    /// allocation deferrals, and preemptions (newest slot evicted to
    /// let an older one grow).
    pub kv_admission_deferrals: AtomicU64,
    pub kv_round_deferrals: AtomicU64,
    pub kv_preemptions: AtomicU64,
    /// Fault-isolation counters (DESIGN.md §10): model-call panics
    /// caught by the scheduler's containment, slots quarantined with a
    /// `Failed` response, worker loops re-spawned by the supervisor,
    /// and requests reaped by their deadline or a client disconnect.
    pub panics_caught: AtomicU64,
    pub quarantines: AtomicU64,
    pub worker_restarts: AtomicU64,
    pub deadline_cancels: AtomicU64,
    pub disconnect_cancels: AtomicU64,
    /// Speculative decoding (DESIGN.md §13): draft/verify rounds run,
    /// draft tokens proposed, tokens accepted (agreed prefix + bonus
    /// token — plain decoding would count 1 per round), and slots
    /// degraded to plain decoding by a draft/verify fault.
    pub spec_rounds: AtomicU64,
    pub spec_drafted: AtomicU64,
    pub spec_accepted: AtomicU64,
    pub spec_degraded: AtomicU64,
    /// Configured initial draft depth k (0 = speculation off).
    pub spec_k: AtomicU64,
    /// Draft-model tag for the summary line ("off" until armed).
    spec_tag: Mutex<String>,
    /// Accepted tokens per spec round (p50/p95 in `summary()`).
    spec_accept_per_round: Mutex<Reservoir>,
    latencies_us: Mutex<Reservoir>,
    /// Submit → slot admission, one sample per request.
    queue_wait_us: Mutex<Reservoir>,
    /// Submit → first generated token, one sample per request.
    ttft_us: Mutex<Reservoir>,
    /// Gap between consecutive generated tokens, one sample per gap.
    itl_us: Mutex<Reservoir>,
    /// Per-tenant QoS breakdown, keyed by tenant id. Created lazily on
    /// first record; bounded by the configured tenant table (the
    /// scheduler clamps unknown indices into it), so the map cannot
    /// grow with attacker-supplied ids.
    per_tenant: Mutex<BTreeMap<String, TenantStats>>,
}

/// Per-tenant latency reservoirs + counters (DESIGN.md §9): the
/// fairness numbers the adversarial-mix bench gates on.
#[derive(Debug, Default)]
struct TenantStats {
    ttft_us: Reservoir,
    itl_us: Reservoir,
    queue_wait_us: Reservoir,
    completed: u64,
    /// Submissions bounced at the per-tenant pending bound (the wire
    /// layer's 429s).
    rejected: u64,
}

fn percentile_of(values: &Mutex<Reservoir>, p: f64) -> u64 {
    percentile_sorted(&sorted_clone(values), p)
}

/// One lock + one sort per reservoir, however many percentiles are
/// read from it afterwards (summary() reads several).
fn sorted_clone(values: &Mutex<Reservoir>) -> Vec<u64> {
    let mut v = values.lock().unwrap().samples.clone();
    v.sort_unstable();
    v
}

impl Metrics {
    pub fn new() -> Metrics {
        let m = Metrics::default();
        // 16 = activations stay f32 (the act_bits "off" convention).
        m.act_bits.store(16, Ordering::Relaxed);
        m
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// One decode round over `size` in-flight requests.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size_sum.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Request finished; returns its completion sequence number
    /// (0-based, server-global: finished-earlier means smaller).
    pub fn record_completion(&self, tokens: usize, latency_us: u64) -> u64 {
        let seq = self.completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens as u64, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().offer(latency_us);
        seq
    }

    /// Request admitted into an in-flight slot after `wait_us` in the
    /// queue.
    pub fn record_admission(&self, wait_us: u64) {
        self.queue_wait_us.lock().unwrap().offer(wait_us);
    }

    /// First generated token `us` after submission.
    pub fn record_ttft(&self, us: u64) {
        self.ttft_us.lock().unwrap().offer(us);
    }

    /// One inter-token gap of `us`.
    pub fn record_itl(&self, us: u64) {
        self.itl_us.lock().unwrap().offer(us);
    }

    /// `tokens` prompt tokens prefilled in `us` wall-microseconds.
    pub fn record_prefill(&self, tokens: usize, us: u64) {
        self.prefill_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        self.prefill_us.fetch_add(us, Ordering::Relaxed);
    }

    /// One decode round producing `tokens` next-token logits in `us`.
    pub fn record_decode(&self, tokens: usize, us: u64) {
        self.decode_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        self.decode_us.fetch_add(us, Ordering::Relaxed);
    }

    /// `n` requests are currently slotted (tracks the peak).
    pub fn record_in_flight(&self, n: usize) {
        self.peak_in_flight.fetch_max(n as u64, Ordering::Relaxed);
    }

    /// The queue head started a parked stretch because the pool lacks
    /// free blocks (one event per stretch, not per re-check).
    pub fn record_kv_admission_deferral(&self) {
        self.kv_admission_deferrals.fetch_add(1, Ordering::Relaxed);
    }

    /// A slot sat a round out waiting for pool memory.
    pub fn record_kv_round_deferral(&self) {
        self.kv_round_deferrals.fetch_add(1, Ordering::Relaxed);
    }

    /// The newest slot was evicted so an older one could grow.
    pub fn record_kv_preemption(&self) {
        self.kv_preemptions.fetch_add(1, Ordering::Relaxed);
    }

    /// A model-call panic was caught by the scheduler's containment.
    pub fn record_panic_caught(&self) {
        self.panics_caught.fetch_add(1, Ordering::Relaxed);
    }

    /// A slot was quarantined (its request answered `Failed`).
    pub fn record_quarantine(&self) {
        self.quarantines.fetch_add(1, Ordering::Relaxed);
    }

    /// The supervisor re-spawned the worker loop after a panic.
    pub fn record_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was reaped past its deadline (partial output sent).
    pub fn record_deadline_cancel(&self) {
        self.deadline_cancels.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was reaped because its client went away.
    pub fn record_disconnect_cancel(&self) {
        self.disconnect_cancels.fetch_add(1, Ordering::Relaxed);
    }

    /// Speculation armed at server start: draft tag + initial k for
    /// the startup log / `/metrics` summary.
    pub fn set_spec(&self, tag: &str, k: usize) {
        self.spec_k.store(k as u64, Ordering::Relaxed);
        *self.spec_tag.lock().unwrap() = tag.to_string();
    }

    /// One draft/verify round: `drafted` tokens proposed, `accepted`
    /// tokens emitted (agreed prefix + the bonus token).
    pub fn record_spec_round(&self, drafted: usize, accepted: usize) {
        self.spec_rounds.fetch_add(1, Ordering::Relaxed);
        self.spec_drafted.fetch_add(drafted as u64, Ordering::Relaxed);
        self.spec_accepted.fetch_add(accepted as u64, Ordering::Relaxed);
        self.spec_accept_per_round.lock().unwrap().offer(accepted as u64);
    }

    /// A slot fell back to plain decoding after a draft/verify fault.
    pub fn record_spec_degrade(&self) {
        self.spec_degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// Accepted-tokens-per-round percentile; 0 before any spec round.
    pub fn spec_accepted_percentile(&self, p: f64) -> u64 {
        percentile_of(&self.spec_accept_per_round, p)
    }

    /// Mean accepted tokens per spec round (plain decoding = 1.0 per
    /// decode round); 0 before any spec round.
    pub fn mean_spec_accepted(&self) -> f64 {
        let r = self.spec_rounds.load(Ordering::Relaxed);
        if r == 0 {
            return 0.0;
        }
        self.spec_accepted.load(Ordering::Relaxed) as f64 / r as f64
    }

    /// Republish the KV pool gauges (scheduler, once per round).
    pub fn set_kv_pool(&self, s: &KvPoolStats) {
        self.kv_blocks_total.store(s.budget_blocks as u64, Ordering::Relaxed);
        self.kv_blocks_in_use.store(s.blocks_in_use as u64, Ordering::Relaxed);
        self.kv_blocks_peak.store(s.peak_blocks as u64, Ordering::Relaxed);
        self.kv_resident_bytes.store(s.resident_bytes as u64, Ordering::Relaxed);
        self.kv_resident_peak_bytes.fetch_max(s.resident_bytes as u64, Ordering::Relaxed);
        self.kv_quant_blocks.store(s.quant_blocks as u64, Ordering::Relaxed);
        self.kv_quant_blocks_peak.fetch_max(s.quant_blocks as u64, Ordering::Relaxed);
        self.kv_shared_positions.store(s.shared_positions, Ordering::Relaxed);
    }

    fn with_tenant<R>(&self, tenant: &str, f: impl FnOnce(&mut TenantStats) -> R) -> R {
        let mut map = self.per_tenant.lock().unwrap();
        f(map.entry(tenant.to_string()).or_default())
    }

    /// Tenant `tenant`'s request admitted after `wait_us` queued.
    pub fn record_tenant_admission(&self, tenant: &str, wait_us: u64) {
        self.with_tenant(tenant, |t| t.queue_wait_us.offer(wait_us));
    }

    /// Tenant `tenant` saw its first generated token `us` after submit.
    pub fn record_tenant_ttft(&self, tenant: &str, us: u64) {
        self.with_tenant(tenant, |t| t.ttft_us.offer(us));
    }

    /// One inter-token gap for `tenant`.
    pub fn record_tenant_itl(&self, tenant: &str, us: u64) {
        self.with_tenant(tenant, |t| t.itl_us.offer(us));
    }

    /// A request of `tenant` retired.
    pub fn record_tenant_completion(&self, tenant: &str) {
        self.with_tenant(tenant, |t| t.completed += 1);
    }

    /// A submission of `tenant` bounced at its pending bound (429).
    pub fn record_tenant_rejection(&self, tenant: &str) {
        self.with_tenant(tenant, |t| t.rejected += 1);
    }

    fn tenant_percentile(&self, tenant: &str, p: f64, pick: impl Fn(&TenantStats) -> &Reservoir) -> u64 {
        let map = self.per_tenant.lock().unwrap();
        match map.get(tenant) {
            Some(t) => {
                let mut v = pick(t).samples.clone();
                v.sort_unstable();
                percentile_sorted(&v, p)
            }
            None => 0,
        }
    }

    /// Per-tenant TTFT percentile (µs); 0 for unknown tenants.
    pub fn tenant_ttft_percentile_us(&self, tenant: &str, p: f64) -> u64 {
        self.tenant_percentile(tenant, p, |t| &t.ttft_us)
    }

    /// Per-tenant inter-token-latency percentile (µs); 0 if unknown.
    pub fn tenant_itl_percentile_us(&self, tenant: &str, p: f64) -> u64 {
        self.tenant_percentile(tenant, p, |t| &t.itl_us)
    }

    /// Per-tenant queue-wait percentile (µs); 0 if unknown.
    pub fn tenant_queue_wait_percentile_us(&self, tenant: &str, p: f64) -> u64 {
        self.tenant_percentile(tenant, p, |t| &t.queue_wait_us)
    }

    /// Completed request count for `tenant`.
    pub fn tenant_completed(&self, tenant: &str) -> u64 {
        let map = self.per_tenant.lock().unwrap();
        map.get(tenant).map_or(0, |t| t.completed)
    }

    /// Bounced submission count for `tenant`.
    pub fn tenant_rejected(&self, tenant: &str) -> u64 {
        let map = self.per_tenant.lock().unwrap();
        map.get(tenant).map_or(0, |t| t.rejected)
    }

    /// One line per tenant with the QoS numbers; empty string when no
    /// tenant ever recorded anything (single-tenant legacy paths).
    pub fn tenant_summary(&self) -> String {
        let map = self.per_tenant.lock().unwrap();
        let mut out = String::new();
        for (id, t) in map.iter() {
            let mut ttft = t.ttft_us.samples.clone();
            ttft.sort_unstable();
            let mut itl = t.itl_us.samples.clone();
            itl.sort_unstable();
            let mut qw = t.queue_wait_us.samples.clone();
            qw.sort_unstable();
            if !out.is_empty() {
                out.push('\n');
            }
            out.push_str(&format!(
                "tenant={} completed={} rejected={} qwait_p50={}us ttft_p50={}us ttft_p95={}us \
                 itl_p50={}us itl_p95={}us",
                id,
                t.completed,
                t.rejected,
                percentile_sorted(&qw, 0.5),
                percentile_sorted(&ttft, 0.5),
                percentile_sorted(&ttft, 0.95),
                percentile_sorted(&itl, 0.5),
                percentile_sorted(&itl, 0.95),
            ));
        }
        out
    }

    /// Mean prefill cost per prompt token (µs); 0 before any prefill.
    pub fn prefill_us_per_token(&self) -> f64 {
        let t = self.prefill_tokens.load(Ordering::Relaxed);
        if t == 0 {
            return 0.0;
        }
        self.prefill_us.load(Ordering::Relaxed) as f64 / t as f64
    }

    /// Mean decode cost per generated token (µs); 0 before any decode.
    pub fn decode_us_per_token(&self) -> f64 {
        let t = self.decode_tokens.load(Ordering::Relaxed);
        if t == 0 {
            return 0.0;
        }
        self.decode_us.load(Ordering::Relaxed) as f64 / t as f64
    }

    /// End-to-end latency percentile (µs); 0 when empty.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        percentile_of(&self.latencies_us, p)
    }

    /// Queue-wait percentile (µs); 0 when empty.
    pub fn queue_wait_percentile_us(&self, p: f64) -> u64 {
        percentile_of(&self.queue_wait_us, p)
    }

    /// Time-to-first-token percentile (µs); 0 when empty.
    pub fn ttft_percentile_us(&self, p: f64) -> u64 {
        percentile_of(&self.ttft_us, p)
    }

    /// Inter-token-latency percentile (µs); 0 when empty.
    pub fn itl_percentile_us(&self, p: f64) -> u64 {
        percentile_of(&self.itl_us, p)
    }

    /// Mean decode-round width.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// `spec=` summary token: `off` until armed, else `tag:k=N`.
    pub fn spec_label(&self) -> String {
        let k = self.spec_k.load(Ordering::Relaxed);
        if k == 0 {
            return "off".to_string();
        }
        format!("{}:k={}", self.spec_tag.lock().unwrap(), k)
    }

    pub fn summary(&self) -> String {
        let lat = sorted_clone(&self.latencies_us);
        let ttft = sorted_clone(&self.ttft_us);
        let itl = sorted_clone(&self.itl_us);
        format!(
            "requests={} completed={} tokens={} rounds={} mean_batch={:.2} p50={}us p99={}us \
             qwait_p50={}us ttft_p50={}us ttft_p95={}us itl_p50={}us itl_p95={}us \
             prefill={:.0}us/tok decode={:.0}us/tok inflight_peak={} \
             kv_blocks={}/{} kv_blocks_peak={} kv_bytes={} kv_bytes_peak={} kv_quant_blocks={} \
             kv_shared_pos={} kv_defer={}+{} kv_preempt={} panics_caught={} quarantines={} \
             worker_restarts={} deadline_cancels={} disconnect_cancels={} \
             spec_rounds={} spec_drafted={} spec_accepted={} spec_acc_p50={} spec_acc_p95={} \
             spec_degraded={} act_bits={} simd={} spec={} gather_tile={} par_min_work={}",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            percentile_sorted(&lat, 0.5),
            percentile_sorted(&lat, 0.99),
            self.queue_wait_percentile_us(0.5),
            percentile_sorted(&ttft, 0.5),
            percentile_sorted(&ttft, 0.95),
            percentile_sorted(&itl, 0.5),
            percentile_sorted(&itl, 0.95),
            self.prefill_us_per_token(),
            self.decode_us_per_token(),
            self.peak_in_flight.load(Ordering::Relaxed),
            self.kv_blocks_in_use.load(Ordering::Relaxed),
            self.kv_blocks_total.load(Ordering::Relaxed),
            self.kv_blocks_peak.load(Ordering::Relaxed),
            self.kv_resident_bytes.load(Ordering::Relaxed),
            self.kv_resident_peak_bytes.load(Ordering::Relaxed),
            self.kv_quant_blocks.load(Ordering::Relaxed),
            self.kv_shared_positions.load(Ordering::Relaxed),
            self.kv_admission_deferrals.load(Ordering::Relaxed),
            self.kv_round_deferrals.load(Ordering::Relaxed),
            self.kv_preemptions.load(Ordering::Relaxed),
            self.panics_caught.load(Ordering::Relaxed),
            self.quarantines.load(Ordering::Relaxed),
            self.worker_restarts.load(Ordering::Relaxed),
            self.deadline_cancels.load(Ordering::Relaxed),
            self.disconnect_cancels.load(Ordering::Relaxed),
            self.spec_rounds.load(Ordering::Relaxed),
            self.spec_drafted.load(Ordering::Relaxed),
            self.spec_accepted.load(Ordering::Relaxed),
            self.spec_accepted_percentile(0.5),
            self.spec_accepted_percentile(0.95),
            self.spec_degraded.load(Ordering::Relaxed),
            self.act_bits.load(Ordering::Relaxed),
            crate::util::simd::active().name(),
            self.spec_label(),
            crate::util::autotune::gather_tile(),
            crate::util::parallel::par_min_work(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(2);
        assert_eq!(m.record_completion(10, 1000), 0);
        assert_eq!(m.record_completion(20, 3000), 1, "seq increases per completion");
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.tokens_generated.load(Ordering::Relaxed), 30);
        assert_eq!(m.mean_batch_size(), 2.0);
        assert_eq!(m.latency_percentile_us(0.0), 1000);
        assert_eq!(m.latency_percentile_us(1.0), 3000);
        assert!(!m.summary().is_empty());
    }

    #[test]
    fn empty_percentile_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(0.5), 0);
        assert_eq!(m.ttft_percentile_us(0.5), 0);
        assert_eq!(m.itl_percentile_us(0.5), 0);
        assert_eq!(m.queue_wait_percentile_us(0.5), 0);
    }

    #[test]
    fn per_phase_rates() {
        let m = Metrics::new();
        assert_eq!(m.prefill_us_per_token(), 0.0);
        assert_eq!(m.decode_us_per_token(), 0.0);
        m.record_prefill(10, 500);
        m.record_prefill(10, 300);
        m.record_decode(4, 100);
        assert_eq!(m.prefill_us_per_token(), 40.0);
        assert_eq!(m.decode_us_per_token(), 25.0);
        assert!(m.summary().contains("prefill=40us/tok"));
    }

    #[test]
    fn reservoir_stays_bounded_and_representative() {
        let mut r = Reservoir::default();
        for _ in 0..100_000 {
            r.offer(5);
        }
        assert_eq!(r.samples.len(), RESERVOIR_CAP, "capped at RESERVOIR_CAP");
        assert_eq!(r.seen, 100_000);
        assert!(r.samples.iter().all(|&v| v == 5), "uniform stream stays uniform");
        // Via the public surface: a million ITL samples cost constant
        // memory and the percentile still reflects the stream.
        let m = Metrics::new();
        for _ in 0..50_000 {
            m.record_itl(7);
        }
        assert_eq!(m.itl_percentile_us(0.5), 7);
    }

    #[test]
    fn kv_pool_gauges_and_counters() {
        let m = Metrics::new();
        m.record_in_flight(2);
        m.record_in_flight(5);
        m.record_in_flight(3);
        assert_eq!(m.peak_in_flight.load(Ordering::Relaxed), 5, "peak is monotone");
        m.record_kv_preemption();
        m.record_kv_admission_deferral();
        m.record_kv_round_deferral();
        let s1 = KvPoolStats {
            budget_blocks: 16,
            blocks_in_use: 7,
            peak_blocks: 9,
            resident_bytes: 4096,
            quant_blocks: 2,
            shared_positions: 12,
            ..KvPoolStats::default()
        };
        m.set_kv_pool(&s1);
        // Gauges track the latest snapshot; the bytes peak is sticky.
        let s2 = KvPoolStats { blocks_in_use: 3, resident_bytes: 1024, ..s1 };
        m.set_kv_pool(&s2);
        assert_eq!(m.kv_blocks_in_use.load(Ordering::Relaxed), 3);
        assert_eq!(m.kv_resident_bytes.load(Ordering::Relaxed), 1024);
        assert_eq!(m.kv_resident_peak_bytes.load(Ordering::Relaxed), 4096);
        let s = m.summary();
        assert!(s.contains("kv_blocks=3/16"), "summary carries pool gauges: {s}");
        assert!(s.contains("kv_preempt=1") && s.contains("kv_defer=1+1"), "{s}");
        assert!(s.contains("inflight_peak=5"), "{s}");
    }

    #[test]
    fn fault_counters_reach_the_summary() {
        let m = Metrics::new();
        m.record_panic_caught();
        m.record_quarantine();
        m.record_worker_restart();
        m.record_deadline_cancel();
        m.record_deadline_cancel();
        m.record_disconnect_cancel();
        let s = m.summary();
        assert!(s.contains("panics_caught=1"), "{s}");
        assert!(s.contains("quarantines=1"), "{s}");
        assert!(s.contains("worker_restarts=1"), "{s}");
        assert!(s.contains("deadline_cancels=2"), "{s}");
        assert!(s.contains("disconnect_cancels=1"), "{s}");
    }

    #[test]
    fn summary_reports_kernel_dispatch() {
        // The /metrics surface carries the active SIMD level and the
        // live tuning constants. Values are process-global (other
        // tests may transiently retune them), so only presence and
        // well-formedness are pinned here.
        let m = Metrics::new();
        let s = m.summary();
        let level = crate::util::simd::active().name();
        assert!(s.contains("act_bits=16"), "{s}");
        assert!(s.contains(&format!("simd={level}")), "{s}");
        assert!(s.contains("gather_tile="), "{s}");
        assert!(s.contains("par_min_work="), "{s}");
        m.act_bits.store(8, Ordering::Relaxed);
        assert!(m.summary().contains("act_bits=8"));
    }

    #[test]
    fn spec_counters_reservoir_and_label() {
        let m = Metrics::new();
        assert_eq!(m.spec_label(), "off");
        assert!(m.summary().contains("spec=off"), "{}", m.summary());
        assert_eq!(m.mean_spec_accepted(), 0.0);
        m.set_spec("btc-0.8", 4);
        assert_eq!(m.spec_label(), "btc-0.8:k=4");
        m.record_spec_round(4, 5);
        m.record_spec_round(4, 1);
        m.record_spec_round(2, 3);
        m.record_spec_degrade();
        assert_eq!(m.spec_rounds.load(Ordering::Relaxed), 3);
        assert_eq!(m.spec_drafted.load(Ordering::Relaxed), 10);
        assert_eq!(m.spec_accepted.load(Ordering::Relaxed), 9);
        assert_eq!(m.mean_spec_accepted(), 3.0);
        assert_eq!(m.spec_accepted_percentile(0.5), 3);
        assert_eq!(m.spec_accepted_percentile(1.0), 5);
        let s = m.summary();
        assert!(s.contains("spec=btc-0.8:k=4"), "{s}");
        assert!(s.contains("spec_rounds=3"), "{s}");
        assert!(s.contains("spec_drafted=10"), "{s}");
        assert!(s.contains("spec_accepted=9"), "{s}");
        assert!(s.contains("spec_acc_p50=3"), "{s}");
        assert!(s.contains("spec_degraded=1"), "{s}");
    }

    #[test]
    fn per_tenant_reservoirs_are_isolated() {
        let m = Metrics::new();
        m.record_tenant_admission("alice", 10);
        m.record_tenant_ttft("alice", 100);
        m.record_tenant_ttft("alice", 200);
        m.record_tenant_itl("alice", 7);
        m.record_tenant_completion("alice");
        m.record_tenant_ttft("flood", 9000);
        m.record_tenant_rejection("flood");
        m.record_tenant_rejection("flood");
        assert_eq!(m.tenant_ttft_percentile_us("alice", 1.0), 200);
        assert_eq!(m.tenant_ttft_percentile_us("flood", 0.5), 9000);
        assert_eq!(m.tenant_queue_wait_percentile_us("alice", 0.5), 10);
        assert_eq!(m.tenant_itl_percentile_us("alice", 0.5), 7);
        assert_eq!(m.tenant_completed("alice"), 1);
        assert_eq!(m.tenant_rejected("flood"), 2);
        // Unknown tenants read as zero, not panic.
        assert_eq!(m.tenant_ttft_percentile_us("nobody", 0.5), 0);
        assert_eq!(m.tenant_completed("nobody"), 0);
        let s = m.tenant_summary();
        assert!(s.contains("tenant=alice") && s.contains("tenant=flood"), "{s}");
        assert!(s.contains("rejected=2"), "{s}");
        // Global reservoirs are untouched by tenant recorders.
        assert_eq!(m.ttft_percentile_us(0.5), 0);
    }

    #[test]
    fn serving_latency_reservoirs() {
        let m = Metrics::new();
        m.record_admission(5);
        m.record_ttft(100);
        m.record_ttft(300);
        m.record_itl(10);
        m.record_itl(20);
        m.record_itl(90);
        assert_eq!(m.queue_wait_percentile_us(0.5), 5);
        assert_eq!(m.ttft_percentile_us(0.0), 100);
        assert_eq!(m.ttft_percentile_us(1.0), 300);
        assert_eq!(m.itl_percentile_us(0.5), 20);
        let s = m.summary();
        assert!(s.contains("ttft_p50=") && s.contains("itl_p50=") && s.contains("qwait_p50="));
    }
}
