//! Dependency-free TCP front-end: minimal HTTP/1.1 on [`std::net`]
//! bridging wire clients onto the in-process [`Server`] submission
//! surface (DESIGN.md §9). No external crates — the parser, the JSON
//! reader and the chunked/SSE writer are all here, small enough to
//! test exhaustively.
//!
//! **Wire protocol.**
//! - `POST /generate` with a JSON body:
//!   `{"prompt": [ids], "max_new": n, "temperature": t,
//!     "stop": [ids], "eos": id, "tenant": "name", "stream": bool,
//!     "deadline_ms": ms}`.
//!   Only `prompt` is required. With `"stream": true` (the default)
//!   the response is `Transfer-Encoding: chunked` server-sent events:
//!   one `data: {"token": id}` event per generated token the moment
//!   the scheduler accepts it, then a final
//!   `data: {"done": true, "finish": "...", "tokens": [...]}` event
//!   carrying the generated ids, then the terminal chunk. With
//!   `"stream": false` it is one JSON document with Content-Length.
//! - `GET /metrics` returns the global summary plus the per-tenant
//!   QoS lines; `GET /healthz` returns `ok`.
//!
//! **Backpressure contract.** The front-end buffers nothing per
//! tenant: admission control is entirely the server's submit path.
//! A tenant over its `max_pending` bound gets HTTP 429 immediately
//! ([`ServeError::TenantOverloaded`], with a `Retry-After` header so
//! well-behaved clients back off), a draining server 503, a dead
//! worker 500. Wire-layer abuse (oversized headers/body, malformed
//! request line, bad JSON) is a clean 4xx + close — never a panic,
//! never an unbounded buffer (pinned by the tests below).
//!
//! **Request lifecycle.** Every submission goes through
//! [`Server::submit_qos_cancellable`]: `deadline_ms` in the body (or
//! the server's configured default) bounds wall-clock time, and a
//! client that hangs up trips the request's `CancelToken` — streaming
//! connections when an SSE write fails, non-streaming ones via a
//! 0-byte socket probe between response polls — so generation stops
//! within one decode round instead of running to completion for
//! nobody. A request quarantined by the scheduler
//! (`finish: "failed"`, DESIGN.md §10) maps to HTTP 500 with the
//! usual JSON body on the non-streaming path.
//!
//! **Streaming bridge.** Each connection thread submits with a
//! [`std::sync::mpsc::Sender<u16>`] token channel — exactly the
//! in-process streaming surface — and forwards tokens to the socket
//! as SSE chunks, so a TCP client observes the same token ids in the
//! same order as an in-process `submit_streaming` caller (pinned
//! bit-identical in `rust/tests/serving.rs`).
//!
//! **Shutdown.** [`NetServer::shutdown`] stops the acceptor (a
//! self-connect unblocks `accept`), then runs the server's bounded
//! drain, then joins every connection thread: in-flight clients get
//! their final event (possibly `finish: "cancelled"`) and a closed
//! socket, never a hang.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::server::{FinishReason, GenResponse, ServeError, Server, StopSet};

/// Wire-layer tunables.
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Max bytes of request line + headers (431 beyond).
    pub max_header_bytes: usize,
    /// Max request body bytes (413 beyond) — bounds what one client
    /// can make the front-end buffer.
    pub max_body_bytes: usize,
    /// Default `max_new` when the request omits it.
    pub default_max_new: usize,
    /// Socket read poll interval: how often a blocked reader rechecks
    /// the shutdown flag.
    pub read_timeout: Duration,
}

impl Default for NetOptions {
    fn default() -> NetOptions {
        NetOptions {
            max_header_bytes: 8 * 1024,
            max_body_bytes: 1024 * 1024,
            default_max_new: 64,
            read_timeout: Duration::from_millis(200),
        }
    }
}

// ---------------------------------------------------------------------------
// HTTP request parsing: a pure incremental function over the bytes
// received so far, so partial reads at any split point are just
// "call it again with more bytes".
// ---------------------------------------------------------------------------

/// A parsed request (only what the routes need).
#[derive(Debug, Clone, PartialEq, Eq)]
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
}

/// Outcome of parsing the bytes received so far.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Parse {
    /// No complete request yet; read more and call again.
    NeedMore,
    /// Protocol violation: answer `status` and close.
    Bad { status: u16, reason: String },
    /// One complete request.
    Ready(HttpRequest),
}

fn bad(status: u16, reason: &str) -> Parse {
    Parse::Bad { status, reason: reason.to_string() }
}

/// Incremental HTTP/1.1 request parser. Pure: same bytes in, same
/// verdict out, no state between calls, no panics on any input.
fn parse_http(buf: &[u8], opts: &NetOptions) -> Parse {
    // Header section ends at the first CRLFCRLF.
    let head_end = match find(buf, b"\r\n\r\n") {
        Some(i) => i,
        None => {
            if buf.len() > opts.max_header_bytes {
                return bad(431, "header section too large");
            }
            return Parse::NeedMore;
        }
    };
    if head_end > opts.max_header_bytes {
        return bad(431, "header section too large");
    }
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(s) => s,
        Err(_) => return bad(400, "headers are not valid UTF-8"),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, path, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v), None) if !m.is_empty() => (m, p, v),
            _ => return bad(400, "malformed request line"),
        };
    if !path.starts_with('/') || !version.starts_with("HTTP/1.") {
        return bad(400, "malformed request line");
    }
    let mut content_length = 0usize;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return bad(400, "malformed header line");
        };
        if name.trim().eq_ignore_ascii_case("content-length") {
            match value.trim().parse::<usize>() {
                Ok(n) => content_length = n,
                Err(_) => return bad(400, "unparseable content-length"),
            }
        }
    }
    if content_length > opts.max_body_bytes {
        return bad(413, "body too large");
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + content_length {
        return Parse::NeedMore;
    }
    Parse::Ready(HttpRequest {
        method: method.to_string(),
        path: path.to_string(),
        body: buf[body_start..body_start + content_length].to_vec(),
    })
}

fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

// ---------------------------------------------------------------------------
// Minimal JSON: recursive descent, depth-capped, panic-free.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

const JSON_MAX_DEPTH: usize = 32;

struct JsonParser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, lit: &[u8]) -> bool {
        if self.b[self.pos..].starts_with(lit) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > JSON_MAX_DEPTH {
            return Err("nesting too deep".into());
        }
        self.ws();
        match self.peek() {
            None => Err("unexpected end of input".into()),
            Some(b'n') if self.eat(b"null") => Ok(Json::Null),
            Some(b't') if self.eat(b"true") => Ok(Json::Bool(true)),
            Some(b'f') if self.eat(b"false") => Ok(Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value(depth + 1)?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err("expected ',' or ']' in array".into()),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut kv = Vec::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(kv));
                }
                loop {
                    self.ws();
                    if self.peek() != Some(b'"') {
                        return Err("expected string key in object".into());
                    }
                    let k = self.string()?;
                    self.ws();
                    if self.peek() != Some(b':') {
                        return Err("expected ':' in object".into());
                    }
                    self.pos += 1;
                    kv.push((k, self.value(depth + 1)?));
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(kv));
                        }
                        _ => return Err("expected ',' or '}' in object".into()),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected byte 0x{c:02x}")),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        debug_assert_eq!(self.peek(), Some(b'"'));
        self.pos += 1;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = &self.b[self.pos + 1..self.pos + 5];
                            let s = std::str::from_utf8(hex)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(s, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a whole UTF-8 scalar, not a byte.
                    let rest = match std::str::from_utf8(&self.b[self.pos..]) {
                        Ok(s) => s,
                        Err(e) if e.valid_up_to() > 0 => {
                            // SAFETY-free fallback: only the valid prefix.
                            std::str::from_utf8(&self.b[self.pos..self.pos + e.valid_up_to()])
                                .unwrap_or("")
                        }
                        Err(_) => return Err("invalid UTF-8 in string".into()),
                    };
                    match rest.chars().next() {
                        Some(c) => {
                            out.push(c);
                            self.pos += c.len_utf8();
                        }
                        None => return Err("invalid UTF-8 in string".into()),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| "bad number")?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }
}

fn parse_json(bytes: &[u8]) -> Result<Json, String> {
    let mut p = JsonParser { b: bytes, pos: 0 };
    let v = p.value(0)?;
    p.ws();
    if p.pos != bytes.len() {
        return Err("trailing bytes after JSON value".into());
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// The /generate request body.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
struct GenerateSpec {
    tenant: String,
    prompt: Vec<u16>,
    max_new: usize,
    temperature: f64,
    /// `None` = the server's default stop set.
    stop: Option<StopSet>,
    stream: bool,
    /// `None` = the server's configured default deadline.
    deadline_ms: Option<u64>,
}

fn token_array(v: &Json, what: &str) -> Result<Vec<u16>, String> {
    let arr = v.as_arr().ok_or_else(|| format!("{what} must be an array of token ids"))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let n = item.as_f64().ok_or_else(|| format!("{what} must contain only numbers"))?;
        if n.fract() != 0.0 || !(0.0..=u16::MAX as f64).contains(&n) {
            return Err(format!("{what} ids must be integers in 0..=65535"));
        }
        out.push(n as u16);
    }
    Ok(out)
}

fn generate_spec(body: &[u8], opts: &NetOptions) -> Result<GenerateSpec, String> {
    let v = parse_json(body).map_err(|e| format!("invalid JSON body: {e}"))?;
    let prompt = token_array(v.get("prompt").ok_or("missing required field \"prompt\"")?, "prompt")?;
    if prompt.is_empty() {
        return Err("prompt must not be empty".into());
    }
    let max_new = match v.get("max_new") {
        Some(n) => {
            let n = n.as_f64().ok_or("max_new must be a number")?;
            if n.fract() != 0.0 || n < 1.0 || n > 1e9 {
                return Err("max_new must be an integer >= 1".into());
            }
            n as usize
        }
        None => opts.default_max_new,
    };
    let temperature = match v.get("temperature") {
        Some(t) => t.as_f64().ok_or("temperature must be a number")?,
        None => 0.0,
    };
    let stops = match v.get("stop") {
        Some(s) => Some(token_array(s, "stop")?),
        None => None,
    };
    let eos = match v.get("eos") {
        Some(e) => {
            let n = e.as_f64().ok_or("eos must be a number")?;
            if n.fract() != 0.0 || !(0.0..=u16::MAX as f64).contains(&n) {
                return Err("eos must be an integer in 0..=65535".into());
            }
            Some(n as u16)
        }
        None => None,
    };
    let stop = match (stops, eos) {
        (None, None) => None,
        (stops, eos) => Some(StopSet { eos, stops: stops.unwrap_or_default() }),
    };
    let tenant = match v.get("tenant") {
        Some(t) => t.as_str().ok_or("tenant must be a string")?.to_string(),
        None => "default".to_string(),
    };
    let stream = match v.get("stream") {
        Some(s) => s.as_bool().ok_or("stream must be a boolean")?,
        None => true,
    };
    let deadline_ms = match v.get("deadline_ms") {
        Some(d) => {
            let n = d.as_f64().ok_or("deadline_ms must be a number")?;
            if n.fract() != 0.0 || n < 1.0 || n > 1e12 {
                return Err("deadline_ms must be an integer >= 1".into());
            }
            Some(n as u64)
        }
        None => None,
    };
    Ok(GenerateSpec { tenant, prompt, max_new, temperature, stop, stream, deadline_ms })
}

// ---------------------------------------------------------------------------
// Response writing.
// ---------------------------------------------------------------------------

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

fn write_plain(w: &mut TcpStream, status: u16, body: &str) -> std::io::Result<()> {
    write_plain_with(w, status, "", body)
}

/// Like [`write_plain`] with extra response headers (each terminated
/// by `\r\n`), e.g. `Retry-After` on a 429.
fn write_plain_with(
    w: &mut TcpStream,
    status: u16,
    extra_headers: &str,
    body: &str,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: text/plain\r\n{}Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        status,
        reason_phrase(status),
        extra_headers,
        body.len(),
        body
    )?;
    w.flush()
}

/// Answer a refused submission. A 429 carries `Retry-After: 1` so a
/// well-behaved client backs off instead of hammering the tenant's
/// pending bound.
fn write_submit_err(w: &mut TcpStream, e: &ServeError) -> std::io::Result<()> {
    let status = submit_status(e);
    let extra = if status == 429 { "Retry-After: 1\r\n" } else { "" };
    write_plain_with(w, status, extra, &format!("{e}\n"))
}

fn write_json(w: &mut TcpStream, status: u16, body: &str) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        status,
        reason_phrase(status),
        body.len(),
        body
    )?;
    w.flush()
}

fn write_chunk(w: &mut TcpStream, data: &str) -> std::io::Result<()> {
    crate::fault_point!(
        "net.write",
        return Err(std::io::Error::new(ErrorKind::BrokenPipe, "injected fault: net.write"))
    );
    write!(w, "{:x}\r\n{}\r\n", data.len(), data)?;
    w.flush()
}

fn finish_str(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Length => "length",
        FinishReason::Stop => "stop",
        FinishReason::Eos => "eos",
        FinishReason::Cancelled => "cancelled",
        FinishReason::DeadlineExceeded => "deadline_exceeded",
        FinishReason::Failed => "failed",
    }
}

fn ids_json(ids: &[u16]) -> String {
    let mut s = String::from("[");
    for (i, id) in ids.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&id.to_string());
    }
    s.push(']');
    s
}

/// HTTP status for a refused submission.
fn submit_status(e: &ServeError) -> u16 {
    match e {
        ServeError::TenantOverloaded { .. } => 429,
        ServeError::ShuttingDown => 503,
        ServeError::WorkerGone | ServeError::InvalidConfig(_) => 500,
    }
}

// ---------------------------------------------------------------------------
// Connection handling.
// ---------------------------------------------------------------------------

/// Read one request (tolerating arbitrary read()-boundary splits),
/// route it, write the response. One request per connection
/// (`Connection: close`) — the protocol surface stays minimal.
fn handle_conn(server: &Server, mut stream: TcpStream, opts: &NetOptions, stop: &AtomicBool) {
    let _ = stream.set_read_timeout(Some(opts.read_timeout));
    let mut buf: Vec<u8> = Vec::new();
    let req = loop {
        match parse_http(&buf, opts) {
            Parse::Ready(r) => break r,
            Parse::Bad { status, reason } => {
                let _ = write_plain(&mut stream, status, &format!("{reason}\n"));
                return;
            }
            Parse::NeedMore => {}
        }
        if stop.load(Ordering::SeqCst) {
            return; // shutting down before a full request arrived
        }
        let mut tmp = [0u8; 4096];
        match stream.read(&mut tmp) {
            Ok(0) => return, // client closed mid-request
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => continue,
            Err(_) => return,
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/generate") => handle_generate(server, &mut stream, &req.body, opts),
        ("GET", "/healthz") => {
            let _ = write_plain(&mut stream, 200, "ok\n");
        }
        ("GET", "/metrics") => {
            let tenants = server.metrics.tenant_summary();
            let body = if tenants.is_empty() {
                format!("{}\n", server.metrics.summary())
            } else {
                format!("{}\n{}\n", server.metrics.summary(), tenants)
            };
            let _ = write_plain(&mut stream, 200, &body);
        }
        _ => {
            let _ = write_plain(&mut stream, 404, "not found\n");
        }
    }
}

fn handle_generate(server: &Server, stream: &mut TcpStream, body: &[u8], opts: &NetOptions) {
    let spec = match generate_spec(body, opts) {
        Ok(s) => s,
        Err(msg) => {
            let _ = write_plain(stream, 400, &format!("{msg}\n"));
            return;
        }
    };
    if spec.stream {
        let (stx, srx) = channel();
        let submitted = server.submit_qos_cancellable(
            &spec.tenant,
            spec.prompt,
            spec.max_new,
            spec.temperature,
            spec.stop,
            Some(stx),
            spec.deadline_ms,
        );
        let (rrx, cancel) = match submitted {
            Ok(pair) => pair,
            Err(e) => {
                let _ = write_submit_err(stream, &e);
                return;
            }
        };
        let mut client_gone = false;
        if write!(
            stream,
            "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
        )
        .and_then(|_| stream.flush())
        .is_err()
        {
            // Client gone before the response line: stop generating
            // for nobody, then drain below so the request's blocks
            // are provably released before the thread exits.
            client_gone = true;
            cancel.cancel();
        }
        loop {
            match srx.recv_timeout(Duration::from_millis(200)) {
                Ok(tok) => {
                    if !client_gone
                        && write_chunk(stream, &format!("data: {{\"token\":{tok}}}\n\n")).is_err()
                    {
                        // Keep draining the channel so the worker's
                        // sends never error into a closed buffer, but
                        // stop writing — and stop generating: a dead
                        // socket cancels the request between rounds.
                        client_gone = true;
                        cancel.cancel();
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // The stream sender is dropped only after the response is
        // delivered, so the final response is already here.
        if let Ok(r) = rrx.recv_timeout(Duration::from_secs(10)) {
            if !client_gone {
                let done = format!(
                    "data: {{\"done\":true,\"finish\":\"{}\",\"prompt_len\":{},\"tokens\":{}}}\n\n",
                    finish_str(r.finish),
                    r.prompt_len,
                    ids_json(&r.tokens[r.prompt_len..])
                );
                let _ = write_chunk(stream, &done);
                let _ = write!(stream, "0\r\n\r\n");
                let _ = stream.flush();
            }
        }
    } else {
        let submitted = server.submit_qos_cancellable(
            &spec.tenant,
            spec.prompt,
            spec.max_new,
            spec.temperature,
            spec.stop,
            None,
            spec.deadline_ms,
        );
        let (rrx, cancel) = match submitted {
            Ok(pair) => pair,
            Err(e) => {
                let _ = write_submit_err(stream, &e);
                return;
            }
        };
        // Poll the response channel, probing the socket between
        // polls: a 0-byte read means the client hung up, and tripping
        // the cancel token stops generation within one decode round
        // instead of running the request to completion for nobody.
        let r = loop {
            match rrx.recv_timeout(Duration::from_millis(100)) {
                Ok(r) => break r,
                Err(RecvTimeoutError::Timeout) => {
                    // Extra bytes before the response are not part of
                    // this one-request protocol and are ignored; only
                    // a 0-byte read (orderly close) or a hard socket
                    // error counts as the client leaving.
                    let mut probe = [0u8; 64];
                    let gone = match stream.read(&mut probe) {
                        Ok(n) => n == 0,
                        Err(e) => !matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut),
                    };
                    if gone {
                        cancel.cancel();
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    let _ = write_plain(stream, 500, "worker gone before responding\n");
                    return;
                }
            }
        };
        // A quarantined request (DESIGN.md §10) is a server-side
        // failure: surface it as 500, body still carrying the finish
        // reason and any partial output.
        let status = if r.finish == FinishReason::Failed { 500 } else { 200 };
        let _ = write_json(stream, status, &response_json(&r));
    }
}

fn response_json(r: &GenResponse) -> String {
    format!(
        "{{\"finish\":\"{}\",\"prompt_len\":{},\"tokens\":{}}}",
        finish_str(r.finish),
        r.prompt_len,
        ids_json(&r.tokens)
    )
}

// ---------------------------------------------------------------------------
// The listener.
// ---------------------------------------------------------------------------

/// The TCP front-end: an acceptor thread plus one thread per live
/// connection, all bridging onto a shared [`Server`].
pub struct NetServer {
    server: Arc<Server>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Mutex<Option<JoinHandle<()>>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8090"`; port 0 = OS-assigned,
    /// read back via [`NetServer::local_addr`]) and start accepting.
    /// A bad address is [`ServeError::InvalidConfig`] — reported here,
    /// not a panic in the acceptor thread.
    pub fn bind(server: Arc<Server>, addr: &str, opts: NetOptions) -> Result<NetServer, ServeError> {
        let sock: SocketAddr = addr
            .parse()
            .map_err(|e| ServeError::InvalidConfig(format!("listen address {addr:?}: {e}")))?;
        let listener = TcpListener::bind(sock)
            .map_err(|e| ServeError::InvalidConfig(format!("bind {sock}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| ServeError::InvalidConfig(format!("local_addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let acceptor = {
            let server = server.clone();
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break; // the shutdown self-connect lands here
                    }
                    let stream = match conn {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    // Reap finished handlers so a long-lived server
                    // doesn't accumulate dead JoinHandles.
                    {
                        let mut guard = conns.lock().unwrap();
                        let mut i = 0;
                        while i < guard.len() {
                            if guard[i].is_finished() {
                                let h = guard.swap_remove(i);
                                let _ = h.join();
                            } else {
                                i += 1;
                            }
                        }
                    }
                    let server = server.clone();
                    let stop = stop.clone();
                    let opts = opts.clone();
                    let h = std::thread::spawn(move || {
                        handle_conn(&server, stream, &opts, &stop);
                    });
                    conns.lock().unwrap().push(h);
                }
            })
        };
        Ok(NetServer {
            server,
            addr: local,
            stop,
            acceptor: Mutex::new(Some(acceptor)),
            conns,
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The server this front-end bridges onto.
    pub fn server(&self) -> &Arc<Server> {
        &self.server
    }

    /// Stop accepting, drain the engine within `drain`
    /// ([`Server::shutdown_within`]) and join every connection thread.
    /// In-flight clients get a final event (`finish: "cancelled"` past
    /// the deadline) and a closed socket. Idempotent.
    pub fn shutdown(&self, drain: Duration) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock accept() so the acceptor sees the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.lock().unwrap().take() {
            let _ = a.join();
        }
        self.server.shutdown_within(drain);
        let handles: Vec<JoinHandle<()>> = {
            let mut guard = self.conns.lock().unwrap();
            guard.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown(Duration::from_secs(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> NetOptions {
        NetOptions::default()
    }

    fn http(s: &str) -> Parse {
        parse_http(s.as_bytes(), &opts())
    }

    #[test]
    fn parses_a_complete_post() {
        let raw = "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        match http(raw) {
            Parse::Ready(r) => {
                assert_eq!(r.method, "POST");
                assert_eq!(r.path, "/generate");
                assert_eq!(r.body, b"abcd");
            }
            other => panic!("expected Ready, got {other:?}"),
        }
    }

    #[test]
    fn get_without_body_parses() {
        match http("GET /healthz HTTP/1.1\r\n\r\n") {
            Parse::Ready(r) => {
                assert_eq!(r.method, "GET");
                assert!(r.body.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_request_lines_are_400_not_panic() {
        for raw in [
            "\r\n\r\n",
            "GARBAGE\r\n\r\n",
            "GET\r\n\r\n",
            "GET /x\r\n\r\n",
            "GET /x HTTP/1.1 extra\r\n\r\n",
            "GET x HTTP/1.1\r\n\r\n",
            "GET /x SMTP/1.0\r\n\r\n",
            "GET /x HTTP/1.1\r\nno-colon-header\r\n\r\n",
            "GET /x HTTP/1.1\r\nContent-Length: banana\r\n\r\n",
        ] {
            match http(raw) {
                Parse::Bad { status, .. } => assert_eq!(status, 400, "{raw:?}"),
                other => panic!("{raw:?} must be Bad(400), got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_header_and_body_are_rejected() {
        let huge = format!("GET /x HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(9000));
        assert!(matches!(http(&huge), Parse::Bad { status: 431, .. }));
        // Oversized without a terminator yet: reject as soon as the
        // cap is exceeded — no unbounded buffering while waiting.
        let endless = format!("GET /x HTTP/1.1\r\nX-Pad: {}", "a".repeat(9000));
        assert!(matches!(http(&endless), Parse::Bad { status: 431, .. }));
        let big_body =
            format!("POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 10 * 1024 * 1024);
        assert!(matches!(http(&big_body), Parse::Bad { status: 413, .. }));
    }

    #[test]
    fn truncated_body_needs_more() {
        let raw = "POST /g HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        assert_eq!(http(raw), Parse::NeedMore);
        assert_eq!(http(""), Parse::NeedMore);
        assert_eq!(http("POST /g HT"), Parse::NeedMore);
    }

    #[test]
    fn byte_at_a_time_feed_matches_whole_buffer_parse() {
        // Property: for every split point, the incremental result is
        // NeedMore until the exact byte where the whole-buffer parse
        // completes, then identical — reads can split anywhere.
        let raw = "POST /generate HTTP/1.1\r\nContent-Length: 17\r\n\r\n{\"prompt\":[1,2,3]}";
        let raw = &raw[..raw.len() - 1]; // body is 17 bytes: drop the final '}' padding
        let full = parse_http(raw.as_bytes(), &opts());
        assert!(matches!(full, Parse::Ready(_)), "{full:?}");
        for cut in 0..raw.len() {
            let partial = parse_http(&raw.as_bytes()[..cut], &opts());
            assert_eq!(partial, Parse::NeedMore, "cut at {cut}");
        }
        assert_eq!(parse_http(raw.as_bytes(), &opts()), full);
    }

    #[test]
    fn fuzzish_inputs_never_panic() {
        // Deterministic pseudo-random byte soup through the parser:
        // any outcome is fine, panicking is not.
        let mut x: u64 = 0x9e3779b97f4a7c15;
        for len in 0..512usize {
            let mut bytes = Vec::with_capacity(len);
            for _ in 0..len {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                bytes.push((x >> 24) as u8);
            }
            let _ = parse_http(&bytes, &opts());
            let _ = parse_json(&bytes);
        }
    }

    #[test]
    fn json_values_parse() {
        let v = parse_json(br#"{"a": [1, 2.5, -3], "b": "x\n", "c": true, "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x\n"));
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("d"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
        assert_eq!(parse_json(br#""\u0041""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn json_rejects_garbage_cleanly() {
        for bad in [
            &b"{"[..],
            b"[1, 2",
            b"{\"a\" 1}",
            b"{\"a\": }",
            b"tru",
            b"\"unterminated",
            b"1 2",
            b"{\"a\":1} trailing",
            b"",
            b"\"\\u00\"",
            b"\"\\q\"",
        ] {
            assert!(parse_json(bad).is_err(), "{:?}", String::from_utf8_lossy(bad));
        }
        // Depth cap: 40 nested arrays exceed JSON_MAX_DEPTH.
        let deep = format!("{}1{}", "[".repeat(40), "]".repeat(40));
        assert!(parse_json(deep.as_bytes()).unwrap_err().contains("deep"));
    }

    #[test]
    fn generate_spec_defaults_and_validation() {
        let o = opts();
        let s = generate_spec(br#"{"prompt": [1, 2, 3]}"#, &o).unwrap();
        assert_eq!(s.prompt, vec![1, 2, 3]);
        assert_eq!(s.max_new, o.default_max_new);
        assert_eq!(s.temperature, 0.0);
        assert_eq!(s.stop, None, "no stop/eos fields = server default stop set");
        assert_eq!(s.tenant, "default");
        assert!(s.stream, "streaming is the default");
        assert_eq!(s.deadline_ms, None, "no deadline field = server default");
        let s = generate_spec(
            br#"{"prompt": [7], "max_new": 4, "temperature": 0.5, "stop": [10],
                 "eos": 2, "tenant": "alice", "stream": false, "deadline_ms": 250}"#,
            &o,
        )
        .unwrap();
        assert_eq!(s.max_new, 4);
        assert_eq!(s.temperature, 0.5);
        assert_eq!(s.stop, Some(StopSet { eos: Some(2), stops: vec![10] }));
        assert_eq!(s.tenant, "alice");
        assert!(!s.stream);
        assert_eq!(s.deadline_ms, Some(250));
        // Eos alone still builds a stop set.
        let s = generate_spec(br#"{"prompt": [7], "eos": 2}"#, &o).unwrap();
        assert_eq!(s.stop, Some(StopSet { eos: Some(2), stops: vec![] }));
        for bad in [
            &br#"{}"#[..],
            br#"{"prompt": []}"#,
            br#"{"prompt": "text"}"#,
            br#"{"prompt": [70000]}"#,
            br#"{"prompt": [1.5]}"#,
            br#"{"prompt": [-1]}"#,
            br#"{"prompt": [1], "max_new": 0}"#,
            br#"{"prompt": [1], "max_new": "lots"}"#,
            br#"{"prompt": [1], "stream": "yes"}"#,
            br#"{"prompt": [1], "tenant": 7}"#,
            br#"{"prompt": [1], "deadline_ms": 0}"#,
            br#"{"prompt": [1], "deadline_ms": 1.5}"#,
            br#"{"prompt": [1], "deadline_ms": "soon"}"#,
            br#"not json at all"#,
        ] {
            assert!(generate_spec(bad, &o).is_err(), "{:?}", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn wire_helpers_format_correctly() {
        assert_eq!(ids_json(&[1, 22, 333]), "[1,22,333]");
        assert_eq!(ids_json(&[]), "[]");
        assert_eq!(finish_str(FinishReason::Cancelled), "cancelled");
        assert_eq!(finish_str(FinishReason::DeadlineExceeded), "deadline_exceeded");
        assert_eq!(finish_str(FinishReason::Failed), "failed");
        assert_eq!(reason_phrase(429), "Too Many Requests");
        assert_eq!(
            submit_status(&ServeError::TenantOverloaded { tenant: "x".into() }),
            429
        );
        assert_eq!(submit_status(&ServeError::ShuttingDown), 503);
        assert_eq!(submit_status(&ServeError::WorkerGone), 500);
    }
}
