//! Rust port of the tinywiki PCFG generator
//! (`python/compile/corpus.py`). Used for serving-workload generation
//! and hermetic tests; training/eval read the artifacts files written
//! by the python side. Same SplitMix64 core, same grammar families.

use crate::util::rng::Rng;

pub const NOUNS: &[(&str, &str)] = &[
    ("cat", "cats"), ("dog", "dogs"), ("bird", "birds"), ("fox", "foxes"),
    ("cow", "cows"), ("frog", "frogs"), ("crab", "crabs"), ("hen", "hens"),
    ("rock", "rocks"), ("lamp", "lamps"), ("door", "doors"), ("cup", "cups"),
    ("box", "boxes"), ("car", "cars"), ("ship", "ships"), ("coin", "coins"),
];
pub const ANIMALS: &[&str] = &["cat", "dog", "bird", "fox", "cow", "frog", "crab", "hen"];
pub const VERBS: &[(&str, &str)] = &[
    ("runs", "run"), ("sleeps", "sleep"), ("jumps", "jump"),
    ("sings", "sing"), ("hides", "hide"), ("waits", "wait"),
    ("turns", "turn"), ("falls", "fall"),
];
pub const ADJS: &[&str] = &["big", "small", "red", "blue", "old", "new", "slow", "fast"];
pub const PLACES: &[&str] = &["barn", "lake", "hill", "road", "town", "yard", "cave", "dock"];
pub const NUMBER_WORDS: &[&str] = &["one", "two", "three", "four", "five", "six", "seven", "eight"];

pub fn is_animal(noun: &str) -> bool {
    ANIMALS.contains(&noun)
}

fn noun_phrase(rng: &mut Rng, plural: bool) -> String {
    let pair = rng.choice(NOUNS);
    let noun = if plural { pair.1 } else { pair.0 };
    if rng.uniform() < 0.4 {
        format!("the {} {}", rng.choice(ADJS), noun)
    } else {
        format!("the {noun}")
    }
}

pub fn sent_agreement(rng: &mut Rng) -> String {
    let plural = rng.uniform() < 0.5;
    let v = rng.choice(VERBS);
    let verb = if plural { v.1 } else { v.0 };
    format!("{} {} .", noun_phrase(rng, plural), verb)
}

pub fn sent_embedded(rng: &mut Rng) -> String {
    let plural = rng.uniform() < 0.5;
    let inner = rng.choice(NOUNS).0;
    let v = rng.choice(VERBS);
    let verb = if plural { v.1 } else { v.0 };
    let h = rng.choice(NOUNS);
    let head = if plural { h.1 } else { h.0 };
    format!("the {head} that sees the {inner} {verb} .")
}

pub fn sent_category(rng: &mut Rng) -> String {
    let noun = rng.choice(NOUNS).0;
    let kind = if is_animal(noun) { "animal" } else { "object" };
    format!("the {noun} is an {kind} .")
}

pub fn sent_place(rng: &mut Rng) -> String {
    let plural = rng.uniform() < 0.3;
    let v = rng.choice(VERBS);
    let verb = if plural { v.1 } else { v.0 };
    format!("{} {} near the {} .", noun_phrase(rng, plural), verb, rng.choice(PLACES))
}

pub fn sent_counting(rng: &mut Rng) -> String {
    let start = rng.below(4);
    let ln = 3 + rng.below(4);
    let mut parts: Vec<&str> = Vec::new();
    for w in NUMBER_WORDS.iter().skip(start).take(ln) {
        parts.push(w);
    }
    format!("{} .", parts.join(" "))
}

pub fn sent_induction(rng: &mut Rng) -> String {
    let a = rng.choice(NOUNS).0;
    let b = rng.choice(PLACES);
    let mid = rng.choice(ADJS);
    format!("{a} {b} {mid} {a} {b} .")
}

pub fn sent_brackets(rng: &mut Rng) -> String {
    let depth = 1 + rng.below(2);
    let letters = ["a", "b", "c", "d", "e", "f", "g", "h"];
    let mut out: Vec<&str> = Vec::new();
    for _ in 0..depth {
        out.push("(");
        out.push(letters[rng.below(8)]);
    }
    out.push(letters[rng.below(8)]);
    for _ in 0..depth {
        out.push(")");
    }
    format!("{} .", out.join(" "))
}

/// One random sentence, weighted as in the python generator.
pub fn sentence(rng: &mut Rng) -> String {
    let u = rng.uniform();
    let kinds: [(fn(&mut Rng) -> String, f64); 7] = [
        (sent_agreement, 0.30),
        (sent_embedded, 0.12),
        (sent_category, 0.15),
        (sent_place, 0.18),
        (sent_counting, 0.10),
        (sent_induction, 0.08),
        (sent_brackets, 0.07),
    ];
    let mut acc = 0.0;
    for (f, w) in kinds {
        acc += w;
        if u < acc {
            return f(rng);
        }
    }
    sent_agreement(rng)
}

/// Generate roughly `n_chars` of corpus text.
pub fn generate(n_chars: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    let mut parts = Vec::new();
    let mut total = 0;
    while total < n_chars {
        let s = sentence(&mut rng);
        total += s.len() + 1;
        parts.push(s);
    }
    parts.join("\n") + "\n"
}

/// Generate `n` prompt strings (sentence prefixes) for serving
/// workloads: the request trace the coordinator benches replay.
pub fn prompts(n: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let s = sentence(&mut rng);
            let words: Vec<&str> = s.split(' ').collect();
            let keep = 1 + rng.below(words.len().max(2) - 1);
            words[..keep].join(" ")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(generate(5_000, 7), generate(5_000, 7));
        assert_ne!(generate(5_000, 7), generate(5_000, 8));
    }

    #[test]
    fn ascii_only_and_terminated() {
        let text = generate(20_000, 42);
        assert!(text.bytes().all(|b| b < 128));
        for line in text.trim().lines() {
            assert!(line.ends_with('.'), "{line}");
        }
    }

    #[test]
    fn category_facts_consistent() {
        let text = generate(60_000, 42);
        for line in text.lines() {
            if line.contains(" is an animal") {
                let noun = line.split(' ').nth(1).unwrap();
                assert!(is_animal(noun), "{line}");
            }
        }
    }

    #[test]
    fn brackets_balanced() {
        let text = generate(60_000, 42);
        for line in text.lines() {
            if line.starts_with('(') {
                let mut depth = 0i32;
                for tok in line.split(' ') {
                    match tok {
                        "(" => depth += 1,
                        ")" => depth -= 1,
                        _ => {}
                    }
                    assert!(depth >= 0, "{line}");
                }
                assert_eq!(depth, 0, "{line}");
            }
        }
    }

    #[test]
    fn prompts_nonempty_and_distinct() {
        let ps = prompts(50, 1);
        assert_eq!(ps.len(), 50);
        assert!(ps.iter().all(|p| !p.is_empty()));
        let uniq: std::collections::HashSet<_> = ps.iter().collect();
        assert!(uniq.len() > 10); // overwhelmingly distinct
    }

    #[test]
    fn agreement_morphology_present() {
        let text = generate(60_000, 42);
        assert!(text.contains(" runs .") || text.contains(" runs near"));
        assert!(text.contains(" run .") || text.contains(" run near"));
    }
}
