//! Data substrate: byte-level tokenizer, the synthetic "tinywiki"
//! corpus generator (Rust port, used for serving workloads and tests;
//! the artifacts corpus from `python/compile/corpus.py` is the source
//! of truth for training/eval), and calibration-set sampling.

pub mod calib;
pub mod corpus;
pub mod tokenizer;

pub use tokenizer::ByteTokenizer;
