//! Byte-level tokenizer (vocab = 128 ASCII codepoints) — matches the
//! training-side tokenization in `python/compile/train.py`, which feeds
//! raw corpus bytes to the model.

/// Byte-level tokenizer over 7-bit ASCII.
#[derive(Debug, Clone)]
pub struct ByteTokenizer {
    pub vocab_size: usize,
}

impl Default for ByteTokenizer {
    fn default() -> Self {
        ByteTokenizer { vocab_size: 128 }
    }
}

impl ByteTokenizer {
    pub fn new(vocab_size: usize) -> Self {
        ByteTokenizer { vocab_size }
    }

    /// Encode text to token ids. Non-ASCII bytes are clamped to '?'.
    pub fn encode(&self, text: &str) -> Vec<u16> {
        text.bytes()
            .map(|b| if (b as usize) < self.vocab_size { b as u16 } else { b'?' as u16 })
            .collect()
    }

    /// Decode token ids back to text (lossless for ASCII input).
    pub fn decode(&self, tokens: &[u16]) -> String {
        tokens
            .iter()
            .map(|&t| if (t as usize) < self.vocab_size { t as u8 as char } else { '?' })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn roundtrip_ascii() {
        let tok = ByteTokenizer::default();
        let s = "the cat runs .\n( a b ) .";
        assert_eq!(tok.decode(&tok.encode(s)), s);
    }

    #[test]
    fn roundtrip_property_on_corpus_alphabet() {
        let tok = ByteTokenizer::default();
        check(
            "tokenizer roundtrip",
            30,
            |r| {
                let alphabet = b"abcdefghijklmnopqrstuvwxyz (().\n";
                (0..1 + r.below(100))
                    .map(|_| alphabet[r.below(alphabet.len())] as char)
                    .collect::<String>()
            },
            |s| {
                if tok.decode(&tok.encode(s)) == *s {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn non_ascii_clamped() {
        let tok = ByteTokenizer::default();
        let enc = tok.encode("héllo");
        assert!(enc.iter().all(|&t| (t as usize) < 128));
    }

    #[test]
    fn ids_bounded_by_vocab() {
        let tok = ByteTokenizer::new(96);
        for &t in tok.encode("the {cat}~").iter() {
            assert!((t as usize) < 128);
        }
    }
}
