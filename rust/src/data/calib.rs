//! Calibration-set sampling (paper §D.2: 128 sequences from the
//! training set drive the block-wise transform optimization and the
//! ARB split-point statistics).

use crate::util::rng::Rng;

/// A calibration set: token sequences drawn from a corpus.
#[derive(Debug, Clone)]
pub struct CalibSet {
    pub seqs: Vec<Vec<u16>>,
    pub seq_len: usize,
}

impl CalibSet {
    /// Sample `n` random crops of `seq_len` tokens from corpus bytes.
    pub fn sample(corpus: &[u8], n: usize, seq_len: usize, seed: u64) -> Self {
        assert!(corpus.len() > seq_len + 1, "corpus too small for calibration");
        let mut rng = Rng::new(seed);
        let hi = corpus.len() - seq_len - 1;
        let seqs = (0..n)
            .map(|_| {
                let start = rng.below(hi);
                corpus[start..start + seq_len].iter().map(|&b| b.min(127) as u16).collect()
            })
            .collect();
        CalibSet { seqs, seq_len }
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Total token count.
    pub fn tokens(&self) -> usize {
        self.seqs.len() * self.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_shapes() {
        let corpus: Vec<u8> = (0..10_000).map(|i| (i % 90 + 32) as u8).collect();
        let cs = CalibSet::sample(&corpus, 16, 64, 42);
        assert_eq!(cs.len(), 16);
        assert!(cs.seqs.iter().all(|s| s.len() == 64));
        assert_eq!(cs.tokens(), 1024);
    }

    #[test]
    fn deterministic() {
        let corpus: Vec<u8> = (0..5_000).map(|i| (i % 90 + 32) as u8).collect();
        let a = CalibSet::sample(&corpus, 4, 32, 7);
        let b = CalibSet::sample(&corpus, 4, 32, 7);
        assert_eq!(a.seqs, b.seqs);
    }

    #[test]
    fn tokens_bounded_by_vocab() {
        let corpus: Vec<u8> = (0..3_000).map(|i| (i % 256) as u8).collect();
        let cs = CalibSet::sample(&corpus, 4, 16, 1);
        assert!(cs.seqs.iter().flatten().all(|&t| t < 128));
    }

    #[test]
    #[should_panic(expected = "corpus too small")]
    fn too_small_panics() {
        let corpus = vec![0u8; 10];
        CalibSet::sample(&corpus, 1, 64, 0);
    }
}
