//! Evaluation harness: WikiText2-style perplexity, the 7 zero-shot
//! probe tasks, memory/bits accounting (Table 3c) and the
//! activation/weight error statistics behind Figs. 2 and 6-9.

pub mod error_stats;
pub mod memory;
pub mod perplexity;
pub mod zeroshot;
