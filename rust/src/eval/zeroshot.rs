//! The 7 zero-shot probe tasks (Table 2 analog).
//!
//! Each probe is a ranking task on the tinywiki grammar: the model
//! scores a correct continuation against a distractor
//! (`continuation_logprob`, the same protocol the lm-eval harness uses
//! for Winogrande/ARC/etc.). Mapping to the paper's suite (DESIGN.md):
//! agreement→Winogrande, embedded-agreement→RTE, category→OBQA,
//! induction→HellaSwag, counting→ARC-e, brackets→BoolQ, adj-order→ARC-c.

use crate::data::corpus::{ADJS, ANIMALS, NOUNS, NUMBER_WORDS, PLACES, VERBS};
use crate::data::ByteTokenizer;
use crate::eval::perplexity::continuation_logprob;
use crate::model::Transformer;
use crate::util::rng::Rng;

/// One ranking example: prefix + correct/distractor continuations.
#[derive(Debug, Clone)]
pub struct Example {
    pub prefix: String,
    pub correct: String,
    pub distractor: String,
}

/// The task roster.
pub const TASK_NAMES: [&str; 7] =
    ["agreement", "embedded", "category", "induction", "counting", "brackets", "adj-order"];

/// Generate `n` examples for the named task.
pub fn examples(task: &str, n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed ^ 0xbeef);
    (0..n)
        .map(|_| match task {
            "agreement" => {
                let plural = rng.uniform() < 0.5;
                let noun = rng.choice(NOUNS);
                let verb = rng.choice(VERBS);
                Example {
                    prefix: format!("the {} ", if plural { noun.1 } else { noun.0 }),
                    correct: (if plural { verb.1 } else { verb.0 }).to_string(),
                    distractor: (if plural { verb.0 } else { verb.1 }).to_string(),
                }
            }
            "embedded" => {
                let plural = rng.uniform() < 0.5;
                let head = rng.choice(NOUNS);
                let inner = rng.choice(NOUNS).0;
                let verb = rng.choice(VERBS);
                Example {
                    prefix: format!(
                        "the {} that sees the {} ",
                        if plural { head.1 } else { head.0 },
                        inner
                    ),
                    correct: (if plural { verb.1 } else { verb.0 }).to_string(),
                    distractor: (if plural { verb.0 } else { verb.1 }).to_string(),
                }
            }
            "category" => {
                let noun = rng.choice(NOUNS).0;
                let animal = ANIMALS.contains(&noun);
                Example {
                    prefix: format!("the {noun} is an "),
                    correct: (if animal { "animal" } else { "object" }).to_string(),
                    distractor: (if animal { "object" } else { "animal" }).to_string(),
                }
            }
            "induction" => {
                let a = rng.choice(NOUNS).0;
                let b = rng.choice(PLACES);
                let mid = rng.choice(ADJS);
                let mut wrong = rng.choice(PLACES);
                while wrong == b {
                    wrong = rng.choice(PLACES);
                }
                Example {
                    prefix: format!("{a} {b} {mid} {a} "),
                    correct: (*b).to_string(),
                    distractor: (*wrong).to_string(),
                }
            }
            "counting" => {
                let start = rng.below(4);
                let next = NUMBER_WORDS[start + 3];
                let mut wrong = rng.choice(NUMBER_WORDS);
                while *wrong == next {
                    wrong = rng.choice(NUMBER_WORDS);
                }
                Example {
                    prefix: format!(
                        "{} {} {} ",
                        NUMBER_WORDS[start],
                        NUMBER_WORDS[start + 1],
                        NUMBER_WORDS[start + 2]
                    ),
                    correct: next.to_string(),
                    distractor: (*wrong).to_string(),
                }
            }
            "brackets" => {
                let letters = ["a", "b", "c", "d", "e", "f", "g", "h"];
                let l1 = letters[rng.below(8)];
                let l2 = letters[rng.below(8)];
                let l3 = letters[rng.below(8)];
                Example {
                    prefix: format!("( {l1} ( {l2} {l3} ) "),
                    correct: ")".to_string(),
                    distractor: "(".to_string(),
                }
            }
            "adj-order" => {
                let adj = rng.choice(ADJS);
                let noun = rng.choice(NOUNS).0;
                let verb = rng.choice(VERBS).0;
                Example {
                    prefix: format!("the {adj} "),
                    correct: noun.to_string(),
                    distractor: verb.to_string(),
                }
            }
            other => panic!("unknown task {other}"),
        })
        .collect()
}

/// Accuracy of one task: correct continuation must out-score the
/// distractor (length-normalized log-prob, the lm-eval convention).
pub fn task_accuracy(model: &Transformer, task: &str, n: usize, seed: u64) -> f64 {
    let tok = ByteTokenizer::default();
    let exs = examples(task, n, seed);
    let mut hits = 0usize;
    for ex in &exs {
        let prefix = tok.encode(&ex.prefix);
        let c = tok.encode(&ex.correct);
        let d = tok.encode(&ex.distractor);
        let lc = continuation_logprob(model, &prefix, &c) / c.len() as f64;
        let ld = continuation_logprob(model, &prefix, &d) / d.len() as f64;
        if lc > ld {
            hits += 1;
        }
    }
    100.0 * hits as f64 / exs.len() as f64
}

/// Run all 7 tasks; returns (name, accuracy) pairs plus the mean.
pub fn run_all(model: &Transformer, n_per_task: usize, seed: u64) -> (Vec<(String, f64)>, f64) {
    let mut results = Vec::new();
    for task in TASK_NAMES {
        results.push((task.to_string(), task_accuracy(model, task, n_per_task, seed)));
    }
    let mean = results.iter().map(|(_, a)| a).sum::<f64>() / results.len() as f64;
    (results, mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::tests::tiny_model;

    #[test]
    fn examples_deterministic_and_distinct_continuations() {
        for task in TASK_NAMES {
            let a = examples(task, 10, 7);
            let b = examples(task, 10, 7);
            assert_eq!(a.len(), 10);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.prefix, y.prefix);
                assert_ne!(x.correct, x.distractor, "{task}");
            }
        }
    }

    #[test]
    fn category_examples_truthful() {
        for ex in examples("category", 20, 3) {
            let noun = ex.prefix.split(' ').nth(1).unwrap();
            let is_animal = ANIMALS.contains(&noun);
            assert_eq!(ex.correct == "animal", is_animal);
        }
    }

    #[test]
    fn accuracy_in_range_for_random_model() {
        let m = tiny_model(1, 4);
        // 32-vocab random model vs 128-vocab text: just bounds checking.
        let acc = task_accuracy_bounded(&m);
        assert!((0.0..=100.0).contains(&acc));
    }

    fn task_accuracy_bounded(m: &Transformer) -> f64 {
        // tiny_model has vocab 32; clamp text bytes via tokenizer(32).
        let tok = crate::data::ByteTokenizer::new(32);
        let exs = examples("agreement", 4, 1);
        let mut hits = 0;
        for ex in &exs {
            let p = tok.encode(&ex.prefix).iter().map(|&t| t % 32).collect::<Vec<_>>();
            let c = tok.encode(&ex.correct).iter().map(|&t| t % 32).collect::<Vec<_>>();
            let d = tok.encode(&ex.distractor).iter().map(|&t| t % 32).collect::<Vec<_>>();
            let lc = continuation_logprob(m, &p, &c) / c.len() as f64;
            let ld = continuation_logprob(m, &p, &d) / d.len() as f64;
            if lc > ld {
                hits += 1;
            }
        }
        100.0 * hits as f64 / exs.len() as f64
    }
}
