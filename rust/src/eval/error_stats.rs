//! Error & distribution statistics behind the paper's figures:
//! Fig. 2 / 8-9 (activation distributions before/after the learnable
//! transformation) and Figs. 6-7 (relative weight quantization error).

use crate::model::transformer::{Capture, CaptureSite};
use crate::model::Transformer;
use crate::tensor::stats::{summarize, Summary};

/// Per-(layer, site) activation summary: the raw activations the site
/// produces and, when the consuming linear carries a transformation,
/// the transformed activations the quantized GEMM actually sees.
#[derive(Debug, Clone)]
pub struct ActStats {
    pub layer: usize,
    pub site: &'static str,
    pub raw: Summary,
    pub transformed: Option<Summary>,
}

/// Capture activations on `tokens` and summarize per site (Fig. 2).
pub fn activation_stats(model: &Transformer, tokens: &[u16], max_rows: usize) -> Vec<ActStats> {
    let mut cap = Capture::new(max_rows);
    {
        let mut opt = Some(&mut cap);
        model.forward_capture(tokens, &mut opt);
    }
    let sites: [(CaptureSite, &'static str); 4] = [
        (CaptureSite::Ln1Out, "ln1_out(k_proj in)"),
        (CaptureSite::AttnOut, "attn_out(o_proj in)"),
        (CaptureSite::Ln2Out, "ln2_out(gate in)"),
        (CaptureSite::FfnMid, "ffn_mid(down in)"),
    ];
    let mut out = Vec::new();
    for li in 0..model.cfg.n_layer {
        for (site, name) in sites.iter() {
            let Some(x) = cap.matrix(li, *site) else { continue };
            let raw = summarize(&x.data);
            // The consuming linear (first of the group) may transform.
            let lin = match site {
                CaptureSite::Ln1Out => &model.blocks[li].wk,
                CaptureSite::AttnOut => &model.blocks[li].wo,
                CaptureSite::Ln2Out => &model.blocks[li].wgate,
                CaptureSite::FfnMid => &model.blocks[li].wdown,
            };
            let transformed = lin.transform.as_ref().map(|t| summarize(&t.apply(&x).data));
            out.push(ActStats { layer: li, site: name, raw, transformed });
        }
    }
    out
}

/// Relative weight reconstruction error per linear of a quantized
/// model vs its fp reference (Figs. 6-7).
pub fn weight_errors(fp: &Transformer, quant: &Transformer) -> Vec<(usize, &'static str, f64)> {
    let mut out = Vec::new();
    for (li, (bf, bq)) in fp.blocks.iter().zip(quant.blocks.iter()).enumerate() {
        for ((name, lf), (_, lq)) in bf.linears().iter().zip(bq.linears().iter()) {
            // Compare in the quantized layer's (possibly transformed)
            // coordinate system: reconstruct effective weight and map
            // the fp weight with the same transform.
            let wq = lq.backend.reconstruct();
            let wf = match &lq.transform {
                Some(t) => t.transform_weight(&lf.backend.reconstruct()),
                None => lf.backend.reconstruct(),
            };
            out.push((li, *name, crate::tensor::stats::rel_error(&wf.data, &wq.data)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::tests::tiny_model;

    #[test]
    fn activation_stats_cover_all_sites() {
        let m = tiny_model(1, 4);
        let stats = activation_stats(&m, &[1, 2, 3, 4, 5], 64);
        assert_eq!(stats.len(), 2 * 4);
        assert!(stats.iter().all(|s| s.raw.max_abs.is_finite()));
        assert!(stats.iter().all(|s| s.transformed.is_none())); // fp model
    }

    #[test]
    fn weight_errors_zero_for_identical_models() {
        let m = tiny_model(2, 4);
        let errs = weight_errors(&m, &m);
        assert_eq!(errs.len(), 2 * 7);
        assert!(errs.iter().all(|(_, _, e)| *e < 1e-12));
    }
}
