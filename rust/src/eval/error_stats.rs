//! Error & distribution statistics behind the paper's figures:
//! Fig. 2 / 8-9 (activation distributions before/after the learnable
//! transformation) and Figs. 6-7 (relative weight quantization error).

use crate::model::transformer::{Capture, CaptureSite};
use crate::model::Transformer;
use crate::tensor::stats::{summarize, Summary};
use crate::tensor::Matrix;

/// Divergence between two same-shaped logit matrices: the accuracy
/// gate for the integer compute path (DESIGN.md §12) compares the
/// W1A8 lane against the f32 sim-quant reference with these numbers.
#[derive(Debug, Clone, Copy)]
pub struct Divergence {
    /// max_i |a_i - b_i|
    pub max_abs: f64,
    /// mean_i |a_i - b_i|
    pub mean_abs: f64,
    /// ||a - b||_2 / ||b||_2 (b is the reference)
    pub rel: f64,
}

/// Element-wise divergence of `a` (candidate) from `b` (reference).
pub fn logit_divergence(a: &Matrix, b: &Matrix) -> Divergence {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "logit_divergence: shape mismatch");
    let mut max_abs = 0f64;
    let mut sum_abs = 0f64;
    for (&x, &y) in a.data.iter().zip(&b.data) {
        let d = (x as f64 - y as f64).abs();
        max_abs = max_abs.max(d);
        sum_abs += d;
    }
    Divergence {
        max_abs,
        mean_abs: sum_abs / a.data.len().max(1) as f64,
        // rel_error's first argument is the norm denominator.
        rel: crate::tensor::stats::rel_error(&b.data, &a.data),
    }
}

/// Per-(layer, site) activation summary: the raw activations the site
/// produces and, when the consuming linear carries a transformation,
/// the transformed activations the quantized GEMM actually sees.
#[derive(Debug, Clone)]
pub struct ActStats {
    pub layer: usize,
    pub site: &'static str,
    pub raw: Summary,
    pub transformed: Option<Summary>,
}

/// Capture activations on `tokens` and summarize per site (Fig. 2).
pub fn activation_stats(model: &Transformer, tokens: &[u16], max_rows: usize) -> Vec<ActStats> {
    let mut cap = Capture::new(max_rows);
    {
        let mut opt = Some(&mut cap);
        model.forward_capture(tokens, &mut opt);
    }
    let sites: [(CaptureSite, &'static str); 4] = [
        (CaptureSite::Ln1Out, "ln1_out(k_proj in)"),
        (CaptureSite::AttnOut, "attn_out(o_proj in)"),
        (CaptureSite::Ln2Out, "ln2_out(gate in)"),
        (CaptureSite::FfnMid, "ffn_mid(down in)"),
    ];
    let mut out = Vec::new();
    for li in 0..model.cfg.n_layer {
        for (site, name) in sites.iter() {
            let Some(x) = cap.matrix(li, *site) else { continue };
            let raw = summarize(&x.data);
            // The consuming linear (first of the group) may transform.
            let lin = match site {
                CaptureSite::Ln1Out => &model.blocks[li].wk,
                CaptureSite::AttnOut => &model.blocks[li].wo,
                CaptureSite::Ln2Out => &model.blocks[li].wgate,
                CaptureSite::FfnMid => &model.blocks[li].wdown,
            };
            let transformed = lin.transform.as_ref().map(|t| summarize(&t.apply(&x).data));
            out.push(ActStats { layer: li, site: name, raw, transformed });
        }
    }
    out
}

/// Relative weight reconstruction error per linear of a quantized
/// model vs its fp reference (Figs. 6-7).
pub fn weight_errors(fp: &Transformer, quant: &Transformer) -> Vec<(usize, &'static str, f64)> {
    let mut out = Vec::new();
    for (li, (bf, bq)) in fp.blocks.iter().zip(quant.blocks.iter()).enumerate() {
        for ((name, lf), (_, lq)) in bf.linears().iter().zip(bq.linears().iter()) {
            // Compare in the quantized layer's (possibly transformed)
            // coordinate system: reconstruct effective weight and map
            // the fp weight with the same transform.
            let wq = lq.backend.reconstruct();
            let wf = match &lq.transform {
                Some(t) => t.transform_weight(&lf.backend.reconstruct()),
                None => lf.backend.reconstruct(),
            };
            out.push((li, *name, crate::tensor::stats::rel_error(&wf.data, &wq.data)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::tests::tiny_model;

    #[test]
    fn activation_stats_cover_all_sites() {
        let m = tiny_model(1, 4);
        let stats = activation_stats(&m, &[1, 2, 3, 4, 5], 64);
        assert_eq!(stats.len(), 2 * 4);
        assert!(stats.iter().all(|s| s.raw.max_abs.is_finite()));
        assert!(stats.iter().all(|s| s.transformed.is_none())); // fp model
    }

    #[test]
    fn logit_divergence_reports_known_perturbation() {
        let b = Matrix { rows: 2, cols: 2, data: vec![1.0, -2.0, 3.0, -4.0] };
        let zero = logit_divergence(&b, &b);
        assert_eq!(zero.max_abs, 0.0);
        assert_eq!(zero.mean_abs, 0.0);
        assert_eq!(zero.rel, 0.0);
        let mut a = b.clone();
        a.data[2] += 0.5;
        let d = logit_divergence(&a, &b);
        assert!((d.max_abs - 0.5).abs() < 1e-9);
        assert!((d.mean_abs - 0.125).abs() < 1e-9);
        let want_rel = 0.5 / (30.0f64).sqrt(); // ||b|| = sqrt(1+4+9+16)
        assert!((d.rel - want_rel).abs() < 1e-7, "rel {}", d.rel);
    }

    #[test]
    fn weight_errors_zero_for_identical_models() {
        let m = tiny_model(2, 4);
        let errs = weight_errors(&m, &m);
        assert_eq!(errs.len(), 2 * 7);
        assert!(errs.iter().all(|(_, _, e)| *e < 1e-12));
    }
}
