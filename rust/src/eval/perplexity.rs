//! Perplexity evaluation (the paper's WikiText2 metric, on the
//! held-out tinywiki split).

use crate::model::Transformer;

/// Next-token cross-entropy over a token stream, chunked into
/// independent windows of `seq_len` (the lm-eval sliding convention,
/// stride = window).
pub fn nll(model: &Transformer, tokens: &[u16], seq_len: usize) -> (f64, usize) {
    assert!(seq_len >= 2);
    let mut total_nll = 0f64;
    let mut count = 0usize;
    let mut start = 0;
    while start + 2 <= tokens.len() {
        let end = (start + seq_len).min(tokens.len());
        let window = &tokens[start..end];
        if window.len() < 2 {
            break;
        }
        let logits = model.forward(&window[..window.len() - 1]);
        for pos in 0..window.len() - 1 {
            let target = window[pos + 1] as usize;
            let row = logits.row(pos);
            total_nll += -log_softmax_at(row, target);
            count += 1;
        }
        start = end;
    }
    (total_nll, count)
}

/// Perplexity = exp(mean NLL).
pub fn perplexity(model: &Transformer, tokens: &[u16], seq_len: usize, max_tokens: usize) -> f64 {
    let clipped = &tokens[..tokens.len().min(max_tokens)];
    let (nll_sum, n) = nll(model, clipped, seq_len);
    (nll_sum / n.max(1) as f64).exp()
}

/// log softmax(row)[idx], numerically stable.
pub fn log_softmax_at(row: &[f32], idx: usize) -> f64 {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let lse: f64 = row.iter().map(|&v| ((v as f64) - max).exp()).sum::<f64>().ln() + max;
    row[idx] as f64 - lse
}

/// Sum of log-probabilities of `continuation` given `prefix`
/// (the zero-shot ranking primitive).
pub fn continuation_logprob(model: &Transformer, prefix: &[u16], continuation: &[u16]) -> f64 {
    assert!(!continuation.is_empty());
    let mut seq = prefix.to_vec();
    seq.extend_from_slice(continuation);
    let logits = model.forward(&seq[..seq.len() - 1]);
    let mut lp = 0f64;
    for (k, &tok) in continuation.iter().enumerate() {
        let pos = prefix.len() + k - 1;
        lp += log_softmax_at(logits.row(pos), tok as usize);
    }
    lp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::tests::tiny_model;

    #[test]
    fn log_softmax_normalizes() {
        let row = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&row, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ppl_bounded_by_vocab_for_uniformish_model() {
        let m = tiny_model(1, 4);
        let tokens: Vec<u16> = (0..120).map(|i| (i % 30) as u16).collect();
        let ppl = perplexity(&m, &tokens, 32, 1000);
        // A near-random 32-vocab model: ppl in (1, ~40).
        assert!(ppl > 1.0 && ppl < 45.0, "ppl {ppl}");
    }

    #[test]
    fn continuation_logprob_is_negative() {
        let m = tiny_model(2, 4);
        let lp = continuation_logprob(&m, &[1, 2, 3], &[4, 5]);
        assert!(lp < 0.0);
    }

    #[test]
    fn nll_counts_tokens() {
        let m = tiny_model(3, 4);
        let tokens: Vec<u16> = (0..33).map(|i| (i % 30) as u16).collect();
        let (_, n) = nll(&m, &tokens, 16);
        // windows: 16+16+1(tail dropped—needs >=2) => 15+15+... compute:
        // [0..16) -> 15 preds, [16..32) -> 15, [32..33) -> too short.
        assert_eq!(n, 30);
    }
}
