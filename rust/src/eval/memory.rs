//! Memory / effective-bits accounting (Table 3c and the W-Bits columns
//! of every table) **plus the measured truth**: next to the accounted
//! bits (packed signs or indices, fp16 scales/biases, column-group
//! ids, Kronecker transform factors, the shared codebook, the fp16
//! embedding/norm residue) this report now carries what each backend
//! *actually* holds resident in RAM ([`crate::model::WeightBackend::resident_bytes`])
//! and what it serializes to the QLM1 wire
//! ([`crate::model::WeightBackend::wire_bytes`]), so any regression of
//! the accounted-vs-real gap is visible in tests and benches.

use std::collections::BTreeSet;

use crate::model::kvcache::{KvPool, KvPoolStats};
use crate::model::Transformer;

/// Full memory report for one model.
#[derive(Debug, Clone, Default)]
pub struct MemoryReport {
    /// fp16 baseline for the whole model (the paper's "FP16" row).
    pub fp16_total_bytes: usize,
    /// Quantized linear-weight payload (signs/indices + scales +
    /// groups), by the accounting convention.
    pub linear_bytes: usize,
    /// Measured: bytes the linear backends actually hold in RAM.
    pub linear_resident_bytes: usize,
    /// Measured: bytes the linear backends serialize to the QLM1 wire.
    pub linear_wire_bytes: usize,
    /// Shared codebook payload (accounted: c x v bits). All *distinct*
    /// codebooks are summed (deduped by Arc identity), so
    /// multi-codebook models are not under-reported.
    pub codebook_bytes: usize,
    /// Measured: bytes the distinct codebooks hold resident (one u64
    /// per centroid for the XOR/POPCNT hot paths).
    pub codebook_resident_bytes: usize,
    /// Transform factors (f32 Kronecker matrices + sigma ±1 bitmaps) —
    /// p1/p2 are counted at the f32 width they ship and occupy.
    pub transform_bytes: usize,
    /// Embeddings + norms kept in fp16.
    pub residual_fp16_bytes: usize,
    /// Accounted linear-weight bits per linear weight (the W-bits
    /// measurement the paper's tables report).
    pub linear_bits_per_weight: f64,
    /// Measured resident linear bits per weight: after the packed-plane
    /// refactor this matches the accounted number for the codebook
    /// lane; lanes that keep wider buffers (dense f32, unpacked masks)
    /// show their real cost here.
    pub resident_bits_per_weight: f64,
    /// Total model bytes after quantization (accounting convention).
    pub total_bytes: usize,
    /// fp16_total / total.
    pub compression: f64,
    /// codebook share of the quantized model.
    pub codebook_overhead: f64,
}

/// Compute the report from a (possibly quantized) model.
pub fn report(model: &Transformer) -> MemoryReport {
    let cfg = &model.cfg;
    let fp16_total_bytes = cfg.param_count() * 2;
    let residual_fp16_bytes =
        (cfg.vocab * cfg.d_model + cfg.d_model + cfg.n_layer * 2 * cfg.d_model) * 2;

    let mut linear_bits = 0usize;
    let mut linear_resident_bytes = 0usize;
    let mut linear_wire_bytes = 0usize;
    let mut linear_weights = 0usize;
    let mut transform_bits = 0usize;
    let mut codebook_bits = 0usize;
    let mut codebook_resident_bytes = 0usize;
    // Distinct shared codebooks, deduped by Arc identity: custom
    // methods may attach per-family codebooks, and each one is real
    // memory.
    let mut seen_codebooks: BTreeSet<usize> = BTreeSet::new();
    for block in &model.blocks {
        for (_, lin) in block.linears() {
            let (o, i) = lin.backend.shape();
            linear_weights += o * i;
            linear_bits += lin.backend.storage_bits();
            linear_resident_bytes += lin.backend.resident_bytes();
            linear_wire_bytes += lin.backend.wire_bytes();
            if let Some(t) = &lin.transform {
                transform_bits += (t.p1.data.len() + t.p2.data.len()) * 32 + t.sigma.len();
            }
            if let Some(cb) = lin.backend.shared_codebook() {
                if seen_codebooks.insert(std::sync::Arc::as_ptr(&cb) as usize) {
                    codebook_bits += cb.storage_bits();
                    codebook_resident_bytes += cb.resident_bytes();
                }
            }
        }
    }
    let linear_bytes = linear_bits.div_ceil(8);
    let codebook_bytes = codebook_bits.div_ceil(8);
    let transform_bytes = transform_bits.div_ceil(8);
    let total_bytes = linear_bytes + codebook_bytes + transform_bytes + residual_fp16_bytes;
    MemoryReport {
        fp16_total_bytes,
        linear_bytes,
        linear_resident_bytes,
        linear_wire_bytes,
        codebook_bytes,
        codebook_resident_bytes,
        transform_bytes,
        residual_fp16_bytes,
        linear_bits_per_weight: linear_bits as f64 / linear_weights.max(1) as f64,
        resident_bits_per_weight: (linear_resident_bytes * 8) as f64
            / linear_weights.max(1) as f64,
        total_bytes,
        compression: fp16_total_bytes as f64 / total_bytes.max(1) as f64,
        codebook_overhead: codebook_bytes as f64 / total_bytes.max(1) as f64,
    }
}

/// KV-pool residency report — the serving-time counterpart of the
/// weight numbers above, for code that holds a [`KvPool`] directly
/// (custom serving loops, tests, tools). Once weights are sub-1-bit,
/// the KV cache is the dominant resident allocation. The in-process
/// `Server` publishes the same underlying numbers through its
/// `Metrics` KV gauges each round (the pool lives inside the worker
/// thread), which is what `bench_serve_e2e` emits into
/// `BENCH_serve.json` next to the weight residency from [`report`].
#[derive(Debug, Clone, Copy)]
pub struct KvPoolReport {
    /// Raw pool snapshot (blocks, measured resident bytes, peaks,
    /// prefix-sharing hits).
    pub stats: KvPoolStats,
    /// `blocks_in_use / budget_blocks`.
    pub utilization: f64,
    /// What the same in-use blocks would hold resident all-f32.
    pub f32_equivalent_bytes: usize,
    /// `f32_equivalent_bytes / resident_bytes` (1.0 with quantization
    /// off; > 1 once cold blocks pack down).
    pub compression: f64,
}

/// Snapshot a pool's residency.
pub fn kv_report(pool: &KvPool) -> KvPoolReport {
    let stats = pool.stats();
    let f32_equivalent_bytes = stats.blocks_in_use * pool.f32_block_bytes();
    KvPoolReport {
        stats,
        utilization: stats.blocks_in_use as f64 / stats.budget_blocks.max(1) as f64,
        f32_equivalent_bytes,
        compression: f32_equivalent_bytes as f64 / stats.resident_bytes.max(1) as f64,
    }
}

/// Pretty-print helper: bytes → human string.
pub fn human_bytes(b: usize) -> String {
    if b >= 1 << 30 {
        format!("{:.2}GB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2}MB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2}KB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::tests::tiny_model;

    #[test]
    fn fp16_model_report() {
        let m = tiny_model(1, 4);
        let r = report(&m);
        assert_eq!(r.fp16_total_bytes, m.cfg.param_count() * 2);
        // Dense backends count at fp16 => compression ~1...
        assert!((r.linear_bits_per_weight - 16.0).abs() < 1e-9);
        assert!(r.compression > 0.9 && r.compression < 1.1);
        assert_eq!(r.codebook_bytes, 0);
        // ...but the *measured* resident number tells the truth: the
        // dense lane actually holds f32.
        assert!((r.resident_bits_per_weight - 32.0).abs() < 1e-9);
    }

    #[test]
    fn quantized_model_compresses() {
        use crate::quant::pipeline::{quantize_model, tests::fixture_public, QuantConfig};
        let (raw, corpus) = fixture_public();
        let cfg = QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            transform_outer: 1,
            arb_iters: 2,
            v: 8,
            ..QuantConfig::btc(0.8)
        };
        let qm = quantize_model(&raw, &corpus, &cfg).unwrap();
        let r = report(&qm.model);
        // Tiny fixture (d=16): fp16 row scales dominate the measured
        // figure; payload bits are the paper-comparable number.
        assert!(qm.stats.payload_bits < 1.0, "payload {}", qm.stats.payload_bits);
        assert!(r.linear_bits_per_weight < 8.0, "bits {}", r.linear_bits_per_weight);
        assert!(r.compression > 1.5, "compression {}", r.compression);
        assert!(r.codebook_overhead > 0.0 && r.codebook_overhead < 0.6);
        // Measured truth: resident and wire bytes now track the
        // accounted number. At d=16 the per-row word padding of the
        // packed planes is the dominant slack, so the bound is loose
        // here; the release-mode memory bench pins <= 5% at a real
        // shape. Pre-refactor these were ~4x (u32 indices, f32 scales).
        assert!(r.linear_resident_bytes > 0 && r.linear_wire_bytes > 0);
        assert!(
            r.linear_resident_bytes < 3 * r.linear_bytes,
            "resident {} vs accounted {}",
            r.linear_resident_bytes,
            r.linear_bytes
        );
        assert!(
            r.linear_wire_bytes < 2 * r.linear_bytes,
            "wire {} vs accounted {}",
            r.linear_wire_bytes,
            r.linear_bytes
        );
        assert!(r.codebook_resident_bytes >= r.codebook_bytes);
    }

    #[test]
    fn distinct_codebooks_are_all_counted() {
        use crate::quant::binarize::BinaryLayer;
        use crate::quant::codebook::{collect_vectors, BinaryCodebook, CodebookLayer};
        use crate::model::Linear;
        use crate::tensor::Matrix;
        use crate::util::rng::Rng;
        use std::sync::Arc;

        let mut m = tiny_model(1, 4);
        let mut rng = Rng::new(21);
        let mut make = |rows: usize, cols: usize, c: usize| {
            let w = Matrix::randn(rows, cols, &mut rng);
            let bl = BinaryLayer::quantize(&w);
            let vectors = collect_vectors(&bl, 8);
            let (cb, assign, _) = BinaryCodebook::build(&vectors, 8, c, 3);
            CodebookLayer::from_assignments(&bl, Arc::new(cb), assign)
        };
        let (rows, cols) = m.blocks[0].wq.backend.shape();
        let cl1 = make(rows, cols, 8);
        let cl2 = make(rows, cols, 4);
        let shared = cl1.codebook.clone();
        let bits1 = shared.storage_bits();
        let bits2 = cl2.codebook.storage_bits();
        m.blocks[0].wq = Linear::new(Box::new(cl1.clone()));
        m.blocks[0].wo = Linear::new(Box::new(cl2));
        // A second layer referencing the SAME Arc must not double-count.
        m.blocks[0].wk = Linear::new(Box::new(CodebookLayer::new(
            rows,
            cols,
            shared.clone(),
            &cl1.idx.to_u32s(),
            &cl1.alpha_f32(),
            &cl1.mu_f32(),
            &cl1.col_groups(),
            cl1.n_groups,
        )));
        let r = report(&m);
        assert_eq!(r.codebook_bytes, (bits1 + bits2).div_ceil(8));
        assert_eq!(
            r.codebook_resident_bytes,
            (shared.c() + m.blocks[0].wo.backend.shared_codebook().unwrap().c()) * 8
        );
    }

    #[test]
    fn kv_pool_report_tracks_quantization() {
        use crate::model::kvcache::PoolConfig;
        use crate::quant::kvquant::KvQuantConfig;
        let m = tiny_model(2, 4); // kv_dim 16: quantized rows word-align
        let cfg = PoolConfig {
            block_size: 4,
            budget_blocks: 16,
            quant: KvQuantConfig { bits: 4, local_window: 4 },
        };
        let mut pool = m.new_pool(&cfg, 1);
        let mut cache = pool.new_cache();
        let prompt: Vec<u16> = (1..=12).collect();
        m.prefill_paged(&prompt, &mut cache, &mut pool);
        let r0 = kv_report(&pool);
        assert_eq!(r0.stats.blocks_in_use, 3);
        assert!(r0.utilization > 0.0 && r0.utilization <= 1.0);
        assert!((r0.compression - 1.0).abs() < 1e-9, "all-f32 pool is 1x");
        pool.quantize_cold(&cache);
        let r1 = kv_report(&pool);
        assert_eq!(r1.stats.quant_blocks, 2, "(12 - 4) / 4 cold blocks");
        assert!(r1.stats.resident_bytes < r0.stats.resident_bytes);
        assert!(r1.compression > 1.5, "cold blocks packed: {}", r1.compression);
        pool.release(&mut cache);
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(2048), "2.00KB");
        assert!(human_bytes(3 << 20).starts_with("3.00MB"));
    }
}
