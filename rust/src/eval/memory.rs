//! Memory / effective-bits accounting (Table 3c and the W-Bits columns
//! of every table). Counts what actually ships: packed signs or
//! indices, fp16 scales/biases, column-group ids, Kronecker transform
//! factors, the shared codebook, and the fp16 embedding/norm residue.

use crate::model::Transformer;

/// Full memory report for one model.
#[derive(Debug, Clone, Default)]
pub struct MemoryReport {
    /// fp16 baseline for the whole model (the paper's "FP16" row).
    pub fp16_total_bytes: usize,
    /// Quantized linear-weight payload (signs/indices + scales + groups).
    pub linear_bytes: usize,
    /// Shared codebook payload.
    pub codebook_bytes: usize,
    /// Transform factors (+ sigma bitmaps).
    pub transform_bytes: usize,
    /// Embeddings + norms kept in fp16.
    pub residual_fp16_bytes: usize,
    /// Linear-weight bits per linear weight (the W-bits measurement).
    pub linear_bits_per_weight: f64,
    /// Total model bytes after quantization.
    pub total_bytes: usize,
    /// fp16_total / total.
    pub compression: f64,
    /// codebook share of the quantized model.
    pub codebook_overhead: f64,
}

/// Compute the report from a (possibly quantized) model.
pub fn report(model: &Transformer) -> MemoryReport {
    let cfg = &model.cfg;
    let fp16_total_bytes = cfg.param_count() * 2;
    let residual_fp16_bytes =
        (cfg.vocab * cfg.d_model + cfg.d_model + cfg.n_layer * 2 * cfg.d_model) * 2;

    let mut linear_bits = 0usize;
    let mut linear_weights = 0usize;
    let mut transform_bits = 0usize;
    let mut codebook_bits = 0usize;
    let mut seen_codebook = false;
    for block in &model.blocks {
        for (_, lin) in block.linears() {
            let (o, i) = lin.backend.shape();
            linear_weights += o * i;
            linear_bits += lin.backend.storage_bits();
            if let Some(t) = &lin.transform {
                transform_bits += (t.p1.data.len() + t.p2.data.len()) * 16 + t.sigma.len();
            }
            if let Some(cb) = lin.backend.shared_codebook() {
                if !seen_codebook {
                    codebook_bits = cb.storage_bits();
                    seen_codebook = true;
                }
            }
        }
    }
    let linear_bytes = linear_bits.div_ceil(8);
    let codebook_bytes = codebook_bits.div_ceil(8);
    let transform_bytes = transform_bits.div_ceil(8);
    let total_bytes = linear_bytes + codebook_bytes + transform_bytes + residual_fp16_bytes;
    MemoryReport {
        fp16_total_bytes,
        linear_bytes,
        codebook_bytes,
        transform_bytes,
        residual_fp16_bytes,
        linear_bits_per_weight: linear_bits as f64 / linear_weights.max(1) as f64,
        total_bytes,
        compression: fp16_total_bytes as f64 / total_bytes.max(1) as f64,
        codebook_overhead: codebook_bytes as f64 / total_bytes.max(1) as f64,
    }
}

/// Pretty-print helper: bytes → human string.
pub fn human_bytes(b: usize) -> String {
    if b >= 1 << 30 {
        format!("{:.2}GB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2}MB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2}KB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::tests::tiny_model;

    #[test]
    fn fp16_model_report() {
        let m = tiny_model(1, 4);
        let r = report(&m);
        assert_eq!(r.fp16_total_bytes, m.cfg.param_count() * 2);
        // Dense backends count at fp16 => compression ~1.
        assert!((r.linear_bits_per_weight - 16.0).abs() < 1e-9);
        assert!(r.compression > 0.9 && r.compression < 1.1);
        assert_eq!(r.codebook_bytes, 0);
    }

    #[test]
    fn quantized_model_compresses() {
        use crate::quant::pipeline::{quantize_model, tests::fixture_public, QuantConfig};
        let (raw, corpus) = fixture_public();
        let cfg = QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            transform_outer: 1,
            arb_iters: 2,
            v: 8,
            ..QuantConfig::btc(0.8)
        };
        let qm = quantize_model(&raw, &corpus, &cfg).unwrap();
        let r = report(&qm.model);
        // Tiny fixture (d=16): fp16 row scales dominate the measured
        // figure; payload bits are the paper-comparable number.
        assert!(qm.stats.payload_bits < 1.0, "payload {}", qm.stats.payload_bits);
        assert!(r.linear_bits_per_weight < 8.0, "bits {}", r.linear_bits_per_weight);
        assert!(r.compression > 1.5, "compression {}", r.compression);
        assert!(r.codebook_overhead > 0.0 && r.codebook_overhead < 0.6);
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(2048), "2.00KB");
        assert!(human_bytes(3 << 20).starts_with("3.00MB"));
    }
}
