//! # BTC-LLM — sub-1-bit LLM quantization (ACL 2026) in Rust + JAX + Pallas
//!
//! Reproduction of "BTC-LLM: Efficient Sub-1-Bit LLM Quantization via
//! Learnable Transformation and Binary Codebook".
//!
//! Three-layer architecture (see DESIGN.md):
//! - **L1** Pallas kernels (`python/compile/kernels/`) — binary-codebook
//!   LUT-GEMM and W1A16 sign-GEMM, AOT-lowered to HLO text.
//! - **L2** JAX model (`python/compile/model.py`) — the TinyLM workload
//!   family, trained at build time; python never runs at serve time.
//! - **L3** this crate — the deployment system: quantization pipeline
//!   (learnable transformation + ARB + binary codebook and every
//!   baseline), a CPU inference engine (XNOR-POPCNT GEMM, two-stage
//!   LUT-GEMM), evaluation harness, serving coordinator, and the PJRT
//!   runtime that loads the AOT artifacts.
//!
//! The build image is offline, so all infrastructure (PRNG, CLI, TOML
//! config, bench harness, property testing, threaded serving) lives
//! in-repo under [`util`]; the only dependency is the vendored mini
//! `anyhow` (rust/vendor/anyhow), and the PJRT/XLA client is gated
//! behind the `pjrt` feature (see [`runtime`]).
//!
//! The quantization surface is **open** (DESIGN.md §3): methods
//! implement [`quant::Quantizer`] and register by name in
//! [`quant::registry`]; weight formats implement
//! [`model::WeightBackend`] and register a deserializer by tag — see
//! `examples/custom_method.rs` for a third-party lane in one file.

pub mod benchsuite;
pub mod bitops;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod io;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$BTC_ARTIFACTS` or ./artifacts,
/// searching upward a couple of levels so tests/benches work from any
/// cargo working directory.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("BTC_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    for _ in 0..4 {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            break;
        }
    }
    ARTIFACTS_DIR.into()
}
