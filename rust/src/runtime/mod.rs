//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! lowered once by `python/compile/aot.py`) and executes them on the
//! XLA CPU client — python never runs on this path.
//!
//! The real client wraps the vendored `xla` crate (xla_extension
//! 0.5.1), which only exists on the build image. Default builds — and
//! `--features pjrt` builds off the image — use a stub with the same
//! API whose constructor fails at runtime, so the crate compiles
//! anywhere; on the image, enable the `pjrt` feature AND pass
//! `RUSTFLAGS="--cfg xla_runtime"` (after adding the vendored `xla`
//! path dependency) for the real thing. Parity tests skip when
//! artifacts are missing, so the stub never breaks `cargo test`.

// The real client needs BOTH the `pjrt` feature and the build image's
// vendored `xla` crate (signalled via `--cfg xla_runtime` in
// RUSTFLAGS, declared in Cargo.toml's `[lints.rust]` check-cfg).
// `--features pjrt` alone compiles the stub everywhere, so CI can
// build-check the feature without the image.
#[cfg(all(feature = "pjrt", xla_runtime))]
pub mod pjrt;

#[cfg(not(all(feature = "pjrt", xla_runtime)))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use pjrt::{PjrtRuntime, TensorArg};
