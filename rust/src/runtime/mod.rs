//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! lowered once by `python/compile/aot.py`) and executes them on the
//! XLA CPU client — python never runs on this path.

pub mod pjrt;

pub use pjrt::{PjrtRuntime, TensorArg};
