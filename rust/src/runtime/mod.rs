//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! lowered once by `python/compile/aot.py`) and executes them on the
//! XLA CPU client — python never runs on this path.
//!
//! The real client wraps the vendored `xla` crate (xla_extension
//! 0.5.1), which only exists on the build image. Default builds use a
//! stub with the same API whose constructor fails at runtime, so the
//! crate compiles anywhere; enable the `pjrt` feature on the image
//! (after adding the vendored `xla` path dependency) for the real
//! thing. Parity tests skip when artifacts are missing, so the stub
//! never breaks `cargo test`.

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use pjrt::{PjrtRuntime, TensorArg};
