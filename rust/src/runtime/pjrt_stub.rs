//! API-compatible stand-in for the real PJRT client, used unless the
//! crate is built with the `pjrt` feature AND `--cfg xla_runtime`
//! (the vendored `xla` crate only exists on the build image).
//! Construction fails with a clear error; everything downstream (CLI
//! `parity`, hlo_parity example, runtime parity tests) already
//! handles that by skipping.

use std::path::Path;

use anyhow::{bail, Result};

/// A typed input tensor for an AOT executable.
#[derive(Debug, Clone)]
pub enum TensorArg {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

/// Stub PJRT client: carries the same API as the real runtime but can
/// never be constructed.
pub struct PjrtRuntime {
    _private: (),
}

impl PjrtRuntime {
    /// Always fails: this build has no XLA client.
    pub fn cpu(_artifacts_dir: &Path) -> Result<PjrtRuntime> {
        bail!(
            "PJRT runtime unavailable: built without the `pjrt` feature + `--cfg xla_runtime` \
             (the vendored xla_extension crate only exists on the build image)"
        )
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Load + compile an HLO text artifact (cached).
    pub fn load(&mut self, _name: &str) -> Result<()> {
        bail!("PJRT runtime unavailable (pjrt feature disabled)")
    }

    /// Execute an artifact.
    pub fn run_f32(&mut self, _name: &str, _args: &[TensorArg]) -> Result<Vec<f32>> {
        bail!("PJRT runtime unavailable (pjrt feature disabled)")
    }

    /// Names of the loaded executables (diagnostics).
    pub fn loaded(&self) -> Vec<String> {
        Vec::new()
    }
}
