//! Thin wrapper over the `xla` crate (PJRT C API, xla_extension 0.5.1):
//! `HloModuleProto::from_text_file → XlaComputation → compile → execute`.
//!
//! Interchange is HLO **text** — jax ≥ 0.5 serialized protos carry
//! 64-bit instruction ids this XLA rejects; the text parser reassigns
//! ids (see /opt/xla-example/README.md). All artifacts are lowered with
//! `return_tuple=True`, so results unwrap via `to_tuple1()`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A typed input tensor for an AOT executable.
#[derive(Debug, Clone)]
pub enum TensorArg {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl TensorArg {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            TensorArg::F32(dims, data) => {
                let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(data).reshape(&d)?
            }
            TensorArg::I32(dims, data) => {
                let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(data).reshape(&d)?
            }
        })
    }
}

/// PJRT CPU client + compiled-executable cache keyed by artifact path.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU runtime rooted at the artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtRuntime { client, artifacts_dir: artifacts_dir.to_path_buf(), cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<()> {
        let path = self.artifacts_dir.join(name);
        if self.cache.contains_key(&path) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("path utf8")?)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        self.cache.insert(path, exe);
        Ok(())
    }

    /// Execute an artifact. Returns the flattened f32 output of the
    /// single tuple element (all our artifacts return 1-tuples).
    pub fn run_f32(&mut self, name: &str, args: &[TensorArg]) -> Result<Vec<f32>> {
        self.load(name)?;
        let path = self.artifacts_dir.join(name);
        let exe = self.cache.get(&path).unwrap();
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let out = result.to_tuple1().context("unwrap 1-tuple")?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Names of the loaded executables (diagnostics).
    pub fn loaded(&self) -> Vec<String> {
        self.cache.keys().filter_map(|p| p.file_name()).map(|s| s.to_string_lossy().into_owned()).collect()
    }
}

// NOTE: runtime tests that need real artifacts live in
// rust/tests/runtime_parity.rs (integration), because they depend on
// `make artifacts` having run.
