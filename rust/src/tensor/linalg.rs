//! Small-matrix linear algebra for the learnable transformation:
//! LU inverse (for `P⁻¹ = P₁⁻¹ ⊗ P₂⁻¹`), Kronecker products, and a
//! Jacobi symmetric eigensolver (for the Gram-spectrum auxiliary loss
//! `L_sim = Tr(G) − Σ topK λ_i(G)`).
//!
//! These run on Kronecker *factors* (≤ 32×32) and sampled Gram matrices
//! (≤ 64×64), so O(n³) dense algorithms are the right tool.

use super::matrix::Matrix;

/// Invert a square matrix via LU decomposition with partial pivoting.
/// Returns `None` if singular (pivot below `1e-12`).
pub fn invert(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols, "invert: square required");
    let n = a.rows;
    // Augmented [A | I] Gauss-Jordan in f64 for stability.
    let mut aug = vec![0f64; n * 2 * n];
    for r in 0..n {
        for c in 0..n {
            aug[r * 2 * n + c] = a.at(r, c) as f64;
        }
        aug[r * 2 * n + n + r] = 1.0;
    }
    for col in 0..n {
        // Pivot: largest |value| in column.
        let mut piv = col;
        for r in col + 1..n {
            if aug[r * 2 * n + col].abs() > aug[piv * 2 * n + col].abs() {
                piv = r;
            }
        }
        if aug[piv * 2 * n + col].abs() < 1e-12 {
            return None;
        }
        if piv != col {
            for c in 0..2 * n {
                aug.swap(col * 2 * n + c, piv * 2 * n + c);
            }
        }
        let pval = aug[col * 2 * n + col];
        for c in 0..2 * n {
            aug[col * 2 * n + c] /= pval;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = aug[r * 2 * n + col];
            if f == 0.0 {
                continue;
            }
            for c in 0..2 * n {
                aug[r * 2 * n + c] -= f * aug[col * 2 * n + c];
            }
        }
    }
    let mut out = Matrix::zeros(n, n);
    for r in 0..n {
        for c in 0..n {
            out.data[r * n + c] = aug[r * 2 * n + n + c] as f32;
        }
    }
    Some(out)
}

/// Kronecker product A ⊗ B: shape (a.rows·b.rows, a.cols·b.cols).
pub fn kron(a: &Matrix, b: &Matrix) -> Matrix {
    let (ra, ca, rb, cb) = (a.rows, a.cols, b.rows, b.cols);
    let mut out = Matrix::zeros(ra * rb, ca * cb);
    for i in 0..ra {
        for j in 0..ca {
            let av = a.at(i, j);
            if av == 0.0 {
                continue;
            }
            for p in 0..rb {
                for q in 0..cb {
                    *out.at_mut(i * rb + p, j * cb + q) = av * b.at(p, q);
                }
            }
        }
    }
    out
}

/// Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues descending, eigenvectors as columns of V).
pub fn jacobi_eigh(a: &Matrix, max_sweeps: usize) -> (Vec<f32>, Matrix) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0f64;
        for r in 0..n {
            for c in r + 1..n {
                off += m[r * n + c] * m[r * n + c];
            }
        }
        if off.sqrt() < 1e-10 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-14 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q of M.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    // Extract + sort descending by eigenvalue.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[i * n + i], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let evals: Vec<f32> = pairs.iter().map(|(e, _)| *e as f32).collect();
    let mut evecs = Matrix::zeros(n, n);
    for (newc, (_, oldc)) in pairs.iter().enumerate() {
        for r in 0..n {
            evecs.data[r * n + newc] = v[r * n + oldc] as f32;
        }
    }
    (evals, evecs)
}

/// Matrix 1-norm condition estimate helper: ||A||_1.
pub fn norm1(a: &Matrix) -> f32 {
    let mut best = 0f32;
    for c in 0..a.cols {
        let mut s = 0f32;
        for r in 0..a.rows {
            s += a.at(r, c).abs();
        }
        best = best.max(s);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, check};
    use crate::util::rng::Rng;

    #[test]
    fn invert_identity() {
        let i = Matrix::eye(5);
        assert_close(&invert(&i).unwrap().data, &i.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn invert_roundtrip_property() {
        check(
            "A*inv(A)=I",
            20,
            |r| {
                let n = 1 + r.below(12);
                // Diagonally-dominant => well-conditioned.
                let mut a = Matrix::randn(n, n, r);
                for i in 0..n {
                    *a.at_mut(i, i) += 4.0;
                }
                a
            },
            |a| {
                let inv = invert(a).ok_or("singular")?;
                assert_close(&a.matmul(&inv).data, &Matrix::eye(a.rows).data, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn invert_singular_returns_none() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(invert(&a).is_none());
    }

    #[test]
    fn kron_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::eye(2);
        let k = kron(&a, &b);
        assert_eq!(k.rows, 4);
        assert_eq!(k.at(0, 0), 1.0);
        assert_eq!(k.at(1, 1), 1.0);
        assert_eq!(k.at(0, 2), 2.0);
        assert_eq!(k.at(2, 0), 3.0);
        assert_eq!(k.at(3, 3), 4.0);
        assert_eq!(k.at(0, 1), 0.0);
    }

    #[test]
    fn kron_inverse_is_inverse_of_kron() {
        let mut r = Rng::new(3);
        let mut p1 = Matrix::randn(3, 3, &mut r);
        let mut p2 = Matrix::randn(4, 4, &mut r);
        for i in 0..3 {
            *p1.at_mut(i, i) += 3.0;
        }
        for i in 0..4 {
            *p2.at_mut(i, i) += 3.0;
        }
        let big = kron(&p1, &p2);
        let inv_small = kron(&invert(&p1).unwrap(), &invert(&p2).unwrap());
        let prod = big.matmul(&inv_small);
        assert_close(&prod.data, &Matrix::eye(12).data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn jacobi_diagonal() {
        let d = Matrix::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let (evals, _) = jacobi_eigh(&d, 20);
        assert_close(&evals, &[3.0, 2.0, 1.0], 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn jacobi_reconstruction_property() {
        check(
            "V diag(l) V^T = A",
            15,
            |r| {
                let n = 2 + r.below(8);
                let b = Matrix::randn(n, n, r);
                b.matmul_bt(&b) // symmetric PSD
            },
            |a| {
                let n = a.rows;
                let (evals, v) = jacobi_eigh(a, 50);
                let mut d = Matrix::zeros(n, n);
                for i in 0..n {
                    d.data[i * n + i] = evals[i];
                }
                let rec = v.matmul(&d).matmul(&v.transpose());
                assert_close(&rec.data, &a.data, 1e-2, 1e-2)
            },
        );
    }

    #[test]
    fn jacobi_trace_preserved() {
        let mut r = Rng::new(5);
        let b = Matrix::randn(6, 6, &mut r);
        let a = b.matmul_bt(&b);
        let (evals, _) = jacobi_eigh(&a, 50);
        let tr: f32 = (0..6).map(|i| a.at(i, i)).sum();
        let se: f32 = evals.iter().sum();
        assert!((tr - se).abs() < 1e-2 * tr.abs().max(1.0));
    }
}
