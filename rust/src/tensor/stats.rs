//! Streaming / summary statistics used by the evaluation harness and the
//! activation-distribution figures (paper Figs. 2, 8-9).

/// Summary of a sample of f32 values.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f32,
    pub max: f32,
    pub max_abs: f32,
    pub p50: f32,
    pub p99: f32,
    /// Excess kurtosis — the paper's outlier indicator for activations.
    pub kurtosis: f64,
}

/// Compute a full summary (sorts a copy; fine for eval-sized samples).
pub fn summarize(xs: &[f32]) -> Summary {
    assert!(!xs.is_empty());
    let n = xs.len();
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
    let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
    let std = var.sqrt();
    let m4 = xs.iter().map(|&x| (x as f64 - mean).powi(4)).sum::<f64>() / n as f64;
    let kurtosis = if var > 0.0 { m4 / (var * var) - 3.0 } else { 0.0 };
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| sorted[(((n - 1) as f64) * p).round() as usize];
    Summary {
        n,
        mean,
        std,
        min: sorted[0],
        max: sorted[n - 1],
        max_abs: sorted[0].abs().max(sorted[n - 1].abs()),
        p50: pct(0.5),
        p99: pct(0.99),
        kurtosis,
    }
}

/// Percentile of a sample (p in [0,1]).
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty());
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted[(((xs.len() - 1) as f64) * p.clamp(0.0, 1.0)).round() as usize]
}

/// Histogram with uniform bins over [lo, hi].
pub fn histogram(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f32;
    for &x in xs {
        if x < lo || x > hi {
            continue;
        }
        let b = (((x - lo) / w) as usize).min(bins - 1);
        h[b] += 1;
    }
    h
}

/// Relative error ||a-b||_F / ||a||_F (weight-error figures 6-7).
pub fn rel_error(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum();
    let den: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum();
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant() {
        let s = summarize(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.max_abs, 2.0);
    }

    #[test]
    fn summary_known() {
        let s = summarize(&[-3.0, 0.0, 3.0]);
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.min, -3.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 0.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs: Vec<f32> = (0..101).map(|i| i as f32).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.5), 50.0);
    }

    #[test]
    fn histogram_sums() {
        let xs = vec![0.1, 0.2, 0.5, 0.9];
        let h = histogram(&xs, 0.0, 1.0, 4);
        assert_eq!(h.iter().sum::<usize>(), 4);
        assert_eq!(h[0], 2);
    }

    #[test]
    fn rel_error_zero_for_equal() {
        let a = vec![1.0, -2.0, 3.0];
        assert!(rel_error(&a, &a) < 1e-12);
        let b = vec![0.0, 0.0, 0.0];
        assert!((rel_error(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kurtosis_sign() {
        // Heavy-tailed sample has positive excess kurtosis.
        let mut xs = vec![0.0f32; 100];
        xs[0] = 50.0;
        xs[1] = -50.0;
        assert!(summarize(&xs).kurtosis > 1.0);
    }
}
