//! Row-major f32 matrix with the operations the quantization pipeline
//! and inference engine are built on.
//!
//! GEMM kernels: `matmul` (A·B), `matmul_bt` (A·Bᵀ — the inference
//! layout, weights stored (out, in)), `matmul_at` (Aᵀ·B — gradient
//! accumulation). The hot path is `matmul_bt`: both operands stream
//! row-major, so the inner loop is a pure dot product over contiguous
//! slices that LLVM auto-vectorizes; the §Perf pass unrolled it into
//! four accumulators (see EXPERIMENTS.md §Perf). Above
//! [`crate::util::parallel::PAR_MIN_WORK`] scalar ops, `matmul_bt`
//! fans output rows (or GEMV column chunks) across scoped threads —
//! bit-identical to the serial path (see DESIGN.md §6).

use crate::util::rng::Rng;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Gaussian random matrix (tests, synthetic workloads).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    /// Random ±1 matrix.
    pub fn rand_sign(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Matrix { rows, cols, data: (0..rows * cols).map(|_| rng.sign()).collect() }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// C = A · B.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul shape {}x{} · {}x{}", self.rows, self.cols, b.rows, b.cols);
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut out = Matrix::zeros(m, n);
        // ikj loop order: C row accumulates scaled B rows (contiguous).
        for i in 0..m {
            let arow = self.row(i);
            let crow = &mut out.data[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += a * bv;
                }
            }
        }
        out
    }

    /// C = A · Bᵀ — the inference layout (`y = x @ W^T`, W stored (out, in)).
    ///
    /// Thread-parallel over output rows (or over column chunks when
    /// m == 1, the GEMV decode shape); every `C[i,j]` is one `dot` in
    /// a fixed order, so the parallel result is bit-identical to the
    /// serial one.
    pub fn matmul_bt(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_bt shape {}x{} · ({}x{})^T", self.rows, self.cols, b.rows, b.cols);
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut out = Matrix::zeros(m, n);
        let nt = crate::util::parallel::threads_for(m * k * n);
        if m == 1 && nt > 1 {
            let arow = self.row(0);
            crate::util::parallel::par_row_ranges_with(nt, &mut out.data, 1, |j0, chunk| {
                for (jj, ov) in chunk.iter_mut().enumerate() {
                    let j = j0 + jj;
                    *ov = dot(arow, &b.data[j * k..(j + 1) * k]);
                }
            });
        } else {
            crate::util::parallel::par_row_ranges_with(nt, &mut out.data, n, |i0, chunk| {
                for (ii, orow) in chunk.chunks_mut(n).enumerate() {
                    let arow = self.row(i0 + ii);
                    for (j, ov) in orow.iter_mut().enumerate() {
                        *ov = dot(arow, &b.data[j * k..(j + 1) * k]);
                    }
                }
            });
        }
        out
    }

    /// C = Aᵀ · B (gradient accumulation).
    pub fn matmul_at(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, b.rows, "matmul_at shape ({}x{})^T · {}x{}", self.rows, self.cols, b.rows, b.cols);
        let (k, m, n) = (self.rows, self.cols, b.cols);
        let mut out = Matrix::zeros(m, n);
        for kk in 0..k {
            let arow = self.row(kk);
            let brow = b.row(kk);
            for (i, &a) in arow.iter().enumerate().take(m) {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (ov, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *ov += a * bv;
                }
            }
        }
        out
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix { rows: self.rows, cols: self.cols, data: self.data.iter().map(|a| a * s).collect() }
    }

    /// Squared Frobenius norm.
    pub fn fro2(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Row means, length `rows`.
    pub fn row_means(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).iter().sum::<f32>() / self.cols as f32)
            .collect()
    }

    /// Mean of |x| per row.
    pub fn row_abs_means(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).iter().map(|x| x.abs()).sum::<f32>() / self.cols as f32)
            .collect()
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }
}

/// Unrolled dot product over contiguous slices — the GEMM inner loop.
/// Dispatches on the global [`crate::util::simd`] level: the scalar
/// lane is the historical 4-accumulator unroll (bit-identical to
/// pre-SIMD outputs under `PALLAS_SIMD=scalar`); the vector lanes use
/// explicit `mul_add` in a wider unroll, which contracts and
/// reassociates — ULP-bounded rather than bit-identical against
/// scalar (bound asserted in `rust/tests/simd_equivalence.rs`). Each
/// `matmul_bt` call resolves the level once, so parallel splits and
/// the serial reference always agree bitwise.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_with_level(crate::util::simd::active(), a, b)
}

/// [`dot`] at an explicit dispatch level.
#[inline]
pub fn dot_with_level(level: crate::util::simd::Level, a: &[f32], b: &[f32]) -> f32 {
    use crate::util::simd::Level;
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 | Level::Avx512 => unsafe { dot_lanes::fma(a, b) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { dot_lanes::fma(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// The scalar oracle: the pre-SIMD 4-accumulator unroll, association
/// preserved exactly.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// FMA dot body: `W` independent lane accumulators fed by explicit
/// `mul_add` (deterministic per level — Rust only contracts where the
/// source says so), reduced in a fixed order. Instantiated inside the
/// feature-gated wrappers so LLVM lowers `mul_add` to real `vfmadd` /
/// `fmla` and vectorizes the lane loop.
#[inline(always)]
fn dot_fma_generic<const W: usize>(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / W;
    let mut acc = [0f32; W];
    for c in 0..chunks {
        let i = c * W;
        for l in 0..W {
            acc[l] = a[i + l].mul_add(b[i + l], acc[l]);
        }
    }
    let mut s = 0f32;
    for v in acc {
        s += v;
    }
    for i in chunks * W..n {
        s = a[i].mul_add(b[i], s);
    }
    s
}

#[cfg(target_arch = "x86_64")]
mod dot_lanes {
    /// # Safety
    /// Caller must ensure AVX2+FMA (guaranteed by dispatching on
    /// [`crate::util::simd::Level`]).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn fma(a: &[f32], b: &[f32]) -> f32 {
        super::dot_fma_generic::<16>(a, b)
    }
}

#[cfg(target_arch = "aarch64")]
mod dot_lanes {
    /// # Safety
    /// Caller must ensure NEON (guaranteed by dispatching on
    /// [`crate::util::simd::Level`]).
    #[target_feature(enable = "neon")]
    pub unsafe fn fma(a: &[f32], b: &[f32]) -> f32 {
        super::dot_fma_generic::<16>(a, b)
    }
}

/// y += alpha * x (axpy).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x.iter()) {
        *yv += alpha * xv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, check};

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive_property() {
        check(
            "matmul==naive",
            20,
            |r| {
                let (m, k, n) = (1 + r.below(12), 1 + r.below(12), 1 + r.below(12));
                (Matrix::randn(m, k, r), Matrix::randn(k, n, r))
            },
            |(a, b)| assert_close(&a.matmul(b).data, &naive_matmul(a, b).data, 1e-4, 1e-4),
        );
    }

    #[test]
    fn matmul_bt_matches_transpose() {
        check(
            "matmul_bt==matmul(transpose)",
            20,
            |r| {
                let (m, k, n) = (1 + r.below(10), 1 + r.below(16), 1 + r.below(10));
                (Matrix::randn(m, k, r), Matrix::randn(n, k, r))
            },
            |(a, b)| assert_close(&a.matmul_bt(b).data, &a.matmul(&b.transpose()).data, 1e-4, 1e-4),
        );
    }

    #[test]
    fn matmul_at_matches_transpose() {
        check(
            "matmul_at==transpose.matmul",
            20,
            |r| {
                let (k, m, n) = (1 + r.below(10), 1 + r.below(10), 1 + r.below(10));
                (Matrix::randn(k, m, r), Matrix::randn(k, n, r))
            },
            |(a, b)| assert_close(&a.matmul_at(b).data, &a.transpose().matmul(b).data, 1e-4, 1e-4),
        );
    }

    #[test]
    fn matmul_bt_parallel_paths_bitwise_serial() {
        // Shapes crossing PAR_MIN_WORK exercise both parallel splits
        // (row split for m>1, column split for m==1); results must be
        // bit-identical to the per-element serial reference.
        let mut r = Rng::new(31);
        let a = Matrix::randn(4, 64, &mut r);
        let b = Matrix::randn(300, 64, &mut r); // 4*64*300 > PAR_MIN_WORK
        let par = a.matmul_bt(&b);
        for i in 0..a.rows {
            for j in 0..b.rows {
                assert_eq!(par.at(i, j).to_bits(), dot(a.row(i), b.row(j)).to_bits());
            }
        }
        let a1 = Matrix::randn(1, 300, &mut r);
        let b1 = Matrix::randn(250, 300, &mut r); // 1*300*250 > PAR_MIN_WORK
        let par1 = a1.matmul_bt(&b1);
        for j in 0..b1.rows {
            assert_eq!(par1.at(0, j).to_bits(), dot(a1.row(0), b1.row(j)).to_bits());
        }
    }

    #[test]
    fn transpose_involution() {
        let mut r = Rng::new(9);
        let a = Matrix::randn(5, 7, &mut r);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn identity_is_neutral() {
        let mut r = Rng::new(10);
        let a = Matrix::randn(6, 6, &mut r);
        let i = Matrix::eye(6);
        assert_close(&a.matmul(&i).data, &a.data, 1e-6, 1e-6).unwrap();
        assert_close(&i.matmul(&a).data, &a.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn dot_matches_naive() {
        check(
            "dot==naive",
            30,
            |r| {
                let n = r.below(40);
                (r.normal_vec(n), r.normal_vec(n))
            },
            |(a, b)| {
                let naive: f32 = a.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
                assert_close(&[dot(a, b)], &[naive], 1e-4, 1e-4)
            },
        );
    }

    #[test]
    fn dot_lanes_within_f64_bound() {
        // The FMA lanes reassociate; the contract is an asserted error
        // bound vs the f64 reference, which the scalar oracle must
        // also satisfy (and Scalar must equal dot_scalar bitwise).
        let mut r = Rng::new(77);
        for n in [0usize, 1, 7, 8, 15, 16, 64, 1000] {
            let a = r.normal_vec(n);
            let b = r.normal_vec(n);
            let ref64: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let mag: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            let bound = 4.0 * n.max(1) as f64 * f32::EPSILON as f64 * mag + 1e-30;
            for l in crate::util::simd::supported_levels() {
                let d = dot_with_level(l, &a, &b) as f64;
                assert!((d - ref64).abs() <= bound, "n={n} {l:?}: |{d} - {ref64}| > {bound}");
            }
            let s = dot_with_level(crate::util::simd::Level::Scalar, &a, &b);
            assert_eq!(s.to_bits(), dot_scalar(&a, &b).to_bits());
        }
    }

    #[test]
    fn row_stats() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, -2.0, -3.0]);
        assert_eq!(m.row_means(), vec![2.0, -2.0]);
        assert_eq!(m.row_abs_means(), vec![2.0, 2.0]);
        assert_eq!(m.max_abs(), 3.0);
    }

    #[test]
    fn fro2_known() {
        let m = Matrix::from_vec(1, 3, vec![1.0, 2.0, 2.0]);
        assert!((m.fro2() - 9.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
