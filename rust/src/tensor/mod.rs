//! Dense numeric substrate: row-major f32 matrices, blocked GEMM,
//! small-matrix linear algebra (LU inverse, Kronecker products, Jacobi
//! symmetric eigendecomposition) and streaming statistics.
//!
//! Everything the quantizers, the learnable transformation and the
//! inference engine need — implemented from scratch (no BLAS in the
//! offline image) and tuned in the §Perf pass.

pub mod linalg;
pub mod matrix;
pub mod stats;

pub use matrix::Matrix;
