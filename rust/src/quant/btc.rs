//! The BTC-LLM method lane as a [`Quantizer`]: learnable
//! transformation fit per capture-site group (§4.2) → grouped ARB
//! binarization → either the salient-residual binary lane (the paper's
//! 1.11-bit row, `target_bits >= 1`) or the **shared binary codebook**
//! sub-1-bit lane (`target_bits < 1`).
//!
//! The codebook lane is the reason [`Quantizer`] has a `finalize`
//! hook: every layer's sign vectors must be collected before the
//! cross-layer codebook can be clustered (paper Alg. 3), so
//! `quantize_group` defers those layers and `finalize` builds the
//! codebook once and returns one [`CodebookLayer`] per deferred site.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::arb::{arb_quantize, ResidualBinary};
use super::binarize::BinaryLayer;
use super::codebook::{collect_vectors, BinaryCodebook, CodebookLayer};
use super::pipeline::{QuantConfig, QuantStats};
use super::quantizer::{QuantOutcome, Quantizer, SiteId};
use super::splits::{column_importance, salient_columns, split_columns};
use super::transform::{fit, FitConfig, Transform};
use crate::model::WeightBackend;
use crate::tensor::Matrix;

/// Snap column groups to `v`-block granularity (block importance =
/// sum of member columns) so the LUT-GEMM engine can fold per-group
/// scales into the gather.
pub fn block_aligned_split(importance: &[f64], n_splits: usize, v: usize) -> (Vec<u16>, usize) {
    if n_splits == 0 {
        return (vec![0u16; importance.len()], 1);
    }
    let nb = importance.len().div_ceil(v);
    let block_imp: Vec<f64> = (0..nb)
        .map(|b| importance[b * v..((b + 1) * v).min(importance.len())].iter().sum())
        .collect();
    let (bg, ng) = split_columns(&block_imp, n_splits);
    let col_group: Vec<u16> = (0..importance.len()).map(|c| bg[c / v]).collect();
    (col_group, ng)
}

/// BTC-LLM quantizer. Per-run state: the binarized layers awaiting the
/// shared codebook build.
#[derive(Debug)]
pub struct BtcQuantizer {
    target_bits: f64,
    v: usize,
    /// Codebook size, resolved once from [`QuantConfig::derived_c`].
    c: usize,
    em_iters: usize,
    n_splits: usize,
    salient_frac: f64,
    arb_iters: usize,
    transform_p: bool,
    transform_sigma: bool,
    transform_outer: usize,
    /// Binarized layers deferred to the codebook build, in
    /// `quantize_group` call order (matches the driver's site order).
    pending: Vec<BinaryLayer>,
}

impl BtcQuantizer {
    pub fn from_config(cfg: &QuantConfig) -> BtcQuantizer {
        BtcQuantizer {
            target_bits: cfg.target_bits,
            v: cfg.v,
            c: cfg.derived_c(),
            em_iters: cfg.em_iters,
            n_splits: cfg.n_splits,
            salient_frac: cfg.salient_frac,
            arb_iters: cfg.arb_iters,
            transform_p: cfg.transform_p,
            transform_sigma: cfg.transform_sigma,
            transform_outer: cfg.transform_outer,
            pending: Vec::new(),
        }
    }

    /// Sub-1-bit targets engage the shared codebook; >= 1.0 is the
    /// binary (no codebook) lane labelled 1.11 in the paper.
    fn uses_codebook(&self) -> bool {
        self.target_bits < 1.0
    }
}

impl Quantizer for BtcQuantizer {
    fn name(&self) -> String {
        "BTC-LLM".to_string()
    }

    fn fit_transform(&mut self, x: &Matrix, ws: &[&Matrix]) -> Result<Option<Transform>> {
        if !self.transform_p && !self.transform_sigma {
            return Ok(None);
        }
        let fit_cfg = FitConfig {
            outer_iters: self.transform_outer,
            learn_p: self.transform_p,
            learn_sigma: self.transform_sigma,
            n_splits: self.n_splits,
            ..Default::default()
        };
        let (t, _fit_stats) = fit(x, ws, &fit_cfg);
        Ok(Some(t))
    }

    fn quantize_group(
        &mut self,
        _site: &SiteId,
        weff: &Matrix,
        act_sq: &[f32],
    ) -> Result<QuantOutcome> {
        let imp = column_importance(weff, act_sq);
        if self.uses_codebook() {
            // Block-aligned groups, no salient residual (sub-1-bit
            // storage must stay mask-free).
            let (groups, ng) = block_aligned_split(&imp, self.n_splits, self.v);
            let bl = arb_quantize(weff, &groups, ng, self.arb_iters);
            self.pending.push(bl);
            Ok(QuantOutcome::Deferred)
        } else {
            // Binary lane (paper's 1.11-bit row).
            let (groups, ng) = split_columns(&imp, self.n_splits);
            let sal = salient_columns(&imp, self.salient_frac);
            Ok(QuantOutcome::Ready(Box::new(ResidualBinary::quantize(
                weff,
                &groups,
                ng,
                &sal,
                self.arb_iters,
            ))))
        }
    }

    fn finalize(&mut self, stats: &mut QuantStats) -> Result<Vec<Box<dyn WeightBackend>>> {
        if self.pending.is_empty() {
            return Ok(Vec::new());
        }
        let pending = std::mem::take(&mut self.pending);
        let mut all_vectors: Vec<u64> = Vec::new();
        let mut offsets = Vec::with_capacity(pending.len());
        for bl in &pending {
            offsets.push(all_vectors.len());
            all_vectors.extend(collect_vectors(bl, self.v));
        }
        if all_vectors.is_empty() {
            bail!("BTC codebook build: no sign vectors collected");
        }
        let (cb, assignments, build_stats) =
            BinaryCodebook::build(&all_vectors, self.v, self.c, self.em_iters);
        let cb = Arc::new(cb);
        stats.codebook_bits = cb.storage_bits();
        stats.codebook_stats = Some(build_stats);

        // Sample aux losses on the final sign vectors (diagnostics).
        let sample: Vec<Vec<f32>> = all_vectors
            .iter()
            .step_by((all_vectors.len() / 48).max(1))
            .take(48)
            .map(|&w| (0..self.v).map(|j| if w >> j & 1 == 1 { 1.0 } else { -1.0 }).collect())
            .collect();
        if sample.len() >= 4 {
            stats.aux_losses = Some(super::transform::aux_losses(&sample, 8));
        }

        let mut out: Vec<Box<dyn WeightBackend>> = Vec::with_capacity(pending.len());
        for (pi, bl) in pending.iter().enumerate() {
            let start = offsets[pi];
            let end = offsets.get(pi + 1).copied().unwrap_or(all_vectors.len());
            let idx = assignments[start..end].to_vec();
            out.push(Box::new(CodebookLayer::from_assignments(bl, cb.clone(), idx)));
        }
        Ok(out)
    }
}
