//! **Flash & Accurate Binary Codebook** — the paper's primary
//! contribution (§4.1, App. E/G).
//!
//! Clusters the length-`v` ±1 sub-vectors of binarized weight matrices
//! into `c` binary centroids with a binary-specialized K-means:
//!
//! 1. **Init**: unique-vector census; if `M <= c` the codebook is the
//!    unique set (exact, early termination); else top-`c` most frequent.
//! 2. **E-step**: exact-match hash fast path, otherwise nearest centroid
//!    under Hamming distance = one `XOR -> POPCNT` per candidate
//!    (`||b-c||² = 4·d_H`, paper Eq. 4-5).
//! 3. **M-step**: sign-of-mean majority vote per bit, `sign(0) = +1`.
//!
//! The EM loop runs over *unique* vectors weighted by frequency — an
//! exact reformulation that cuts work by the duplication factor the
//! paper's Figure 1 shows is large.
//!
//! Sub-vectors are packed into single `u64` words (`v <= 64`), so all
//! distances are single-word XOR+POPCNT.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::binarize::BinaryLayer;
use crate::bitops::PackedPlane;
use crate::engine::{ComputeEngine, EngineCtx, LutGemmEngine};
use crate::io::wire;
use crate::model::{BackendIoCtx, WeightBackend};
use crate::tensor::Matrix;
use crate::util::f16;

/// A binary codebook: `c` centroids of `v` bits each, packed one per u64.
#[derive(Debug, Clone)]
pub struct BinaryCodebook {
    pub v: usize,
    pub words: Vec<u64>,
}

/// Build statistics (reported by the benches).
#[derive(Debug, Clone, Default)]
pub struct BuildStats {
    pub n_vectors: usize,
    pub n_unique: usize,
    pub c: usize,
    pub iters_run: usize,
    /// True when unique <= c: exact reconstruction, single pass.
    pub exact: bool,
    /// Total Hamming error (sum of 4*d_H) at convergence.
    pub total_sq_err: u64,
}

#[inline]
fn vmask(v: usize) -> u64 {
    if v == 64 {
        u64::MAX
    } else {
        (1u64 << v) - 1
    }
}

impl BinaryCodebook {
    pub fn c(&self) -> usize {
        self.words.len()
    }

    /// Index bits per sub-vector (ceil(log2 c), >= 1).
    pub fn index_bits(&self) -> usize {
        (usize::BITS - (self.c().saturating_sub(1)).leading_zeros()).max(1) as usize
    }

    /// Codebook storage in bits: c centroids x v bits (binary!).
    pub fn storage_bits(&self) -> usize {
        self.c() * self.v
    }

    /// Actually-resident bytes: centroids are kept one-per-u64 for the
    /// XOR/POPCNT hot paths, so RAM holds 64 bits per centroid even
    /// when `v < 64`. The QLM1 v3 wire packs them to `v` bits.
    pub fn resident_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Decode centroid `k` to ±1 values.
    pub fn decode(&self, k: usize) -> Vec<f32> {
        let w = self.words[k];
        (0..self.v).map(|j| if w >> j & 1 == 1 { 1.0 } else { -1.0 }).collect()
    }

    /// Nearest centroid for a packed sub-vector (lowest index wins ties).
    pub fn assign(&self, vec_word: u64) -> u32 {
        let mask = vmask(self.v);
        let x = vec_word & mask;
        let mut best = (u32::MAX, 0u32);
        for (k, &cw) in self.words.iter().enumerate() {
            let d = (x ^ cw).count_ones();
            if d < best.0 {
                best = (d, k as u32);
                if d == 0 {
                    break;
                }
            }
        }
        best.1
    }

    /// Build a codebook from packed sub-vectors (Alg. 3). `c_target`
    /// caps the codebook size; `max_iter` caps EM rounds (paper: 5).
    pub fn build(vectors: &[u64], v: usize, c_target: usize, max_iter: usize) -> (BinaryCodebook, Vec<u32>, BuildStats) {
        assert!(v >= 1 && v <= 64, "v must be in 1..=64");
        assert!(!vectors.is_empty());
        let mask = vmask(v);

        // (1) Unique census.
        let mut counts: HashMap<u64, u32> = HashMap::new();
        for &raw in vectors {
            *counts.entry(raw & mask).or_insert(0) += 1;
        }
        let n_unique = counts.len();
        let mut uniq: Vec<(u64, u32)> = counts.into_iter().collect();
        // Sort by frequency desc, then value for determinism.
        uniq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));

        let mut stats = BuildStats {
            n_vectors: vectors.len(),
            n_unique,
            ..Default::default()
        };

        if n_unique <= c_target {
            // Early exact termination: codebook = unique set.
            let words: Vec<u64> = uniq.iter().map(|&(w, _)| w).collect();
            let cb = BinaryCodebook { v, words };
            let lookup: HashMap<u64, u32> =
                cb.words.iter().enumerate().map(|(k, &w)| (w, k as u32)).collect();
            let assignments = vectors.iter().map(|&x| lookup[&(x & mask)]).collect();
            stats.c = cb.c();
            stats.exact = true;
            stats.iters_run = 1;
            return (cb, assignments, stats);
        }

        // (2) Init with the top-c most frequent unique vectors.
        let c = c_target.max(1);
        let mut words: Vec<u64> = uniq.iter().take(c).map(|&(w, _)| w).collect();

        // EM over unique vectors with frequency weights.
        let mut assign_u: Vec<u32> = vec![0; n_unique];
        let mut iters_run = 0;
        for _ in 0..max_iter.max(1) {
            iters_run += 1;
            // E-step (exact-match fast path via hash).
            let lookup: HashMap<u64, u32> =
                words.iter().enumerate().map(|(k, &w)| (w, k as u32)).collect();
            let mut changed = false;
            for (ui, &(uw, _)) in uniq.iter().enumerate() {
                let k = if let Some(&k) = lookup.get(&uw) {
                    k
                } else {
                    let mut best = (u32::MAX, 0u32);
                    for (k, &cw) in words.iter().enumerate() {
                        let d = (uw ^ cw).count_ones();
                        if d < best.0 {
                            best = (d, k as u32);
                        }
                    }
                    best.1
                };
                if assign_u[ui] != k {
                    assign_u[ui] = k;
                    changed = true;
                }
            }
            if !changed && iters_run > 1 {
                break;
            }
            // M-step: weighted majority vote per bit, sign(0) = +1.
            let mut plus = vec![0u64; c * v];
            let mut tot = vec![0u64; c];
            for (ui, &(uw, cnt)) in uniq.iter().enumerate() {
                let k = assign_u[ui] as usize;
                tot[k] += cnt as u64;
                let base = k * v;
                for j in 0..v {
                    if uw >> j & 1 == 1 {
                        plus[base + j] += cnt as u64;
                    }
                }
            }
            for (k, w) in words.iter_mut().enumerate() {
                if tot[k] == 0 {
                    continue; // empty cluster: keep (paper skips)
                }
                let mut nw = 0u64;
                for j in 0..v {
                    // bit=1 (+1) when mean >= 0, i.e. 2*plus >= total.
                    if 2 * plus[k * v + j] >= tot[k] {
                        nw |= 1u64 << j;
                    }
                }
                *w = nw;
            }
        }

        let cb = BinaryCodebook { v, words };
        // Final E-step refresh so assignments are optimal w.r.t. the
        // *returned* centroids (the loop may exit right after an M-step).
        let lookup: HashMap<u64, u32> =
            cb.words.iter().enumerate().map(|(k, &w)| (w, k as u32)).collect();
        for (ui, &(uw, _)) in uniq.iter().enumerate() {
            assign_u[ui] = if let Some(&k) = lookup.get(&uw) {
                k
            } else {
                let mut best = (u32::MAX, 0u32);
                for (k, &cw) in cb.words.iter().enumerate() {
                    let d = (uw ^ cw).count_ones();
                    if d < best.0 {
                        best = (d, k as u32);
                    }
                }
                best.1
            };
        }
        let uniq_to_k: HashMap<u64, u32> = uniq
            .iter()
            .enumerate()
            .map(|(ui, &(uw, _))| (uw, assign_u[ui]))
            .collect();
        let mut total_sq_err = 0u64;
        let assignments: Vec<u32> = vectors
            .iter()
            .map(|&x| {
                let k = uniq_to_k[&(x & mask)];
                total_sq_err += 4 * ((x & mask) ^ cb.words[k as usize]).count_ones() as u64;
                k
            })
            .collect();
        stats.c = cb.c();
        stats.iters_run = iters_run;
        stats.total_sq_err = total_sq_err;
        (cb, assignments, stats)
    }
}

/// Chunk a binarized layer's sign matrix into packed length-`v`
/// sub-vector words, **per row** (blocks never straddle row
/// boundaries — required by the LUT-GEMM engine's index-gather), with
/// each row tail padded by alternating +1/-1 (paper Alg. 1/2).
pub fn collect_vectors(bl: &BinaryLayer, v: usize) -> Vec<u64> {
    let per_row = bl.cols.div_ceil(v);
    let mut out = Vec::with_capacity(bl.rows * per_row);
    for r in 0..bl.rows {
        let mut word = 0u64;
        let mut nbits = 0usize;
        for c in 0..bl.cols {
            if bl.b.get(r, c) > 0.0 {
                word |= 1u64 << nbits;
            }
            nbits += 1;
            if nbits == v {
                out.push(word);
                word = 0;
                nbits = 0;
            }
        }
        if nbits > 0 {
            let mut j = nbits;
            let mut plus = true;
            while j < v {
                if plus {
                    word |= 1u64 << j;
                }
                plus = !plus;
                j += 1;
            }
            out.push(word);
        }
    }
    out
}

/// Bits needed for a group id (`0` when there is a single group —
/// matching the storage accounting, which charges nothing for it).
fn group_id_bits(n_groups: usize) -> usize {
    if n_groups > 1 {
        (usize::BITS - (n_groups - 1).leading_zeros()) as usize
    } else {
        0
    }
}

/// A codebook-compressed binarized layer (the deployed BTC format):
/// a *packed* plane of indices into a shared [`BinaryCodebook`] +
/// half-precision scales/bias and packed column-group ids carried over
/// from the underlying [`BinaryLayer`]. Everything is stored at the
/// width the accounting claims (`index_bits()` per index, 16 bits per
/// scale, `ceil(log2 n_groups)` per group id), so resident bytes ==
/// accounted bits — the paper's sub-1-bit number is what actually
/// sits in RAM.
#[derive(Debug, Clone)]
pub struct CodebookLayer {
    pub rows: usize,
    pub cols: usize,
    pub v: usize,
    /// Centroid indices, `rows x blocks_per_row` at
    /// `codebook.index_bits()` bits each.
    pub idx: PackedPlane,
    pub codebook: Arc<BinaryCodebook>,
    /// Per-(row, group) scales as IEEE binary16 bits (decode on use).
    pub alpha: Vec<u16>,
    /// Per-row bias as IEEE binary16 bits (decode on use).
    pub mu: Vec<u16>,
    /// Packed per-column group ids (`1 x cols`); empty when
    /// `n_groups == 1` (every column is group 0).
    pub groups: PackedPlane,
    pub n_groups: usize,
}

impl CodebookLayer {
    /// Assemble from dense parts, packing indices/groups and rounding
    /// scales to their shipping precision (f16, nearest-even).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rows: usize,
        cols: usize,
        codebook: Arc<BinaryCodebook>,
        idx: &[u32],
        alpha: &[f32],
        mu: &[f32],
        col_group: &[u16],
        n_groups: usize,
    ) -> CodebookLayer {
        let v = codebook.v;
        let nb = cols.div_ceil(v);
        assert_eq!(idx.len(), rows * nb, "index count != rows * blocks_per_row");
        assert_eq!(mu.len(), rows);
        assert_eq!(alpha.len(), rows * n_groups);
        assert_eq!(col_group.len(), cols);
        let k = codebook.index_bits();
        CodebookLayer {
            rows,
            cols,
            v,
            idx: PackedPlane::from_u32s(rows, nb, k, idx),
            codebook,
            alpha: f16::encode_vec(alpha),
            mu: f16::encode_vec(mu),
            groups: pack_groups(col_group, n_groups),
            n_groups,
        }
    }

    /// Compress a binarized layer against a shared codebook.
    pub fn from_binary(bl: &BinaryLayer, codebook: Arc<BinaryCodebook>) -> CodebookLayer {
        let vectors = collect_vectors(bl, codebook.v);
        let idx: Vec<u32> = vectors.iter().map(|&w| codebook.assign(w)).collect();
        Self::new(bl.rows, bl.cols, codebook, &idx, &bl.alpha, &bl.mu, &bl.col_group, bl.n_groups)
    }

    /// Compress using precomputed assignments (from the builder, which
    /// already assigned this layer's vector slice).
    pub fn from_assignments(bl: &BinaryLayer, codebook: Arc<BinaryCodebook>, idx: Vec<u32>) -> CodebookLayer {
        Self::new(bl.rows, bl.cols, codebook, &idx, &bl.alpha, &bl.mu, &bl.col_group, bl.n_groups)
    }

    /// Blocks per row (last block of each row may be padding-extended).
    pub fn blocks_per_row(&self) -> usize {
        self.cols.div_ceil(self.v)
    }

    /// Group id of column `c`.
    #[inline]
    pub fn group(&self, c: usize) -> usize {
        if self.n_groups == 1 {
            0
        } else {
            self.groups.get(0, c) as usize
        }
    }

    /// Decode the per-column group ids (dense u16, for engine setup).
    pub fn col_groups(&self) -> Vec<u16> {
        (0..self.cols).map(|c| self.group(c) as u16).collect()
    }

    /// Decode the per-(row, group) scales to f32.
    pub fn alpha_f32(&self) -> Vec<f32> {
        f16::decode_vec(&self.alpha)
    }

    /// Decode the per-row biases to f32.
    pub fn mu_f32(&self) -> Vec<f32> {
        f16::decode_vec(&self.mu)
    }

    /// Decode the sign matrix (±1 dense, row-major), dropping per-row
    /// padding.
    pub fn decode_signs(&self) -> Vec<f32> {
        let per_row = self.blocks_per_row();
        let mut flat = Vec::with_capacity(self.rows * self.cols);
        let mut ibuf = vec![0u32; per_row];
        for r in 0..self.rows {
            self.idx.decode_range(r, 0, &mut ibuf);
            let mut row = Vec::with_capacity(per_row * self.v);
            for &k in &ibuf {
                row.extend(self.codebook.decode(k as usize));
            }
            row.truncate(self.cols);
            flat.extend(row);
        }
        flat
    }

    /// Dequantize to a dense matrix (scales decoded from f16 on use).
    pub fn reconstruct(&self) -> Matrix {
        let signs = self.decode_signs();
        let alpha = self.alpha_f32();
        let mu = self.mu_f32();
        let col_group = self.col_groups();
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let arow = &alpha[r * self.n_groups..(r + 1) * self.n_groups];
            let orow = out.row_mut(r);
            for c in 0..self.cols {
                orow[c] = arow[col_group[c] as usize] * signs[r * self.cols + c] + mu[r];
            }
        }
        out
    }

    pub fn error(&self, w: &Matrix) -> f64 {
        self.reconstruct().sub(w).fro2()
    }

    /// Per-layer storage bits: indices + fp16 scales + column groups.
    /// (Codebook bits are shared — see [`BinaryCodebook::storage_bits`].)
    pub fn storage_bits(&self) -> usize {
        let idx_bits = self.codebook.index_bits();
        let group_bits = self.cols * group_id_bits(self.n_groups);
        self.idx.len() * idx_bits + (self.alpha.len() + self.mu.len()) * 16 + group_bits
    }

    pub fn bits_per_weight(&self) -> f64 {
        self.storage_bits() as f64 / (self.rows * self.cols) as f64
    }
}

/// Pack per-column group ids; a single group packs to nothing.
fn pack_groups(col_group: &[u16], n_groups: usize) -> PackedPlane {
    let gk = group_id_bits(n_groups);
    if gk == 0 {
        return PackedPlane::zeros(0, 0, 1);
    }
    let vals: Vec<u32> = col_group.iter().map(|&g| g as u32).collect();
    PackedPlane::from_u32s(1, col_group.len(), gk, &vals)
}

impl WeightBackend for CodebookLayer {
    fn tag(&self) -> &'static str {
        "codebook"
    }

    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn reconstruct(&self) -> Matrix {
        CodebookLayer::reconstruct(self)
    }

    fn storage_bits(&self) -> usize {
        CodebookLayer::storage_bits(self)
    }

    fn resident_bytes(&self) -> usize {
        self.idx.storage_bytes()
            + self.groups.storage_bytes()
            + (self.alpha.len() + self.mu.len()) * 2
    }

    fn payload_bits_per_weight(&self) -> f64 {
        self.codebook.index_bits() as f64 * self.idx.len() as f64
            / (self.rows * self.cols) as f64
    }

    fn make_engine(&self) -> Option<Box<dyn ComputeEngine>> {
        self.make_engine_with(&EngineCtx::current())
    }

    fn make_engine_with(&self, ctx: &EngineCtx) -> Option<Box<dyn ComputeEngine>> {
        LutGemmEngine::try_with_ctx(self, ctx).map(|e| Box::new(e) as Box<dyn ComputeEngine>)
    }

    fn shared_codebook(&self) -> Option<Arc<BinaryCodebook>> {
        Some(self.codebook.clone())
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        // QLM1 v3 layout. The shared codebook itself is carried once by
        // the container header, not per layer. Indices and group ids go
        // out as unpadded bitstreams (in-memory row padding never
        // ships), streamed row by row so saving never densifies the
        // plane — the transient is one row's decode buffer, not a
        // plane-sized u32 vector.
        wire::w_u32(w, self.rows as u32)?;
        wire::w_u32(w, self.cols as u32)?;
        wire::w_u32(w, self.n_groups as u32)?;
        let mut bw = wire::BitWriter::new(w, self.codebook.index_bits())?;
        let mut ibuf = vec![0u32; self.idx.cols];
        for r in 0..self.idx.rows {
            self.idx.decode_range(r, 0, &mut ibuf);
            for &v in &ibuf {
                bw.push(v as u64)?;
            }
        }
        bw.finish()?;
        wire::w_u16s(w, &self.alpha)?;
        wire::w_u16s(w, &self.mu)?;
        if self.n_groups > 1 {
            let mut bw = wire::BitWriter::new(w, group_id_bits(self.n_groups))?;
            for &g in &self.groups.decode_row(0) {
                bw.push(g as u64)?;
            }
            bw.finish()?;
        }
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn WeightBackend> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Registered deserializer for the `codebook` tag. Requires the
/// container's shared codebook in the [`BackendIoCtx`]. Reads the v3
/// packed layout, or the v1/v2 dense layout (u32 indices, f32 scales,
/// u16 group ids) for older containers — the dense values are packed
/// on load, so old files land in the same sub-byte resident format.
pub fn read_backend(r: &mut dyn Read, ctx: &BackendIoCtx) -> Result<Box<dyn WeightBackend>> {
    let cb = ctx
        .codebook
        .clone()
        .context("codebook backend payload but the container has no shared codebook")?;
    let rows = wire::r_u32(r)? as usize;
    let cols = wire::r_u32(r)? as usize;
    let n_groups = wire::r_u32(r)? as usize;
    wire::check_dims("codebook backend", rows, cols)?;
    if n_groups == 0 || n_groups > cols {
        bail!("codebook backend: implausible n_groups {n_groups} for {cols} columns");
    }
    let nb = cols.div_ceil(cb.v);
    let n_idx = rows * nb;
    let kbits = cb.index_bits();
    let (idx, alpha, mu, col_group) = if ctx.version >= 3 {
        let idx = wire::r_packed_u32s(r, n_idx, kbits)?;
        let alpha = wire::r_u16s(r, rows * n_groups)?;
        let mu = wire::r_u16s(r, rows)?;
        let col_group: Vec<u16> = if n_groups > 1 {
            wire::r_packed_u32s(r, cols, group_id_bits(n_groups))?
                .into_iter()
                .map(|g| g as u16)
                .collect()
        } else {
            vec![0u16; cols]
        };
        (idx, alpha, mu, col_group)
    } else {
        let idx = wire::r_u32s(r, n_idx)?;
        // Pre-v3 files carried full f32 scales; round once to the f16
        // shipping precision the accounting always claimed.
        let alpha = f16::encode_vec(&wire::r_f32s(r, rows * n_groups)?);
        let mu = f16::encode_vec(&wire::r_f32s(r, rows)?);
        let col_group = wire::r_u16s(r, cols)?;
        (idx, alpha, mu, col_group)
    };
    if let Some(&k) = idx.iter().find(|&&k| k as usize >= cb.c()) {
        bail!("codebook backend: centroid index {k} out of range (c={})", cb.c());
    }
    if let Some(&g) = col_group.iter().find(|&&g| g as usize >= n_groups) {
        bail!("codebook backend: column group id {g} out of range (n_groups {n_groups})");
    }
    Ok(Box::new(CodebookLayer {
        rows,
        cols,
        v: cb.v,
        idx: PackedPlane::from_u32s(rows, nb, kbits, &idx),
        codebook: cb,
        alpha,
        mu,
        groups: pack_groups(&col_group, n_groups),
        n_groups,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn random_binary_layer(rng: &mut Rng, rows: usize, cols: usize) -> BinaryLayer {
        let w = Matrix::randn(rows, cols, rng);
        BinaryLayer::quantize(&w)
    }

    #[test]
    fn exact_when_unique_fits() {
        // Few distinct patterns, large c => exact reconstruction.
        let mut rng = Rng::new(1);
        let patterns = [0b1010u64, 0b0110u64, 0b1111u64];
        let vectors: Vec<u64> = (0..500).map(|_| *rng.choice(&patterns)).collect();
        let (cb, assign, stats) = BinaryCodebook::build(&vectors, 4, 16, 5);
        assert!(stats.exact);
        assert_eq!(cb.c(), 3);
        for (i, &k) in assign.iter().enumerate() {
            assert_eq!(cb.words[k as usize], vectors[i]);
        }
    }

    #[test]
    fn estep_assignment_is_optimal_property() {
        // Every vector's assigned centroid must be at minimal Hamming
        // distance among all centroids.
        check(
            "E-step optimality",
            10,
            |r: &mut Rng| {
                let v = 4 + r.below(12);
                let n = 200 + r.below(200);
                let vectors: Vec<u64> = (0..n).map(|_| r.next_u64() & vmask(v)).collect();
                (vectors, v)
            },
            |(vectors, v)| {
                let (cb, assign, _) = BinaryCodebook::build(vectors, *v, 16, 5);
                for (i, &x) in vectors.iter().enumerate() {
                    let d_assigned = (x ^ cb.words[assign[i] as usize]).count_ones();
                    for &cw in &cb.words {
                        if (x ^ cw).count_ones() < d_assigned {
                            return Err(format!("vector {i} not optimally assigned"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn em_error_not_worse_than_init() {
        // EM with majority-vote updates should beat (or match) the
        // frequency-only init codebook.
        let mut rng = Rng::new(3);
        // Clustered data: 8 true centers + bit noise.
        let centers: Vec<u64> = (0..8).map(|_| rng.next_u64() & vmask(16)).collect();
        let vectors: Vec<u64> = (0..2000)
            .map(|_| {
                let mut x = *rng.choice(&centers);
                for j in 0..16 {
                    if rng.uniform() < 0.05 {
                        x ^= 1 << j;
                    }
                }
                x
            })
            .collect();
        let err = |cb: &BinaryCodebook, asg: &[u32]| -> u64 {
            vectors
                .iter()
                .zip(asg)
                .map(|(&x, &k)| (x ^ cb.words[k as usize]).count_ones() as u64)
                .sum()
        };
        let (cb1, asg1, _) = BinaryCodebook::build(&vectors, 16, 8, 1);
        let (cb5, asg5, stats5) = BinaryCodebook::build(&vectors, 16, 8, 5);
        assert!(err(&cb5, &asg5) <= err(&cb1, &asg1), "EM must not regress");
        assert!(stats5.iters_run >= 1);
        // With 5% noise around 8 centers, EM should recover them well:
        // mean distance < 16 * 0.10.
        assert!((err(&cb5, &asg5) as f64 / vectors.len() as f64) < 1.6);
    }

    #[test]
    fn codebook_layer_roundtrip_when_exact() {
        let mut rng = Rng::new(4);
        let bl = random_binary_layer(&mut rng, 8, 32);
        let vectors = collect_vectors(&bl, 8);
        let (cb, assign, stats) = BinaryCodebook::build(&vectors, 8, 1 << 8, 5);
        assert!(stats.exact || cb.c() == 256);
        let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign.clone());
        // Packed indices round-trip losslessly.
        assert_eq!(cl.idx.to_u32s(), assign);
        // Exact codebook => identical sign matrix.
        assert_eq!(cl.decode_signs(), bl.b.unpack());
        // Reconstruction equals the BinaryLayer's with scales rounded
        // to their f16 shipping precision — bit-exactly.
        let a = cl.reconstruct();
        let alpha16 = f16::decode_vec(&f16::encode_vec(&bl.alpha));
        let mu16 = f16::decode_vec(&f16::encode_vec(&bl.mu));
        let signs = bl.b.unpack();
        for r in 0..bl.rows {
            for c in 0..bl.cols {
                let want = alpha16[r] * signs[r * bl.cols + c] + mu16[r];
                assert_eq!(a.at(r, c), want, "({r},{c})");
            }
        }
    }

    #[test]
    fn v3_payload_roundtrips_bit_identically_and_is_tight() {
        let mut rng = Rng::new(12);
        let w = Matrix::randn(6, 40, &mut rng);
        let groups: Vec<u16> = (0..40).map(|c| (c / 20) as u16).collect();
        let bl = crate::quant::arb::arb_quantize(&w, &groups, 2, 3);
        let vectors = collect_vectors(&bl, 10);
        let (cb, assign, _) = BinaryCodebook::build(&vectors, 10, 8, 5);
        let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
        let mut buf = Vec::new();
        WeightBackend::write_payload(&cl, &mut buf).unwrap();
        // Wire bytes equal the accounted layout exactly: dims + packed
        // indices + u16 scales + packed group ids. No padding ships.
        let expect = 12
            + (cl.idx.len() * cl.codebook.index_bits()).div_ceil(8)
            + (cl.alpha.len() + cl.mu.len()) * 2
            + cl.cols.div_ceil(8); // 1 bit per column for 2 groups
        assert_eq!(buf.len(), expect);
        assert_eq!(WeightBackend::wire_bytes(&cl), buf.len());
        let ctx = BackendIoCtx { codebook: Some(cl.codebook.clone()), ..Default::default() };
        let back = read_backend(&mut &buf[..], &ctx).unwrap();
        let bcl = back.as_any().downcast_ref::<CodebookLayer>().unwrap();
        assert_eq!(bcl.idx, cl.idx);
        assert_eq!(bcl.alpha, cl.alpha);
        assert_eq!(bcl.mu, cl.mu);
        assert_eq!(bcl.groups, cl.groups);
        assert_eq!(back.reconstruct().data, CodebookLayer::reconstruct(&cl).data);
    }

    #[test]
    fn resident_bytes_are_owned_buffer_sizes() {
        let mut rng = Rng::new(13);
        let bl = random_binary_layer(&mut rng, 64, 320);
        let vectors = collect_vectors(&bl, 16);
        let (cb, assign, _) = BinaryCodebook::build(&vectors, 16, 256, 3);
        let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
        let expect = cl.idx.storage_bytes()
            + cl.groups.storage_bytes()
            + (cl.alpha.len() + cl.mu.len()) * 2;
        assert_eq!(WeightBackend::resident_bytes(&cl), expect);
        // The resident plane really is sub-byte per index: 8-bit codes
        // over v=16 blocks, 20 blocks/row -> 160 bits -> 3 words/row.
        assert_eq!(cl.idx.storage_bytes(), 64 * 3 * 8);
    }

    #[test]
    fn codebook_error_at_least_binary_error_property() {
        // Lossy codebook reconstruction error >= the underlying binary
        // error (information can only be lost).
        check(
            "codebook >= binary err",
            8,
            |r: &mut Rng| Matrix::randn(8, 40, r),
            |w| {
                let bl = BinaryLayer::quantize(w);
                let vectors = collect_vectors(&bl, 10);
                let (cb, assign, _) = BinaryCodebook::build(&vectors, 10, 8, 5);
                let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
                let eb = bl.error(w);
                let ec = cl.error(w);
                if ec >= eb - 1e-6 {
                    Ok(())
                } else {
                    Err(format!("codebook err {ec} < binary err {eb}"))
                }
            },
        );
    }

    #[test]
    fn collect_vectors_pads_alternating() {
        let mut rng = Rng::new(5);
        let bl = random_binary_layer(&mut rng, 1, 5); // 5 bits, v=4 => pad 3
        let vecs = collect_vectors(&bl, 4);
        assert_eq!(vecs.len(), 2);
        // Second vector: bit0 = sign of element 4; bits 1..3 alternate +1,-1,+1.
        let w = vecs[1];
        assert_eq!(w >> 1 & 1, 1);
        assert_eq!(w >> 2 & 1, 0);
        assert_eq!(w >> 3 & 1, 1);
    }

    #[test]
    fn bits_per_weight_sub_one() {
        let mut rng = Rng::new(6);
        let bl = random_binary_layer(&mut rng, 64, 320);
        let vectors = collect_vectors(&bl, 16);
        let (cb, assign, _) = BinaryCodebook::build(&vectors, 16, 256, 3);
        let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
        // 8 index bits / 16 weights = 0.5 + scales => well below 1.
        assert!(cl.bits_per_weight() < 1.0, "bits {}", cl.bits_per_weight());
    }

    #[test]
    fn deterministic_build() {
        let mut rng = Rng::new(7);
        let vectors: Vec<u64> = (0..500).map(|_| rng.next_u64() & vmask(12)).collect();
        let (cb1, a1, _) = BinaryCodebook::build(&vectors, 12, 32, 5);
        let (cb2, a2, _) = BinaryCodebook::build(&vectors, 12, 32, 5);
        assert_eq!(cb1.words, cb2.words);
        assert_eq!(a1, a2);
    }

    #[test]
    fn index_bits_formula() {
        let cb = BinaryCodebook { v: 8, words: vec![0; 9] };
        assert_eq!(cb.index_bits(), 4); // ceil(log2 9)
        let cb2 = BinaryCodebook { v: 8, words: vec![0; 256] };
        assert_eq!(cb2.index_bits(), 8);
    }

    use super::vmask;
}
