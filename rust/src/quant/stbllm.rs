//! STBLLM baseline (Dong et al., ICLR 2025): structured N:M sparse
//! binarization — in every group of M consecutive weights, keep the N
//! most important as ±alpha, prune the rest to zero.
//!
//! Storage accounting exposes the paper's core critique: the N:M mask
//! costs `ceil(log2 C(M,N))` bits per group on top of the N sign bits,
//! so "0.8-bit" STBLLM configurations are > 1 bit of real storage
//! (intro example: 2:4 = 1.25 bits/weight). We report both the nominal
//! (mask-free) and measured figures.

use std::io::{Read, Write};

use anyhow::{bail, Result};

use super::quantizer::{QuantOutcome, Quantizer, SiteId};
use crate::io::wire;
use crate::model::{BackendIoCtx, WeightBackend};
use crate::tensor::Matrix;

/// N:M structured sparse binary layer.
#[derive(Debug, Clone)]
pub struct NmSparseBinary {
    pub rows: usize,
    pub cols: usize,
    pub n: usize,
    pub m: usize,
    /// Per-row scale.
    pub alpha: Vec<f32>,
    /// Per-row bias (applied to kept positions only).
    pub mu: Vec<f32>,
    /// Dense ternary matrix in {-1, 0, +1} (kept signs / pruned zeros).
    /// Kept dense for clarity; storage_bits() reports the packed cost.
    pub tern: Vec<i8>,
}

/// Binomial coefficient (small arguments).
pub fn binom(m: u64, n: u64) -> u64 {
    if n > m {
        return 0;
    }
    let n = n.min(m - n);
    let mut num = 1u64;
    let mut den = 1u64;
    for i in 0..n {
        num *= m - i;
        den *= i + 1;
    }
    num / den
}

impl NmSparseBinary {
    /// Quantize with N:M sparsity. Importance of an element is
    /// `|w̃| * act_sq[col]` (activation-aware magnitude pruning).
    pub fn quantize(w: &Matrix, act_sq: &[f32], n: usize, m: usize) -> NmSparseBinary {
        assert!(n >= 1 && n <= m, "need 1 <= N <= M");
        let (rows, cols) = (w.rows, w.cols);
        let mu = w.row_means();
        let mut tern = vec![0i8; rows * cols];
        let mut alpha = vec![0f32; rows];
        for r in 0..rows {
            let wrow = w.row(r);
            let mut kept_abs_sum = 0f64;
            let mut kept_count = 0usize;
            let mut c0 = 0;
            while c0 < cols {
                let end = (c0 + m).min(cols);
                // Rank elements of this group by importance.
                let mut idx: Vec<usize> = (c0..end).collect();
                idx.sort_by(|&a, &b| {
                    let ia = ((wrow[a] - mu[r]).abs()
                        * act_sq.get(a).copied().unwrap_or(1.0).sqrt()) as f64;
                    let ib = ((wrow[b] - mu[r]).abs()
                        * act_sq.get(b).copied().unwrap_or(1.0).sqrt()) as f64;
                    ib.partial_cmp(&ia).unwrap()
                });
                let keep = n.min(end - c0);
                for &c in idx.iter().take(keep) {
                    let t = wrow[c] - mu[r];
                    tern[r * cols + c] = if t >= 0.0 { 1 } else { -1 };
                    kept_abs_sum += t.abs() as f64;
                    kept_count += 1;
                }
                c0 = end;
            }
            alpha[r] = if kept_count > 0 { (kept_abs_sum / kept_count as f64) as f32 } else { 0.0 };
        }
        NmSparseBinary { rows, cols, n, m, alpha, mu, tern }
    }

    pub fn reconstruct(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let orow = out.row_mut(r);
            for c in 0..self.cols {
                let t = self.tern[r * self.cols + c];
                if t != 0 {
                    orow[c] = self.alpha[r] * t as f32 + self.mu[r];
                }
            }
        }
        out
    }

    pub fn error(&self, w: &Matrix) -> f64 {
        self.reconstruct().sub(w).fro2()
    }

    /// Nominal bits/weight under STBLLM's own (mask-free) accounting.
    pub fn nominal_bits(&self) -> f64 {
        self.n as f64 / self.m as f64
    }

    /// Honest storage: sign bits for kept + mask bits per group + fp16
    /// scales (the intro's 1.25-bit example for 2:4).
    pub fn storage_bits(&self) -> usize {
        let groups_per_row = self.cols.div_ceil(self.m);
        let mask_bits = 64 - (binom(self.m as u64, self.n as u64).saturating_sub(1)).leading_zeros() as usize;
        let per_row = groups_per_row * (self.n + mask_bits);
        self.rows * per_row + (self.alpha.len() + self.mu.len()) * 16
    }

    pub fn bits_per_weight(&self) -> f64 {
        self.storage_bits() as f64 / (self.rows * self.cols) as f64
    }

    /// Bit cost of one group's combination mask: `ceil(log2 C(M,N))`.
    pub fn mask_bits(n: usize, m: usize) -> usize {
        64 - (binom(m as u64, n as u64).saturating_sub(1)).leading_zeros() as usize
    }

    /// Validate the N:M structural invariant.
    pub fn is_valid_nm(&self) -> bool {
        for r in 0..self.rows {
            let mut c0 = 0;
            while c0 < self.cols {
                let end = (c0 + self.m).min(self.cols);
                let nz = (c0..end).filter(|&c| self.tern[r * self.cols + c] != 0).count();
                if nz > self.n {
                    return false;
                }
                c0 = end;
            }
        }
        true
    }
}

impl WeightBackend for NmSparseBinary {
    fn tag(&self) -> &'static str {
        "nm-sparse"
    }

    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn reconstruct(&self) -> Matrix {
        NmSparseBinary::reconstruct(self)
    }

    fn storage_bits(&self) -> usize {
        NmSparseBinary::storage_bits(self)
    }

    fn resident_bytes(&self) -> usize {
        // The ternary matrix is held dense (one byte per element) —
        // far wider than the packed accounting; reported honestly.
        self.tern.len() + (self.alpha.len() + self.mu.len()) * 4
    }

    fn payload_bits_per_weight(&self) -> f64 {
        (self.n + Self::mask_bits(self.n, self.m)) as f64 / self.m as f64
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        wire::w_u32(w, self.rows as u32)?;
        wire::w_u32(w, self.cols as u32)?;
        wire::w_u32(w, self.n as u32)?;
        wire::w_u32(w, self.m as u32)?;
        wire::w_f32s(w, &self.alpha)?;
        wire::w_f32s(w, &self.mu)?;
        let bytes: Vec<u8> = self.tern.iter().map(|&t| t as u8).collect();
        w.write_all(&bytes)?;
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn WeightBackend> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Registered deserializer for the `nm-sparse` tag.
pub fn read_backend(r: &mut dyn Read, _ctx: &BackendIoCtx) -> Result<Box<dyn WeightBackend>> {
    let rows = wire::r_u32(r)? as usize;
    let cols = wire::r_u32(r)? as usize;
    let n = wire::r_u32(r)? as usize;
    let m = wire::r_u32(r)? as usize;
    wire::check_dims("nm-sparse backend", rows, cols)?;
    if n == 0 || m == 0 || n > m || m > 1024 {
        bail!("nm-sparse backend: implausible N:M = {n}:{m}");
    }
    let alpha = wire::r_f32s(r, rows)?;
    let mu = wire::r_f32s(r, rows)?;
    let mut bytes = vec![0u8; rows * cols];
    r.read_exact(&mut bytes)?;
    let tern: Vec<i8> = bytes.into_iter().map(|b| b as i8).collect();
    if let Some(&t) = tern.iter().find(|&&t| !(-1..=1).contains(&t)) {
        bail!("nm-sparse backend: ternary value {t} out of {{-1,0,1}}");
    }
    Ok(Box::new(NmSparseBinary { rows, cols, n, m, alpha, mu, tern }))
}

/// The `stbllm` method lane: activation-aware N:M structured sparse
/// binarization of every linear.
#[derive(Debug)]
pub struct StbllmQuantizer {
    pub n: usize,
    pub m: usize,
}

impl Quantizer for StbllmQuantizer {
    fn name(&self) -> String {
        "STBLLM".to_string()
    }

    fn quantize_group(
        &mut self,
        _site: &SiteId,
        weff: &Matrix,
        act_sq: &[f32],
    ) -> Result<QuantOutcome> {
        Ok(QuantOutcome::Ready(Box::new(NmSparseBinary::quantize(
            weff, act_sq, self.n, self.m,
        ))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn binom_known() {
        assert_eq!(binom(4, 2), 6);
        assert_eq!(binom(8, 4), 70);
        assert_eq!(binom(5, 0), 1);
        assert_eq!(binom(3, 5), 0);
    }

    #[test]
    fn intro_example_2_4_is_1_25_bits() {
        // Paper intro: 2:4 => (2 signs + 3 mask bits)/4 = 1.25 bits/weight
        // (excluding scales).
        let mut rng = Rng::new(1);
        let w = Matrix::randn(128, 256, &mut rng);
        let q = NmSparseBinary::quantize(&w, &[], 2, 4);
        let no_scale_bits = q.storage_bits() - (q.alpha.len() + q.mu.len()) * 16;
        let per_weight = no_scale_bits as f64 / (q.rows * q.cols) as f64;
        assert!((per_weight - 1.25).abs() < 1e-9, "{per_weight}");
    }

    #[test]
    fn nm_invariant_property() {
        check(
            "N:M validity",
            15,
            |r: &mut Rng| {
                let rows = 1 + r.below(10);
                let cols = 8 * (1 + r.below(6));
                let n = 1 + r.below(3);
                let m = n + 1 + r.below(4);
                (Matrix::randn(rows, cols, r), n, m)
            },
            |(w, n, m)| {
                let q = NmSparseBinary::quantize(w, &[], *n, *m);
                if q.is_valid_nm() { Ok(()) } else { Err("invalid N:M".into()) }
            },
        );
    }

    #[test]
    fn denser_is_better() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(16, 64, &mut rng);
        let e_dense = NmSparseBinary::quantize(&w, &[], 7, 8).error(&w);
        let e_sparse = NmSparseBinary::quantize(&w, &[], 2, 8).error(&w);
        assert!(e_dense < e_sparse);
    }

    #[test]
    fn keeps_largest_magnitude() {
        let w = Matrix::from_vec(1, 4, vec![0.1, -5.0, 0.2, 4.0]);
        let q = NmSparseBinary::quantize(&w, &[], 2, 4);
        // mu ~ -0.175; largest |residual| at cols 1 and 3.
        assert_eq!(q.tern[0], 0);
        assert_eq!(q.tern[1], -1);
        assert_eq!(q.tern[2], 0);
        assert_eq!(q.tern[3], 1);
    }

    #[test]
    fn nominal_vs_measured_gap() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(32, 64, &mut rng);
        let q = NmSparseBinary::quantize(&w, &[], 4, 5);
        assert!((q.nominal_bits() - 0.8).abs() < 1e-9);
        assert!(q.bits_per_weight() > 1.0, "mask overhead must show up");
    }
}
