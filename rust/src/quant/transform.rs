//! **Learnable Transformation** (paper §4.2) — the second contribution.
//!
//! `T = D± · P` with `D± = diag(σ)`, `σ ∈ {±1}` (channel sign flips)
//! and `P = P1 ⊗ P2` a learnable invertible Kronecker-factored affine
//! (FlatQuant-style). Each linear layer is reparameterized
//! `Y = XWᵀ = (XT)(T⁻¹Wᵀ)`; only the transformed weight
//! `W' = W T⁻ᵀ` is quantized, and `T` is applied to activations online
//! (two small factor GEMMs — the Kronecker structure keeps both the
//! storage and the runtime cost negligible).
//!
//! ## Optimization
//! Block objective `L = Σ_w ‖XWᵀ − (XT) Qᵀ‖²` with `Q = quant(W T⁻ᵀ)`:
//! - `P` factors: Adam on the analytic straight-through gradient
//!     dL/dT = −2 Xᵀ R (Q − W T⁻ᵀ),   R = XWᵀ − (XT)Qᵀ,
//!   (derived by combining the direct term with the STE term through
//!   the quantizer; with an exact quantizer the gradient vanishes, as
//!   it must). Verified against finite differences in tests.
//! - `σ`: exact greedy coordinate descent — flipping σ_c is a rank-1
//!   update `A ← A − 2σ_c x_c p_cᵀ`, so ΔL is closed-form and each
//!   accepted flip updates the residual incrementally.
//! - Alternation: requantize, update σ, update P, repeat; keep the
//!   best-seen transform (early-stopping patience as in §D.2).
//!
//! The auxiliary losses `L_sim` (Gram-spectrum concentration) and
//! `L_bal` (global sign balance) of §4.2 are implemented as
//! diagnostics ([`aux_losses`]) and reported by the pipeline; the
//! clustering pressure itself is exerted by the σ/P alternation against
//! the quantizer (the requantization between outer iterations plays the
//! role of the STE coupling).

use super::arb;
use super::splits;
use crate::tensor::linalg::{invert, jacobi_eigh};
use crate::tensor::Matrix;

/// Invertible transformation `T = diag(σ) · (P1 ⊗ P2)`.
#[derive(Debug, Clone)]
pub struct Transform {
    pub sigma: Vec<f32>,
    pub p1: Matrix,
    pub p2: Matrix,
}

/// Pick Kronecker factor sizes (n1, n2) with n1·n2 = dim, n1 as close
/// to sqrt(dim) as possible.
pub fn kron_factors(dim: usize) -> (usize, usize) {
    let mut best = (1, dim);
    let mut best_gap = dim as i64;
    let mut d = 1;
    while d * d <= dim {
        if dim % d == 0 {
            let gap = (dim / d) as i64 - d as i64;
            if gap < best_gap {
                best_gap = gap;
                best = (d, dim / d);
            }
        }
        d += 1;
    }
    best
}

/// x (A ⊗ B) for every row of x: reshape row to (n1, n2) as Xm and
/// compute Aᵀ · Xm · B.
pub fn apply_kron(x: &Matrix, a: &Matrix, b: &Matrix) -> Matrix {
    let (n1, n2) = (a.rows, b.rows);
    assert_eq!(a.rows, a.cols);
    assert_eq!(b.rows, b.cols);
    assert_eq!(x.cols, n1 * n2, "kron dim mismatch");
    let mut out = Matrix::zeros(x.rows, x.cols);
    let mut xm = Matrix::zeros(n1, n2);
    for r in 0..x.rows {
        xm.data.copy_from_slice(x.row(r));
        let t = a.matmul_at(&xm); // Aᵀ Xm  (n1 x n2)
        let z = t.matmul(b); //  · B
        out.row_mut(r).copy_from_slice(&z.data);
    }
    out
}

impl Transform {
    pub fn identity(dim: usize) -> Transform {
        let (n1, n2) = kron_factors(dim);
        Transform { sigma: vec![1.0; dim], p1: Matrix::eye(n1), p2: Matrix::eye(n2) }
    }

    pub fn dim(&self) -> usize {
        self.sigma.len()
    }

    /// Dense T (tests / runtime fusion only — hot paths use factors).
    pub fn t_matrix(&self) -> Matrix {
        let p = crate::tensor::linalg::kron(&self.p1, &self.p2);
        let mut t = p;
        for r in 0..t.rows {
            let s = self.sigma[r];
            for v in t.row_mut(r) {
                *v *= s;
            }
        }
        t
    }

    /// X → X·T = (X·Dσ)(P1⊗P2).
    pub fn apply(&self, x: &Matrix) -> Matrix {
        let xs = self.scale_cols(x);
        apply_kron(&xs, &self.p1, &self.p2)
    }

    /// W → W' = W·T⁻ᵀ = (W·Dσ)(P1⁻ᵀ ⊗ P2⁻ᵀ).
    pub fn transform_weight(&self, w: &Matrix) -> Matrix {
        let (p1i, p2i) = self.factor_inverses();
        let ws = self.scale_cols(w);
        apply_kron(&ws, &p1i.transpose(), &p2i.transpose())
    }

    /// Inverses of the factors (P singular is a hard error: σ flips and
    /// Adam steps are rejected before they can make P singular).
    pub fn factor_inverses(&self) -> (Matrix, Matrix) {
        (
            invert(&self.p1).expect("P1 must stay invertible"),
            invert(&self.p2).expect("P2 must stay invertible"),
        )
    }

    fn scale_cols(&self, x: &Matrix) -> Matrix {
        let mut out = x.clone();
        for r in 0..out.rows {
            for (c, v) in out.row_mut(r).iter_mut().enumerate() {
                *v *= self.sigma[c];
            }
        }
        out
    }
}

/// Trainer configuration (defaults follow paper §D.2, scaled down).
#[derive(Debug, Clone, Copy)]
pub struct FitConfig {
    pub outer_iters: usize,
    pub p_steps: usize,
    pub lr: f32,
    pub learn_sigma: bool,
    pub learn_p: bool,
    pub arb_iters: usize,
    pub n_splits: usize,
    pub patience: usize,
}

impl Default for FitConfig {
    fn default() -> Self {
        // One gentle P step per outer iteration with immediate
        // requantization: the fixed-Q STE surrogate diverges from the
        // true objective if the inner loop runs ahead (probe:
        // examples/probe_transform.rs — 1 step/outer at lr 2e-3 cuts
        // block loss ~40%; 6 steps/outer at lr 2e-2 cuts 0%).
        FitConfig {
            outer_iters: 14,
            p_steps: 1,
            lr: 2e-3,
            learn_sigma: true,
            learn_p: true,
            arb_iters: 4,
            n_splits: 2,
            patience: 8,
        }
    }
}

/// Fit statistics.
#[derive(Debug, Clone, Default)]
pub struct FitStats {
    pub initial_loss: f64,
    pub final_loss: f64,
    pub outer_iters_run: usize,
    pub sigma_flips: usize,
}

/// Quantize a transformed weight with the configured grouped ARB.
fn quantize_transformed(wt: &Matrix, act_sq: &[f32], cfg: &FitConfig) -> Matrix {
    let imp = splits::column_importance(wt, act_sq);
    let (groups, ng) = splits::split_columns(&imp, cfg.n_splits);
    arb::arb_quantize(wt, &groups, ng, cfg.arb_iters).reconstruct()
}

/// Block loss Σ_w ‖Y_w − (XT) Q_wᵀ‖² for the *current* quantization.
fn block_loss(a: &Matrix, ys: &[Matrix], qs: &[Matrix]) -> f64 {
    ys.iter()
        .zip(qs)
        .map(|(y, q)| y.sub(&a.matmul_bt(q)).fro2())
        .sum()
}

/// Fit a transformation for a group of weight matrices sharing input
/// activations `x` (e.g. {wq, wk, wv} of one block). Returns the fitted
/// transform and stats. `x`: (batch, in_dim); each `w`: (out, in).
pub fn fit(x: &Matrix, ws: &[&Matrix], cfg: &FitConfig) -> (Transform, FitStats) {
    let dim = x.cols;
    for w in ws {
        assert_eq!(w.cols, dim);
    }
    let mut t = Transform::identity(dim);
    let ys: Vec<Matrix> = ws.iter().map(|w| x.matmul_bt(w)).collect();

    // Activation second moments in transformed space drive grouping.
    let act_sq = |a: &Matrix| -> Vec<f32> {
        let mut v = vec![0f32; a.cols];
        for r in 0..a.rows {
            for (c, &val) in a.row(r).iter().enumerate() {
                v[c] += val * val;
            }
        }
        for val in v.iter_mut() {
            *val /= a.rows as f32;
        }
        v
    };

    let evaluate = |t: &Transform| -> (Matrix, Vec<Matrix>, Vec<Matrix>, f64) {
        let a = t.apply(x);
        let asq = act_sq(&a);
        let wts: Vec<Matrix> = ws.iter().map(|w| t.transform_weight(w)).collect();
        let qs: Vec<Matrix> = wts.iter().map(|wt| quantize_transformed(wt, &asq, cfg)).collect();
        let loss = block_loss(&a, &ys, &qs);
        (a, wts, qs, loss)
    };

    let (_, _, _, init_loss) = evaluate(&t);
    let mut stats = FitStats { initial_loss: init_loss, final_loss: init_loss, ..Default::default() };
    let mut best = (t.clone(), init_loss);
    let mut since_best = 0usize;

    // Adam state over (p1, p2) concatenated.
    let n_params = t.p1.data.len() + t.p2.data.len();
    let mut adam_m = vec![0f32; n_params];
    let mut adam_v = vec![0f32; n_params];
    let mut adam_t = 0;

    // dL/dT = -2 Xᵀ R (Q − W') summed over the weight group, with Q
    // held fixed (alternating) and STE through the quantizer.
    let grad_t = |t: &Transform, qs: &[Matrix], wts: &[Matrix]| -> Matrix {
        let a = t.apply(x);
        let mut g = Matrix::zeros(dim, dim);
        for ((y, q), wt) in ys.iter().zip(qs).zip(wts) {
            let r_m = y.sub(&a.matmul_bt(q)); // (b, o)
            let dq = q.sub(wt); // quantization error (o, i)
            let xtr = x.matmul_at(&r_m); // Xᵀ R (i, o)
            g = g.add(&xtr.matmul(&dq).scale(-2.0));
        }
        g
    };

    for outer in 0..cfg.outer_iters {
        let (_a, wts, qs, loss) = evaluate(&t);
        stats.outer_iters_run = outer + 1;
        if loss < best.1 {
            best = (t.clone(), loss);
            since_best = 0;
        } else {
            since_best += 1;
            if since_best >= cfg.patience {
                break;
            }
        }

        // ---- σ STE pass ------------------------------------------------
        // dL/dσ_c = Σ_j dL/dT[c,j]·P[c,j] (T = Dσ P). A flip moves σ_c
        // by −2σ_c, so ΔL ≈ −2σ_c g_c: flip the strongest descent
        // channels (capped at ~10% per outer iter, "larger lr" per
        // §D.2); requantization next iter + best-tracking keep it safe.
        if cfg.learn_sigma {
            let g_t = grad_t(&t, &qs, &wts);
            let p = crate::tensor::linalg::kron(&t.p1, &t.p2);
            let mut scored: Vec<(f64, usize)> = (0..dim)
                .filter_map(|c| {
                    let g_c: f64 = (0..dim)
                        .map(|j| g_t.at(c, j) as f64 * p.at(c, j) as f64)
                        .sum();
                    let gain = t.sigma[c] as f64 * g_c; // >0 => flip helps
                    if gain > 0.0 {
                        Some((gain, c))
                    } else {
                        None
                    }
                })
                .collect();
            scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            for &(_, c) in scored.iter().take((dim / 10).max(1)) {
                t.sigma[c] = -t.sigma[c];
                stats.sigma_flips += 1;
            }
        }

        // ---- P Adam steps (analytic STE gradient) --------------------
        if cfg.learn_p {
            for _ in 0..cfg.p_steps {
                let wts_cur: Vec<Matrix> = if cfg.learn_sigma {
                    ws.iter().map(|w| t.transform_weight(w)).collect()
                } else {
                    wts.clone()
                };
                // Keep Q fixed within the outer iteration (alternating).
                let mut g_t = grad_t(&t, &qs, &wts_cur);
                // dL/dP = Dσ · dL/dT (row scale by σ).
                for r in 0..dim {
                    let s = t.sigma[r];
                    for v in g_t.row_mut(r) {
                        *v *= s;
                    }
                }
                // Kronecker factor gradients.
                let (n1, n2) = (t.p1.rows, t.p2.rows);
                let mut g1 = Matrix::zeros(n1, n1);
                let mut g2 = Matrix::zeros(n2, n2);
                for aa in 0..n1 {
                    for bb in 0..n1 {
                        let mut s = 0f64;
                        for p in 0..n2 {
                            for q in 0..n2 {
                                s += g_t.at(aa * n2 + p, bb * n2 + q) as f64 * t.p2.at(p, q) as f64;
                            }
                        }
                        *g1.at_mut(aa, bb) = s as f32;
                    }
                }
                for p in 0..n2 {
                    for q in 0..n2 {
                        let mut s = 0f64;
                        for aa in 0..n1 {
                            for bb in 0..n1 {
                                s += g_t.at(aa * n2 + p, bb * n2 + q) as f64 * t.p1.at(aa, bb) as f64;
                            }
                        }
                        *g2.at_mut(p, q) = s as f32;
                    }
                }
                // Adam step over concatenated factors; reject steps that
                // break invertibility.
                adam_t += 1;
                let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
                let bc1 = 1.0 - b1.powi(adam_t);
                let bc2 = 1.0 - b2.powi(adam_t);
                let mut p1_new = t.p1.clone();
                let mut p2_new = t.p2.clone();
                let grads = g1.data.iter().chain(g2.data.iter());
                let params = p1_new.data.iter_mut().chain(p2_new.data.iter_mut());
                for (i, (pv, &gv)) in params.zip(grads).enumerate() {
                    adam_m[i] = b1 * adam_m[i] + (1.0 - b1) * gv;
                    adam_v[i] = b2 * adam_v[i] + (1.0 - b2) * gv * gv;
                    *pv -= cfg.lr * (adam_m[i] / bc1) / ((adam_v[i] / bc2).sqrt() + eps);
                }
                if invert(&p1_new).is_some() && invert(&p2_new).is_some() {
                    t.p1 = p1_new;
                    t.p2 = p2_new;
                } else {
                    break; // singular step rejected; stop P updates
                }
            }
        }
    }

    // Final evaluation; keep the best transform seen.
    let (_, _, _, final_loss) = evaluate(&t);
    if final_loss < best.1 {
        best = (t, final_loss);
    }
    stats.final_loss = best.1;
    (best.0, stats)
}

/// Auxiliary losses of §4.2 computed on a sample of sign sub-vectors:
/// `L_sim = Tr(G) − Σ_{i<=K} λ_i(G)` with `G = (1/v) M Mᵀ`, and
/// `L_bal = (mean sign)²`.
pub fn aux_losses(sign_vectors: &[Vec<f32>], top_k: usize) -> (f64, f64) {
    assert!(!sign_vectors.is_empty());
    let b = sign_vectors.len();
    let v = sign_vectors[0].len();
    let mut m = Matrix::zeros(b, v);
    for (r, sv) in sign_vectors.iter().enumerate() {
        m.row_mut(r).copy_from_slice(sv);
    }
    let g = m.matmul_bt(&m).scale(1.0 / v as f32);
    let (evals, _) = jacobi_eigh(&g, 30);
    let trace: f64 = (0..b).map(|i| g.at(i, i) as f64).sum();
    let topk: f64 = evals.iter().take(top_k).map(|&e| e as f64).sum();
    let l_sim = trace - topk;
    let mean: f64 =
        sign_vectors.iter().flat_map(|sv| sv.iter()).map(|&x| x as f64).sum::<f64>() / (b * v) as f64;
    (l_sim, mean * mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, check};
    use crate::util::rng::Rng;

    #[test]
    fn kron_factors_near_square() {
        assert_eq!(kron_factors(96), (8, 12));
        assert_eq!(kron_factors(128), (8, 16));
        assert_eq!(kron_factors(256), (16, 16));
        assert_eq!(kron_factors(7), (1, 7));
    }

    #[test]
    fn apply_kron_matches_dense_property() {
        check(
            "x(A kron B) == dense",
            15,
            |r: &mut Rng| {
                let n1 = 2 + r.below(3);
                let n2 = 2 + r.below(3);
                let b = 1 + r.below(5);
                (Matrix::randn(b, n1 * n2, r), Matrix::randn(n1, n1, r), Matrix::randn(n2, n2, r))
            },
            |(x, a, b)| {
                let dense = x.matmul(&crate::tensor::linalg::kron(a, b));
                let fast = apply_kron(x, a, b);
                assert_close(&fast.data, &dense.data, 1e-4, 1e-4)
            },
        );
    }

    #[test]
    fn transform_equivalence_in_full_precision() {
        // Y = XWᵀ must equal (XT)(W T⁻ᵀ)ᵀ for any invertible T.
        check(
            "XW^T == (XT)(WT^-T)^T",
            10,
            |r: &mut Rng| {
                let dim = 12;
                let mut t = Transform::identity(dim);
                for s in t.sigma.iter_mut() {
                    *s = r.sign();
                }
                t.p1 = Matrix::randn(t.p1.rows, t.p1.cols, r);
                t.p2 = Matrix::randn(t.p2.rows, t.p2.cols, r);
                for i in 0..t.p1.rows {
                    *t.p1.at_mut(i, i) += 3.0;
                }
                for i in 0..t.p2.rows {
                    *t.p2.at_mut(i, i) += 3.0;
                }
                (Matrix::randn(5, dim, r), Matrix::randn(7, dim, r), t)
            },
            |(x, w, t)| {
                let y = x.matmul_bt(w);
                let yt = t.apply(x).matmul_bt(&t.transform_weight(w));
                assert_close(&yt.data, &y.data, 1e-2, 1e-2)
            },
        );
    }

    #[test]
    fn t_matrix_consistent_with_apply() {
        let mut r = Rng::new(3);
        let mut t = Transform::identity(8);
        t.sigma[2] = -1.0;
        t.p1 = Matrix::randn(t.p1.rows, t.p1.cols, &mut r);
        t.p2 = Matrix::randn(t.p2.rows, t.p2.cols, &mut r);
        let x = Matrix::randn(3, 8, &mut r);
        let via_factors = t.apply(&x);
        let via_dense = x.matmul(&t.t_matrix());
        assert_close(&via_factors.data, &via_dense.data, 1e-4, 1e-4).unwrap();
    }

    /// Finite-difference check of the analytic STE gradient
    /// dL/dT = -2 Xᵀ R (Q - W T⁻ᵀ) with Q(T) = W T⁻ᵀ + E (E fixed),
    /// chained onto the P1/P2 factors.
    #[test]
    fn analytic_gradient_matches_finite_difference() {
        let mut rng = Rng::new(7);
        let dim = 6; // factors (2, 3)
        let x = Matrix::randn(4, dim, &mut rng);
        let w = Matrix::randn(5, dim, &mut rng);
        let e = Matrix::randn(5, dim, &mut rng).scale(0.1); // fixed quant error
        let mut t = Transform::identity(dim);
        t.sigma[1] = -1.0;
        t.p1 = Matrix::randn(2, 2, &mut rng);
        t.p2 = Matrix::randn(3, 3, &mut rng);
        for i in 0..2 {
            *t.p1.at_mut(i, i) += 2.5;
        }
        for i in 0..3 {
            *t.p2.at_mut(i, i) += 2.5;
        }

        let loss = |t: &Transform| -> f64 {
            let q = t.transform_weight(&w).add(&e);
            let a = t.apply(&x);
            x.matmul_bt(&w).sub(&a.matmul_bt(&q)).fro2()
        };

        // Analytic gradient at t.
        let q = t.transform_weight(&w).add(&e);
        let a = t.apply(&x);
        let r_m = x.matmul_bt(&w).sub(&a.matmul_bt(&q));
        let dq = q.sub(&t.transform_weight(&w)); // = E
        let g_t = x.matmul_at(&r_m).matmul(&dq).scale(-2.0);
        // chain: dL/dP = Dσ g_t; then factor contraction.
        let mut g_p = g_t.clone();
        for r in 0..dim {
            let s = t.sigma[r];
            for v in g_p.row_mut(r) {
                *v *= s;
            }
        }
        let (n1, n2) = (2, 3);
        let mut g1 = Matrix::zeros(n1, n1);
        for aa in 0..n1 {
            for bb in 0..n1 {
                let mut s = 0f64;
                for p in 0..n2 {
                    for qq in 0..n2 {
                        s += g_p.at(aa * n2 + p, bb * n2 + qq) as f64 * t.p2.at(p, qq) as f64;
                    }
                }
                *g1.at_mut(aa, bb) = s as f32;
            }
        }
        // Finite differences on P1.
        let h = 1e-3f32;
        for aa in 0..n1 {
            for bb in 0..n1 {
                let mut tp = t.clone();
                *tp.p1.at_mut(aa, bb) += h;
                let mut tm = t.clone();
                *tm.p1.at_mut(aa, bb) -= h;
                let fd = ((loss(&tp) - loss(&tm)) / (2.0 * h as f64)) as f32;
                let an = g1.at(aa, bb);
                assert!(
                    (fd - an).abs() < 0.05 * an.abs().max(1.0),
                    "P1[{aa},{bb}]: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn fit_reduces_block_loss_on_outlier_weights() {
        // LLM-like input with outlier channels: the transform must beat
        // the identity baseline (paper Table 3b ordering).
        let mut rng = Rng::new(11);
        let dim = 12;
        let hot: Vec<f32> = (0..dim).map(|c| if c % 5 == 0 { 8.0 } else { 1.0 }).collect();
        let x = Matrix::from_fn(64, dim, |_, c| rng.normal() * hot[c]);
        let w = Matrix::from_fn(16, dim, |_, c| rng.normal() * if c % 5 == 0 { 3.0 } else { 0.5 });
        let cfg = FitConfig { outer_iters: 6, p_steps: 3, ..Default::default() };
        let (_, stats) = fit(&x, &[&w], &cfg);
        assert!(
            stats.final_loss < stats.initial_loss * 0.9,
            "no improvement: {} -> {}",
            stats.initial_loss,
            stats.final_loss
        );
    }

    #[test]
    fn sigma_only_fit_helps() {
        let mut rng = Rng::new(13);
        let dim = 8;
        let x = Matrix::randn(32, dim, &mut rng);
        let w = Matrix::from_fn(8, dim, |_, c| rng.normal() + if c < 4 { 2.0 } else { -2.0 });
        let cfg = FitConfig { learn_p: false, outer_iters: 4, ..Default::default() };
        let (t, stats) = fit(&x, &[&w], &cfg);
        assert!(stats.final_loss <= stats.initial_loss + 1e-9);
        assert!(t.sigma.iter().all(|&s| s == 1.0 || s == -1.0));
    }

    #[test]
    fn aux_losses_detect_clustering() {
        // Identical sign vectors => G has one dominant eigenvalue =>
        // L_sim ~ 0; random vectors => L_sim large.
        let clustered: Vec<Vec<f32>> = (0..16).map(|_| vec![1.0, -1.0, 1.0, 1.0]).collect();
        let (sim_c, _) = aux_losses(&clustered, 1);
        let mut rng = Rng::new(17);
        let random: Vec<Vec<f32>> =
            (0..16).map(|_| (0..4).map(|_| rng.sign()).collect()).collect();
        let (sim_r, _) = aux_losses(&random, 1);
        assert!(sim_c < 0.5, "clustered L_sim {sim_c}");
        assert!(sim_r > sim_c, "random {sim_r} !> clustered {sim_c}");
        // Balance: all-ones is maximally unbalanced.
        let ones: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; 4]).collect();
        let (_, bal) = aux_losses(&ones, 1);
        assert!((bal - 1.0).abs() < 1e-9);
    }
}
