//! Per-model quantization pipeline (paper Fig. 4): calibration capture
//! → (BTC only) block-wise learnable-transformation fit → grouped ARB
//! binarization (with optional salient residual) → shared binary
//! codebook → activation quantization. Also drives every baseline
//! (naive / BiLLM / ARB-LLM / STBLLM / FP-VQ) through the same
//! scaffolding so the benches compare like with like.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::actquant::ActQuant;
use super::arb::{arb_quantize, ResidualBinary};
use super::billm::{self, SalientBinaryConfig};
use super::binarize::BinaryLayer;
use super::codebook::{collect_vectors, BinaryCodebook, BuildStats, CodebookLayer};
use super::fpvq::FpVqLayer;
use super::splits::{column_importance, salient_columns, split_columns};
use super::stbllm::NmSparseBinary;
use super::transform::{fit, FitConfig, Transform};
use crate::data::calib::CalibSet;
use crate::io::weights::RawModel;
use crate::model::transformer::{Capture, CaptureSite, Transformer};
use crate::model::{Linear, LinearBackend};
use crate::tensor::Matrix;

/// Quantization method lanes (one per row family of Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMethod {
    Fp16,
    Naive,
    BiLlm,
    ArbLlm,
    Stbllm,
    FpVq,
    Btc,
}

impl QuantMethod {
    pub fn name(&self) -> &'static str {
        match self {
            QuantMethod::Fp16 => "FP16",
            QuantMethod::Naive => "Naive",
            QuantMethod::BiLlm => "BiLLM",
            QuantMethod::ArbLlm => "ARB-LLM",
            QuantMethod::Stbllm => "STBLLM",
            QuantMethod::FpVq => "FP-VQ",
            QuantMethod::Btc => "BTC-LLM",
        }
    }
}

/// Full pipeline configuration. Use the presets
/// ([`QuantConfig::btc`] etc.) for paper-table settings.
#[derive(Debug, Clone)]
pub struct QuantConfig {
    pub method: QuantMethod,
    /// Nominal W-bits label (the paper's table column).
    pub target_bits: f64,
    /// Codebook sub-vector length (BTC sub-1-bit).
    pub v: usize,
    /// Codebook size; 0 = derive as 2^round(target_bits * v).
    pub codebook_c: usize,
    /// EM iterations for the binary codebook (paper: 5).
    pub em_iters: usize,
    pub n_splits: usize,
    pub salient_frac: f64,
    pub arb_iters: usize,
    /// Learnable transformation components (Table 3b ablation).
    pub transform_p: bool,
    pub transform_sigma: bool,
    pub transform_outer: usize,
    /// Activation bits (16 = off; Table 3d).
    pub act_bits: u32,
    /// STBLLM N:M.
    pub nm: (usize, usize),
    /// FP-VQ (v, c).
    pub fpvq: (usize, usize),
    /// Calibration: #sequences, sequence length, captured row cap.
    pub calib_seqs: usize,
    pub calib_seq_len: usize,
    pub calib_rows: usize,
    pub seed: u64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            method: QuantMethod::Fp16,
            target_bits: 16.0,
            v: 16,
            codebook_c: 0,
            em_iters: 5,
            n_splits: 2,
            salient_frac: 0.10,
            arb_iters: 15,
            transform_p: true,
            transform_sigma: true,
            transform_outer: 14,
            act_bits: 16,
            nm: (4, 5),
            fpvq: (4, 256),
            calib_seqs: 16,
            calib_seq_len: 64,
            calib_rows: 192,
            seed: 42,
        }
    }
}

impl QuantConfig {
    pub fn fp16() -> Self {
        Self::default()
    }

    pub fn naive() -> Self {
        QuantConfig { method: QuantMethod::Naive, target_bits: 1.0, ..Self::default() }
    }

    pub fn billm() -> Self {
        let p = SalientBinaryConfig::billm();
        QuantConfig {
            method: QuantMethod::BiLlm,
            target_bits: 1.11,
            n_splits: p.n_splits,
            salient_frac: p.salient_frac,
            arb_iters: p.arb_iters,
            ..Self::default()
        }
    }

    pub fn arb_llm() -> Self {
        let p = SalientBinaryConfig::arb_llm();
        QuantConfig {
            method: QuantMethod::ArbLlm,
            target_bits: 1.11,
            n_splits: p.n_splits,
            salient_frac: p.salient_frac,
            arb_iters: p.arb_iters,
            ..Self::default()
        }
    }

    /// STBLLM at a nominal sub-1 bit target (0.8 -> 4:5, 0.7 -> 7:10).
    pub fn stbllm(bits: f64) -> Self {
        let nm = if bits <= 0.55 {
            (1, 2)
        } else if bits <= 0.72 {
            (7, 10)
        } else {
            (4, 5)
        };
        QuantConfig { method: QuantMethod::Stbllm, target_bits: bits, nm, ..Self::default() }
    }

    /// FP vector quantization at a bits target.
    pub fn fpvq(bits: f64) -> Self {
        let (v, c) = if bits >= 1.5 {
            (4usize, 256usize) // 2-bit lane
        } else {
            // sub-1: v=8, c = 2^(bits*8)
            (8, (2f64.powf(bits * 8.0)).round().max(2.0) as usize)
        };
        QuantConfig { method: QuantMethod::FpVq, target_bits: bits, fpvq: (v, c), ..Self::default() }
    }

    /// BTC-LLM at a bits target. >= 1.0 is the binary (no codebook)
    /// lane labelled 1.11 in the paper; < 1.0 engages the codebook.
    pub fn btc(bits: f64) -> Self {
        QuantConfig {
            method: QuantMethod::Btc,
            target_bits: bits,
            v: 16,
            ..Self::default()
        }
    }

    fn uses_codebook(&self) -> bool {
        self.method == QuantMethod::Btc && self.target_bits < 1.0
    }

    /// Codebook size for the bits target.
    pub fn derived_c(&self) -> usize {
        if self.codebook_c > 0 {
            return self.codebook_c;
        }
        let c = 2f64.powf(self.target_bits * self.v as f64).round() as usize;
        c.clamp(2, 1 << 22)
    }
}

/// Per-pipeline stats: timings, errors, storage.
#[derive(Debug, Clone, Default)]
pub struct QuantStats {
    pub method: String,
    pub target_bits: f64,
    /// Measured linear-weight bits (incl. scales/groups/indices, excl.
    /// the shared codebook, which is reported separately).
    pub measured_bits: f64,
    /// Payload bits/weight (signs/indices/masks only — the paper's
    /// table convention; per-row fp16 scales excluded, see
    /// `LinearBackend::payload_bits_per_weight`).
    pub payload_bits: f64,
    /// Shared codebook storage bits (0 when unused).
    pub codebook_bits: usize,
    /// Transform storage bits (Kronecker factors + sigma).
    pub transform_bits: usize,
    /// Sum of per-layer relative reconstruction errors.
    pub mean_rel_error: f64,
    pub transform_secs: f64,
    pub quant_secs: f64,
    pub codebook_secs: f64,
    pub codebook_stats: Option<BuildStats>,
    /// Auxiliary losses sampled after quantization (L_sim, L_bal).
    pub aux_losses: Option<(f64, f64)>,
    pub n_linears: usize,
}

/// A quantized model plus its pipeline stats.
pub struct QuantizedModel {
    pub model: Transformer,
    pub stats: QuantStats,
    pub config: QuantConfig,
}

/// Snap column groups to `v`-block granularity (block importance =
/// sum of member columns) so the LUT-GEMM engine can fold per-group
/// scales into the gather.
fn block_aligned_split(importance: &[f64], n_splits: usize, v: usize) -> (Vec<u16>, usize) {
    if n_splits == 0 {
        return (vec![0u16; importance.len()], 1);
    }
    let nb = importance.len().div_ceil(v);
    let block_imp: Vec<f64> = (0..nb)
        .map(|b| importance[b * v..((b + 1) * v).min(importance.len())].iter().sum())
        .collect();
    let (bg, ng) = split_columns(&block_imp, n_splits);
    let col_group: Vec<u16> = (0..importance.len()).map(|c| bg[c / v]).collect();
    (col_group, ng)
}

/// Quantize a full model. `corpus` supplies calibration sequences.
pub fn quantize_model(raw: &RawModel, corpus: &[u8], cfg: &QuantConfig) -> Result<QuantizedModel> {
    let mut model = Transformer::from_raw(raw)?;
    let mut stats = QuantStats {
        method: cfg.method.name().to_string(),
        target_bits: cfg.target_bits,
        ..Default::default()
    };
    if cfg.method == QuantMethod::Fp16 {
        model.cache_dense_all();
        stats.measured_bits = 16.0;
        return Ok(QuantizedModel { model, stats, config: cfg.clone() });
    }

    // ---- calibration capture on the fp model --------------------------
    let calib = CalibSet::sample(corpus, cfg.calib_seqs, cfg.calib_seq_len, cfg.seed);
    let mut capture = Capture::new(cfg.calib_rows);
    for seq in &calib.seqs {
        if capture
            .matrix(0, CaptureSite::Ln1Out)
            .map(|m| m.rows >= cfg.calib_rows)
            .unwrap_or(false)
        {
            break;
        }
        let mut opt = Some(&mut capture);
        model.forward_capture(seq, &mut opt);
    }

    let act_sq_of = |x: &Matrix| -> Vec<f32> {
        let mut v = vec![0f32; x.cols];
        for r in 0..x.rows {
            for (c, &val) in x.row(r).iter().enumerate() {
                v[c] += val * val;
            }
        }
        for val in v.iter_mut() {
            *val /= x.rows.max(1) as f32;
        }
        v
    };

    // ---- per layer, per site group -------------------------------------
    // Collected binary layers destined for the shared codebook:
    // (layer, linear name, BinaryLayer, transform).
    let mut pending: Vec<(usize, &'static str, BinaryLayer, Option<Transform>)> = Vec::new();
    let mut total_weight_bits = 0usize;
    let mut total_weights = 0usize;
    let mut rel_err_sum = 0f64;
    let mut n_linears = 0usize;

    let site_groups: [(CaptureSite, &[&str]); 4] = [
        (CaptureSite::Ln1Out, &["wq", "wk", "wv"]),
        (CaptureSite::AttnOut, &["wo"]),
        (CaptureSite::Ln2Out, &["wgate", "wup"]),
        (CaptureSite::FfnMid, &["wdown"]),
    ];

    let n_layer = model.cfg.n_layer;
    for li in 0..n_layer {
        for (site, names) in site_groups.iter() {
            let x = capture
                .matrix(li, *site)
                .ok_or_else(|| anyhow::anyhow!("no calibration capture for layer {li}"))?;

            // Pull the fp weights of this group.
            let ws: Vec<Matrix> = names
                .iter()
                .map(|n| {
                    let block = &model.blocks[li];
                    let lin = block.linears().iter().find(|(nm, _)| nm == n).unwrap().1.backend.reconstruct();
                    lin
                })
                .collect();

            // BTC: fit the learnable transformation for this group.
            let transform: Option<Transform> = if cfg.method == QuantMethod::Btc
                && (cfg.transform_p || cfg.transform_sigma)
            {
                let t0 = Instant::now();
                let fit_cfg = FitConfig {
                    outer_iters: cfg.transform_outer,
                    learn_p: cfg.transform_p,
                    learn_sigma: cfg.transform_sigma,
                    n_splits: cfg.n_splits,
                    ..Default::default()
                };
                let refs: Vec<&Matrix> = ws.iter().collect();
                let (t, _fit_stats) = fit(&x, &refs, &fit_cfg);
                stats.transform_secs += t0.elapsed().as_secs_f64();
                stats.transform_bits +=
                    (t.p1.data.len() + t.p2.data.len()) * 16 + t.sigma.len();
                Some(t)
            } else {
                None
            };

            let xt = match &transform {
                Some(t) => t.apply(&x),
                None => x.clone(),
            };
            let act_sq = act_sq_of(&xt);

            // Activation quantizer calibrated in transformed space.
            let act_quant = if cfg.act_bits < 16 {
                Some(ActQuant::calibrate(&xt, cfg.act_bits))
            } else {
                None
            };

            let t_quant = Instant::now();
            for (name, w) in names.iter().zip(ws.iter()) {
                let weff = match &transform {
                    Some(t) => t.transform_weight(w),
                    None => w.clone(),
                };
                let imp = column_importance(&weff, &act_sq);
                n_linears += 1;
                total_weights += weff.rows * weff.cols;

                let backend: LinearBackend = match cfg.method {
                    QuantMethod::Fp16 => unreachable!(),
                    QuantMethod::Naive => {
                        LinearBackend::Binary(BinaryLayer::quantize(&weff))
                    }
                    QuantMethod::BiLlm | QuantMethod::ArbLlm => {
                        let preset = SalientBinaryConfig {
                            salient_frac: cfg.salient_frac,
                            n_splits: cfg.n_splits,
                            arb_iters: cfg.arb_iters,
                        };
                        LinearBackend::Residual(billm::quantize(&weff, &act_sq, &preset))
                    }
                    QuantMethod::Stbllm => LinearBackend::NmSparse(NmSparseBinary::quantize(
                        &weff, &act_sq, cfg.nm.0, cfg.nm.1,
                    )),
                    QuantMethod::FpVq => LinearBackend::FpVq(FpVqLayer::quantize(
                        &weff, cfg.fpvq.0, cfg.fpvq.1, 8, cfg.seed,
                    )),
                    QuantMethod::Btc => {
                        if cfg.uses_codebook() {
                            // Block-aligned groups, no salient residual
                            // (sub-1-bit storage must stay mask-free).
                            let (groups, ng) = block_aligned_split(&imp, cfg.n_splits, cfg.v);
                            let bl = arb_quantize(&weff, &groups, ng, cfg.arb_iters);
                            pending.push((li, name, bl, transform.clone()));
                            // Placeholder; replaced after codebook build.
                            LinearBackend::Dense(weff.clone())
                        } else {
                            // Binary lane (paper's 1.11-bit row).
                            let (groups, ng) = split_columns(&imp, cfg.n_splits);
                            let sal = salient_columns(&imp, cfg.salient_frac);
                            LinearBackend::Residual(ResidualBinary::quantize(
                                &weff, &groups, ng, &sal, cfg.arb_iters,
                            ))
                        }
                    }
                };

                if !(cfg.method == QuantMethod::Btc && cfg.uses_codebook()) {
                    let rec = backend.reconstruct();
                    rel_err_sum += crate::tensor::stats::rel_error(&weff.data, &rec.data);
                    total_weight_bits += backend.storage_bits();
                }

                // Install the linear.
                let block = &mut model.blocks[li];
                for (nm, lin) in block.linears_mut() {
                    if nm == *name {
                        let mut new_lin = Linear::new(backend.clone());
                        new_lin.transform = transform.clone();
                        new_lin.act_quant = act_quant.clone();
                        *lin = new_lin;
                        break;
                    }
                }
            }
            stats.quant_secs += t_quant.elapsed().as_secs_f64();
        }
    }

    // ---- shared binary codebook over all pending layers -----------------
    if !pending.is_empty() {
        let t0 = Instant::now();
        let mut all_vectors: Vec<u64> = Vec::new();
        let mut offsets = Vec::with_capacity(pending.len());
        for (_, _, bl, _) in &pending {
            offsets.push(all_vectors.len());
            all_vectors.extend(collect_vectors(bl, cfg.v));
        }
        let c = cfg.derived_c();
        let (cb, assignments, build_stats) =
            BinaryCodebook::build(&all_vectors, cfg.v, c, cfg.em_iters);
        let cb = Arc::new(cb);
        stats.codebook_bits = cb.storage_bits();
        stats.codebook_stats = Some(build_stats);

        for (pi, (li, name, bl, _t)) in pending.iter().enumerate() {
            let start = offsets[pi];
            let end = offsets.get(pi + 1).copied().unwrap_or(all_vectors.len());
            let idx = assignments[start..end].to_vec();
            let cl = CodebookLayer::from_assignments(bl, cb.clone(), idx);
            let weff = {
                let block = &model.blocks[*li];
                block.linears().iter().find(|(nm, _)| nm == name).unwrap().1.backend.reconstruct()
            };
            rel_err_sum += crate::tensor::stats::rel_error(&weff.data, &cl.reconstruct().data);
            total_weight_bits += cl.storage_bits();
            let block = &mut model.blocks[*li];
            for (nm, lin) in block.linears_mut() {
                if nm == *name {
                    lin.backend = LinearBackend::Codebook(cl.clone());
                    break;
                }
            }
        }
        stats.codebook_secs = t0.elapsed().as_secs_f64();

        // Sample aux losses on the final sign vectors (diagnostics).
        let sample: Vec<Vec<f32>> = all_vectors
            .iter()
            .step_by((all_vectors.len() / 48).max(1))
            .take(48)
            .map(|&w| (0..cfg.v).map(|j| if w >> j & 1 == 1 { 1.0 } else { -1.0 }).collect())
            .collect();
        if sample.len() >= 4 {
            stats.aux_losses = Some(super::transform::aux_losses(&sample, 8));
        }
    }

    stats.measured_bits = total_weight_bits as f64 / total_weights.max(1) as f64;
    let mut payload_weighted = 0f64;
    let mut wtot = 0usize;
    for block in &model.blocks {
        for (_, lin) in block.linears() {
            let (o, i) = lin.backend.shape();
            payload_weighted += lin.backend.payload_bits_per_weight() * (o * i) as f64;
            wtot += o * i;
        }
    }
    stats.payload_bits = payload_weighted / wtot.max(1) as f64;
    stats.mean_rel_error = rel_err_sum / n_linears.max(1) as f64;
    stats.n_linears = n_linears;
    model.cache_dense_all();
    Ok(QuantizedModel { model, stats, config: cfg.clone() })
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::data::corpus;
    use crate::io::weights::{ModelConfig, RawModel};
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    /// Shared fixture for cross-module tests (io::qweights etc.).
    pub fn fixture_public() -> (RawModel, Vec<u8>) {
        fixture()
    }

    /// Small random model + corpus for pipeline tests.
    fn fixture() -> (RawModel, Vec<u8>) {
        let mut rng = Rng::new(9);
        let cfg = ModelConfig {
            vocab: 128,
            d_model: 16,
            n_layer: 2,
            n_head: 2,
            n_kv_head: 2,
            d_ff: 24,
            max_seq: 64,
            rope_theta: 10000.0,
        };
        let mut tensors = BTreeMap::new();
        fn add(
            tensors: &mut BTreeMap<String, (Vec<usize>, Vec<f32>)>,
            name: String,
            rows: usize,
            cols: usize,
            rng: &mut Rng,
        ) {
            let m = Matrix::randn(rows, cols, rng).scale(0.2);
            tensors.insert(name, (vec![rows, cols], m.data));
        }
        add(&mut tensors, "emb".into(), cfg.vocab, cfg.d_model, &mut rng);
        tensors.insert("lnf".into(), (vec![cfg.d_model], vec![1.0; cfg.d_model]));
        for i in 0..cfg.n_layer {
            tensors.insert(format!("l{i}.ln1"), (vec![cfg.d_model], vec![1.0; cfg.d_model]));
            tensors.insert(format!("l{i}.ln2"), (vec![cfg.d_model], vec![1.0; cfg.d_model]));
            add(&mut tensors, format!("l{i}.wq"), cfg.d_model, cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wk"), cfg.kv_dim(), cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wv"), cfg.kv_dim(), cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wo"), cfg.d_model, cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wgate"), cfg.d_ff, cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wup"), cfg.d_ff, cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wdown"), cfg.d_model, cfg.d_ff, &mut rng);
        }
        let raw = RawModel { config: cfg, tensors };
        let text = corpus::generate(4000, 1);
        (raw, text.into_bytes())
    }

    fn quick(cfg: QuantConfig) -> QuantConfig {
        QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            transform_outer: 2,
            arb_iters: 4,
            v: 8,
            ..cfg
        }
    }

    #[test]
    fn fp16_passthrough() {
        let (raw, corpus) = fixture();
        let qm = quantize_model(&raw, &corpus, &QuantConfig::fp16()).unwrap();
        assert_eq!(qm.stats.measured_bits, 16.0);
        let logits = qm.model.forward(&[1, 2, 3]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn all_methods_produce_runnable_models() {
        let (raw, corpus) = fixture();
        for cfg in [
            QuantConfig::naive(),
            QuantConfig::billm(),
            QuantConfig::stbllm(0.8),
            QuantConfig::fpvq(2.0),
            QuantConfig::btc(0.8),
        ] {
            let qm = quantize_model(&raw, &corpus, &quick(cfg)).unwrap();
            let logits = qm.model.forward(&[5, 6, 7, 8]);
            assert!(
                logits.data.iter().all(|v| v.is_finite()),
                "{} produced non-finite logits",
                qm.stats.method
            );
            assert!(qm.stats.n_linears == 14, "{}", qm.stats.n_linears);
        }
    }

    #[test]
    fn btc_sub1_bits_actually_sub1() {
        let (raw, corpus) = fixture();
        let qm = quantize_model(&raw, &corpus, &quick(QuantConfig::btc(0.7))).unwrap();
        // Payload convention (signs/indices only): must be sub-1.
        // The fully-measured figure includes per-row fp16 scales that
        // only amortize at real LLM widths — see payload_bits docs.
        assert!(
            qm.stats.payload_bits < 1.0,
            "payload {} bits",
            qm.stats.payload_bits
        );
        assert!(qm.stats.codebook_bits > 0);
        assert!(qm.stats.codebook_stats.is_some());
    }

    #[test]
    fn stbllm_mask_overhead_visible() {
        let (raw, corpus) = fixture();
        let qm = quantize_model(&raw, &corpus, &quick(QuantConfig::stbllm(0.8))).unwrap();
        // Nominal 0.8 but payload > 1.0 even before scales — the
        // paper's intro critique of N:M mask storage.
        assert!(qm.stats.payload_bits > 1.0, "payload {}", qm.stats.payload_bits);
    }

    #[test]
    fn btc_transform_reduces_error_vs_no_transform() {
        let (raw, corpus) = fixture();
        let mut with_t = quick(QuantConfig::btc(0.8));
        with_t.transform_outer = 4;
        let mut no_t = with_t.clone();
        no_t.transform_p = false;
        no_t.transform_sigma = false;
        let qt = quantize_model(&raw, &corpus, &with_t).unwrap();
        let qn = quantize_model(&raw, &corpus, &no_t).unwrap();
        // Table 3b ordering on weight reconstruction error.
        assert!(
            qt.stats.mean_rel_error <= qn.stats.mean_rel_error * 1.25,
            "transform err {} vs none {}",
            qt.stats.mean_rel_error,
            qn.stats.mean_rel_error
        );
        assert!(qt.stats.transform_bits > 0);
        assert_eq!(qn.stats.transform_bits, 0);
    }

    #[test]
    fn act_quant_attached() {
        let (raw, corpus) = fixture();
        let mut cfg = quick(QuantConfig::btc(0.8));
        cfg.act_bits = 8;
        let qm = quantize_model(&raw, &corpus, &cfg).unwrap();
        assert!(qm.model.blocks[0].wq.act_quant.is_some());
        let logits = qm.model.forward(&[1, 2, 3]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn derived_c_scaling() {
        let mut cfg = QuantConfig::btc(0.8);
        cfg.v = 10;
        assert_eq!(cfg.derived_c(), 256); // 2^8
        cfg.v = 20;
        assert_eq!(cfg.derived_c(), 65536); // 2^16
        cfg.codebook_c = 77;
        assert_eq!(cfg.derived_c(), 77);
    }
}
