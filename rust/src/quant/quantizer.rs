//! **`Quantizer`** — the open method-strategy trait the pipeline driver
//! runs every lane through, replacing the old closed `QuantMethod`
//! enum's match arms.
//!
//! Lifecycle (one call sequence per model, driven by
//! [`super::pipeline::quantize_model`]):
//!
//! ```text
//! calibrate(capture)                       once, after activation capture
//! per (layer, capture-site) group:
//!   fit_transform(x, weights)              optional learnable transformation
//!   per linear in the group:
//!     quantize_group(site, W̃, act_sq)      -> Ready(backend) | Deferred
//! finalize(stats)                          -> backends for Deferred sites
//! ```
//!
//! The `Deferred` outcome plus the `finalize` hook exist for
//! cross-layer state: BTC's shared binary codebook must see the sign
//! vectors of *every* layer before any codebook layer can be built, so
//! its quantizer accumulates binarized layers during `quantize_group`
//! and resolves them all at `finalize`. Methods without cross-layer
//! state simply return `Ready` and inherit the default `finalize`.
//!
//! Methods are instantiated by name through
//! [`super::registry`] (`quant::registry::get("btc-0.8")`), so adding a
//! lane = one new file with a `Quantizer` impl + one
//! `registry::register` call.

use anyhow::Result;

use super::pipeline::QuantStats;
use super::transform::Transform;
use crate::model::transformer::Capture;
use crate::model::WeightBackend;
use crate::tensor::Matrix;

/// Identifies one linear while the pipeline runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SiteId {
    pub layer: usize,
    /// Linear slot name ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown").
    pub name: &'static str,
}

/// Result of quantizing one linear.
pub enum QuantOutcome {
    /// Backend ready to install.
    Ready(Box<dyn WeightBackend>),
    /// Resolution deferred to [`Quantizer::finalize`] (cross-layer
    /// state, e.g. a shared codebook). The driver installs a dense
    /// placeholder meanwhile and records the site.
    Deferred,
}

/// Read-only view of the calibration capture handed to
/// [`Quantizer::calibrate`].
pub struct CalibView<'a> {
    pub capture: &'a Capture,
    pub n_layers: usize,
}

/// One quantization method (a Table 1 lane, or anything registered at
/// runtime). Implementations hold their own per-run state; the driver
/// constructs a fresh instance per `quantize_model` call.
pub trait Quantizer {
    /// Display name for stats/tables (e.g. "BTC-LLM").
    fn name(&self) -> String;

    /// Identity lane (FP16): the driver skips calibration and
    /// quantization entirely and ships the dense weights.
    fn is_identity(&self) -> bool {
        false
    }

    /// Called once after calibration capture, before any group.
    fn calibrate(&mut self, _calib: &CalibView) -> Result<()> {
        Ok(())
    }

    /// Fit the learnable input transformation for one capture-site
    /// group (`x`: captured activations, `ws`: the fp weights sharing
    /// that input). Default: no transformation.
    fn fit_transform(&mut self, _x: &Matrix, _ws: &[&Matrix]) -> Result<Option<Transform>> {
        Ok(None)
    }

    /// Quantize one linear's effective (already transformed) weight.
    /// `act_sq` is the per-input-channel mean squared activation in the
    /// transformed space.
    fn quantize_group(
        &mut self,
        site: &SiteId,
        weff: &Matrix,
        act_sq: &[f32],
    ) -> Result<QuantOutcome>;

    /// Cross-layer finalize: return backends for every `Deferred`
    /// site, in the order the deferrals were returned. Method-specific
    /// stats (codebook size/build stats, aux losses) go into `stats`.
    fn finalize(&mut self, _stats: &mut QuantStats) -> Result<Vec<Box<dyn WeightBackend>>> {
        Ok(Vec::new())
    }
}
