//! Method registry: quantization lanes resolved **by name**, so the
//! CLI, benches and serving coordinator never enumerate methods.
//!
//! A spec is either a bare key (`"btc"`, `"arb-llm"`) or a key plus a
//! bits suffix (`"btc-0.8"`, `"stbllm-0.7"`). Built-in lanes are
//! pre-registered; adding a lane at runtime is one [`register`] call:
//!
//! ```no_run
//! use btc_llm::quant::pipeline::registry::{self, MethodEntry};
//! # fn preset(_b: f64) -> btc_llm::quant::QuantConfig { todo!() }
//! # fn make(_c: &btc_llm::quant::QuantConfig) -> Box<dyn btc_llm::quant::Quantizer> { todo!() }
//! registry::register(MethodEntry {
//!     key: "my-method",
//!     display: "My-Method",
//!     aliases: &[],
//!     takes_bits: true,
//!     default_bits: 1.0,
//!     preset,
//!     make,
//! });
//! let cfg = registry::get("my-method-0.5").unwrap();
//! ```

use std::collections::BTreeMap;
use std::sync::{OnceLock, RwLock};

use anyhow::{bail, Result};

use super::QuantConfig;
use crate::quant::billm::{SalientBinaryConfig, SalientResidualQuantizer};
use crate::quant::binarize::NaiveQuantizer;
use crate::quant::btc::BtcQuantizer;
use crate::quant::fpvq::FpVqQuantizer;
use crate::quant::quantizer::{QuantOutcome, Quantizer, SiteId};
use crate::quant::stbllm::StbllmQuantizer;

/// One registered quantization method.
#[derive(Debug, Clone, Copy)]
pub struct MethodEntry {
    /// Registry key; also the value of [`QuantConfig::method`].
    pub key: &'static str,
    /// Display name for tables/logs (e.g. "ARB-LLM").
    pub display: &'static str,
    /// Alternative lookup keys (e.g. `"arb"` for `"arb-llm"`).
    pub aliases: &'static [&'static str],
    /// Whether the method is parameterized by a bits target. When
    /// false, a `<key>-<bits>` spec is rejected instead of silently
    /// ignoring the suffix.
    pub takes_bits: bool,
    /// Bits target used when the spec has no suffix.
    pub default_bits: f64,
    /// Build the paper-preset config for a bits target.
    pub preset: fn(f64) -> QuantConfig,
    /// Instantiate the per-run strategy from a config.
    pub make: fn(&QuantConfig) -> Box<dyn Quantizer>,
}

fn table() -> &'static RwLock<BTreeMap<String, MethodEntry>> {
    static T: OnceLock<RwLock<BTreeMap<String, MethodEntry>>> = OnceLock::new();
    T.get_or_init(|| {
        let mut m = BTreeMap::new();
        for e in builtin_entries() {
            insert(&mut m, e);
        }
        RwLock::new(m)
    })
}

fn insert(m: &mut BTreeMap<String, MethodEntry>, e: MethodEntry) {
    m.insert(e.key.to_string(), e);
    for a in e.aliases {
        m.insert(a.to_string(), e);
    }
}

/// Register (or replace) a method. The entry is looked up under its
/// key and every alias.
pub fn register(entry: MethodEntry) {
    insert(&mut table().write().unwrap(), entry);
}

/// Primary keys of all registered methods (aliases excluded).
pub fn names() -> Vec<String> {
    let t = table().read().unwrap();
    t.iter().filter(|(k, e)| k.as_str() == e.key).map(|(k, _)| k.clone()).collect()
}

/// Single source of truth for spec resolution: exact key first, then
/// `<key>-<bits>` suffix form. Returns the entry plus the suffix bits
/// (if the spec carried one).
fn lookup<'a>(
    t: &'a BTreeMap<String, MethodEntry>,
    spec: &str,
) -> Option<(&'a MethodEntry, Option<f64>)> {
    if let Some(e) = t.get(spec) {
        return Some((e, None));
    }
    if let Some((prefix, suffix)) = spec.rsplit_once('-') {
        if let Ok(bits) = suffix.parse::<f64>() {
            if let Some(e) = t.get(prefix) {
                return Some((e, Some(bits)));
            }
        }
    }
    None
}

/// Reject `<key>-<bits>` specs for methods that are not parameterized
/// by bits — silently ignoring the suffix would run at a different
/// width than the user asked for.
fn check_suffix(e: &MethodEntry, suffix_bits: Option<f64>, spec: &str) -> Result<()> {
    if suffix_bits.is_some() && !e.takes_bits {
        bail!("method {:?} does not take a bits target (spec {spec:?})", e.key);
    }
    Ok(())
}

/// Resolve a spec (`"btc"`, `"btc-0.8"`, `"stbllm-0.7"`, …) to its
/// paper-preset [`QuantConfig`].
pub fn get(spec: &str) -> Result<QuantConfig> {
    let t = table().read().unwrap();
    match lookup(&t, spec) {
        Some((e, suffix_bits)) => {
            check_suffix(e, suffix_bits, spec)?;
            Ok((e.preset)(suffix_bits.unwrap_or(e.default_bits)))
        }
        None => bail!("unknown quantization method {spec:?}; registered: {:?}", keys_of(&t)),
    }
}

/// Resolve a method name with an explicit bits override (`None` =
/// the method's default, or the suffix if `name` carries one; an
/// explicit override wins over a suffix).
pub fn get_with_bits(name: &str, bits: Option<f64>) -> Result<QuantConfig> {
    match bits {
        None => get(name),
        Some(b) => {
            let t = table().read().unwrap();
            match lookup(&t, name) {
                Some((e, suffix_bits)) => {
                    check_suffix(e, suffix_bits, name)?;
                    Ok((e.preset)(b))
                }
                None => {
                    bail!("unknown quantization method {name:?}; registered: {:?}", keys_of(&t))
                }
            }
        }
    }
}

/// Resolve a spec where a bits suffix in the spec wins, then
/// `fallback`, then the method default — serve-config semantics: the
/// config file always supplies a bits value, and it must not mask a
/// more specific suffix in the spec (`backend = "btc-0.5"`).
pub fn get_with_fallback_bits(spec: &str, fallback: Option<f64>) -> Result<QuantConfig> {
    let t = table().read().unwrap();
    match lookup(&t, spec) {
        Some((e, suffix_bits)) => {
            check_suffix(e, suffix_bits, spec)?;
            Ok((e.preset)(suffix_bits.or(fallback).unwrap_or(e.default_bits)))
        }
        None => bail!("unknown quantization method {spec:?}; registered: {:?}", keys_of(&t)),
    }
}

/// Display name for a registered method key or spec.
pub fn display_name(spec: &str) -> Option<&'static str> {
    let t = table().read().unwrap();
    lookup(&t, spec).map(|(e, _)| e.display)
}

/// Instantiate the strategy for a config's method key.
pub fn quantizer_for(cfg: &QuantConfig) -> Result<Box<dyn Quantizer>> {
    let t = table().read().unwrap();
    match t.get(&cfg.method) {
        Some(e) => Ok((e.make)(cfg)),
        None => {
            bail!(
                "unknown quantization method {:?}; registered: {:?}",
                cfg.method,
                keys_of(&t)
            )
        }
    }
}

fn keys_of(t: &BTreeMap<String, MethodEntry>) -> Vec<String> {
    t.keys().cloned().collect()
}

// ---- built-in lanes --------------------------------------------------

/// The FP16 identity lane: dense weights shipped as-is.
#[derive(Debug, Default)]
pub struct Fp16Quantizer;

impl Quantizer for Fp16Quantizer {
    fn name(&self) -> String {
        "FP16".to_string()
    }

    fn is_identity(&self) -> bool {
        true
    }

    fn quantize_group(
        &mut self,
        _site: &SiteId,
        _weff: &crate::tensor::Matrix,
        _act_sq: &[f32],
    ) -> Result<QuantOutcome> {
        bail!("FP16 is an identity lane; the driver skips quantization")
    }
}

fn make_fp16(_cfg: &QuantConfig) -> Box<dyn Quantizer> {
    Box::<Fp16Quantizer>::default()
}

fn make_naive(_cfg: &QuantConfig) -> Box<dyn Quantizer> {
    Box::<NaiveQuantizer>::default()
}

fn salient_preset(cfg: &QuantConfig) -> SalientBinaryConfig {
    SalientBinaryConfig {
        salient_frac: cfg.salient_frac,
        n_splits: cfg.n_splits,
        arb_iters: cfg.arb_iters,
    }
}

fn make_billm(cfg: &QuantConfig) -> Box<dyn Quantizer> {
    Box::new(SalientResidualQuantizer::new("BiLLM", salient_preset(cfg)))
}

fn make_arb(cfg: &QuantConfig) -> Box<dyn Quantizer> {
    Box::new(SalientResidualQuantizer::new("ARB-LLM", salient_preset(cfg)))
}

fn make_stbllm(cfg: &QuantConfig) -> Box<dyn Quantizer> {
    Box::new(StbllmQuantizer { n: cfg.nm.0, m: cfg.nm.1 })
}

fn make_fpvq(cfg: &QuantConfig) -> Box<dyn Quantizer> {
    Box::new(FpVqQuantizer { v: cfg.fpvq.0, c: cfg.fpvq.1, iters: 8, seed: cfg.seed })
}

fn make_btc(cfg: &QuantConfig) -> Box<dyn Quantizer> {
    Box::new(BtcQuantizer::from_config(cfg))
}

fn builtin_entries() -> [MethodEntry; 7] {
    [
        MethodEntry {
            key: "fp16",
            display: "FP16",
            aliases: &[],
            takes_bits: false,
            default_bits: 16.0,
            preset: |_b| QuantConfig::fp16(),
            make: make_fp16,
        },
        MethodEntry {
            key: "naive",
            display: "Naive",
            aliases: &[],
            takes_bits: false,
            default_bits: 1.0,
            preset: |_b| QuantConfig::naive(),
            make: make_naive,
        },
        MethodEntry {
            key: "billm",
            display: "BiLLM",
            aliases: &[],
            takes_bits: false,
            default_bits: 1.11,
            preset: |_b| QuantConfig::billm(),
            make: make_billm,
        },
        MethodEntry {
            key: "arb-llm",
            display: "ARB-LLM",
            aliases: &["arb"],
            takes_bits: false,
            default_bits: 1.11,
            preset: |_b| QuantConfig::arb_llm(),
            make: make_arb,
        },
        MethodEntry {
            key: "stbllm",
            display: "STBLLM",
            aliases: &[],
            takes_bits: true,
            default_bits: 0.8,
            preset: QuantConfig::stbllm,
            make: make_stbllm,
        },
        MethodEntry {
            key: "fp-vq",
            display: "FP-VQ",
            aliases: &["fpvq"],
            // Matches the historical CLI default (`--method fpvq`
            // without --bits ran the sub-1-bit lane).
            takes_bits: true,
            default_bits: 0.8,
            preset: QuantConfig::fpvq,
            make: make_fpvq,
        },
        MethodEntry {
            key: "btc",
            display: "BTC-LLM",
            aliases: &[],
            takes_bits: true,
            default_bits: 0.8,
            preset: QuantConfig::btc,
            make: make_btc,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::WeightBackend;
    use crate::quant::binarize::BinaryLayer;
    use crate::quant::pipeline::{quantize_model, tests as pipeline_tests};
    use crate::tensor::Matrix;

    #[test]
    fn specs_resolve_with_and_without_bits() {
        let c = get("btc-0.8").unwrap();
        assert_eq!(c.method, "btc");
        assert!((c.target_bits - 0.8).abs() < 1e-12);
        let c = get("btc").unwrap();
        assert!((c.target_bits - 0.8).abs() < 1e-12);
        let c = get("stbllm-0.7").unwrap();
        assert_eq!(c.nm, (7, 10));
        let c = get("arb-llm").unwrap();
        assert_eq!(c.method, "arb-llm");
        let c = get("arb").unwrap();
        assert_eq!(c.method, "arb-llm");
        assert!(get("nope-1.0").is_err());
        let err = get("nope").unwrap_err().to_string();
        assert!(err.contains("btc") && err.contains("stbllm"), "{err}");
        // Bits suffix on a method that isn't bits-parameterized is an
        // error, not a silently-ignored number.
        let err = get("billm-0.5").unwrap_err().to_string();
        assert!(err.contains("does not take a bits target"), "{err}");
    }

    #[test]
    fn fallback_bits_yield_to_spec_suffix() {
        // Serve semantics: a suffix in the spec wins over the config's
        // bits value; a bare key takes the fallback; no fallback = the
        // method default (fp-vq keeps the historical CLI 0.8).
        let c = get_with_fallback_bits("btc-0.5", Some(0.8)).unwrap();
        assert!((c.target_bits - 0.5).abs() < 1e-12);
        let c = get_with_fallback_bits("btc", Some(0.7)).unwrap();
        assert!((c.target_bits - 0.7).abs() < 1e-12);
        let c = get_with_fallback_bits("fp-vq", None).unwrap();
        assert!((c.target_bits - 0.8).abs() < 1e-12);
        assert!(get_with_fallback_bits("nope", Some(1.0)).is_err());
    }

    #[test]
    fn names_cover_builtins() {
        let n = names();
        for key in ["fp16", "naive", "billm", "arb-llm", "stbllm", "fp-vq", "btc"] {
            assert!(n.contains(&key.to_string()), "missing {key} in {n:?}");
        }
    }

    #[test]
    fn custom_method_registers_and_runs_end_to_end() {
        // A toy method defined entirely here: binarize with plain
        // signs. One register call makes it a first-class lane.
        #[derive(Debug, Default)]
        struct ToySign;
        impl Quantizer for ToySign {
            fn name(&self) -> String {
                "Toy-Sign".to_string()
            }
            fn quantize_group(
                &mut self,
                _site: &SiteId,
                weff: &Matrix,
                _act_sq: &[f32],
            ) -> Result<QuantOutcome> {
                Ok(QuantOutcome::Ready(Box::new(BinaryLayer::quantize(weff))))
            }
        }
        fn toy_preset(bits: f64) -> QuantConfig {
            QuantConfig {
                method: "toy-sign-test".into(),
                target_bits: bits,
                ..pipeline_tests::quick(QuantConfig::default())
            }
        }
        fn toy_make(_cfg: &QuantConfig) -> Box<dyn Quantizer> {
            Box::<ToySign>::default()
        }
        register(MethodEntry {
            key: "toy-sign-test",
            display: "Toy-Sign",
            aliases: &[],
            takes_bits: true,
            default_bits: 1.0,
            preset: toy_preset,
            make: toy_make,
        });

        let (raw, corpus) = pipeline_tests::fixture_public();
        let cfg = get("toy-sign-test-1.0").unwrap();
        let qm = quantize_model(&raw, &corpus, &cfg).unwrap();
        assert_eq!(qm.stats.method, "Toy-Sign");
        assert_eq!(qm.model.blocks[0].wq.backend_name(), "binary");
        let logits = qm.model.forward(&[3, 1, 4]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
        let bits = qm.model.blocks[0].wq.backend.storage_bits();
        assert!(bits > 0);
    }
}
