//! Per-model quantization pipeline (paper Fig. 4) as a thin staged
//! driver over the open [`Quantizer`] strategy trait:
//!
//! ```text
//! CalibStage     capture activations on the fp model
//! TransformStage quantizer.fit_transform per capture-site group
//! QuantStage     quantizer.quantize_group per linear (Ready | Deferred)
//! CodebookStage  quantizer.finalize -> backends for deferred sites
//! StatsStage     measured/payload bits, mean relative error
//! ```
//!
//! The driver knows *no* method names: lanes are resolved through
//! [`registry`] (`quant::registry::get("btc-0.8")`), so every baseline
//! (naive / BiLLM / ARB-LLM / STBLLM / FP-VQ) and BTC itself — plus any
//! method registered at runtime — runs through identical scaffolding
//! and the benches compare like with like.

pub mod registry;
pub mod stages;

use anyhow::Result;

use super::billm::SalientBinaryConfig;
use super::codebook::BuildStats;
use super::quantizer::CalibView;
use crate::io::weights::RawModel;
use crate::model::transformer::Transformer;

/// Full pipeline configuration. `method` is a [`registry`] key
/// (`"btc"`, `"arb-llm"`, …); use the presets ([`QuantConfig::btc`]
/// etc.) or [`registry::get`] for paper-table settings.
#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// Method registry key (see [`registry::names`]).
    pub method: String,
    /// Nominal W-bits label (the paper's table column).
    pub target_bits: f64,
    /// Codebook sub-vector length (BTC sub-1-bit).
    pub v: usize,
    /// Codebook size; 0 = derive as 2^round(target_bits * v).
    pub codebook_c: usize,
    /// EM iterations for the binary codebook (paper: 5).
    pub em_iters: usize,
    pub n_splits: usize,
    pub salient_frac: f64,
    pub arb_iters: usize,
    /// Learnable transformation components (Table 3b ablation).
    pub transform_p: bool,
    pub transform_sigma: bool,
    pub transform_outer: usize,
    /// Activation bits (16 = off; Table 3d).
    pub act_bits: u32,
    /// STBLLM N:M.
    pub nm: (usize, usize),
    /// FP-VQ (v, c).
    pub fpvq: (usize, usize),
    /// Calibration: #sequences, sequence length, captured row cap.
    pub calib_seqs: usize,
    pub calib_seq_len: usize,
    pub calib_rows: usize,
    pub seed: u64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            method: "fp16".to_string(),
            target_bits: 16.0,
            v: 16,
            codebook_c: 0,
            em_iters: 5,
            n_splits: 2,
            salient_frac: 0.10,
            arb_iters: 15,
            transform_p: true,
            transform_sigma: true,
            transform_outer: 14,
            act_bits: 16,
            nm: (4, 5),
            fpvq: (4, 256),
            calib_seqs: 16,
            calib_seq_len: 64,
            calib_rows: 192,
            seed: 42,
        }
    }
}

impl QuantConfig {
    pub fn fp16() -> Self {
        Self::default()
    }

    pub fn naive() -> Self {
        QuantConfig { method: "naive".into(), target_bits: 1.0, ..Self::default() }
    }

    pub fn billm() -> Self {
        let p = SalientBinaryConfig::billm();
        QuantConfig {
            method: "billm".into(),
            target_bits: 1.11,
            n_splits: p.n_splits,
            salient_frac: p.salient_frac,
            arb_iters: p.arb_iters,
            ..Self::default()
        }
    }

    pub fn arb_llm() -> Self {
        let p = SalientBinaryConfig::arb_llm();
        QuantConfig {
            method: "arb-llm".into(),
            target_bits: 1.11,
            n_splits: p.n_splits,
            salient_frac: p.salient_frac,
            arb_iters: p.arb_iters,
            ..Self::default()
        }
    }

    /// STBLLM at a nominal sub-1 bit target (0.8 -> 4:5, 0.7 -> 7:10).
    pub fn stbllm(bits: f64) -> Self {
        let nm = if bits <= 0.55 {
            (1, 2)
        } else if bits <= 0.72 {
            (7, 10)
        } else {
            (4, 5)
        };
        QuantConfig { method: "stbllm".into(), target_bits: bits, nm, ..Self::default() }
    }

    /// FP vector quantization at a bits target.
    pub fn fpvq(bits: f64) -> Self {
        let (v, c) = if bits >= 1.5 {
            (4usize, 256usize) // 2-bit lane
        } else {
            // sub-1: v=8, c = 2^(bits*8)
            (8, (2f64.powf(bits * 8.0)).round().max(2.0) as usize)
        };
        QuantConfig { method: "fp-vq".into(), target_bits: bits, fpvq: (v, c), ..Self::default() }
    }

    /// BTC-LLM at a bits target. >= 1.0 is the binary (no codebook)
    /// lane labelled 1.11 in the paper; < 1.0 engages the codebook.
    pub fn btc(bits: f64) -> Self {
        QuantConfig { method: "btc".into(), target_bits: bits, v: 16, ..Self::default() }
    }

    /// Codebook size for the bits target.
    pub fn derived_c(&self) -> usize {
        if self.codebook_c > 0 {
            return self.codebook_c;
        }
        let c = 2f64.powf(self.target_bits * self.v as f64).round() as usize;
        c.clamp(2, 1 << 22)
    }
}

/// Per-pipeline stats: timings, errors, storage.
#[derive(Debug, Clone, Default)]
pub struct QuantStats {
    pub method: String,
    pub target_bits: f64,
    /// Measured linear-weight bits (incl. scales/groups/indices, excl.
    /// the shared codebook, which is reported separately).
    pub measured_bits: f64,
    /// Payload bits/weight (signs/indices/masks only — the paper's
    /// table convention; per-row fp16 scales excluded, see
    /// [`crate::model::WeightBackend::payload_bits_per_weight`]).
    pub payload_bits: f64,
    /// Shared codebook storage bits (0 when unused).
    pub codebook_bits: usize,
    /// Transform storage bits (Kronecker factors + sigma).
    pub transform_bits: usize,
    /// Mean of the per-layer relative reconstruction errors
    /// (sum over linears divided by `n_linears`).
    pub mean_rel_error: f64,
    pub transform_secs: f64,
    pub quant_secs: f64,
    pub codebook_secs: f64,
    pub codebook_stats: Option<BuildStats>,
    /// Auxiliary losses sampled after quantization (L_sim, L_bal).
    pub aux_losses: Option<(f64, f64)>,
    pub n_linears: usize,
}

/// A quantized model plus its pipeline stats.
pub struct QuantizedModel {
    pub model: Transformer,
    pub stats: QuantStats,
    pub config: QuantConfig,
}

/// Quantize a full model. `corpus` supplies calibration sequences; the
/// method is resolved by name through the [`registry`].
pub fn quantize_model(raw: &RawModel, corpus: &[u8], cfg: &QuantConfig) -> Result<QuantizedModel> {
    let mut quantizer = registry::quantizer_for(cfg)?;
    let mut model = Transformer::from_raw(raw)?;
    let mut stats = QuantStats {
        method: quantizer.name(),
        target_bits: cfg.target_bits,
        ..Default::default()
    };
    if quantizer.is_identity() {
        model.cache_dense_all();
        stats.measured_bits = 16.0;
        return Ok(QuantizedModel { model, stats, config: cfg.clone() });
    }

    // ---- CalibStage ----------------------------------------------------
    let capture = stages::calib_stage(&model, corpus, cfg);
    quantizer.calibrate(&CalibView { capture: &capture, n_layers: model.cfg.n_layer })?;

    // ---- TransformStage + QuantStage per (layer, capture-site) group ---
    let mut acc = stages::Accum::default();
    for li in 0..model.cfg.n_layer {
        for group in stages::SITE_GROUPS.iter() {
            let x = capture
                .matrix(li, group.site)
                .ok_or_else(|| anyhow::anyhow!("no calibration capture for layer {li}"))?;
            let ws = stages::group_weights(&model, li, group.names);
            let prep = stages::transform_stage(quantizer.as_mut(), &x, &ws, cfg, &mut stats)?;
            stages::quant_stage(
                quantizer.as_mut(),
                &mut model,
                li,
                group.names,
                &ws,
                &prep,
                &mut acc,
                &mut stats,
            )?;
        }
    }

    // ---- CodebookStage (cross-layer finalize) --------------------------
    stages::codebook_stage(quantizer.as_mut(), &mut model, &mut acc, &mut stats)?;

    // ---- StatsStage ----------------------------------------------------
    stages::stats_stage(&model, &acc, &mut stats);
    model.cache_dense_all();
    Ok(QuantizedModel { model, stats, config: cfg.clone() })
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::io::weights::RawModel;
    use crate::util::fixture::tiny_raw_model;

    /// Shared fixture for cross-module tests (io::qweights etc.).
    pub fn fixture_public() -> (RawModel, Vec<u8>) {
        fixture()
    }

    /// Small random model + corpus for pipeline tests.
    fn fixture() -> (RawModel, Vec<u8>) {
        tiny_raw_model(9)
    }

    /// Shrink a preset for fast tests (shared with io/eval tests).
    pub fn quick(cfg: QuantConfig) -> QuantConfig {
        QuantConfig {
            calib_seqs: 4,
            calib_seq_len: 24,
            calib_rows: 48,
            transform_outer: 2,
            arb_iters: 4,
            v: 8,
            ..cfg
        }
    }

    #[test]
    fn fp16_passthrough() {
        let (raw, corpus) = fixture();
        let qm = quantize_model(&raw, &corpus, &QuantConfig::fp16()).unwrap();
        assert_eq!(qm.stats.measured_bits, 16.0);
        assert_eq!(qm.stats.method, "FP16");
        let logits = qm.model.forward(&[1, 2, 3]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn all_methods_produce_runnable_models() {
        let (raw, corpus) = fixture();
        for cfg in [
            QuantConfig::naive(),
            QuantConfig::billm(),
            QuantConfig::stbllm(0.8),
            QuantConfig::fpvq(2.0),
            QuantConfig::btc(0.8),
        ] {
            let qm = quantize_model(&raw, &corpus, &quick(cfg)).unwrap();
            let logits = qm.model.forward(&[5, 6, 7, 8]);
            assert!(
                logits.data.iter().all(|v| v.is_finite()),
                "{} produced non-finite logits",
                qm.stats.method
            );
            assert!(qm.stats.n_linears == 14, "{}", qm.stats.n_linears);
        }
    }

    #[test]
    fn btc_sub1_bits_actually_sub1() {
        let (raw, corpus) = fixture();
        let qm = quantize_model(&raw, &corpus, &quick(QuantConfig::btc(0.7))).unwrap();
        // Payload convention (signs/indices only): must be sub-1.
        // The fully-measured figure includes per-row fp16 scales that
        // only amortize at real LLM widths — see payload_bits docs.
        assert!(
            qm.stats.payload_bits < 1.0,
            "payload {} bits",
            qm.stats.payload_bits
        );
        assert!(qm.stats.codebook_bits > 0);
        assert!(qm.stats.codebook_stats.is_some());
    }

    #[test]
    fn stbllm_mask_overhead_visible() {
        let (raw, corpus) = fixture();
        let qm = quantize_model(&raw, &corpus, &quick(QuantConfig::stbllm(0.8))).unwrap();
        // Nominal 0.8 but payload > 1.0 even before scales — the
        // paper's intro critique of N:M mask storage.
        assert!(qm.stats.payload_bits > 1.0, "payload {}", qm.stats.payload_bits);
    }

    #[test]
    fn btc_transform_reduces_error_vs_no_transform() {
        let (raw, corpus) = fixture();
        let mut with_t = quick(QuantConfig::btc(0.8));
        with_t.transform_outer = 4;
        let mut no_t = with_t.clone();
        no_t.transform_p = false;
        no_t.transform_sigma = false;
        let qt = quantize_model(&raw, &corpus, &with_t).unwrap();
        let qn = quantize_model(&raw, &corpus, &no_t).unwrap();
        // Table 3b ordering on weight reconstruction error.
        assert!(
            qt.stats.mean_rel_error <= qn.stats.mean_rel_error * 1.25,
            "transform err {} vs none {}",
            qt.stats.mean_rel_error,
            qn.stats.mean_rel_error
        );
        assert!(qt.stats.transform_bits > 0);
        assert_eq!(qn.stats.transform_bits, 0);
    }

    #[test]
    fn act_quant_attached() {
        let (raw, corpus) = fixture();
        let mut cfg = quick(QuantConfig::btc(0.8));
        cfg.act_bits = 8;
        let qm = quantize_model(&raw, &corpus, &cfg).unwrap();
        assert!(qm.model.blocks[0].wq.act_quant.is_some());
        let logits = qm.model.forward(&[1, 2, 3]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn derived_c_scaling() {
        let mut cfg = QuantConfig::btc(0.8);
        cfg.v = 10;
        assert_eq!(cfg.derived_c(), 256); // 2^8
        cfg.v = 20;
        assert_eq!(cfg.derived_c(), 65536); // 2^16
        cfg.codebook_c = 77;
        assert_eq!(cfg.derived_c(), 77);
    }

    #[test]
    fn unknown_method_fails_loudly() {
        let (raw, corpus) = fixture();
        let cfg = QuantConfig { method: "no-such-method".into(), ..QuantConfig::default() };
        let err = quantize_model(&raw, &corpus, &cfg).unwrap_err().to_string();
        assert!(err.contains("no-such-method"), "{err}");
        assert!(err.contains("btc"), "error should list known methods: {err}");
    }

    #[test]
    fn backends_carry_stable_tags() {
        let (raw, corpus) = fixture();
        for (cfg, tag) in [
            (QuantConfig::naive(), "binary"),
            (QuantConfig::arb_llm(), "residual"),
            (QuantConfig::stbllm(0.8), "nm-sparse"),
            (QuantConfig::fpvq(2.0), "fp-vq"),
            (QuantConfig::btc(0.8), "codebook"),
        ] {
            let qm = quantize_model(&raw, &corpus, &quick(cfg)).unwrap();
            assert_eq!(qm.model.blocks[0].wq.backend_name(), tag);
        }
    }
}
