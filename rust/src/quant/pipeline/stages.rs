//! The pipeline's stages, factored out of the driver so each is
//! testable and method-agnostic: every method-specific decision lives
//! behind the [`Quantizer`] trait.

use std::time::Instant;

use anyhow::{bail, Result};

use super::{QuantConfig, QuantStats};
use crate::data::calib::CalibSet;
use crate::model::transformer::{Capture, CaptureSite, Transformer};
use crate::model::{Linear, WeightBackend};
use crate::quant::actquant::ActQuant;
use crate::quant::quantizer::{QuantOutcome, Quantizer, SiteId};
use crate::quant::transform::Transform;
use crate::tensor::Matrix;

/// One capture site and the linears fed by it.
pub struct SiteGroup {
    pub site: CaptureSite,
    pub names: &'static [&'static str],
}

/// The 7 linears of a block, grouped by shared input.
pub const SITE_GROUPS: [SiteGroup; 4] = [
    SiteGroup { site: CaptureSite::Ln1Out, names: &["wq", "wk", "wv"] },
    SiteGroup { site: CaptureSite::AttnOut, names: &["wo"] },
    SiteGroup { site: CaptureSite::Ln2Out, names: &["wgate", "wup"] },
    SiteGroup { site: CaptureSite::FfnMid, names: &["wdown"] },
];

/// CalibStage: run calibration sequences through the fp model,
/// capturing activations at every site until `calib_rows` is reached.
pub fn calib_stage(model: &Transformer, corpus: &[u8], cfg: &QuantConfig) -> Capture {
    let calib = CalibSet::sample(corpus, cfg.calib_seqs, cfg.calib_seq_len, cfg.seed);
    let mut capture = Capture::new(cfg.calib_rows);
    for seq in &calib.seqs {
        if capture
            .matrix(0, CaptureSite::Ln1Out)
            .map(|m| m.rows >= cfg.calib_rows)
            .unwrap_or(false)
        {
            break;
        }
        let mut opt = Some(&mut capture);
        model.forward_capture(seq, &mut opt);
    }
    capture
}

/// Per-input-channel mean squared activation.
pub fn act_sq_of(x: &Matrix) -> Vec<f32> {
    let mut v = vec![0f32; x.cols];
    for r in 0..x.rows {
        for (c, &val) in x.row(r).iter().enumerate() {
            v[c] += val * val;
        }
    }
    for val in v.iter_mut() {
        *val /= x.rows.max(1) as f32;
    }
    v
}

/// Pull the current (dense) weights of one site group.
pub fn group_weights(model: &Transformer, li: usize, names: &[&str]) -> Vec<Matrix> {
    names
        .iter()
        .map(|n| {
            let block = &model.blocks[li];
            block
                .linears()
                .iter()
                .find(|(nm, _)| nm == n)
                .expect("known linear slot")
                .1
                .backend
                .reconstruct()
        })
        .collect()
}

/// TransformStage output for one site group.
pub struct GroupPrep {
    pub transform: Option<Transform>,
    pub act_quant: Option<ActQuant>,
    /// Mean squared activation per channel, in transformed space.
    pub act_sq: Vec<f32>,
}

/// TransformStage: let the quantizer fit its input transformation for
/// the group, then calibrate the activation quantizer in transformed
/// space.
pub fn transform_stage(
    quantizer: &mut dyn Quantizer,
    x: &Matrix,
    ws: &[Matrix],
    cfg: &QuantConfig,
    stats: &mut QuantStats,
) -> Result<GroupPrep> {
    let t0 = Instant::now();
    let refs: Vec<&Matrix> = ws.iter().collect();
    let transform = quantizer.fit_transform(x, &refs)?;
    stats.transform_secs += t0.elapsed().as_secs_f64();
    if let Some(t) = &transform {
        stats.transform_bits += (t.p1.data.len() + t.p2.data.len()) * 16 + t.sigma.len();
    }
    let xt = match &transform {
        Some(t) => t.apply(x),
        None => x.clone(),
    };
    let act_sq = act_sq_of(&xt);
    let act_quant = if cfg.act_bits < 16 {
        Some(ActQuant::calibrate(&xt, cfg.act_bits))
    } else {
        None
    };
    Ok(GroupPrep { transform, act_quant, act_sq })
}

/// Running totals across QuantStage / CodebookStage.
#[derive(Default)]
pub struct Accum {
    /// Sites whose backend is deferred to the quantizer's finalize.
    pub deferred: Vec<SiteId>,
    pub total_weight_bits: usize,
    pub total_weights: usize,
    pub rel_err_sum: f64,
    pub n_linears: usize,
}

fn install_backend(
    model: &mut Transformer,
    li: usize,
    name: &str,
    backend: Box<dyn WeightBackend>,
    prep: &GroupPrep,
) {
    let block = &mut model.blocks[li];
    for (nm, lin) in block.linears_mut() {
        if nm == name {
            let mut new_lin = Linear::new(backend);
            new_lin.transform = prep.transform.clone();
            new_lin.act_quant = prep.act_quant.clone();
            *lin = new_lin;
            break;
        }
    }
}

/// QuantStage: quantize every linear of one site group through the
/// quantizer, installing ready backends immediately and dense
/// placeholders for deferred ones.
#[allow(clippy::too_many_arguments)]
pub fn quant_stage(
    quantizer: &mut dyn Quantizer,
    model: &mut Transformer,
    li: usize,
    names: &'static [&'static str],
    ws: &[Matrix],
    prep: &GroupPrep,
    acc: &mut Accum,
    stats: &mut QuantStats,
) -> Result<()> {
    let t0 = Instant::now();
    for (&name, w) in names.iter().zip(ws.iter()) {
        let weff = match &prep.transform {
            Some(t) => t.transform_weight(w),
            None => w.clone(),
        };
        acc.n_linears += 1;
        acc.total_weights += weff.rows * weff.cols;
        let site = SiteId { layer: li, name };
        let backend: Box<dyn WeightBackend> =
            match quantizer.quantize_group(&site, &weff, &prep.act_sq)? {
                QuantOutcome::Ready(b) => {
                    let rec = b.reconstruct();
                    acc.rel_err_sum += crate::tensor::stats::rel_error(&weff.data, &rec.data);
                    acc.total_weight_bits += b.storage_bits();
                    b
                }
                QuantOutcome::Deferred => {
                    acc.deferred.push(site);
                    // Dense placeholder holding the effective weight;
                    // replaced (and error-accounted) at CodebookStage.
                    Box::new(weff)
                }
            };
        install_backend(model, li, name, backend, prep);
    }
    stats.quant_secs += t0.elapsed().as_secs_f64();
    Ok(())
}

/// CodebookStage: resolve deferred sites through the quantizer's
/// cross-layer finalize (the shared-codebook build for BTC), swapping
/// each placeholder for its final backend.
pub fn codebook_stage(
    quantizer: &mut dyn Quantizer,
    model: &mut Transformer,
    acc: &mut Accum,
    stats: &mut QuantStats,
) -> Result<()> {
    let t0 = Instant::now();
    let finals = quantizer.finalize(stats)?;
    if finals.len() != acc.deferred.len() {
        bail!(
            "quantizer finalized {} backends for {} deferred sites",
            finals.len(),
            acc.deferred.len()
        );
    }
    if finals.is_empty() {
        return Ok(());
    }
    for (site, backend) in acc.deferred.iter().zip(finals) {
        let block = &mut model.blocks[site.layer];
        for (nm, lin) in block.linears_mut() {
            if nm == site.name {
                // The placeholder reconstructs to the effective weight.
                let weff = lin.backend.reconstruct();
                acc.rel_err_sum +=
                    crate::tensor::stats::rel_error(&weff.data, &backend.reconstruct().data);
                acc.total_weight_bits += backend.storage_bits();
                lin.backend = backend;
                break;
            }
        }
    }
    stats.codebook_secs = t0.elapsed().as_secs_f64();
    Ok(())
}

/// StatsStage: measured/payload bits per weight and mean relative
/// reconstruction error.
pub fn stats_stage(model: &Transformer, acc: &Accum, stats: &mut QuantStats) {
    stats.measured_bits = acc.total_weight_bits as f64 / acc.total_weights.max(1) as f64;
    let mut payload_weighted = 0f64;
    let mut wtot = 0usize;
    for block in &model.blocks {
        for (_, lin) in block.linears() {
            let (o, i) = lin.backend.shape();
            payload_weighted += lin.backend.payload_bits_per_weight() * (o * i) as f64;
            wtot += o * i;
        }
    }
    stats.payload_bits = payload_weighted / wtot.max(1) as f64;
    stats.mean_rel_error = acc.rel_err_sum / acc.n_linears.max(1) as f64;
    stats.n_linears = acc.n_linears;
}
