//! ARB: Alternating Refined Binarization (paper §3, after ARB-LLM).
//!
//! Iteratively refines, holding the others fixed:
//!   mu    <- mu + row-mean of the residual            (bias refit)
//!   alpha <- per-(row, group) least-squares scale      (scale refit)
//!   B     <- sign(W - mu)                              (sign refit)
//!
//! Each step is the exact coordinate minimizer of the Frobenius
//! objective, so the reconstruction error is monotonically
//! non-increasing — pinned by a property test.

use std::io::{Read, Write};

use anyhow::{bail, Result};

use super::binarize::{read_binary_payload, write_binary_payload, BinaryLayer};
use crate::io::wire;
use crate::model::{BackendIoCtx, WeightBackend};
use crate::tensor::Matrix;

/// Run `iters` rounds of alternating refinement starting from a plain
/// grouped binarization of `w`.
pub fn arb_quantize(w: &Matrix, col_group: &[u16], n_groups: usize, iters: usize) -> BinaryLayer {
    let mut q = BinaryLayer::quantize_grouped(w, col_group, n_groups);
    refine(&mut q, w, iters);
    q
}

/// Refine an existing binarization in place.
pub fn refine(q: &mut BinaryLayer, w: &Matrix, iters: usize) {
    let (rows, cols, ng) = (q.rows, q.cols, q.n_groups);
    let mut group_count = vec![0f64; ng];
    for &g in &q.col_group {
        group_count[g as usize] += 1.0;
    }
    let mut prev_err = f64::INFINITY;
    for _ in 0..iters {
        for r in 0..rows {
            let wrow = w.row(r);
            let signs = q.b.unpack_row(r);
            let arow_off = r * ng;

            // (1) bias refit: mu_r = mean(w - alpha*B) over the row.
            let mut s = 0f64;
            for c in 0..cols {
                s += (wrow[c] - q.alpha[arow_off + q.col_group[c] as usize] * signs[c]) as f64;
            }
            q.mu[r] = (s / cols as f64) as f32;

            // (2) scale refit: alpha_{r,g} = mean over group of B*(w-mu)
            //     (exact LS because B in {-1,1} => B^T B = |group|).
            let mut acc = vec![0f64; ng];
            for c in 0..cols {
                acc[q.col_group[c] as usize] += (signs[c] * (wrow[c] - q.mu[r])) as f64;
            }
            for g in 0..ng {
                if group_count[g] > 0.0 {
                    // Negative LS scale would flip all signs; clamp at 0
                    // (sign refit below re-aligns B anyway).
                    q.alpha[arow_off + g] = (acc[g] / group_count[g]).max(0.0) as f32;
                }
            }

            // (3) sign refit: B = sign(w - mu).
            for c in 0..cols {
                q.b.set(r, c, wrow[c] - q.mu[r] >= 0.0);
            }
        }
        // Early exit on convergence.
        let err = q.error(w);
        if prev_err - err < 1e-9 * prev_err.abs().max(1.0) {
            break;
        }
        prev_err = err;
    }
}

/// Residual second-order binarization (BiLLM-style, used for salient
/// columns): quantize `w`, then binarize the residual on the given
/// column subset and return both layers.
#[derive(Debug, Clone)]
pub struct ResidualBinary {
    pub primary: BinaryLayer,
    /// Residual signs over salient columns only (rows x n_salient).
    pub residual: BinaryLayer,
    /// The salient column indices the residual applies to.
    pub salient_cols: Vec<usize>,
}

impl ResidualBinary {
    pub fn quantize(
        w: &Matrix,
        col_group: &[u16],
        n_groups: usize,
        salient_cols: &[usize],
        arb_iters: usize,
    ) -> ResidualBinary {
        let primary = if arb_iters > 0 {
            arb_quantize(w, col_group, n_groups, arb_iters)
        } else {
            BinaryLayer::quantize_grouped(w, col_group, n_groups)
        };
        // Residual restricted to salient columns.
        let rec = primary.reconstruct();
        let mut res = Matrix::zeros(w.rows, salient_cols.len());
        for r in 0..w.rows {
            for (j, &c) in salient_cols.iter().enumerate() {
                *res.at_mut(r, j) = w.at(r, c) - rec.at(r, c);
            }
        }
        let residual = BinaryLayer::quantize(&res);
        ResidualBinary { primary, residual, salient_cols: salient_cols.to_vec() }
    }

    pub fn reconstruct(&self) -> Matrix {
        let mut out = self.primary.reconstruct();
        let res = self.residual.reconstruct();
        for r in 0..out.rows {
            for (j, &c) in self.salient_cols.iter().enumerate() {
                *out.at_mut(r, c) += res.at(r, j);
            }
        }
        out
    }

    pub fn error(&self, w: &Matrix) -> f64 {
        self.reconstruct().sub(w).fro2()
    }

    /// Storage bits: primary + residual signs/scales + salient bitmap.
    pub fn storage_bits(&self) -> usize {
        self.primary.storage_bits() + self.residual.storage_bits() + self.primary.cols
    }

    pub fn bits_per_weight(&self) -> f64 {
        self.storage_bits() as f64 / (self.primary.rows * self.primary.cols) as f64
    }
}

impl WeightBackend for ResidualBinary {
    fn tag(&self) -> &'static str {
        "residual"
    }

    fn shape(&self) -> (usize, usize) {
        (self.primary.rows, self.primary.cols)
    }

    fn reconstruct(&self) -> Matrix {
        ResidualBinary::reconstruct(self)
    }

    fn storage_bits(&self) -> usize {
        ResidualBinary::storage_bits(self)
    }

    fn resident_bytes(&self) -> usize {
        self.primary.resident_bytes()
            + self.residual.resident_bytes()
            + self.salient_cols.len() * std::mem::size_of::<usize>()
    }

    fn payload_bits_per_weight(&self) -> f64 {
        let p = &self.primary;
        let group = if p.n_groups > 1 {
            p.cols * (usize::BITS - (p.n_groups - 1).leading_zeros()) as usize
        } else {
            0
        };
        // primary signs + residual signs on salient cols + bitmap
        (p.rows * p.cols + self.residual.rows * self.residual.cols + p.cols + group) as f64
            / (p.rows * p.cols) as f64
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        write_binary_payload(w, &self.primary)?;
        write_binary_payload(w, &self.residual)?;
        wire::w_u32(w, self.salient_cols.len() as u32)?;
        wire::w_u32s(w, &self.salient_cols.iter().map(|&c| c as u32).collect::<Vec<_>>())
    }

    fn clone_box(&self) -> Box<dyn WeightBackend> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Registered deserializer for the `residual` tag.
pub fn read_backend(r: &mut dyn Read, _ctx: &BackendIoCtx) -> Result<Box<dyn WeightBackend>> {
    let primary = read_binary_payload(r)?;
    let residual = read_binary_payload(r)?;
    let n_sal = wire::r_u32(r)? as usize;
    if n_sal > primary.cols {
        bail!(
            "residual backend: {n_sal} salient columns exceed width {}",
            primary.cols
        );
    }
    if residual.cols != n_sal || residual.rows != primary.rows {
        bail!(
            "residual backend: residual block {}x{} does not match {} salient columns of {} rows",
            residual.rows,
            residual.cols,
            n_sal,
            primary.rows
        );
    }
    let salient_cols: Vec<usize> = wire::r_u32s(r, n_sal)?.into_iter().map(|c| c as usize).collect();
    if let Some(&c) = salient_cols.iter().find(|&&c| c >= primary.cols) {
        bail!("residual backend: salient column {c} out of range (cols {})", primary.cols);
    }
    Ok(Box::new(ResidualBinary { primary, residual, salient_cols }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn arb_never_worse_than_naive_property() {
        check(
            "arb <= naive",
            20,
            |r: &mut Rng| Matrix::randn(6, 32, r),
            |w| {
                let naive = BinaryLayer::quantize(w).error(w);
                let arb = arb_quantize(w, &vec![0u16; 32], 1, 15).error(w);
                if arb <= naive + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("arb {arb} > naive {naive}"))
                }
            },
        );
    }

    #[test]
    fn arb_error_monotone_per_iteration() {
        let mut rng = Rng::new(11);
        let w = Matrix::from_fn(8, 64, |_, _| rng.heavy_tailed(0.05, 8.0));
        let cg = vec![0u16; 64];
        let mut prev = f64::INFINITY;
        for iters in [0usize, 1, 2, 4, 8, 15] {
            let q = arb_quantize(&w, &cg, 1, iters);
            let e = q.error(&w);
            assert!(e <= prev + 1e-6, "iters {iters}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn arb_with_shifted_distribution() {
        // ARB's bias refinement should handle a strong mean shift.
        let mut rng = Rng::new(5);
        let w = Matrix::from_fn(4, 48, |_, _| rng.normal() + 3.0);
        let q = arb_quantize(&w, &vec![0u16; 48], 1, 10);
        // mu should land near 3.
        assert!(q.mu.iter().all(|&m| (m - 3.0).abs() < 0.5), "mu {:?}", q.mu);
    }

    #[test]
    fn residual_reduces_error_on_salient() {
        check(
            "residual helps",
            10,
            |r: &mut Rng| Matrix::from_fn(6, 40, |_, c| r.normal() * if c < 4 { 10.0 } else { 1.0 }),
            |w| {
                let cg = vec![0u16; 40];
                let plain = arb_quantize(w, &cg, 1, 8).error(w);
                let sal: Vec<usize> = (0..4).collect();
                let resid = ResidualBinary::quantize(w, &cg, 1, &sal, 8).error(w);
                if resid <= plain + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("residual {resid} > plain {plain}"))
                }
            },
        );
    }

    #[test]
    fn residual_bits_accounting() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(64, 128, &mut rng);
        let sal: Vec<usize> = (0..13).collect(); // ~10% salient
        let rb = ResidualBinary::quantize(&w, &vec![0u16; 128], 1, &sal, 4);
        let bits = rb.bits_per_weight();
        // 1 sign + ~0.1 residual signs + bitmap + fp16 scales. At this
        // tiny width the per-row scales are a visible fraction (they
        // amortize at LLM widths): expect [1.05, 1.8].
        assert!(bits > 1.05 && bits < 1.8, "bits {bits}");
        // Scale-free payload: 1 + 13/128 + bitmap 1/64... ≈ 1.11 —
        // the paper's "1.11 bits" figure.
        let payload =
            (rb.primary.rows * rb.primary.cols + rb.residual.rows * rb.residual.cols
                + rb.primary.cols) as f64
                / (rb.primary.rows * rb.primary.cols) as f64;
        assert!(payload > 1.05 && payload < 1.2, "payload {payload}");
    }
}
