//! Activation quantization (paper §5.3 Table 3d, App. F): symmetric
//! min-max integer quantization with per-channel scales calibrated on
//! sample activations. Simulated quantization (quantize-dequantize) —
//! the standard way to measure WxAy accuracy.

use crate::tensor::Matrix;

/// Per-channel symmetric activation quantizer.
///
/// Invariant: `bits >= 16` implies an empty `scale` (identity). The
/// fields stay public for construction-site ergonomics and wire
/// compatibility, so [`ActQuant::apply`] asserts the invariant rather
/// than trusting it — a hand-built `{ bits: 16, scale: vec![...] }`
/// used to *silently quantize* at a width the config said was off.
/// Use [`ActQuant::checked`] to validate untrusted (deserialized)
/// values up front.
#[derive(Debug, Clone)]
pub struct ActQuant {
    pub bits: u32,
    /// Per-channel scale (absmax / qmax). Empty = identity (A16).
    pub scale: Vec<f32>,
}

impl ActQuant {
    /// A16 = no activation quantization.
    pub fn identity() -> ActQuant {
        ActQuant { bits: 16, scale: Vec::new() }
    }

    /// Validate a hand-built / deserialized quantizer against the
    /// type's invariant: `bits` in `2..=16`, and `bits >= 16` only as
    /// the scale-free identity.
    pub fn checked(bits: u32, scale: Vec<f32>) -> Result<ActQuant, String> {
        if !(2..=16).contains(&bits) {
            return Err(format!("act-quant bits must be in 2..=16, got {bits}"));
        }
        if bits >= 16 && !scale.is_empty() {
            return Err(format!(
                "act-quant bits=16 is identity but carries {} scales",
                scale.len()
            ));
        }
        Ok(ActQuant { bits, scale })
    }

    /// Calibrate per-channel scales from sample activations
    /// (rows = tokens, cols = channels).
    pub fn calibrate(samples: &Matrix, bits: u32) -> ActQuant {
        assert!(bits >= 2 && bits <= 16);
        if bits >= 16 {
            return Self::identity();
        }
        let qmax = ((1i64 << (bits - 1)) - 1) as f32;
        let mut absmax = vec![0f32; samples.cols];
        for r in 0..samples.rows {
            for (c, &v) in samples.row(r).iter().enumerate() {
                absmax[c] = absmax[c].max(v.abs());
            }
        }
        let scale = absmax.iter().map(|&a| if a > 0.0 { a / qmax } else { 1.0 }).collect();
        ActQuant { bits, scale }
    }

    /// Quantize-dequantize a batch of activations in place.
    pub fn apply(&self, x: &mut Matrix) {
        assert!(
            self.bits < 16 || self.scale.is_empty(),
            "ActQuant invariant violated: bits={} (identity) with {} scales would silently quantize",
            self.bits,
            self.scale.len()
        );
        if self.scale.is_empty() {
            return;
        }
        assert_eq!(x.cols, self.scale.len(), "channel mismatch");
        let qmax = ((1i64 << (self.bits - 1)) - 1) as f32;
        for r in 0..x.rows {
            for (c, v) in x.row_mut(r).iter_mut().enumerate() {
                let s = self.scale[c];
                let q = (*v / s).round().clamp(-qmax - 1.0, qmax);
                *v = q * s;
            }
        }
    }

    /// Max representable quantization step (worst-case rounding error).
    pub fn max_step(&self) -> f32 {
        self.scale.iter().fold(0.0f32, |m, &s| m.max(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn identity_is_noop() {
        let mut r = Rng::new(1);
        let mut x = Matrix::randn(4, 8, &mut r);
        let orig = x.clone();
        ActQuant::identity().apply(&mut x);
        assert_eq!(x, orig);
    }

    #[test]
    fn error_bounded_by_half_step_property() {
        check(
            "actquant error <= scale/2",
            20,
            |r: &mut Rng| Matrix::randn(16, 6, r),
            |x| {
                let q = ActQuant::calibrate(x, 8);
                let mut xq = x.clone();
                q.apply(&mut xq);
                for rr in 0..x.rows {
                    for c in 0..x.cols {
                        let err = (x.at(rr, c) - xq.at(rr, c)).abs();
                        if err > q.scale[c] * 0.5 + 1e-6 {
                            return Err(format!("err {err} > half-step {}", q.scale[c] * 0.5));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn more_bits_less_error() {
        let mut r = Rng::new(2);
        let x = Matrix::randn(64, 8, &mut r);
        let err_at = |bits: u32| -> f64 {
            let q = ActQuant::calibrate(&x, bits);
            let mut xq = x.clone();
            q.apply(&mut xq);
            xq.sub(&x).fro2()
        };
        let (e4, e8) = (err_at(4), err_at(8));
        assert!(e8 < e4, "A8 {e8} !< A4 {e4}");
        assert!(err_at(16) == 0.0);
    }

    #[test]
    fn values_land_on_grid() {
        let mut r = Rng::new(3);
        let x = Matrix::randn(8, 4, &mut r);
        let q = ActQuant::calibrate(&x, 4);
        let mut xq = x.clone();
        q.apply(&mut xq);
        for rr in 0..xq.rows {
            for c in 0..xq.cols {
                let steps = xq.at(rr, c) / q.scale[c];
                assert!((steps - steps.round()).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn calibration_covers_range() {
        let x = Matrix::from_vec(2, 1, vec![-4.0, 2.0]);
        let q = ActQuant::calibrate(&x, 8);
        assert!((q.scale[0] - 4.0 / 127.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "ActQuant invariant violated")]
    fn sixteen_bit_with_scales_panics_instead_of_silently_quantizing() {
        // Regression: { bits: 16, scale: [...] } used to run the
        // quantize loop with a 15-bit qmax even though bits=16 means
        // "off" everywhere else.
        let q = ActQuant { bits: 16, scale: vec![0.5, 0.5] };
        let mut x = Matrix::from_vec(1, 2, vec![0.3, -0.7]);
        q.apply(&mut x);
    }

    #[test]
    fn checked_enforces_invariant() {
        assert!(ActQuant::checked(8, vec![1.0; 4]).is_ok());
        assert!(ActQuant::checked(16, Vec::new()).is_ok());
        assert!(ActQuant::checked(16, vec![1.0]).is_err());
        assert!(ActQuant::checked(1, Vec::new()).is_err());
        assert!(ActQuant::checked(17, Vec::new()).is_err());
    }
}
