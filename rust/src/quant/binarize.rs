//! Core binarization (paper §3, Eq. 1-3).
//!
//! `W ≈ alpha ⊙ B + mu` row-wise: `mu_r` recenters the row,
//! `alpha_r = mean |W_r − mu_r|` is the optimal L2 scale and
//! `B = sign(W − mu)`. Column groups (from [`crate::quant::splits`])
//! refine `alpha` per (row, group).

use std::io::{Read, Write};

use anyhow::{bail, Result};

use super::quantizer::{QuantOutcome, Quantizer, SiteId};
use crate::bitops::BitMatrix;
use crate::engine::{BinaryGemmEngine, ComputeEngine, EngineCtx};
use crate::io::wire;
use crate::model::{BackendIoCtx, WeightBackend};
use crate::tensor::Matrix;

/// A binarized weight matrix with per-row scale/bias and optional
/// column-group-refined scales.
#[derive(Debug, Clone)]
pub struct BinaryLayer {
    pub rows: usize,
    pub cols: usize,
    /// Packed sign matrix.
    pub b: BitMatrix,
    /// Per-(row, group) scales, indexed `r * n_groups + g`.
    pub alpha: Vec<f32>,
    /// Per-row bias.
    pub mu: Vec<f32>,
    /// Column -> group id (all zeros when ungrouped).
    pub col_group: Vec<u16>,
    pub n_groups: usize,
}

impl BinaryLayer {
    /// Plain sign binarization with a single group (paper Eq. 2).
    pub fn quantize(w: &Matrix) -> BinaryLayer {
        Self::quantize_grouped(w, &vec![0u16; w.cols], 1)
    }

    /// Binarize with the given column grouping: per-row bias, per
    /// (row, group) scale.
    pub fn quantize_grouped(w: &Matrix, col_group: &[u16], n_groups: usize) -> BinaryLayer {
        assert_eq!(col_group.len(), w.cols);
        let (rows, cols) = (w.rows, w.cols);
        let mu = w.row_means();
        let mut signs = vec![0f32; rows * cols];
        let mut alpha = vec![0f32; rows * n_groups];
        let mut counts = vec![0f32; n_groups];
        for (c, &g) in col_group.iter().enumerate() {
            let _ = c;
            counts[g as usize] += 1.0;
        }
        for r in 0..rows {
            let wrow = w.row(r);
            let m = mu[r];
            let arow = &mut alpha[r * n_groups..(r + 1) * n_groups];
            for (c, (&wv, &g)) in wrow.iter().zip(col_group.iter()).enumerate() {
                let t = wv - m;
                arow[g as usize] += t.abs();
                // sign(0) = +1 (paper's tie rule).
                signs[r * cols + c] = if t >= 0.0 { 1.0 } else { -1.0 };
            }
            for (g, a) in arow.iter_mut().enumerate() {
                if counts[g] > 0.0 {
                    *a /= counts[g];
                }
            }
        }
        BinaryLayer {
            rows,
            cols,
            b: BitMatrix::from_signs(rows, cols, &signs),
            alpha,
            mu,
            col_group: col_group.to_vec(),
            n_groups,
        }
    }

    /// Dequantize to a dense matrix.
    pub fn reconstruct(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let signs = self.b.unpack_row(r);
            let arow = &self.alpha[r * self.n_groups..(r + 1) * self.n_groups];
            let orow = out.row_mut(r);
            for c in 0..self.cols {
                orow[c] = arow[self.col_group[c] as usize] * signs[c] + self.mu[r];
            }
        }
        out
    }

    /// Frobenius² reconstruction error vs a reference matrix (Eq. 3).
    pub fn error(&self, w: &Matrix) -> f64 {
        self.reconstruct().sub(w).fro2()
    }

    /// Storage in bits: signs + fp16 alpha/mu + per-column group ids.
    pub fn storage_bits(&self) -> usize {
        let sign_bits = self.rows * self.cols;
        let scale_bits = (self.alpha.len() + self.mu.len()) * 16;
        let group_bits = if self.n_groups > 1 {
            self.cols * (usize::BITS - (self.n_groups - 1).leading_zeros()) as usize
        } else {
            0
        };
        sign_bits + scale_bits + group_bits
    }

    /// Effective bits per weight.
    pub fn bits_per_weight(&self) -> f64 {
        self.storage_bits() as f64 / (self.rows * self.cols) as f64
    }

    /// Actually-resident bytes: packed signs, but f32 scales and u16
    /// group ids (wider than the fp16/packed accounting claims — the
    /// truth gap [`crate::eval::memory`] makes visible).
    pub fn resident_bytes(&self) -> usize {
        self.b.storage_bytes() + (self.alpha.len() + self.mu.len()) * 4 + self.col_group.len() * 2
    }
}

impl WeightBackend for BinaryLayer {
    fn tag(&self) -> &'static str {
        "binary"
    }

    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn reconstruct(&self) -> Matrix {
        BinaryLayer::reconstruct(self)
    }

    fn storage_bits(&self) -> usize {
        BinaryLayer::storage_bits(self)
    }

    fn resident_bytes(&self) -> usize {
        BinaryLayer::resident_bytes(self)
    }

    fn payload_bits_per_weight(&self) -> f64 {
        let group = if self.n_groups > 1 {
            self.cols * (usize::BITS - (self.n_groups - 1).leading_zeros()) as usize
        } else {
            0
        };
        (self.rows * self.cols + group) as f64 / (self.rows * self.cols) as f64
    }

    fn make_engine(&self) -> Option<Box<dyn ComputeEngine>> {
        self.make_engine_with(&EngineCtx::current())
    }

    fn make_engine_with(&self, ctx: &EngineCtx) -> Option<Box<dyn ComputeEngine>> {
        Some(Box::new(BinaryGemmEngine::with_ctx(self, ctx)))
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        write_binary_payload(w, self)
    }

    fn clone_box(&self) -> Box<dyn WeightBackend> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Raw payload writer, shared with [`super::arb::ResidualBinary`]
/// (which embeds two binary blocks in its own payload).
pub fn write_binary_payload(w: &mut dyn Write, b: &BinaryLayer) -> Result<()> {
    wire::w_u32(w, b.rows as u32)?;
    wire::w_u32(w, b.cols as u32)?;
    wire::w_u32(w, b.n_groups as u32)?;
    wire::w_u64s(w, &b.b.data)?;
    wire::w_f32s(w, &b.alpha)?;
    wire::w_f32s(w, &b.mu)?;
    wire::w_u16s(w, &b.col_group)
}

/// Raw payload reader matching [`write_binary_payload`].
pub fn read_binary_payload(r: &mut dyn Read) -> Result<BinaryLayer> {
    let rows = wire::r_u32(r)? as usize;
    let cols = wire::r_u32(r)? as usize;
    let n_groups = wire::r_u32(r)? as usize;
    wire::check_dims("binary backend", rows, cols)?;
    if n_groups == 0 || n_groups > cols {
        bail!("binary backend: implausible n_groups {n_groups} for {cols} columns");
    }
    let mut b = BitMatrix::zeros(rows, cols);
    let n_words = b.data.len();
    b.data = wire::r_u64s(r, n_words)?;
    let alpha = wire::r_f32s(r, rows * n_groups)?;
    let mu = wire::r_f32s(r, rows)?;
    let col_group = wire::r_u16s(r, cols)?;
    if let Some(&g) = col_group.iter().find(|&&g| g as usize >= n_groups) {
        bail!("binary backend: column group id {g} out of range (n_groups {n_groups})");
    }
    Ok(BinaryLayer { rows, cols, b, alpha, mu, col_group, n_groups })
}

/// Registered deserializer for the `binary` tag.
pub fn read_backend(r: &mut dyn Read, _ctx: &BackendIoCtx) -> Result<Box<dyn WeightBackend>> {
    Ok(Box::new(read_binary_payload(r)?))
}

/// The `naive` method: plain sign binarization of every linear, no
/// saliency, no grouping — the weakest lane of the paper's Table 1.
#[derive(Debug, Default)]
pub struct NaiveQuantizer;

impl Quantizer for NaiveQuantizer {
    fn name(&self) -> String {
        "Naive".to_string()
    }

    fn quantize_group(
        &mut self,
        _site: &SiteId,
        weff: &Matrix,
        _act_sq: &[f32],
    ) -> Result<QuantOutcome> {
        Ok(QuantOutcome::Ready(Box::new(BinaryLayer::quantize(weff))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn reconstruct_exact_for_binary_input() {
        // W already of form alpha*B + mu => zero error.
        let w = Matrix::from_vec(2, 4, vec![1.5, -0.5, 1.5, -0.5, 3.0, -1.0, 3.0, -1.0]);
        let q = BinaryLayer::quantize(&w);
        assert!(q.error(&w) < 1e-10, "err {}", q.error(&w));
    }

    #[test]
    fn alpha_is_mean_abs_residual() {
        let w = Matrix::from_vec(1, 4, vec![3.0, -1.0, 1.0, -3.0]);
        let q = BinaryLayer::quantize(&w);
        assert_eq!(q.mu[0], 0.0);
        assert_eq!(q.alpha[0], 2.0);
    }

    #[test]
    fn optimality_of_scale_property() {
        // alpha = mean|w-mu| minimizes ||w - mu - a*sign(w-mu)||^2 over a.
        check(
            "alpha optimal",
            20,
            |r: &mut Rng| Matrix::randn(3, 16, r),
            |w| {
                let q = BinaryLayer::quantize(w);
                let base = q.error(w);
                for scale in [0.8, 0.9, 1.1, 1.2] {
                    let mut q2 = q.clone();
                    for a in q2.alpha.iter_mut() {
                        *a *= scale;
                    }
                    if q2.error(w) < base - 1e-6 {
                        return Err(format!("scale {scale} beat optimal: {} < {base}", q2.error(w)));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn grouped_never_worse_than_plain_property() {
        // Splitting columns into magnitude groups can only reduce error.
        check(
            "grouped <= plain",
            15,
            |r: &mut Rng| {
                let w = Matrix::from_fn(4, 32, |_, c| {
                    // heavy columns at the end
                    r.normal() * if c >= 24 { 5.0 } else { 1.0 }
                });
                w
            },
            |w| {
                let plain = BinaryLayer::quantize(w).error(w);
                let groups: Vec<u16> = (0..32).map(|c| if c >= 24 { 1 } else { 0 }).collect();
                let grouped = BinaryLayer::quantize_grouped(w, &groups, 2).error(w);
                if grouped <= plain + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("grouped {grouped} > plain {plain}"))
                }
            },
        );
    }

    #[test]
    fn bits_accounting() {
        let mut r = Rng::new(1);
        let w = Matrix::randn(64, 64, &mut r);
        let q = BinaryLayer::quantize(&w);
        // 1 sign bit + 2*64 fp16 scalars over 4096 weights = 1.5
        assert!((q.bits_per_weight() - 1.5).abs() < 1e-9);
        let groups: Vec<u16> = (0..64).map(|c| (c % 2) as u16).collect();
        let qg = BinaryLayer::quantize_grouped(&w, &groups, 2);
        assert!(qg.bits_per_weight() > q.bits_per_weight());
    }

    #[test]
    fn sign_zero_is_plus() {
        let w = Matrix::from_vec(1, 2, vec![1.0, 1.0]); // residual = 0,0
        let q = BinaryLayer::quantize(&w);
        assert_eq!(q.b.get(0, 0), 1.0);
        assert_eq!(q.b.get(0, 1), 1.0);
    }
}
