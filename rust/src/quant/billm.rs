//! Baseline presets: BiLLM and ARB-LLM (paper §2, §3).
//!
//! Both share the *salient-column + residual binarization* structure;
//! they differ in refinement depth and split points:
//! - **BiLLM**: one-shot binarization, 1 split point, salient residual.
//! - **ARB-LLM**: 15 alternating-refinement iterations, 2 split points,
//!   salient residual.
//!
//! The BTC pipeline reuses the same machinery with the learnable
//! transformation in front (see `transform.rs` / `pipeline.rs`).

use anyhow::Result;

use super::arb::ResidualBinary;
use super::quantizer::{QuantOutcome, Quantizer, SiteId};
use super::splits::{column_importance, salient_columns, split_columns};
use crate::tensor::Matrix;

/// Configuration for salient + grouped binarization.
#[derive(Debug, Clone, Copy)]
pub struct SalientBinaryConfig {
    /// Fraction of columns treated as salient (residual-binarized).
    pub salient_frac: f64,
    /// Number of split points for non-salient grouping (groups = n+1).
    pub n_splits: usize,
    /// Alternating refinement iterations (0 = one-shot BiLLM style).
    pub arb_iters: usize,
}

impl SalientBinaryConfig {
    /// BiLLM (Huang et al., 2024).
    pub fn billm() -> Self {
        SalientBinaryConfig { salient_frac: 0.10, n_splits: 1, arb_iters: 0 }
    }
    /// ARB-LLM (Li et al., 2025).
    pub fn arb_llm() -> Self {
        SalientBinaryConfig { salient_frac: 0.10, n_splits: 2, arb_iters: 15 }
    }
}

/// Quantize one weight matrix under the preset. `act_sq` is the
/// per-input-channel mean squared activation from calibration (may be
/// empty for activation-agnostic importance).
pub fn quantize(w: &Matrix, act_sq: &[f32], cfg: &SalientBinaryConfig) -> ResidualBinary {
    let imp = column_importance(w, act_sq);
    let sal = salient_columns(&imp, cfg.salient_frac);
    let (groups, ng) = split_columns(&imp, cfg.n_splits);
    ResidualBinary::quantize(w, &groups, ng, &sal, cfg.arb_iters)
}

/// [`Quantizer`] over the salient-residual machinery: the BiLLM and
/// ARB-LLM registry lanes (`billm` / `arb-llm`), differing only in
/// preset and display name.
#[derive(Debug)]
pub struct SalientResidualQuantizer {
    display: &'static str,
    preset: SalientBinaryConfig,
}

impl SalientResidualQuantizer {
    pub fn new(display: &'static str, preset: SalientBinaryConfig) -> Self {
        SalientResidualQuantizer { display, preset }
    }
}

impl Quantizer for SalientResidualQuantizer {
    fn name(&self) -> String {
        self.display.to_string()
    }

    fn quantize_group(
        &mut self,
        _site: &SiteId,
        weff: &Matrix,
        act_sq: &[f32],
    ) -> Result<QuantOutcome> {
        Ok(QuantOutcome::Ready(Box::new(quantize(weff, act_sq, &self.preset))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::binarize::BinaryLayer;
    use crate::util::rng::Rng;

    fn llm_like_weights(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
        // A few heavy "outlier" columns, like trained LLM projections.
        let heavy: Vec<bool> = (0..cols).map(|_| rng.uniform() < 0.06).collect();
        Matrix::from_fn(rows, cols, |_, c| rng.normal() * if heavy[c] { 6.0 } else { 1.0 })
    }

    #[test]
    fn method_ordering_naive_billm_arb() {
        // The paper's quality ordering on reconstruction error:
        // naive >= BiLLM >= ARB-LLM (error decreasing).
        let mut rng = Rng::new(42);
        let w = llm_like_weights(&mut rng, 24, 96);
        let naive = BinaryLayer::quantize(&w).error(&w);
        let billm = quantize(&w, &[], &SalientBinaryConfig::billm()).error(&w);
        let arb = quantize(&w, &[], &SalientBinaryConfig::arb_llm()).error(&w);
        assert!(billm < naive, "billm {billm} !< naive {naive}");
        assert!(arb <= billm + 1e-9, "arb {arb} !<= billm {billm}");
    }

    #[test]
    fn bits_in_expected_band() {
        let mut rng = Rng::new(7);
        let w = llm_like_weights(&mut rng, 64, 128);
        let q = quantize(&w, &[], &SalientBinaryConfig::arb_llm());
        let bits = q.bits_per_weight();
        // Sign payload ≈ 1.11; fp16 group scales add ~0.8 at this tiny
        // width (3 groups x 64 rows over 8K weights) — they amortize at
        // LLM widths. Band: [1.0, 2.1].
        assert!(bits > 1.0 && bits < 2.1, "bits {bits}");
    }

    #[test]
    fn activation_aware_salient_changes_selection() {
        let mut rng = Rng::new(9);
        let w = Matrix::randn(16, 32, &mut rng);
        let mut act = vec![1.0f32; 32];
        act[5] = 100.0; // hot input channel
        let imp = column_importance(&w, &act);
        let sal = salient_columns(&imp, 0.05);
        assert!(sal.contains(&5));
    }
}
