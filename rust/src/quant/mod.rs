//! Quantization library: the paper's contribution (learnable
//! transformation + binary codebook, `transform` / `codebook`) plus every
//! baseline it is evaluated against (naive binarization, BiLLM-style
//! salient residual, ARB alternating refinement, STBLLM N:M structured
//! sparse binary, GPTVQ/VPTQ-style floating-point vector quantization)
//! and the per-model pipeline driver.
//!
//! Conventions: weight matrices are (out, in) and applied as
//! `y = x @ W^T`; binarization is per-output-row (`alpha`, `mu` indexed
//! by row); column *groups* (salient / split-point groups) are shared
//! across rows so group membership costs `ceil(log2 G)` bits per
//! **column**, not per weight — the hardware-friendly structured layout
//! the paper argues for.

pub mod actquant;
pub mod arb;
pub mod billm;
pub mod binarize;
pub mod codebook;
pub mod fpvq;
pub mod kvquant;
pub mod pipeline;
pub mod splits;
pub mod stbllm;
pub mod transform;

pub use binarize::BinaryLayer;
pub use codebook::{BinaryCodebook, CodebookLayer};
pub use pipeline::{QuantConfig, QuantMethod, QuantizedModel};
