//! Quantization library: the paper's contribution (learnable
//! transformation + binary codebook, `transform` / `codebook`) plus every
//! baseline it is evaluated against (naive binarization, BiLLM-style
//! salient residual, ARB alternating refinement, STBLLM N:M structured
//! sparse binary, GPTVQ/VPTQ-style floating-point vector quantization)
//! and the per-model pipeline driver.
//!
//! The surface is **open**: methods implement the [`Quantizer`]
//! strategy trait and register by name in [`registry`]
//! (`quant::registry::get("btc-0.8")`); weight formats implement
//! [`crate::model::WeightBackend`] and register their deserializer by
//! tag. Adding a lane touches one new file plus one registration call —
//! no enum, no pipeline edits.
//!
//! Conventions: weight matrices are (out, in) and applied as
//! `y = x @ W^T`; binarization is per-output-row (`alpha`, `mu` indexed
//! by row); column *groups* (salient / split-point groups) are shared
//! across rows so group membership costs `ceil(log2 G)` bits per
//! **column**, not per weight — the hardware-friendly structured layout
//! the paper argues for.

pub mod actquant;
pub mod arb;
pub mod billm;
pub mod binarize;
pub mod btc;
pub mod codebook;
pub mod fpvq;
pub mod kvquant;
pub mod pipeline;
pub mod quantizer;
pub mod splits;
pub mod stbllm;
pub mod transform;

pub use binarize::BinaryLayer;
pub use codebook::{BinaryCodebook, CodebookLayer};
pub use pipeline::registry;
pub use pipeline::{quantize_model, QuantConfig, QuantStats, QuantizedModel};
pub use quantizer::{CalibView, QuantOutcome, Quantizer, SiteId};
