//! KV-cache quantization (paper App. F) as a **real storage format**,
//! not an in-place fake-quant: [`QuantizedRows`] packs K/V rows into a
//! [`bitops::PackedPlane`](crate::bitops::PackedPlane) at `bits` bits
//! per entry with one IEEE binary16 absmax scale per row
//! ([`util::f16`](crate::util::f16)), so the bytes the accounting
//! bills are the bytes actually resident. The paged KV pool
//! ([`crate::model::kvcache::KvPool`]) stores *cold* blocks —
//! everything behind the recency `local_window` — in this format
//! ("we preserve local windows binary representation without sub-bit
//! quantization"); hot rows stay f32 and are never touched.
//!
//! Quantization is symmetric per row: `scale = absmax / (2^(bits-1)-1)`
//! rounded once to f16, entries stored biased-unsigned
//! (`q + 2^(bits-1)` in `bits` bits). The *f16-decoded* scale is used
//! on both the quantize and dequantize side, so a row round-trips to
//! exactly the values attention will read.

use crate::bitops::PackedPlane;
use crate::util::f16;

/// Configuration for cache quantization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvQuantConfig {
    /// Bits for cold cache entries (2..=8; >= 16 disables).
    pub bits: u32,
    /// Most recent positions kept full precision.
    pub local_window: usize,
}

impl Default for KvQuantConfig {
    fn default() -> Self {
        KvQuantConfig { bits: 4, local_window: 16 }
    }
}

impl KvQuantConfig {
    /// Quantization disabled: every position stays f32.
    pub fn off() -> KvQuantConfig {
        KvQuantConfig { bits: 16, local_window: 16 }
    }

    /// Is cold-block quantization active?
    pub fn enabled(&self) -> bool {
        (2..16).contains(&self.bits)
    }

    /// Snap an arbitrary bits value onto the representable lattice:
    /// 0 (the "auto/off" convention every other serve knob uses) and
    /// >= 16 mean off (f32); anything else clamps into the packed
    /// 2..=8 range. 9..=15 has no storage format — rounding down to 8
    /// beats panicking the serving worker on the first cold block.
    pub fn sanitize_bits(bits: u32) -> u32 {
        if bits == 0 || bits >= 16 {
            16
        } else {
            bits.clamp(2, 8)
        }
    }

    /// Self with [`Self::sanitize_bits`] applied.
    pub fn sanitized(self) -> KvQuantConfig {
        KvQuantConfig { bits: Self::sanitize_bits(self.bits), ..self }
    }
}

/// A batch of quantized rows: the resident format of a cold KV block.
/// `rows x width` entries packed at `bits` bits each, plus one f16
/// absmax scale per row.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedRows {
    plane: PackedPlane,
    /// IEEE binary16 per-row scales (decoded on use).
    scales: Vec<u16>,
    bits: u32,
}

impl QuantizedRows {
    /// Quantize `rows * width` f32 values (row-major). `bits` in 2..=8.
    pub fn quantize(values: &[f32], rows: usize, width: usize, bits: u32) -> QuantizedRows {
        assert!((2..=8).contains(&bits), "kv quant bits {bits} out of 2..=8");
        assert_eq!(values.len(), rows * width, "value count != rows*width");
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let offset = 1i32 << (bits - 1);
        let mut plane = PackedPlane::zeros(rows, width, bits as usize);
        let mut scales = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &values[r * width..(r + 1) * width];
            let absmax = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
            // Round the scale to f16 FIRST; quantize against the
            // rounded value so dequantization is exact w.r.t. what we
            // actually ship. A scale that falls off the f16 range —
            // underflow to zero OR overflow to inf (absmax beyond
            // 65504*qmax, whose dequant would be 0*inf = NaN) — or a
            // zero/non-finite row degrades to an all-zero row.
            let h = f16::encode(absmax / qmax);
            let s = f16::decode(h);
            let usable = s.is_finite() && s > 0.0;
            scales.push(if usable { h } else { 0 });
            if usable {
                for (c, &v) in row.iter().enumerate() {
                    let q = (v / s).round().clamp(-(offset as f32), qmax) as i32;
                    plane.set(r, c, (q + offset) as u32);
                }
            } else {
                for c in 0..width {
                    plane.set(r, c, offset as u32);
                }
            }
        }
        QuantizedRows { plane, scales, bits }
    }

    pub fn rows(&self) -> usize {
        self.plane.rows
    }

    pub fn width(&self) -> usize {
        self.plane.cols
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Dequantize row `r` into `dst` (len == width), using `codes` as
    /// a caller-provided decode scratch (len == width) so the hot
    /// gather path never allocates.
    pub fn dequantize_into(&self, r: usize, codes: &mut [u32], dst: &mut [f32]) {
        debug_assert_eq!(codes.len(), self.plane.cols);
        debug_assert_eq!(dst.len(), self.plane.cols);
        let s = f16::decode(self.scales[r]);
        let offset = 1i32 << (self.bits - 1);
        self.plane.decode_range(r, 0, codes);
        for (d, &u) in dst.iter_mut().zip(codes.iter()) {
            *d = (u as i32 - offset) as f32 * s;
        }
    }

    /// Dequantize row `r` as a fresh Vec (tests / slow paths).
    pub fn dequantize_row(&self, r: usize) -> Vec<f32> {
        let mut codes = vec![0u32; self.plane.cols];
        let mut out = vec![0f32; self.plane.cols];
        self.dequantize_into(r, &mut codes, &mut out);
        out
    }

    /// Measured bytes this struct actually holds resident: the packed
    /// plane words plus the u16 scales.
    pub fn resident_bytes(&self) -> usize {
        self.plane.storage_bytes() + self.scales.len() * 2
    }
}

/// Accounted bits for one quantized row of `width` entries: the packed
/// payload plus its 16-bit (f16) scale. Matches [`QuantizedRows`]
/// bytes-in-RAM exactly when `width * bits` is a multiple of 64 (the
/// plane's per-row word alignment is the only slack).
pub fn quantized_row_bits(width: usize, bits: u32) -> usize {
    width * bits as usize + 16
}

/// **Paper-convention estimate** (App. F) of what a cache of `len`
/// positions would occupy under `cfg` (bytes): packed int entries +
/// **f16** scales for cold positions, f16 entries for the local
/// window. The scale term matches the `QuantizedRows` storage format
/// (u16 per row) — bytes-on-the-books equal bytes-in-RAM for the cold
/// region. Note this is the *accounting* the paper's tables use, not
/// a measurement of the serving pool: the pool keeps hot blocks in
/// f32 (not f16) and pads to whole blocks — measure the real thing
/// via `KvPoolStats::resident_bytes` /
/// `eval::memory::kv_report`.
pub fn quantized_cache_bytes(len: usize, kv_dim: usize, cfg: &KvQuantConfig) -> usize {
    if !cfg.enabled() {
        return len * kv_dim * 2 * 2; // k + v, fp16
    }
    let local = cfg.local_window.min(len);
    let old = len - local;
    let old_bits = old * quantized_row_bits(kv_dim, cfg.bits);
    let local_bits = local * kv_dim * 16;
    2 * (old_bits + local_bits).div_ceil(8) // k and v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_rows(rows: usize, width: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..rows * width).map(|_| rng.normal()).collect()
    }

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        for bits in [2u32, 4, 8] {
            let vals = random_rows(12, 16, 7 + bits as u64);
            let q = QuantizedRows::quantize(&vals, 12, 16, bits);
            let qmax = ((1i32 << (bits - 1)) - 1) as f32;
            for r in 0..12 {
                let row = &vals[r * 16..(r + 1) * 16];
                let absmax = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
                // The shipped (f16-rounded) scale defines the step.
                let step = f16::decode(f16::encode(absmax / qmax));
                let deq = q.dequantize_row(r);
                for (a, b) in deq.iter().zip(row) {
                    assert!(
                        (a - b).abs() <= step * 0.5 + 1e-6,
                        "bits={bits} r={r}: |{a} - {b}| > {}",
                        step * 0.5
                    );
                }
            }
        }
    }

    #[test]
    fn negative_extreme_uses_full_range() {
        // The most negative code (-2^(b-1)) is representable: a value
        // at -absmax stays within half a step.
        let vals = vec![-4.0f32, 4.0, 0.0, 2.0];
        let q = QuantizedRows::quantize(&vals, 1, 4, 4);
        let deq = q.dequantize_row(0);
        assert!((deq[0] + 4.0).abs() <= 4.0 / 7.0 * 0.5 + 1e-6);
        assert!((deq[1] - 4.0).abs() <= 4.0 / 7.0 * 0.5 + 1e-6);
        assert_eq!(deq[2], 0.0);
    }

    #[test]
    fn zero_tiny_and_huge_rows_are_safe() {
        // All-zero rows, f16-underflow scales AND f16-overflow scales
        // (absmax beyond 65504*qmax would dequantize as 0*inf = NaN)
        // must all degrade to zero rows, never to non-finite values.
        let mut vals = vec![0f32; 8];
        vals.extend_from_slice(&[1e-30; 8]);
        vals.extend_from_slice(&[1e9; 8]);
        let q = QuantizedRows::quantize(&vals, 3, 8, 4);
        for r in 0..3 {
            for v in q.dequantize_row(r) {
                assert_eq!(v, 0.0, "row {r}");
            }
        }
    }

    #[test]
    fn accounting_equals_measured_resident_bytes() {
        // The satellite contract: with f16 scales, bytes-on-the-books
        // equal bytes-in-RAM at word-aligned widths (width*bits % 64
        // == 0, so the plane has no per-row padding).
        for (width, bits) in [(16usize, 4u32), (32, 4), (8, 8), (64, 2)] {
            let rows = 10;
            let vals = random_rows(rows, width, 3);
            let q = QuantizedRows::quantize(&vals, rows, width, bits);
            let accounted_bits = rows * quantized_row_bits(width, bits);
            assert_eq!(
                q.resident_bytes(),
                accounted_bits / 8,
                "width={width} bits={bits}"
            );
        }
    }

    #[test]
    fn cache_accounting_shrinks_and_matches_format() {
        let cfg = KvQuantConfig { bits: 4, local_window: 8 };
        let fp = quantized_cache_bytes(128, 64, &KvQuantConfig { bits: 16, local_window: 0 });
        let q = quantized_cache_bytes(128, 64, &cfg);
        assert!(q < fp / 2, "q {q} fp {fp}");
        // Cold region accounted exactly as the QuantizedRows format.
        let cold_rows = 120;
        let measured = QuantizedRows::quantize(
            &random_rows(cold_rows, 64, 9),
            cold_rows,
            64,
            4,
        )
        .resident_bytes();
        let accounted_cold = cold_rows * quantized_row_bits(64, 4) / 8;
        assert_eq!(measured, accounted_cold);
    }

    #[test]
    fn disabled_config_reports_fp16() {
        assert!(!KvQuantConfig::off().enabled());
        assert!(KvQuantConfig::default().enabled());
        assert_eq!(
            quantized_cache_bytes(10, 4, &KvQuantConfig::off()),
            10 * 4 * 2 * 2
        );
    }

    #[test]
    fn sanitize_snaps_onto_representable_widths() {
        // 0 follows the serve-config "auto/off" convention.
        assert_eq!(KvQuantConfig::sanitize_bits(0), 16);
        assert_eq!(KvQuantConfig::sanitize_bits(1), 2);
        assert_eq!(KvQuantConfig::sanitize_bits(4), 4);
        assert_eq!(KvQuantConfig::sanitize_bits(8), 8);
        // 9..=15 have no packed format: down to 8, not a worker panic.
        assert_eq!(KvQuantConfig::sanitize_bits(12), 8);
        assert_eq!(KvQuantConfig::sanitize_bits(15), 8);
        assert_eq!(KvQuantConfig::sanitize_bits(16), 16);
        assert_eq!(KvQuantConfig::sanitize_bits(99), 16);
        let c = KvQuantConfig { bits: 13, local_window: 4 }.sanitized();
        assert_eq!((c.bits, c.local_window), (8, 4));
    }
}
