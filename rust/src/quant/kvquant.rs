//! KV-cache quantization (paper App. F — the "preliminary" extension):
//! per-head symmetric int quantization of cached K/V with a
//! recency-weighted saliency rule — the most recent `local_window`
//! positions stay full-precision ("we preserve local windows binary
//! representation without sub-bit quantization"), older entries are
//! quantized to `bits`.

use crate::model::kvcache::LayerKv;

/// Configuration for cache quantization.
#[derive(Debug, Clone, Copy)]
pub struct KvQuantConfig {
    /// Bits for old cache entries (2..=8; 16 disables).
    pub bits: u32,
    /// Most recent positions kept full precision.
    pub local_window: usize,
}

impl Default for KvQuantConfig {
    fn default() -> Self {
        KvQuantConfig { bits: 4, local_window: 16 }
    }
}

/// Quantize-dequantize one cache row in place (per-row absmax scale).
fn quantize_row(row: &mut [f32], bits: u32) {
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let absmax = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if absmax == 0.0 {
        return;
    }
    let scale = absmax / qmax;
    for v in row.iter_mut() {
        *v = (*v / scale).round().clamp(-qmax - 1.0, qmax) * scale;
    }
}

/// Apply App-F quantization to a layer cache: all but the trailing
/// `local_window` positions are quantized to `bits`.
pub fn quantize_layer_cache(kv: &mut LayerKv, cfg: &KvQuantConfig) {
    if cfg.bits >= 16 || kv.len <= cfg.local_window {
        return;
    }
    let kvd = kv.kv_dim;
    let old = kv.len - cfg.local_window;
    for pos in 0..old {
        quantize_row(&mut kv.k[pos * kvd..(pos + 1) * kvd], cfg.bits);
        quantize_row(&mut kv.v[pos * kvd..(pos + 1) * kvd], cfg.bits);
    }
}

/// Worst-case memory the quantized layout would ship (bytes): int
/// entries for old positions, fp16 for the local window + scales.
pub fn quantized_cache_bytes(len: usize, kv_dim: usize, cfg: &KvQuantConfig) -> usize {
    if cfg.bits >= 16 {
        return len * kv_dim * 2 * 2; // k + v, fp16
    }
    let local = cfg.local_window.min(len);
    let old = len - local;
    let old_bits = old * kv_dim * cfg.bits as usize + old * 16; // + scale/row
    let local_bits = local * kv_dim * 16;
    2 * (old_bits + local_bits).div_ceil(8) // k and v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn filled_cache(len: usize, kvd: usize, seed: u64) -> LayerKv {
        let mut rng = Rng::new(seed);
        let mut kv = LayerKv::new(kvd, len);
        for _ in 0..len {
            let k = rng.normal_vec(kvd);
            let v = rng.normal_vec(kvd);
            kv.push(&k, &v);
        }
        kv
    }

    #[test]
    fn local_window_untouched() {
        let mut kv = filled_cache(32, 8, 1);
        let before = kv.k.clone();
        quantize_layer_cache(&mut kv, &KvQuantConfig { bits: 4, local_window: 8 });
        // Last 8 positions identical.
        assert_eq!(&kv.k[24 * 8..], &before[24 * 8..]);
        // Some old position changed.
        assert_ne!(&kv.k[..8], &before[..8]);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut kv = filled_cache(20, 16, 2);
        let before = kv.k.clone();
        quantize_layer_cache(&mut kv, &KvQuantConfig { bits: 8, local_window: 4 });
        for pos in 0..16 {
            let row_before = &before[pos * 16..(pos + 1) * 16];
            let row_after = &kv.k[pos * 16..(pos + 1) * 16];
            let absmax = row_before.iter().fold(0f32, |m, &v| m.max(v.abs()));
            let step = absmax / 127.0;
            for (a, b) in row_after.iter().zip(row_before) {
                assert!((a - b).abs() <= step * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn bits16_is_noop() {
        let mut kv = filled_cache(10, 4, 3);
        let before = kv.k.clone();
        quantize_layer_cache(&mut kv, &KvQuantConfig { bits: 16, local_window: 2 });
        assert_eq!(kv.k, before);
    }

    #[test]
    fn memory_accounting_shrinks() {
        let cfg = KvQuantConfig { bits: 4, local_window: 8 };
        let fp = quantized_cache_bytes(128, 64, &KvQuantConfig { bits: 16, local_window: 0 });
        let q = quantized_cache_bytes(128, 64, &cfg);
        assert!(q < fp / 2, "q {q} fp {fp}");
    }

    #[test]
    fn short_cache_untouched() {
        let mut kv = filled_cache(4, 4, 5);
        let before = kv.k.clone();
        quantize_layer_cache(&mut kv, &KvQuantConfig { bits: 4, local_window: 8 });
        assert_eq!(kv.k, before);
    }
}
