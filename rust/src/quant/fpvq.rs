//! Floating-point vector-quantization baseline (GPTVQ / VPTQ-style):
//! Lloyd's k-means over length-`v` sub-vectors of the fp weight rows.
//!
//! Serves two roles in the reproduction:
//! - the 2-bit VQ rows of Table 1 (where it is competitive), and
//! - the sub-1-bit rows (where, as the paper reports, it collapses —
//!   too few fp centroids for the vector space).
//! Also the comparison target for the binary codebook's build-speed
//! claim (App. C.4: ~2.3× faster), see `bench_codebook_speed`.

use std::io::{Read, Write};

use anyhow::{bail, Result};

use super::quantizer::{QuantOutcome, Quantizer, SiteId};
use crate::io::wire;
use crate::model::{BackendIoCtx, WeightBackend};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// FP codebook compression of one weight matrix.
#[derive(Debug, Clone)]
pub struct FpVqLayer {
    pub rows: usize,
    pub cols: usize,
    pub v: usize,
    /// c x v centroids.
    pub centroids: Vec<f32>,
    pub c: usize,
    /// One index per sub-vector, row-major over the flattened matrix.
    pub idx: Vec<u32>,
    /// Padding length added to flatten evenly.
    pub pad: usize,
}

impl FpVqLayer {
    /// k-means quantization: `c` centroids over length-`v` sub-vectors,
    /// `iters` Lloyd iterations.
    pub fn quantize(w: &Matrix, v: usize, c: usize, iters: usize, seed: u64) -> FpVqLayer {
        let total = w.rows * w.cols;
        let pad = (v - total % v) % v;
        let mut flat = w.data.clone();
        flat.extend(std::iter::repeat(0.0).take(pad));
        let n_vec = flat.len() / v;
        let c = c.min(n_vec).max(1);
        let mut rng = Rng::new(seed);

        // Init: random distinct sample of the data vectors.
        let mut order: Vec<usize> = (0..n_vec).collect();
        rng.shuffle(&mut order);
        let mut centroids = vec![0f32; c * v];
        for (k, &src) in order.iter().take(c).enumerate() {
            centroids[k * v..(k + 1) * v].copy_from_slice(&flat[src * v..(src + 1) * v]);
        }

        let mut idx = vec![0u32; n_vec];
        for _ in 0..iters.max(1) {
            // E-step: nearest centroid by squared Euclidean distance.
            let mut changed = false;
            for i in 0..n_vec {
                let x = &flat[i * v..(i + 1) * v];
                let mut best = (f32::INFINITY, 0u32);
                for k in 0..c {
                    let cen = &centroids[k * v..(k + 1) * v];
                    let mut d = 0f32;
                    for j in 0..v {
                        let t = x[j] - cen[j];
                        d += t * t;
                        if d >= best.0 {
                            break; // early abandon
                        }
                    }
                    if d < best.0 {
                        best = (d, k as u32);
                    }
                }
                if idx[i] != best.1 {
                    changed = true;
                    idx[i] = best.1;
                }
            }
            // M-step: centroid means; reseed empty clusters.
            let mut sums = vec![0f64; c * v];
            let mut counts = vec![0usize; c];
            for i in 0..n_vec {
                let k = idx[i] as usize;
                counts[k] += 1;
                for j in 0..v {
                    sums[k * v + j] += flat[i * v + j] as f64;
                }
            }
            for k in 0..c {
                if counts[k] == 0 {
                    let src = rng.below(n_vec);
                    centroids[k * v..(k + 1) * v].copy_from_slice(&flat[src * v..(src + 1) * v]);
                } else {
                    for j in 0..v {
                        centroids[k * v + j] = (sums[k * v + j] / counts[k] as f64) as f32;
                    }
                }
            }
            if !changed {
                break;
            }
        }
        FpVqLayer { rows: w.rows, cols: w.cols, v, centroids, c, idx, pad }
    }

    pub fn reconstruct(&self) -> Matrix {
        let total = self.rows * self.cols;
        let mut flat = Vec::with_capacity(total + self.pad);
        for &k in &self.idx {
            let cen = &self.centroids[k as usize * self.v..(k as usize + 1) * self.v];
            flat.extend_from_slice(cen);
        }
        flat.truncate(total);
        Matrix::from_vec(self.rows, self.cols, flat)
    }

    pub fn error(&self, w: &Matrix) -> f64 {
        self.reconstruct().sub(w).fro2()
    }

    /// Index bits per weight (ceil(log2 c) / v).
    pub fn index_bits_per_weight(&self) -> f64 {
        let idx_bits = (usize::BITS - (self.c - 1).leading_zeros()) as f64;
        idx_bits / self.v as f64
    }

    /// Honest storage: indices + fp16 codebook.
    pub fn storage_bits(&self) -> usize {
        let idx_bits = (usize::BITS - (self.c - 1).leading_zeros()) as usize;
        self.idx.len() * idx_bits + self.c * self.v * 16
    }

    pub fn bits_per_weight(&self) -> f64 {
        self.storage_bits() as f64 / (self.rows * self.cols) as f64
    }
}

impl WeightBackend for FpVqLayer {
    fn tag(&self) -> &'static str {
        "fp-vq"
    }

    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn reconstruct(&self) -> Matrix {
        FpVqLayer::reconstruct(self)
    }

    fn storage_bits(&self) -> usize {
        FpVqLayer::storage_bits(self)
    }

    fn resident_bytes(&self) -> usize {
        // Indices held as full u32, centroids as f32 — wider than the
        // ceil(log2 c)-bit / fp16 accounting; reported honestly.
        self.idx.len() * 4 + self.centroids.len() * 4
    }

    fn payload_bits_per_weight(&self) -> f64 {
        let idx_bits = (usize::BITS - (self.c - 1).leading_zeros()) as f64;
        idx_bits * self.idx.len() as f64 / (self.rows * self.cols) as f64
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        wire::w_u32(w, self.rows as u32)?;
        wire::w_u32(w, self.cols as u32)?;
        wire::w_u32(w, self.v as u32)?;
        wire::w_u32(w, self.c as u32)?;
        wire::w_u32(w, self.pad as u32)?;
        wire::w_f32s(w, &self.centroids)?;
        wire::w_u32s(w, &self.idx)
    }

    fn clone_box(&self) -> Box<dyn WeightBackend> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Registered deserializer for the `fp-vq` tag.
pub fn read_backend(r: &mut dyn Read, _ctx: &BackendIoCtx) -> Result<Box<dyn WeightBackend>> {
    let rows = wire::r_u32(r)? as usize;
    let cols = wire::r_u32(r)? as usize;
    let v = wire::r_u32(r)? as usize;
    let c = wire::r_u32(r)? as usize;
    let pad = wire::r_u32(r)? as usize;
    wire::check_dims("fp-vq backend", rows, cols)?;
    if v == 0 || v > 4096 {
        bail!("fp-vq backend: implausible sub-vector length v={v}");
    }
    if c == 0 || c > 1 << 22 {
        bail!("fp-vq backend: implausible codebook size c={c}");
    }
    if pad >= v || (rows * cols + pad) % v != 0 {
        bail!("fp-vq backend: padding {pad} inconsistent with {rows}x{cols} / v={v}");
    }
    let centroids = wire::r_f32s(r, c * v)?;
    let n_vec = (rows * cols + pad) / v;
    let idx = wire::r_u32s(r, n_vec)?;
    if let Some(&k) = idx.iter().find(|&&k| k as usize >= c) {
        bail!("fp-vq backend: index {k} out of range (c={c})");
    }
    Ok(Box::new(FpVqLayer { rows, cols, v, centroids, c, idx, pad }))
}

/// The `fp-vq` method lane (GPTVQ / VPTQ-style): Lloyd k-means over fp
/// sub-vectors of every linear.
#[derive(Debug)]
pub struct FpVqQuantizer {
    pub v: usize,
    pub c: usize,
    pub iters: usize,
    pub seed: u64,
}

impl Quantizer for FpVqQuantizer {
    fn name(&self) -> String {
        "FP-VQ".to_string()
    }

    fn quantize_group(
        &mut self,
        _site: &SiteId,
        weff: &Matrix,
        _act_sq: &[f32],
    ) -> Result<QuantOutcome> {
        Ok(QuantOutcome::Ready(Box::new(FpVqLayer::quantize(
            weff, self.v, self.c, self.iters, self.seed,
        ))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn exact_when_centroids_cover_data() {
        // 2 distinct vector values, c=2 => perfect reconstruction.
        let w = Matrix::from_vec(2, 4, vec![1.0, 2.0, 1.0, 2.0, 3.0, 4.0, 3.0, 4.0]);
        let q = FpVqLayer::quantize(&w, 2, 2, 10, 0);
        assert!(q.error(&w) < 1e-9, "err {}", q.error(&w));
    }

    #[test]
    fn error_decreases_with_more_centroids_property() {
        check(
            "fpvq monotone in c",
            10,
            |r| Matrix::randn(8, 32, r),
            |w| {
                let e4 = FpVqLayer::quantize(w, 4, 4, 8, 1).error(w);
                let e32 = FpVqLayer::quantize(w, 4, 32, 8, 1).error(w);
                if e32 <= e4 + 1e-4 {
                    Ok(())
                } else {
                    Err(format!("c=32 err {e32} > c=4 err {e4}"))
                }
            },
        );
    }

    #[test]
    fn padding_roundtrip() {
        let w = Matrix::from_vec(1, 5, vec![1.0, 2.0, 3.0, 4.0, 5.0]); // 5 % 2 != 0
        let q = FpVqLayer::quantize(&w, 2, 3, 5, 2);
        let rec = q.reconstruct();
        assert_eq!(rec.rows, 1);
        assert_eq!(rec.cols, 5);
    }

    #[test]
    fn bits_accounting_2bit_config() {
        // v=4, c=256 => 8/4 = 2 index bits per weight.
        let mut r = crate::util::rng::Rng::new(5);
        let w = Matrix::randn(64, 64, &mut r);
        let q = FpVqLayer::quantize(&w, 4, 256, 2, 3);
        assert!((q.index_bits_per_weight() - 2.0).abs() < 1e-9);
        assert!(q.bits_per_weight() > q.index_bits_per_weight()); // + codebook
    }

    #[test]
    fn centroid_cap_by_data_size() {
        let w = Matrix::from_vec(1, 8, vec![0.0; 8]);
        let q = FpVqLayer::quantize(&w, 4, 100, 2, 4);
        assert!(q.c <= 2);
    }
}
