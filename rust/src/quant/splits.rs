//! Column grouping via split points (paper §5.3 Table 3e, following
//! BiLLM / ARB-LLM / STBLLM's non-salient weight partitioning).
//!
//! We use the *structured* (column-wise) variant: columns are ranked by
//! an activation-aware importance score and partitioned into
//! `n_splits + 1` groups by percentile thresholds. Group membership is
//! then `ceil(log2 G)` bits per **column** — amortized to ~0 bits per
//! weight — unlike element-wise bell-curve splits whose masks would blow
//! the sub-1-bit budget (the paper's own critique of mask overhead).

use crate::tensor::Matrix;

/// Activation-aware column importance: `E[x_c^2] * ||W_{.,c}||_2^2`
/// (diagonal-Hessian proxy, as in BiLLM/GPTQ). `act_sq` may be empty
/// (uniform activations).
pub fn column_importance(w: &Matrix, act_sq: &[f32]) -> Vec<f64> {
    let mut imp = vec![0f64; w.cols];
    for r in 0..w.rows {
        for (c, &v) in w.row(r).iter().enumerate() {
            imp[c] += (v as f64) * (v as f64);
        }
    }
    if !act_sq.is_empty() {
        assert_eq!(act_sq.len(), w.cols);
        for (c, i) in imp.iter_mut().enumerate() {
            *i *= act_sq[c] as f64;
        }
    }
    imp
}

/// Partition columns into `n_splits + 1` groups by importance
/// percentiles. Returns (col_group, n_groups); group 0 = least
/// important. With `n_splits = 0` everything lands in group 0.
pub fn split_columns(importance: &[f64], n_splits: usize) -> (Vec<u16>, usize) {
    let n_groups = n_splits + 1;
    if n_splits == 0 {
        return (vec![0u16; importance.len()], 1);
    }
    let mut order: Vec<usize> = (0..importance.len()).collect();
    order.sort_by(|&a, &b| importance[a].partial_cmp(&importance[b]).unwrap());
    let mut groups = vec![0u16; importance.len()];
    // Unequal buckets: most columns in the low groups, few in the top
    // (mirrors the bell-curve concentration the paper exploits) —
    // boundaries at 70% / 90% / 97%.
    let bounds: Vec<f64> = match n_splits {
        1 => vec![0.9],
        2 => vec![0.7, 0.9],
        _ => vec![0.7, 0.9, 0.97],
    };
    let n = importance.len();
    for (rank, &col) in order.iter().enumerate() {
        let frac = rank as f64 / n as f64;
        let mut g = 0u16;
        for (bi, &b) in bounds.iter().enumerate() {
            if frac >= b {
                g = (bi + 1) as u16;
            }
        }
        groups[col] = g;
    }
    (groups, n_groups.min(bounds.len() + 1))
}

/// Top-`frac` most important columns (salient set for BiLLM residual
/// binarization). Returns a sorted column index list.
pub fn salient_columns(importance: &[f64], frac: f64) -> Vec<usize> {
    let k = ((importance.len() as f64 * frac).round() as usize).clamp(1, importance.len());
    let mut order: Vec<usize> = (0..importance.len()).collect();
    order.sort_by(|&a, &b| importance[b].partial_cmp(&importance[a]).unwrap());
    let mut top: Vec<usize> = order[..k].to_vec();
    top.sort();
    top
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn importance_prefers_heavy_columns() {
        let w = Matrix::from_fn(4, 8, |_, c| if c == 3 { 10.0 } else { 0.1 });
        let imp = column_importance(&w, &[]);
        let max_c = imp
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_c, 3);
    }

    #[test]
    fn activation_weighting() {
        let w = Matrix::filled(2, 3, 1.0);
        let imp = column_importance(&w, &[1.0, 4.0, 0.25]);
        assert!(imp[1] > imp[0] && imp[0] > imp[2]);
    }

    #[test]
    fn split_zero_is_single_group() {
        let (g, n) = split_columns(&[1.0, 2.0, 3.0], 0);
        assert_eq!(n, 1);
        assert!(g.iter().all(|&x| x == 0));
    }

    #[test]
    fn split_counts_and_ordering() {
        let imp: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let (g, n) = split_columns(&imp, 2);
        assert_eq!(n, 3);
        // Least-important columns are group 0, most important group 2.
        assert_eq!(g[0], 0);
        assert_eq!(g[99], 2);
        let count2 = g.iter().filter(|&&x| x == 2).count();
        assert_eq!(count2, 10); // top 10%
        let count0 = g.iter().filter(|&&x| x == 0).count();
        assert_eq!(count0, 70);
    }

    #[test]
    fn groups_monotone_in_importance() {
        let mut rng = Rng::new(3);
        let imp: Vec<f64> = (0..50).map(|_| rng.uniform()).collect();
        let (g, _) = split_columns(&imp, 2);
        for a in 0..50 {
            for b in 0..50 {
                if imp[a] < imp[b] {
                    assert!(g[a] <= g[b], "importance order violated");
                }
            }
        }
    }

    #[test]
    fn salient_selection() {
        let imp = vec![0.0, 5.0, 1.0, 9.0];
        assert_eq!(salient_columns(&imp, 0.5), vec![1, 3]);
        assert_eq!(salient_columns(&imp, 0.01), vec![3]); // clamped to >= 1
    }
}
