//! KV memory substrate for serving (DESIGN.md §8).
//!
//! Two cache shapes live here:
//!
//! - [`KvCache`] — the flat per-sequence cache (contiguous f32 rows
//!   per layer). It remains the reference implementation: simple,
//!   allocation-per-request, used by the single-request eval paths and
//!   as the bit-identity oracle for the paged path.
//! - [`KvPool`] + [`PagedKvCache`] — the serving substrate. A
//!   server-owned pool hands out fixed-size **blocks** (`block_size`
//!   positions × `kv_dim` channels × `n_layer` layers, K and V) from a
//!   bounded budget; each request holds a *block table* instead of
//!   contiguous rows, so allocation is incremental as sequences grow
//!   and admission can be memory-aware instead of reserving worst
//!   case.
//!
//! **Prefix sharing.** Full blocks of *prompt* K/V are content-
//!   addressed by `(parent_block, token_chunk)` in the pool's prefix
//!   map: a request whose prompt begins with an already-resident chunk
//!   chain attaches those blocks (refcount bump) instead of
//!   recomputing them. K/V for a token prefix is deterministic
//!   (positions are absolute), so shared blocks are bit-identical to
//!   what the attaching request would have computed. Writes never
//!   touch a shared block: appends only land in the tail, and
//!   [`KvPool::ensure_append`] copy-on-write-splits a shared tail
//!   first (the [`KvPool::fork`] path).
//!
//! **Quantized cold blocks.** With
//!   [`KvQuantConfig::enabled`](crate::quant::kvquant::KvQuantConfig)
//!   set, full blocks that have fallen entirely behind the owner's
//!   recency `local_window` are re-encoded in place as
//!   [`QuantizedRows`](crate::quant::kvquant::QuantizedRows) (packed
//!   int2..8 + f16 per-row scales — the paper's App. F rule, now a
//!   real storage format); hot blocks stay f32. Only sole-owner
//!   (refcount 1) blocks are quantized, so sharing never changes
//!   another request's hot window. Attention gathers block-wise
//!   ([`KvPool::gather`]), borrowing f32 blocks in place and
//!   dequantizing cold blocks into a reusable scratch — with
//!   quantization off the gathered bytes are exactly the flat cache's.

use std::collections::HashMap;

use crate::quant::kvquant::{KvQuantConfig, QuantizedRows};

/// Growable key/value cache for one layer: rows are positions, columns
/// are `kv_dim` channels.
#[derive(Debug, Clone)]
pub struct LayerKv {
    pub kv_dim: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
}

impl LayerKv {
    pub fn new(kv_dim: usize, capacity: usize) -> LayerKv {
        LayerKv {
            kv_dim,
            k: Vec::with_capacity(capacity * kv_dim),
            v: Vec::with_capacity(capacity * kv_dim),
            len: 0,
        }
    }

    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.kv_dim);
        debug_assert_eq!(v_row.len(), self.kv_dim);
        self.k.extend_from_slice(k_row);
        self.v.extend_from_slice(v_row);
        self.len += 1;
    }

    #[inline]
    pub fn k_at(&self, pos: usize) -> &[f32] {
        &self.k[pos * self.kv_dim..(pos + 1) * self.kv_dim]
    }

    #[inline]
    pub fn v_at(&self, pos: usize) -> &[f32] {
        &self.v[pos * self.kv_dim..(pos + 1) * self.kv_dim]
    }
}

/// Full-model flat cache: one [`LayerKv`] per layer.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub layers: Vec<LayerKv>,
}

impl KvCache {
    pub fn new(n_layer: usize, kv_dim: usize, capacity: usize) -> KvCache {
        KvCache { layers: (0..n_layer).map(|_| LayerKv::new(kv_dim, capacity)).collect() }
    }

    /// Number of cached positions (same across layers).
    pub fn len(&self) -> usize {
        self.layers.first().map(|l| l.len).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Memory footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| (l.k.len() + l.v.len()) * 4).sum()
    }
}

// ---------------------------------------------------------------------------
// Paged pool
// ---------------------------------------------------------------------------

/// Sentinel parent id for the first block of a prompt chain.
const ROOT_PARENT: usize = usize::MAX;

/// Pool shape knobs (resolved by the scheduler/server from
/// `ServeConfig`).
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    /// Positions per block.
    pub block_size: usize,
    /// Total block budget; 0 = auto (sized by the owner for its
    /// worst case, so default configs behave exactly like the old
    /// flat reservation).
    pub budget_blocks: usize,
    /// Cold-block quantization (off by default: pure f32).
    pub quant: KvQuantConfig,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig { block_size: 32, budget_blocks: 0, quant: KvQuantConfig::off() }
    }
}

/// One block's payload. Rows are `(layer, offset)` pairs laid out
/// layer-major (`row = layer * block_size + offset`), so one layer's
/// in-block rows are contiguous and gather per layer is a single
/// slice.
#[derive(Debug, Clone)]
enum BlockData {
    /// Hot: plain f32 rows (`n_layer * block_size * kv_dim` each).
    F32 { k: Vec<f32>, v: Vec<f32> },
    /// Cold: packed int rows + f16 scales (`quant/kvquant.rs`).
    Quant { k: QuantizedRows, v: QuantizedRows },
    /// On the free list (payload dropped).
    Free,
}

#[derive(Debug, Clone)]
struct Block {
    refs: u32,
    data: BlockData,
    /// Reverse link into the prefix map (removed when freed).
    prefix_key: Option<(usize, Vec<u16>)>,
}

/// A contiguous run of gathered K/V rows handed to attention: `n`
/// rows of `kv_dim` f32 channels each.
#[derive(Debug, Clone, Copy)]
pub struct KvChunk<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub n: usize,
}

/// Reusable buffers for [`KvPool::gather`]: cold blocks dequantize in
/// here; one scratch serves a whole forward.
#[derive(Debug, Default)]
pub struct GatherScratch {
    k: Vec<f32>,
    v: Vec<f32>,
    codes: Vec<u32>,
}

impl GatherScratch {
    pub fn new() -> GatherScratch {
        GatherScratch::default()
    }
}

/// Aggregate pool accounting (scanned on demand; the serving loop
/// publishes it into `Metrics` each round).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvPoolStats {
    pub budget_blocks: usize,
    pub blocks_in_use: usize,
    pub peak_blocks: usize,
    pub f32_blocks: usize,
    pub quant_blocks: usize,
    /// Measured bytes all in-use block payloads hold resident.
    pub resident_bytes: usize,
    pub f32_bytes: usize,
    pub quant_bytes: usize,
    pub block_size: usize,
    /// `budget_blocks * block_size`.
    pub position_capacity: usize,
    /// Prompt positions ever served from the prefix map instead of
    /// being recomputed.
    pub shared_positions: u64,
}

/// Server-owned block pool: the single allocator behind every
/// in-flight request's K/V. See the module doc for the contracts.
#[derive(Debug)]
pub struct KvPool {
    n_layer: usize,
    kv_dim: usize,
    block_size: usize,
    budget: usize,
    quant: KvQuantConfig,
    blocks: Vec<Block>,
    free: Vec<usize>,
    in_use: usize,
    peak_in_use: usize,
    /// `(parent_block, prompt_token_chunk)` → full prompt block.
    prefix: HashMap<(usize, Vec<u16>), usize>,
    shared_positions: u64,
}

impl KvPool {
    /// A pool of `budget_blocks` blocks of `block_size` positions.
    /// Blocks are allocated lazily, so a generous budget costs nothing
    /// until used.
    pub fn new(
        n_layer: usize,
        kv_dim: usize,
        block_size: usize,
        budget_blocks: usize,
        quant: KvQuantConfig,
    ) -> KvPool {
        assert!(block_size >= 1, "block_size must be >= 1");
        assert!(budget_blocks >= 1, "pool budget must be >= 1 block");
        KvPool {
            n_layer,
            kv_dim,
            block_size,
            budget: budget_blocks,
            // Normalize unrepresentable bit widths (9..=15) here so a
            // mis-set config degrades to int8 instead of panicking the
            // serving worker at the first cold block.
            quant: quant.sanitized(),
            blocks: Vec::new(),
            free: Vec::new(),
            in_use: 0,
            peak_in_use: 0,
            prefix: HashMap::new(),
            shared_positions: 0,
        }
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn budget_blocks(&self) -> usize {
        self.budget
    }

    pub fn blocks_in_use(&self) -> usize {
        self.in_use
    }

    pub fn peak_blocks(&self) -> usize {
        self.peak_in_use
    }

    pub fn free_blocks(&self) -> usize {
        self.budget - self.in_use
    }

    /// Max positions the whole pool can ever hold.
    pub fn position_capacity(&self) -> usize {
        self.budget * self.block_size
    }

    /// Bytes one fully-f32 block holds resident (K + V, all layers) —
    /// the baseline quantized blocks are compared against.
    pub fn f32_block_bytes(&self) -> usize {
        2 * self.n_layer * self.block_size * self.kv_dim * 4
    }

    /// Blocks needed to hold `positions`.
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.block_size)
    }

    /// Would a *fresh* sequence of `positions` fit right now? (The
    /// admission check: conservative — prefix sharing can only reduce
    /// the real need.)
    ///
    /// Fault point `kvpool.alloc`: an injected `err` reports the pool
    /// as full here — the capacity *query* — so the scheduler takes
    /// its real deferral path. (The committed reservation in
    /// `ensure_append` is deliberately not instrumented: callers have
    /// already been promised the blocks by this gate.)
    pub fn can_fit_new(&self, positions: usize) -> bool {
        crate::fault_point!("kvpool.alloc", return false);
        self.blocks_for(positions) <= self.free_blocks()
    }

    /// An empty cache bound to this pool's geometry.
    pub fn new_cache(&self) -> PagedKvCache {
        PagedKvCache { block_size: self.block_size, len: 0, block_table: Vec::new() }
    }

    fn alloc_block(&mut self) -> Option<usize> {
        let payload = self.n_layer * self.block_size * self.kv_dim;
        let id = if let Some(id) = self.free.pop() {
            self.blocks[id].refs = 1;
            self.blocks[id].data =
                BlockData::F32 { k: vec![0.0; payload], v: vec![0.0; payload] };
            id
        } else if self.blocks.len() < self.budget {
            self.blocks.push(Block {
                refs: 1,
                data: BlockData::F32 { k: vec![0.0; payload], v: vec![0.0; payload] },
                prefix_key: None,
            });
            self.blocks.len() - 1
        } else {
            return None;
        };
        self.in_use += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        Some(id)
    }

    fn dec_ref(&mut self, id: usize) {
        let b = &mut self.blocks[id];
        debug_assert!(b.refs > 0, "double free of block {id}");
        b.refs -= 1;
        if b.refs == 0 {
            if let Some(key) = b.prefix_key.take() {
                if self.prefix.get(&key).copied() == Some(id) {
                    self.prefix.remove(&key);
                }
            }
            self.blocks[id].data = BlockData::Free;
            self.free.push(id);
            self.in_use -= 1;
        }
    }

    /// Refcount of one block (tests / diagnostics).
    pub fn block_refs(&self, id: usize) -> u32 {
        self.blocks[id].refs
    }

    /// How many positions `cache` could append right now without
    /// exceeding the budget (accounts for the copy-on-write block a
    /// shared partial tail would need first).
    ///
    /// Fault point `kvpool.alloc`: an injected `err` reports zero
    /// headroom, forcing the scheduler's round-deferral/preemption
    /// path (see [`KvPool::can_fit_new`]).
    pub fn max_append(&self, cache: &PagedKvCache) -> usize {
        crate::fault_point!("kvpool.alloc", return 0);
        let bs = self.block_size;
        let cap_rem = cache.block_table.len() * bs - cache.len;
        let cow = usize::from(
            cache.len % bs != 0
                && self.blocks[*cache.block_table.last().expect("partial tail implies a block")]
                    .refs
                    > 1,
        );
        let free = self.free_blocks();
        if cow > free {
            return 0; // cannot even make the tail writable
        }
        cap_rem + (free - cow) * bs
    }

    /// Grow `cache` so `extra` more positions can be appended:
    /// copy-on-write-split a shared partial tail, then allocate the
    /// missing blocks. Returns `false` (having changed nothing) when
    /// the budget cannot cover it — callers defer or preempt, they
    /// never panic.
    pub fn ensure_append(&mut self, cache: &mut PagedKvCache, extra: usize) -> bool {
        let bs = self.block_size;
        let need_blocks =
            (cache.len + extra).div_ceil(bs).saturating_sub(cache.block_table.len());
        let cow = usize::from(
            extra > 0
                && cache.len % bs != 0
                && self.blocks[*cache.block_table.last().expect("partial tail implies a block")]
                    .refs
                    > 1,
        );
        if need_blocks + cow > self.free_blocks() {
            return false;
        }
        if cow == 1 {
            let old = *cache.block_table.last().unwrap();
            let new = self.alloc_block().expect("free blocks checked above");
            let (ck, cv) = match &self.blocks[old].data {
                BlockData::F32 { k, v } => (k.clone(), v.clone()),
                // A partially-filled tail is still being written, and
                // writable tails are f32 by construction (only full
                // sole-owner blocks quantize).
                _ => unreachable!("shared partial tail must be f32"),
            };
            match &mut self.blocks[new].data {
                BlockData::F32 { k, v } => {
                    k.copy_from_slice(&ck);
                    v.copy_from_slice(&cv);
                }
                _ => unreachable!("fresh blocks are f32"),
            }
            *cache.block_table.last_mut().unwrap() = new;
            self.dec_ref(old);
        }
        for _ in 0..need_blocks {
            let id = self.alloc_block().expect("free blocks checked above");
            cache.block_table.push(id);
        }
        true
    }

    /// Write one position's K/V row for one layer. Capacity must have
    /// been ensured; `pos` is the absolute position (the caller
    /// advances `cache.len` once all layers of a position are in).
    pub fn append_row(
        &mut self,
        cache: &PagedKvCache,
        li: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert_eq!(k_row.len(), self.kv_dim);
        debug_assert_eq!(v_row.len(), self.kv_dim);
        let bs = self.block_size;
        let id = cache.block_table[pos / bs];
        let row = li * bs + pos % bs;
        let kvd = self.kv_dim;
        match &mut self.blocks[id].data {
            BlockData::F32 { k, v } => {
                k[row * kvd..(row + 1) * kvd].copy_from_slice(k_row);
                v[row * kvd..(row + 1) * kvd].copy_from_slice(v_row);
            }
            _ => panic!("append into a non-f32 block (quantized or freed)"),
        }
    }

    /// Block-wise read view of the first `ctx` positions of `cache`
    /// for layer `li`: f32 blocks are borrowed in place, quantized
    /// blocks dequantize into `scratch`. Chunks come back in position
    /// order, so attention over them is bit-identical to the flat
    /// cache whenever every block is f32.
    pub fn gather<'a>(
        &'a self,
        cache: &PagedKvCache,
        li: usize,
        ctx: usize,
        scratch: &'a mut GatherScratch,
    ) -> Vec<KvChunk<'a>> {
        let bs = self.block_size;
        let kvd = self.kv_dim;
        debug_assert!(ctx <= cache.block_table.len() * bs, "gather beyond capacity");
        let nblocks = ctx.div_ceil(bs);
        scratch.k.clear();
        scratch.v.clear();
        scratch.codes.resize(kvd, 0);
        // Phase 1: dequantize cold blocks into the scratch arena.
        let mut cold_starts = Vec::new();
        for bi in 0..nblocks {
            let id = cache.block_table[bi];
            if let BlockData::Quant { k, v } = &self.blocks[id].data {
                let n = (ctx - bi * bs).min(bs);
                cold_starts.push(scratch.k.len());
                for off in 0..n {
                    let row = li * bs + off;
                    let base = scratch.k.len();
                    scratch.k.resize(base + kvd, 0.0);
                    k.dequantize_into(row, &mut scratch.codes, &mut scratch.k[base..]);
                    let vbase = scratch.v.len();
                    scratch.v.resize(vbase + kvd, 0.0);
                    v.dequantize_into(row, &mut scratch.codes, &mut scratch.v[vbase..]);
                }
            }
        }
        // Phase 2: assemble position-ordered chunks (scratch is
        // read-only from here on).
        let scratch: &'a GatherScratch = scratch;
        let mut chunks = Vec::with_capacity(nblocks);
        let mut cold = 0;
        for bi in 0..nblocks {
            let id = cache.block_table[bi];
            let n = (ctx - bi * bs).min(bs);
            match &self.blocks[id].data {
                BlockData::F32 { k, v } => chunks.push(KvChunk {
                    k: &k[li * bs * kvd..(li * bs + n) * kvd],
                    v: &v[li * bs * kvd..(li * bs + n) * kvd],
                    n,
                }),
                BlockData::Quant { .. } => {
                    let s = cold_starts[cold];
                    cold += 1;
                    chunks.push(KvChunk {
                        k: &scratch.k[s..s + n * kvd],
                        v: &scratch.v[s..s + n * kvd],
                        n,
                    });
                }
                BlockData::Free => unreachable!("gather over a freed block"),
            }
        }
        chunks
    }

    /// Materialize the full gathered context of one layer (tests and
    /// slow tooling).
    pub fn materialize(&self, cache: &PagedKvCache, li: usize) -> (Vec<f32>, Vec<f32>) {
        let mut scratch = GatherScratch::new();
        let chunks = self.gather(cache, li, cache.len, &mut scratch);
        let mut k = Vec::new();
        let mut v = Vec::new();
        for ch in &chunks {
            k.extend_from_slice(ch.k);
            v.extend_from_slice(ch.v);
        }
        (k, v)
    }

    /// Attach as many shared full prompt blocks as the prefix map
    /// holds for `prompt`, starting from an **empty** cache. Returns
    /// the number of positions now resident (a multiple of
    /// `block_size`), always leaving at least the final prompt token
    /// to recompute — its logits seed the first sampled token.
    ///
    /// Hot-window invariant: a block another request already
    /// quantized is attached only if it lies entirely *behind* this
    /// prompt's `local_window` — the attacher's hot positions are
    /// never served from dequantized int rows (they get recomputed in
    /// f32 instead).
    pub fn attach_prefix(&mut self, cache: &mut PagedKvCache, prompt: &[u16]) -> usize {
        assert!(cache.block_table.is_empty() && cache.len == 0, "attach into a used cache");
        if prompt.len() < 2 {
            return 0;
        }
        let bs = self.block_size;
        let max_blocks = (prompt.len() - 1) / bs;
        let hot_from = prompt.len().saturating_sub(self.quant.local_window);
        let mut parent = ROOT_PARENT;
        let mut shared = 0usize;
        for j in 0..max_blocks {
            let key = (parent, prompt[j * bs..(j + 1) * bs].to_vec());
            match self.prefix.get(&key).copied() {
                Some(id) => {
                    let quantized = matches!(self.blocks[id].data, BlockData::Quant { .. });
                    if quantized && (j + 1) * bs > hot_from {
                        break; // would sit inside the attacher's hot window
                    }
                    self.blocks[id].refs += 1;
                    cache.block_table.push(id);
                    parent = id;
                    shared += bs;
                }
                None => break,
            }
        }
        cache.len = shared;
        self.shared_positions += shared as u64;
        shared
    }

    /// Register every fully-computed, fully-prompt-covered block of
    /// `cache` in the prefix map (idempotent; first writer of a chunk
    /// chain wins). Called by the scheduler after prefill chunks.
    pub fn register_prompt_blocks(&mut self, cache: &PagedKvCache, prompt: &[u16]) {
        let bs = self.block_size;
        let full = cache.len.min(prompt.len()) / bs;
        let mut parent = ROOT_PARENT;
        for j in 0..full {
            let id = cache.block_table[j];
            if self.blocks[id].prefix_key.is_none() {
                let key = (parent, prompt[j * bs..(j + 1) * bs].to_vec());
                if !self.prefix.contains_key(&key) {
                    self.prefix.insert(key.clone(), id);
                    self.blocks[id].prefix_key = Some(key);
                }
            }
            parent = id;
        }
    }

    /// Clone `cache`'s block table, bumping every refcount — the
    /// copy-on-write fork primitive (divergent appends split via
    /// [`Self::ensure_append`]).
    pub fn fork(&mut self, cache: &PagedKvCache) -> PagedKvCache {
        for &id in &cache.block_table {
            self.blocks[id].refs += 1;
        }
        PagedKvCache {
            block_size: cache.block_size,
            len: cache.len,
            block_table: cache.block_table.clone(),
        }
    }

    /// Roll `cache` back to `len` positions, returning every whole
    /// tail block past the new length to the pool (shared tails just
    /// drop this holder's refcount — the rollback mirror of
    /// [`Self::fork`]). Rows still resident inside a kept partial
    /// tail are harmless stale data: [`Self::append_row`] writes by
    /// absolute position, and a shared kept tail copy-on-write-splits
    /// in [`Self::ensure_append`] before any re-append touches it.
    /// Also shrinks a table grown past `len` by a speculative
    /// [`Self::ensure_append`] whose positions were never committed.
    pub fn truncate(&mut self, cache: &mut PagedKvCache, len: usize) {
        assert!(len <= cache.len, "truncate can only shrink ({} -> {len})", cache.len);
        let keep = len.div_ceil(self.block_size);
        while cache.block_table.len() > keep {
            let id = cache.block_table.pop().expect("keep <= table len");
            self.dec_ref(id);
        }
        cache.len = len;
    }

    /// Return every block of `cache` to the pool (freed once the last
    /// sharer releases). The cache is empty afterwards.
    pub fn release(&mut self, cache: &mut PagedKvCache) {
        let table = std::mem::take(&mut cache.block_table);
        for id in table {
            self.dec_ref(id);
        }
        cache.len = 0;
    }

    /// Re-encode `cache`'s cold blocks (full blocks entirely behind
    /// `len - local_window`) as packed ints. Only sole-owner blocks
    /// are touched: a block still shared with another request may sit
    /// inside *that* request's hot window. No-op when quantization is
    /// off.
    pub fn quantize_cold(&mut self, cache: &PagedKvCache) {
        if !self.quant.enabled() {
            return;
        }
        let bs = self.block_size;
        let rows = self.n_layer * bs;
        let kvd = self.kv_dim;
        let bits = self.quant.bits;
        let cold_blocks = cache.len.saturating_sub(self.quant.local_window) / bs;
        for j in 0..cold_blocks {
            let id = cache.block_table[j];
            let b = &mut self.blocks[id];
            if b.refs != 1 {
                continue;
            }
            let requantized = match &b.data {
                BlockData::F32 { k, v } => Some((
                    QuantizedRows::quantize(k, rows, kvd, bits),
                    QuantizedRows::quantize(v, rows, kvd, bits),
                )),
                _ => None,
            };
            if let Some((qk, qv)) = requantized {
                b.data = BlockData::Quant { k: qk, v: qv };
            }
        }
    }

    /// Scan the pool's in-use blocks into an accounting snapshot.
    pub fn stats(&self) -> KvPoolStats {
        let mut s = KvPoolStats {
            budget_blocks: self.budget,
            blocks_in_use: self.in_use,
            peak_blocks: self.peak_in_use,
            block_size: self.block_size,
            position_capacity: self.position_capacity(),
            shared_positions: self.shared_positions,
            ..KvPoolStats::default()
        };
        for b in &self.blocks {
            match &b.data {
                BlockData::F32 { k, v } => {
                    s.f32_blocks += 1;
                    s.f32_bytes += (k.len() + v.len()) * 4;
                }
                BlockData::Quant { k, v } => {
                    s.quant_blocks += 1;
                    s.quant_bytes += k.resident_bytes() + v.resident_bytes();
                }
                BlockData::Free => {}
            }
        }
        s.resident_bytes = s.f32_bytes + s.quant_bytes;
        s
    }
}

/// One request's cache: a block table into a [`KvPool`] plus the
/// position count. All storage lives in the pool; this struct is a
/// handle (cheap to move between scheduler slots).
#[derive(Debug, Default)]
pub struct PagedKvCache {
    block_size: usize,
    len: usize,
    block_table: Vec<usize>,
}

impl PagedKvCache {
    /// Cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions the current block table can hold.
    pub fn capacity(&self) -> usize {
        self.block_table.len() * self.block_size
    }

    /// Blocks currently held (shared blocks count once per holder).
    pub fn blocks(&self) -> usize {
        self.block_table.len()
    }

    /// The physical block ids (tests / diagnostics).
    pub fn table(&self) -> &[usize] {
        &self.block_table
    }

    /// Commit `n` appended positions (every layer's rows must already
    /// be in via [`KvPool::append_row`]).
    pub fn advance(&mut self, n: usize) {
        debug_assert!(self.len + n <= self.capacity(), "advance past ensured capacity");
        self.len += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn push_and_read() {
        let mut kv = LayerKv::new(4, 8);
        kv.push(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        kv.push(&[9.0; 4], &[0.0; 4]);
        assert_eq!(kv.len, 2);
        assert_eq!(kv.k_at(0), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(kv.v_at(1), &[0.0; 4]);
    }

    #[test]
    fn model_cache_accounting() {
        let mut c = KvCache::new(3, 4, 16);
        assert!(c.is_empty());
        for l in c.layers.iter_mut() {
            l.push(&[0.0; 4], &[0.0; 4]);
        }
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 3 * 2 * 4 * 4);
    }

    // -- pool ---------------------------------------------------------------

    fn pool(budget: usize, quant: KvQuantConfig) -> KvPool {
        KvPool::new(2, 4, 4, budget, quant) // 2 layers, kv_dim 4, block 4
    }

    /// A pool at a word-aligned width (32 channels * 4 bits = 2 whole
    /// u64 words/row) where quantized-block sizes are meaningful.
    fn wide_pool(budget: usize, quant: KvQuantConfig) -> KvPool {
        KvPool::new(2, 32, 4, budget, quant)
    }

    /// Append `n` deterministic positions (all layers) to `cache`.
    fn fill(pool: &mut KvPool, cache: &mut PagedKvCache, n: usize, seed: u64) {
        let kvd = pool.kv_dim();
        let mut rng = Rng::new(seed.wrapping_add(cache.len() as u64));
        assert!(pool.ensure_append(cache, n), "test pool too small");
        for _ in 0..n {
            let pos = cache.len();
            for li in 0..2 {
                let k = rng.normal_vec(kvd);
                let v = rng.normal_vec(kvd);
                pool.append_row(cache, li, pos, &k, &v);
            }
            cache.advance(1);
        }
    }

    #[test]
    fn incremental_alloc_and_release() {
        let mut p = pool(4, KvQuantConfig::off());
        let mut c = p.new_cache();
        assert_eq!(p.free_blocks(), 4);
        fill(&mut p, &mut c, 1, 1);
        assert_eq!((c.len(), c.blocks(), p.blocks_in_use()), (1, 1, 1));
        fill(&mut p, &mut c, 6, 1); // 7 positions -> 2 blocks
        assert_eq!((c.len(), c.blocks(), p.blocks_in_use()), (7, 2, 2));
        assert_eq!(p.peak_blocks(), 2);
        p.release(&mut c);
        assert_eq!((c.len(), c.blocks(), p.blocks_in_use()), (0, 0, 0));
        assert_eq!(p.free_blocks(), 4);
        // Freed blocks are recycled.
        let mut c2 = p.new_cache();
        fill(&mut p, &mut c2, 16, 2);
        assert_eq!(p.blocks_in_use(), 4);
        assert!(!p.ensure_append(&mut c2, 1), "budget exhausted defers, no panic");
        assert_eq!(p.max_append(&c2), 0);
        p.release(&mut c2);
    }

    #[test]
    fn gather_roundtrips_f32_rows_bitwise() {
        let mut p = pool(8, KvQuantConfig::off());
        let mut c = p.new_cache();
        // Mirror into a flat reference.
        let mut flat = KvCache::new(2, 4, 16);
        let mut rng = Rng::new(3);
        assert!(p.ensure_append(&mut c, 11));
        for pos in 0..11 {
            for li in 0..2 {
                let k = rng.normal_vec(4);
                let v = rng.normal_vec(4);
                p.append_row(&c, li, pos, &k, &v);
                flat.layers[li].push(&k, &v);
            }
            c.advance(1);
        }
        for li in 0..2 {
            let (k, v) = p.materialize(&c, li);
            assert_eq!(k, flat.layers[li].k, "layer {li} K differs");
            assert_eq!(v, flat.layers[li].v, "layer {li} V differs");
            // Partial-context gather too (chunk boundaries inside).
            let mut scratch = GatherScratch::new();
            let chunks = p.gather(&c, li, 6, &mut scratch);
            let total: usize = chunks.iter().map(|ch| ch.n).sum();
            assert_eq!(total, 6);
            let gathered: Vec<f32> =
                chunks.iter().flat_map(|ch| ch.k.iter().copied()).collect();
            assert_eq!(&gathered[..], &flat.layers[li].k[..6 * 4]);
        }
        p.release(&mut c);
    }

    #[test]
    fn prefix_sharing_refcounts_blocks() {
        let mut p = pool(8, KvQuantConfig::off());
        let prompt: Vec<u16> = (0..9).map(|i| i as u16 + 10).collect();
        let mut a = p.new_cache();
        fill(&mut p, &mut a, 9, 7);
        p.register_prompt_blocks(&a, &prompt);
        // A second identical prompt shares the full blocks: (9-1)/4
        // = 2 blocks = 8 positions; the last position recomputes.
        let mut b = p.new_cache();
        let shared = p.attach_prefix(&mut b, &prompt);
        assert_eq!(shared, 8);
        assert_eq!(b.len(), 8);
        assert_eq!(&b.table()[..2], &a.table()[..2]);
        assert_eq!(p.block_refs(a.table()[0]), 2);
        // Shared payload is byte-identical, not a copy.
        assert_eq!(p.materialize(&b, 0).0, p.materialize(&a, 0).0[..8 * 4]);
        // A divergent prompt shares only the common chunk chain.
        let mut divergent = prompt.clone();
        divergent[5] = 99;
        let mut d = p.new_cache();
        assert_eq!(p.attach_prefix(&mut d, &divergent), 4, "first block only");
        // Release A: shared blocks survive under B/D, the rest free.
        let a0 = a.table()[0];
        p.release(&mut a);
        assert_eq!(p.block_refs(a0), 3 - 1, "B and D still hold block 0");
        p.release(&mut b);
        p.release(&mut d);
        assert_eq!(p.blocks_in_use(), 0);
        // Freed blocks left the prefix map: nothing to attach now.
        let mut e = p.new_cache();
        assert_eq!(p.attach_prefix(&mut e, &prompt), 0);
    }

    #[test]
    fn fork_is_copy_on_write_on_divergence() {
        let mut p = pool(8, KvQuantConfig::off());
        let mut a = p.new_cache();
        fill(&mut p, &mut a, 6, 11); // block 0 full, block 1 holds 2 rows
        let mut b = p.fork(&a);
        assert_eq!(b.len(), 6);
        assert_eq!(p.block_refs(a.table()[1]), 2);
        let a_tail_before = p.materialize(&a, 1);
        // Appending to the fork must split the shared partial tail.
        fill(&mut p, &mut b, 1, 99);
        assert_ne!(a.table()[1], b.table()[1], "tail split on first divergent write");
        assert_eq!(a.table()[0], b.table()[0], "full prefix block still shared");
        assert_eq!(p.block_refs(a.table()[1]), 1);
        // A's rows are untouched by B's append...
        assert_eq!(p.materialize(&a, 1), a_tail_before);
        // ...and B kept A's first 6 positions bit-identically.
        let (bk, _) = p.materialize(&b, 1);
        assert_eq!(&bk[..6 * 4], &a_tail_before.0[..]);
        assert_eq!(b.len(), 7);
        p.release(&mut a);
        p.release(&mut b);
        assert_eq!(p.blocks_in_use(), 0);
    }

    #[test]
    fn cold_blocks_quantize_and_shrink() {
        let quant = KvQuantConfig { bits: 4, local_window: 2 };
        let mut p = wide_pool(8, quant);
        let kvd = p.kv_dim();
        let mut c = p.new_cache();
        fill(&mut p, &mut c, 14, 5);
        let before = p.materialize(&c, 0);
        let f32_stats = p.stats();
        p.quantize_cold(&c);
        let s = p.stats();
        // (14 - 2) / 4 = 3 cold full blocks.
        assert_eq!(s.quant_blocks, 3);
        assert_eq!(s.f32_blocks, 1);
        assert!(
            s.resident_bytes < f32_stats.resident_bytes / 2,
            "quantized pool must shrink: {} vs {}",
            s.resident_bytes,
            f32_stats.resident_bytes
        );
        // Hot window bytes (positions 12..14) are untouched.
        let after = p.materialize(&c, 0);
        assert_eq!(&after.0[12 * kvd..], &before.0[12 * kvd..]);
        // Cold rows are within the int4 quantization error bound.
        for (a, b) in after.0[..12 * kvd].iter().zip(&before.0[..12 * kvd]) {
            assert!((a - b).abs() < 0.6, "cold row error too large: {a} vs {b}");
        }
        // Idempotent.
        p.quantize_cold(&c);
        assert_eq!(p.stats().quant_blocks, 3);
        p.release(&mut c);
    }

    #[test]
    fn shared_blocks_are_not_quantized() {
        let quant = KvQuantConfig { bits: 4, local_window: 0 };
        let mut p = wide_pool(8, quant);
        let prompt: Vec<u16> = (0..8).map(|i| i as u16).collect();
        let mut a = p.new_cache();
        fill(&mut p, &mut a, 8, 9);
        p.register_prompt_blocks(&a, &prompt);
        let mut b = p.fork(&a);
        p.quantize_cold(&a);
        assert_eq!(p.stats().quant_blocks, 0, "refcount > 1 blocks stay f32");
        p.release(&mut b);
        p.quantize_cold(&a);
        assert_eq!(p.stats().quant_blocks, 2, "sole-owner cold blocks quantize");
        p.release(&mut a);
    }

    #[test]
    fn attach_skips_quantized_blocks_inside_the_hot_window() {
        let quant = KvQuantConfig { bits: 4, local_window: 6 };
        let mut p = wide_pool(16, quant);
        let prompt: Vec<u16> = (0..12).map(|i| i as u16).collect();
        let mut a = p.new_cache();
        fill(&mut p, &mut a, 12, 21);
        p.register_prompt_blocks(&a, &prompt);
        // A runs ahead; its whole prompt falls cold and quantizes.
        fill(&mut p, &mut a, 8, 22);
        p.quantize_cold(&a);
        assert_eq!(p.stats().quant_blocks, 3);
        // B's hot window is prompt positions 6..12: block 1 (4..8)
        // intersects it and is quantized — sharing must stop before
        // it so B's hot rows are recomputed in f32.
        let mut b = p.new_cache();
        assert_eq!(p.attach_prefix(&mut b, &prompt), 4, "only the cold-for-B block shared");
        p.release(&mut b);
        p.release(&mut a);
    }

    #[test]
    fn truncate_releases_whole_tail_blocks() {
        let mut p = pool(8, KvQuantConfig::off());
        let mut c = p.new_cache();
        fill(&mut p, &mut c, 11, 3); // 3 blocks: 4 + 4 + 3
        assert_eq!((c.len(), c.blocks(), p.blocks_in_use()), (11, 3, 3));
        let kept = p.materialize(&c, 0).0[..6 * 4].to_vec();
        // Truncating inside block 1 drops only block 2; the kept
        // partial tail's surviving rows are untouched.
        p.truncate(&mut c, 6);
        assert_eq!((c.len(), c.blocks(), p.blocks_in_use()), (6, 2, 2));
        assert_eq!(p.materialize(&c, 0).0[..6 * 4], kept[..]);
        // Block-boundary truncation keeps exactly the full blocks.
        p.truncate(&mut c, 4);
        assert_eq!((c.len(), c.blocks(), p.blocks_in_use()), (4, 1, 1));
        // Re-appending after rollback overwrites stale tail rows by
        // absolute position and regrows blocks from the free list.
        fill(&mut p, &mut c, 7, 17);
        assert_eq!((c.len(), c.blocks(), p.blocks_in_use()), (11, 3, 3));
        p.truncate(&mut c, 0);
        assert_eq!((c.len(), c.blocks(), p.blocks_in_use()), (0, 0, 0));
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn truncate_shrinks_an_uncommitted_reservation() {
        // ensure_append may reserve blocks whose positions are never
        // committed (a speculative round that fell back): truncating
        // to the *current* length returns exactly those blocks.
        let mut p = pool(4, KvQuantConfig::off());
        let mut c = p.new_cache();
        fill(&mut p, &mut c, 4, 5);
        assert!(p.ensure_append(&mut c, 8));
        assert_eq!((c.len(), c.blocks(), p.blocks_in_use()), (4, 3, 3));
        p.truncate(&mut c, 4);
        assert_eq!((c.len(), c.blocks(), p.blocks_in_use()), (4, 1, 1));
        p.release(&mut c);
    }

    #[test]
    fn truncate_after_fork_restores_prefork_refcounts() {
        // The speculative rollback cycle: fork -> append -> truncate
        // -> drop must leave refcounts exactly as before the fork and
        // leak zero blocks, across every divergence length.
        let mut p = pool(16, KvQuantConfig::off());
        let mut a = p.new_cache();
        fill(&mut p, &mut a, 6, 11); // block 0 full, block 1 partial
        let base_refs: Vec<u32> = a.table().iter().map(|&id| p.block_refs(id)).collect();
        let base_in_use = p.blocks_in_use();
        for extra in 1..=7usize {
            let mut b = p.fork(&a);
            fill(&mut p, &mut b, extra, 40 + extra as u64);
            // The divergent append COW-split A's partial tail.
            assert_ne!(a.table()[1], b.table()[1]);
            // Roll the fork all the way back, then drop it.
            p.truncate(&mut b, a.len());
            p.release(&mut b);
            let refs_now: Vec<u32> = a.table().iter().map(|&id| p.block_refs(id)).collect();
            assert_eq!(refs_now, base_refs, "refcounts restored after extra={extra}");
            assert_eq!(p.blocks_in_use(), base_in_use, "zero leaked blocks (extra={extra})");
        }
        // Partial rollback keeps the fork consistent: truncate to a
        // mid-point, append again, then drop — still zero leaks.
        let mut b = p.fork(&a);
        fill(&mut p, &mut b, 6, 77);
        p.truncate(&mut b, 8);
        assert_eq!(b.len(), 8);
        fill(&mut p, &mut b, 3, 78);
        assert_eq!(b.len(), 11);
        p.release(&mut b);
        assert_eq!(p.blocks_in_use(), base_in_use);
        p.release(&mut a);
        assert_eq!(p.blocks_in_use(), 0);
    }

    #[test]
    fn truncate_respects_shared_full_blocks() {
        // A truncated holder of a shared prompt block must not free
        // it out from under the other holder.
        let mut p = pool(8, KvQuantConfig::off());
        let prompt: Vec<u16> = (0..9).map(|i| i as u16 + 10).collect();
        let mut a = p.new_cache();
        fill(&mut p, &mut a, 9, 7);
        p.register_prompt_blocks(&a, &prompt);
        let mut b = p.new_cache();
        assert_eq!(p.attach_prefix(&mut b, &prompt), 8);
        let a_before = p.materialize(&a, 0);
        p.truncate(&mut b, 4);
        assert_eq!(p.block_refs(a.table()[0]), 2, "kept shared block still held");
        assert_eq!(p.block_refs(a.table()[1]), 1, "dropped shared block released");
        assert_eq!(p.materialize(&a, 0), a_before, "A untouched by B's rollback");
        p.truncate(&mut b, 0);
        p.release(&mut a);
        assert_eq!(p.blocks_in_use(), 0);
        // The prefix map survived for blocks A still owned at release
        // time only as far as dec_ref removed them: nothing to attach.
        let mut e = p.new_cache();
        assert_eq!(p.attach_prefix(&mut e, &prompt), 0);
    }

    #[test]
    fn quantized_append_capacity_is_checked() {
        // max_append accounts for the COW block a shared tail needs.
        let mut p = pool(2, KvQuantConfig::off());
        let mut a = p.new_cache();
        fill(&mut p, &mut a, 6, 13); // 2 blocks, tail partial
        let b = p.fork(&a);
        // Pool full (2/2 in use): the fork cannot even COW its tail.
        assert_eq!(p.max_append(&a), 0);
        assert!(!p.ensure_append(&mut a, 1));
        let mut b = b;
        p.release(&mut b);
        // Sole owner again: two free rows in the tail, no COW needed.
        assert_eq!(p.max_append(&a), 2);
        p.release(&mut a);
    }
}
