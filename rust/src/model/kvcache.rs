//! Per-sequence KV cache for incremental decoding (the serving path).

/// Growable key/value cache for one layer: rows are positions, columns
/// are `kv_dim` channels.
#[derive(Debug, Clone)]
pub struct LayerKv {
    pub kv_dim: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
}

impl LayerKv {
    pub fn new(kv_dim: usize, capacity: usize) -> LayerKv {
        LayerKv {
            kv_dim,
            k: Vec::with_capacity(capacity * kv_dim),
            v: Vec::with_capacity(capacity * kv_dim),
            len: 0,
        }
    }

    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.kv_dim);
        debug_assert_eq!(v_row.len(), self.kv_dim);
        self.k.extend_from_slice(k_row);
        self.v.extend_from_slice(v_row);
        self.len += 1;
    }

    #[inline]
    pub fn k_at(&self, pos: usize) -> &[f32] {
        &self.k[pos * self.kv_dim..(pos + 1) * self.kv_dim]
    }

    #[inline]
    pub fn v_at(&self, pos: usize) -> &[f32] {
        &self.v[pos * self.kv_dim..(pos + 1) * self.kv_dim]
    }
}

/// Full-model cache: one [`LayerKv`] per layer.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub layers: Vec<LayerKv>,
}

impl KvCache {
    pub fn new(n_layer: usize, kv_dim: usize, capacity: usize) -> KvCache {
        KvCache { layers: (0..n_layer).map(|_| LayerKv::new(kv_dim, capacity)).collect() }
    }

    /// Number of cached positions (same across layers).
    pub fn len(&self) -> usize {
        self.layers.first().map(|l| l.len).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Memory footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| (l.k.len() + l.v.len()) * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read() {
        let mut kv = LayerKv::new(4, 8);
        kv.push(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        kv.push(&[9.0; 4], &[0.0; 4]);
        assert_eq!(kv.len, 2);
        assert_eq!(kv.k_at(0), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(kv.v_at(1), &[0.0; 4]);
    }

    #[test]
    fn model_cache_accounting() {
        let mut c = KvCache::new(3, 4, 16);
        assert!(c.is_empty());
        for l in c.layers.iter_mut() {
            l.push(&[0.0; 4], &[0.0; 4]);
        }
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 3 * 2 * 4 * 4);
    }
}
