//! Transformer inference substrate: RMSNorm + RoPE + causal MHA/GQA +
//! SwiGLU decoder (the Rust twin of `python/compile/model.py`, loaded
//! from the same TLM1 blobs and numerically cross-checked against the
//! AOT-lowered JAX forward in `examples/hlo_parity.rs`).
//!
//! Every linear layer is a [`linear::Linear`] with a pluggable
//! [`backend::WeightBackend`] (dense fp32 / W1A16 sign-GEMM /
//! binary-codebook LUT-GEMM / N:M sparse / fp-VQ / anything registered
//! via [`backend::register_backend`]), an optional learnable input
//! transformation, and an optional activation quantizer — the
//! deployment surface of the whole quantization pipeline.

pub mod backend;
pub mod kvcache;
pub mod linear;
pub mod rope;
pub mod transformer;

pub use backend::{
    backend_reader, backend_tags, register_backend, BackendIoCtx, BackendReader, WeightBackend,
};
pub use kvcache::{KvCache, KvPool, KvPoolStats, PagedKvCache, PoolConfig};
pub use linear::Linear;
pub use transformer::{CaptureSite, Transformer};
